//! Regenerates Table 2: CIFAR-10(-like), α = 0.5, 20% worker
//! participation — the partial-participation stress test where worker-
//! state-free compression matters.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::{run_classification, table2_config};

fn main() {
    let cfg = table2_config(common::paper_scale());
    let report = common::timed("table2 sweep", || run_classification(&cfg));
    println!("{}", report.table());
    common::paper_reference(
        "Table 2 (CIFAR-10, α = 0.5, 20% participation; rounds/bits to 55%/74%)",
        &[
            ("signSGD", "55.35±0.71%   3000/N.A.    1.15e10/N.A."),
            ("Scaled signSGD", "46.86±2.72%   N.A./N.A."),
            ("Noisy signSGD", "74.41±0.61%   625/2600     2.31e9/9.89e9"),
            ("1-bit L2 norm QSGD", "54.58±0.35%   N.A./N.A."),
            ("1-bit Linf norm QSGD", "74.52±0.58%   750/2950     1.64e8/1.05e9"),
            ("TernGrad", "74.92±0.42%   800/2800     9.61e7/5.38e8"),
            ("sparsignSGD (B=1)", "62.34±0.58%   1550/N.A.    1.44e8/N.A."),
            ("EF-sparsignSGD (Bl=10,Bg=1,τ=1)", "78.51±0.51%   300/1025     7.42e7/4.24e8"),
        ],
    );
    // Shape checks that are scale-stable (the fast task saturates around
    // the second target, so "who collapses" is the robust signal — the
    // deterministic-sign non-convergence itself is demonstrated by the
    // adversarial Fig. 1/heterogeneity-sweep workloads):
    // 1. EF-sparsign reaches BOTH targets (the paper's headline row).
    let ef = &report.summaries[7];
    assert!(
        ef.rounds_to_target.iter().all(|r| r.is_some()),
        "EF-sparsign must reach all targets"
    );
    // 2. 1-bit L2 QSGD fails to reach the final target under partial
    //    participation (exactly the paper's N.A./N.A. row: the L2 norm of
    //    a high-dim gradient crushes the keep-probabilities).
    let qsgd_l2 = &report.summaries[3];
    assert!(
        qsgd_l2.rounds_to_target.last().unwrap().is_none(),
        "1-bit L2 QSGD should miss the final target (paper: N.A.)"
    );
    // 3. EF-sparsign lands in the top half by final accuracy.
    let mut accs: Vec<f64> = report.summaries.iter().map(|s| s.final_acc_mean).collect();
    accs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    assert!(
        ef.final_acc_mean >= accs[3] - 1e-9,
        "EF-sparsign {:.3} should be top-half (4th best = {:.3})",
        ef.final_acc_mean,
        accs[3]
    );
    println!(
        "shape check PASSED: EF-sparsign reaches all targets, top-half accuracy; \
         1-bit L2 QSGD fails (paper: N.A./N.A.)"
    );
}
