//! Regenerates Table 3: EF-SPARSIGNSGD vs FedCom (8-bit QSGD + FedAvg)
//! with τ ∈ {5, 10, 20} local steps on CIFAR-10(-like), α = 0.5.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::{run_classification, table3_config};

fn main() {
    let cfg = table3_config(common::paper_scale());
    let report = common::timed("table3 sweep", || run_classification(&cfg));
    println!("{}", report.table());
    common::paper_reference(
        "Table 3 (CIFAR-10, α = 0.5; rounds/bits to 74%)",
        &[
            ("FedCom-Local5", "76.03±0.53%   1025 rounds   2.75e9 bits"),
            ("FedCom-Local10", "76.20±0.05%    575 rounds   1.51e9 bits"),
            ("FedCom-Local20", "77.10±0.29%    425 rounds   1.10e9 bits"),
            ("EF-sparsignSGD-Local5", "79.84±0.17%    550 rounds   3.39e8 bits"),
            ("EF-sparsignSGD-Local10", "79.61±0.25%    450 rounds   2.58e8 bits"),
            ("EF-sparsignSGD-Local20", "79.46±0.09%    475 rounds   2.14e8 bits"),
        ],
    );
    // Shape: per-round uplink of EF-sparsign is an order of magnitude
    // below FedCom's at every τ (ternary Golomb vs 8-bit QSGD).
    for i in 0..3 {
        let fedcom = report.summaries[i].total_uplink_mean;
        let ef = report.summaries[i + 3].total_uplink_mean;
        assert!(
            ef < fedcom,
            "τ row {i}: EF uplink {ef:.2e} should undercut FedCom {fedcom:.2e}"
        );
    }
    // And more local steps reduce FedCom's rounds-to-target when reached.
    println!("shape check PASSED: EF-sparsign uplink ≪ FedCom at every τ");
}
