//! Shared helpers for the paper-reproduction benches (`cargo bench` runs
//! each bench's `main`; no criterion in the offline registry, so timing
//! and reporting are done here).

#![allow(dead_code)]

use std::time::Instant;

/// True when `SPARSIGND_PAPER_SCALE=1` — run the paper's full
/// configuration instead of the sandbox-sized fast preset.
pub fn paper_scale() -> bool {
    std::env::var("SPARSIGND_PAPER_SCALE").map(|v| v == "1").unwrap_or(false)
}

/// Run `f`, print elapsed wall-clock, pass the result through.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("[bench] {label}: {:.2}s", t0.elapsed().as_secs_f64());
    out
}

/// Print the paper's reported numbers for side-by-side comparison.
pub fn paper_reference(title: &str, rows: &[(&str, &str)]) {
    println!("\n### Paper reference — {title}");
    for (k, v) in rows {
        println!("  {k:<58} {v}");
    }
    println!();
}

/// Simple ns/op measurement: run `f` `iters` times over `elems` elements
/// and report throughput.
pub fn throughput(label: &str, elems: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed().as_secs_f64();
    let per_elem_ns = dt / (iters as f64 * elems as f64) * 1e9;
    let meps = (iters as f64 * elems as f64) / dt / 1e6;
    println!("  {label:<44} {per_elem_ns:>8.2} ns/elem   {meps:>9.1} M elem/s");
    meps
}
