//! Regenerates Fig. 3: test accuracy vs communication rounds (left) and
//! vs uplink communication overhead (right) for EF-SPARSIGNSGD and
//! FedCom. Emits `fig3_series.csv` with every curve.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::{fig3_config, run_classification};
use sparsignd::metrics::write_csv;

fn main() {
    let cfg = fig3_config(common::paper_scale());
    let report = common::timed("fig3 sweep", || run_classification(&cfg));
    println!("{}", report.table());

    // Emit the curves: (algorithm, round, acc, cum_bits).
    let mut rows = Vec::new();
    for (label, series) in &report.series {
        for (round, acc, bits) in series {
            rows.push(vec![
                label.clone(),
                round.to_string(),
                format!("{acc:.4}"),
                format!("{bits:.0}"),
            ]);
        }
    }
    write_csv("fig3_series.csv", &["algorithm", "round", "acc", "cum_uplink_bits"], &rows)
        .expect("csv");
    println!("curves → fig3_series.csv");

    common::paper_reference(
        "Fig. 3",
        &[
            (
                "Accuracy vs rounds",
                "EF-sparsign reaches any accuracy level in fewer rounds than FedCom",
            ),
            (
                "Accuracy vs bits",
                "the gap widens on the bits axis (ternary Golomb ≪ 8-bit QSGD)",
            ),
        ],
    );
    // Shape: at the final common bit budget, the best EF curve dominates
    // the best FedCom curve on the bits axis.
    let best_acc_at = |label_prefix: &str, budget: f64| -> f64 {
        report
            .series
            .iter()
            .filter(|(l, _)| l.starts_with(label_prefix))
            .flat_map(|(_, s)| s.iter())
            .filter(|(_, _, bits)| *bits <= budget)
            .map(|(_, acc, _)| *acc)
            .fold(0.0, f64::max)
    };
    let budget = report
        .series
        .iter()
        .filter(|(l, _)| l.starts_with("EF-"))
        .flat_map(|(_, s)| s.iter().map(|(_, _, b)| *b))
        .fold(0.0, f64::max);
    let ef = best_acc_at("EF-", budget);
    let fedcom = best_acc_at("FedCom", budget);
    println!("best accuracy within {budget:.2e} uplink bits: EF {ef:.3} vs FedCom {fedcom:.3}");
    assert!(ef >= fedcom - 0.03, "EF should dominate on the bits axis");
    println!("shape check PASSED");
}
