//! Ablation benches for the design choices DESIGN.md §5 calls out:
//! budget B, server error feedback, position coding, and the
//! stochastic-sign family. See `experiments::ablations` for details.

#[path = "common/mod.rs"]
mod common;

fn main() {
    let rounds = if common::paper_scale() { 300 } else { 100 };
    let out = common::timed("ablation suite", || {
        sparsignd::experiments::ablations::render_all(rounds)
    });
    println!("{out}");
}
