//! §Perf hot-path microbenchmarks — the numbers recorded in
//! EXPERIMENTS.md §Perf come from this bench.
//!
//! Hot paths (DESIGN.md §8–§10):
//!   1. compressors (per-coordinate work, every worker every round)
//!   2. majority-vote / mean aggregation over M ternary messages —
//!      word-parallel packed vote counting vs the seed's dense-i8 decode
//!   3. the pool round engine vs the serial reference (bit-identical)
//!   4. the 10,000-worker streaming cohort: rounds/sec + peak-RSS proxy
//!      with O(threads·d) aggregation memory (no message buffering)
//!   5. Golomb encode/decode of sparse supports
//!   6. the packed SIMD-dispatched GEMM + the zero-allocation
//!      `Mlp::loss_grad_ws` vs the pre-PR scalar path (kept verbatim in
//!      `scalar_baseline` below)
//!   7. PJRT end-to-end worker step (when artifacts are present)
//!   8. the federation transport (DESIGN.md §11): wire-codec
//!      encode/decode frames-per-second plus an end-to-end loopback
//!      federated run (1k virtual clients over UDS, TCP fallback) pinned
//!      bit-identical to the in-process engine
//!   9. the coordinator snapshot (DESIGN.md §12): atomic write + validated
//!      load latency at d = 1e5 with a 200-round history
//!  10. the sharded aggregation tree (DESIGN.md §14): 100k multiplexed
//!      virtual clients through 2–4 aggregator shards over loopback
//!      sockets, bit-identical to the in-process engine
//!  11. the streaming data plane (DESIGN.md §16): row-gather throughput
//!      over an mmap-backed `.sgds` store, then the same 100k-client
//!      sharded cohort trained off the store — asserting its peak RSS
//!      stays within 2× of the synthetic baseline above
//!
//! `cargo bench --bench perf_hotpaths` runs the full configuration;
//! `-- --smoke` (or `PERF_SMOKE=1`) shrinks every section for CI.
//! `-- --json <path>` additionally emits a machine-readable
//! `BENCH_hotpaths.json` (gemm GF/s, loss_grad µs, round throughput) so
//! successive PRs accumulate a measured trajectory.

#[path = "common/mod.rs"]
mod common;

use sparsignd::compressors::{
    CompressedGrad, Compressor, CompressorKind, NoisySignCompressor, NormKind,
    QsgdCompressor, ScaledSignCompressor, SignCompressor, SparsignCompressor,
    TernGradCompressor,
};
use sparsignd::coding::golomb;
use sparsignd::coordinator::{Algorithm, AggregationRule, GradientSource, TrainingRun};
use sparsignd::model::{Mlp, Model, ModelWorkspace};
use sparsignd::optim::LrSchedule;
use sparsignd::util::linalg::{
    self, gemm_with_portable, matmul, Epilogue, GemmScratch, MatLayout,
};
use sparsignd::util::rng::Pcg64;

/// Flat key→value collector behind `--json`: every section records its
/// headline numbers here so future PRs can diff a measured trajectory.
struct Report {
    entries: Vec<(String, String)>,
}

impl Report {
    fn new() -> Self {
        Self { entries: Vec::new() }
    }

    fn num(&mut self, key: &str, v: f64) {
        self.entries.push((key.to_string(), format!("{v:.6}")));
    }

    fn text(&mut self, key: &str, v: &str) {
        self.entries.push((key.to_string(), format!("\"{v}\"")));
    }

    fn write(&self, path: &str) {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.entries.iter().enumerate() {
            let sep = if i + 1 < self.entries.len() { "," } else { "" };
            s.push_str(&format!("  \"{k}\": {v}{sep}\n"));
        }
        s.push_str("}\n");
        std::fs::write(path, &s).expect("write bench json");
        println!("\nwrote {path}");
    }
}

/// The seed's scalar kernels and per-call-allocating MLP loss/grad, kept
/// verbatim as the pre-PR baseline for the §Perf before/after rows.
mod scalar_baseline {
    pub fn matmul(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        c.fill(0.0);
        const MC: usize = 64;
        const KC: usize = 256;
        const NC: usize = 256;
        let mut i0 = 0;
        while i0 < m {
            let ib = MC.min(m - i0);
            let mut p0 = 0;
            while p0 < k {
                let pb = KC.min(k - p0);
                let mut j0 = 0;
                while j0 < n {
                    let jb = NC.min(n - j0);
                    block_kernel(c, a, b, k, n, i0, p0, j0, ib, pb, jb);
                    j0 += NC;
                }
                p0 += KC;
            }
            i0 += MC;
        }
    }

    fn block_kernel(
        c: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        i0: usize,
        p0: usize,
        j0: usize,
        ib: usize,
        pb: usize,
        jb: usize,
    ) {
        let mut i = 0;
        let cptr = c.as_mut_ptr();
        while i + 4 <= ib {
            let r0 = (i0 + i) * k + p0;
            let r1 = r0 + k;
            let r2 = r1 + k;
            let r3 = r2 + k;
            // SAFETY: four distinct rows of c, in-bounds (as in the seed).
            let (t0, t1, t2, t3) = unsafe {
                (
                    std::slice::from_raw_parts_mut(cptr.add((i0 + i) * n + j0), jb),
                    std::slice::from_raw_parts_mut(cptr.add((i0 + i + 1) * n + j0), jb),
                    std::slice::from_raw_parts_mut(cptr.add((i0 + i + 2) * n + j0), jb),
                    std::slice::from_raw_parts_mut(cptr.add((i0 + i + 3) * n + j0), jb),
                )
            };
            for p in 0..pb {
                let a0 = a[r0 + p];
                let a1 = a[r1 + p];
                let a2 = a[r2 + p];
                let a3 = a[r3 + p];
                let brow = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jb];
                for j in 0..jb {
                    let bv = brow[j];
                    t0[j] += a0 * bv;
                    t1[j] += a1 * bv;
                    t2[j] += a2 * bv;
                    t3[j] += a3 * bv;
                }
            }
            i += 4;
        }
        while i < ib {
            let ra = (i0 + i) * k + p0;
            let rc = (i0 + i) * n + j0;
            for p in 0..pb {
                let a0 = a[ra + p];
                let brow = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jb];
                let crow = &mut c[rc..rc + jb];
                for j in 0..jb {
                    crow[j] += a0 * brow[j];
                }
            }
            i += 1;
        }
    }

    fn matmul_at_b(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        for p in 0..k {
            let arow = &a[p * m..(p + 1) * m];
            let brow = &b[p * n..(p + 1) * n];
            for i in 0..m {
                let av = arow[i];
                if av == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..i * n + n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }

    fn matmul_a_bt(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                crow[j] += acc;
            }
        }
    }

    fn softmax_xent_backward(logits: &mut [f32], y: &[usize], classes: usize) -> f32 {
        let batch = y.len();
        for i in 0..batch {
            let row = &mut logits[i * classes..(i + 1) * classes];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        let mut loss = 0.0f64;
        let inv_b = 1.0 / batch as f32;
        for (i, &yi) in y.iter().enumerate() {
            let p = logits[i * classes + yi].max(1e-12);
            loss -= (p as f64).ln();
            let row = &mut logits[i * classes..(i + 1) * classes];
            for v in row.iter_mut() {
                *v *= inv_b;
            }
            row[yi] -= inv_b;
        }
        (loss / batch as f64) as f32
    }

    fn layer_offset(widths: &[usize], l: usize) -> usize {
        let mut off = 0;
        for i in 0..l {
            off += widths[i] * widths[i + 1] + widths[i + 1];
        }
        off
    }

    /// The pre-PR `Mlp::loss_grad`: fresh `Vec` per activation/delta and
    /// an input copy, scalar kernels throughout.
    pub fn mlp_loss_grad(
        widths: &[usize],
        params: &[f32],
        x: &[f32],
        y: &[usize],
        grad: &mut [f32],
    ) -> f32 {
        let layers = widths.len() - 1;
        let classes = *widths.last().unwrap();
        let batch = y.len();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(layers + 1);
        acts.push(x.to_vec());
        for l in 0..layers {
            let (in_w, out_w) = (widths[l], widths[l + 1]);
            let off = layer_offset(widths, l);
            let w = &params[off..off + out_w * in_w];
            let b = &params[off + out_w * in_w..off + out_w * in_w + out_w];
            let mut h = vec![0.0f32; batch * out_w];
            matmul_a_bt(&mut h, &acts[l], w, batch, in_w, out_w);
            for i in 0..batch {
                for (v, &bj) in h[i * out_w..(i + 1) * out_w].iter_mut().zip(b) {
                    *v += bj;
                }
            }
            if l + 1 < layers {
                for v in h.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(h);
        }
        let mut delta = acts.pop().unwrap();
        let loss = softmax_xent_backward(&mut delta, y, classes);
        grad.fill(0.0);
        for l in (0..layers).rev() {
            let (in_w, out_w) = (widths[l], widths[l + 1]);
            let off = layer_offset(widths, l);
            let a_in = &acts[l];
            matmul_at_b(&mut grad[off..off + out_w * in_w], &delta, a_in, out_w, batch, in_w);
            let db = &mut grad[off + out_w * in_w..off + out_w * in_w + out_w];
            for i in 0..batch {
                for (dbj, &dl) in db.iter_mut().zip(&delta[i * out_w..(i + 1) * out_w]) {
                    *dbj += dl;
                }
            }
            if l > 0 {
                let w = &params[off..off + out_w * in_w];
                let mut prev = vec![0.0f32; batch * in_w];
                matmul(&mut prev, &delta, w, batch, out_w, in_w);
                for (d, a) in prev.iter_mut().zip(a_in) {
                    if *a <= 0.0 {
                        *d = 0.0;
                    }
                }
                delta = prev;
            }
        }
        loss
    }
}

fn bench_compressors(d: usize) {
    println!("\n-- compressors (d = {d}) --");
    let mut rng = Pcg64::seed_from(1);
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut g, 0.0, 0.1);
    let iters = 50;

    let run = |label: &str, comp: &mut dyn Compressor| {
        let mut r = Pcg64::seed_from(2);
        common::throughput(label, d, iters, || {
            let msg = comp.compress(&g, &mut r);
            std::hint::black_box(msg.bits());
        });
    };
    run("sign", &mut SignCompressor);
    run("scaled-sign", &mut ScaledSignCompressor);
    run("noisy-sign(0.01)", &mut NoisySignCompressor { noise_std: 0.01 });
    run("sparsign(B=1)", &mut SparsignCompressor { budget: 1.0 });
    run("sparsign(B=0.1)", &mut SparsignCompressor { budget: 0.1 });
    run("terngrad", &mut TernGradCompressor);
    run("qsgd(s=1,l2)", &mut QsgdCompressor { levels: 1, norm: NormKind::L2 });
    run("qsgd(s=255,l2)", &mut QsgdCompressor { levels: 255, norm: NormKind::L2 });
}

/// The seed's aggregation hot path, kept verbatim as the before/after
/// baseline: every message is a dense `Vec<i8>` widened to f32 per
/// coordinate, then averaged and sign-compressed.
fn seed_dense_i8_majority_vote(msgs: &[Vec<i8>]) -> Vec<f32> {
    let d = msgs[0].len();
    let mut avg = vec![0.0f32; d];
    for q in msgs {
        for (a, &qi) in avg.iter_mut().zip(q.iter()) {
            *a += qi as f32;
        }
    }
    let inv = 1.0 / msgs.len() as f32;
    for v in avg.iter_mut() {
        let x = *v * inv;
        *v = if x > 0.0 {
            1.0
        } else if x < 0.0 {
            -1.0
        } else {
            0.0
        };
    }
    avg
}

fn bench_aggregation(d: usize, m: usize) {
    println!("\n-- aggregation over M = {m} ternary messages (d = {d}) --");
    let mut rng = Pcg64::seed_from(3);
    // ~50% density, matching a mid-training sparsign(B≈1) round.
    let codes: Vec<Vec<i8>> = (0..m)
        .map(|_| {
            (0..d)
                .map(|_| match rng.index(4) {
                    0 => 1i8,
                    1 => -1i8,
                    _ => 0i8,
                })
                .collect()
        })
        .collect();
    let iters = 20;
    let base = common::throughput("MajorityVote (seed dense-i8 baseline)", d * m, iters, || {
        std::hint::black_box(seed_dense_i8_majority_vote(&codes));
    });
    let msgs: Vec<CompressedGrad> = codes
        .iter()
        .map(|q| CompressedGrad::ternary_from_codes(q, 1.0, 0.0))
        .collect();
    let i8_bytes = d * m;
    let packed_bytes = 2 * 8 * d.div_ceil(64) * m;
    println!(
        "  message memory: dense-i8 {:.1} MiB → packed {:.1} MiB ({}x)",
        i8_bytes as f64 / (1 << 20) as f64,
        packed_bytes as f64 / (1 << 20) as f64,
        i8_bytes / packed_bytes.max(1)
    );
    for rule in [AggregationRule::MajorityVote, AggregationRule::ScaledSign, AggregationRule::Mean]
    {
        let meps = common::throughput(&format!("{rule:?} (packed word-parallel)"), d * m, iters, || {
            std::hint::black_box(rule.aggregate(&msgs, None));
        });
        if rule == AggregationRule::MajorityVote {
            println!("  => MajorityVote speedup vs seed baseline: {:.2}x", meps / base);
        }
    }
}

/// Synthetic gradient source for the engine bench: deterministic per
/// `(worker, round)` RNG stream, O(d) fill, no model evaluation — isolates
/// engine + compression + aggregation wall-clock.
struct SynthEnv {
    d: usize,
    m: usize,
}

impl GradientSource for SynthEnv {
    fn dim(&self) -> usize {
        self.d
    }

    fn sample_grad(
        &self,
        _worker: usize,
        _params: &[f32],
        rng: &mut Pcg64,
        out: &mut [f32],
    ) -> f32 {
        // Two uniform f32s in [-0.5, 0.5) per raw u64.
        let pairs = out.len() / 2;
        const INV: f32 = 1.0 / 4_294_967_296.0;
        for i in 0..pairs {
            let r = rng.next_u64();
            out[2 * i] = (r as u32) as f32 * INV - 0.5;
            out[2 * i + 1] = (r >> 32) as f32 * INV - 0.5;
        }
        if out.len() % 2 == 1 {
            let n = out.len();
            out[n - 1] = rng.f32() - 0.5;
        }
        1.0
    }

    fn workers(&self) -> usize {
        self.m
    }
}

fn bench_engine(rep: &mut Report, d: usize, m: usize, rounds: usize) {
    println!("\n-- round engine: {m}-worker CompressedGd, d = {d}, {rounds} rounds --");
    let env = SynthEnv { d, m };
    let mk_run = |threads: Option<usize>| TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation: 1.0,
        eval_every: 0,
        seed: 9,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads,
    };
    let eval = |_p: &[f32]| (0.0, 0.0);
    let init = vec![0.0f32; d];

    let t0 = std::time::Instant::now();
    let serial = mk_run(Some(1)).run(&env, init.clone(), &eval);
    let t_serial = t0.elapsed().as_secs_f64();

    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t0 = std::time::Instant::now();
    let threaded = mk_run(None).run(&env, init, &eval);
    let t_par = t0.elapsed().as_secs_f64();

    assert_eq!(
        serial.final_params, threaded.final_params,
        "threaded engine diverged from serial reference"
    );
    assert_eq!(serial.total_uplink(), threaded.total_uplink());
    println!(
        "  serial {t_serial:.3}s | threaded({hw}) {t_par:.3}s | speedup {:.2}x (RunHistory bit-identical)",
        t_serial / t_par
    );
    rep.num("round_throughput_rps", rounds as f64 / t_par);
    rep.num("round_engine_thread_speedup", t_serial / t_par);
}

/// Peak resident set (VmHWM, Linux) as a cheap RSS proxy for the
/// large-cohort leg. `None` off-Linux or when /proc is unreadable.
fn vm_hwm_mib() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

/// The DESIGN.md §10 target workload: a 10,000-worker packed-ternary
/// cohort over the persistent pool engine. The streaming fast path holds
/// `threads + 1` vote accumulators (O(threads·d) words) instead of a
/// 10,000-message buffer (O(n·d) bits), and spawns zero threads after
/// pool construction — this leg times rounds/sec at that scale and
/// records a peak-RSS proxy.
fn bench_engine_10k(rep: &mut Report, smoke: bool) {
    let m = 10_000;
    let d = if smoke { 1 << 12 } else { 1 << 14 };
    let rounds = if smoke { 2 } else { 5 };
    println!("\n-- streaming engine: {m}-worker sparsign cohort, d = {d}, {rounds} rounds --");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let words = d.div_ceil(64);
    let planes = (usize::BITS - m.leading_zeros()) as usize;
    // pos+neg planes, u64 words, per accumulator (threads local + 1 merged).
    let stream_bytes = (threads + 1) * 2 * 8 * words * planes;
    let buffered_bytes = m * 2 * 8 * words;
    println!(
        "  aggregation memory: streaming {:.1} KiB ({} accumulators) vs buffered {:.1} MiB \
         ({m} packed messages)",
        stream_bytes as f64 / 1024.0,
        threads + 1,
        buffered_bytes as f64 / (1 << 20) as f64
    );
    let env = SynthEnv { d, m };
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation: 1.0,
        eval_every: 0,
        seed: 10,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let t0 = std::time::Instant::now();
    let hist = run.run(&env, vec![0.0f32; d], &|_p| (0.0, 0.0));
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(hist.ledger.rounds(), rounds);
    assert!(hist.total_uplink() > 0.0);
    let rps = rounds as f64 / dt;
    println!(
        "  {rounds} rounds in {dt:.2}s → {rps:.2} rounds/s \
         ({:.1}M worker-messages/s, {threads} threads)",
        rps * m as f64 / 1e6
    );
    rep.num("engine10k_workers", m as f64);
    rep.num("engine10k_dim", d as f64);
    rep.num("engine10k_rounds_per_sec", rps);
    rep.num("engine10k_stream_agg_mib", stream_bytes as f64 / (1 << 20) as f64);
    if let Some(mib) = vm_hwm_mib() {
        println!("  peak RSS (VmHWM proxy): {mib:.1} MiB");
        rep.num("engine10k_peak_rss_mib", mib);
    }
}

/// §11: the transport leg — codec throughput, then a 1k-virtual-client
/// loopback federated run (UDS where available, else TCP) diffed
/// bit-exactly against the in-process engine.
fn bench_transport(rep: &mut Report, smoke: bool) {
    use sparsignd::net::{self, wire};

    // --- codec: encode / decode+unpack frames per second -------------
    let d = 1 << 14;
    println!("\n-- transport: wire codec (update frames, d = {d}, ~25% dense) --");
    let mut rng = Pcg64::seed_from(21);
    let codes: Vec<i8> = (0..d).map(|_| [-1i8, 0, 0, 1][rng.index(4)]).collect();
    let pack = sparsignd::compressors::PackedTernary::from_codes(&codes, 1.0);
    let grad = CompressedGrad::ternary(pack, 2.0 * d as f64);
    let mut wbuf = wire::WireBuf::new();
    let mut frame = Vec::new();
    let iters = if smoke { 2_000 } else { 20_000 };

    let t0 = std::time::Instant::now();
    for i in 0..iters {
        frame.clear();
        std::hint::black_box(wbuf.encode_update(7, i as u64, 0.5, &grad, &mut frame));
    }
    let enc = iters as f64 / t0.elapsed().as_secs_f64();
    let mib = frame.len() as f64 * enc / (1u64 << 20) as f64;
    println!("  encode: {enc:>10.0} frames/s ({mib:>7.1} MiB/s, {} B/frame)", frame.len());

    let mut scratch = sparsignd::compressors::PackedTernary::zeros(0, 1.0);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let (f, _) = wire::parse_frame(&frame, wire::MAX_PAYLOAD).expect("frame");
        let uv = wire::decode_update(f.payload).expect("update");
        uv.grad.unpack_ternary_into(&mut scratch).expect("unpack");
        std::hint::black_box(scratch.nnz());
    }
    let dec = iters as f64 / t0.elapsed().as_secs_f64();
    let mib = frame.len() as f64 * dec / (1u64 << 20) as f64;
    println!("  decode: {dec:>10.0} frames/s ({mib:>7.1} MiB/s, CRC + unpack + revalidate)");
    rep.num("wire_frame_bytes", frame.len() as f64);
    rep.num("wire_encode_frames_per_sec", enc);
    rep.num("wire_decode_frames_per_sec", dec);

    // --- end-to-end loopback federated run ----------------------------
    let m = 1_000;
    let de = if smoke { 1 << 12 } else { 1 << 13 };
    let rounds = if smoke { 2 } else { 5 };
    let env = SynthEnv { d: de, m };
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation: 1.0,
        eval_every: 0,
        seed: 12,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let init = vec![0.0f32; de];
    let in_process = run.run(&env, init.clone(), &|_p| (0.0, 0.0));

    let uds = cfg!(unix);
    let transport = if uds { "uds" } else { "tcp" };
    println!(
        "\n-- transport: loopback round engine \
         ({m} virtual clients over {transport}, d = {de}) --"
    );
    let serve_opts = net::ServeOptions::new(net::client::loopback_endpoint(uds));
    let fleet_opts = net::FleetOptions::default();
    let eval = |_p: &[f32]| (0.0, 0.0);
    let t0 = std::time::Instant::now();
    let (wire_hist, stats) =
        net::run_loopback(&run, &env, init, &eval, serve_opts, &fleet_opts).expect("loopback");
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(
        in_process.final_params, wire_hist.final_params,
        "transport run diverged from the in-process engine"
    );
    assert_eq!(in_process.total_uplink(), wire_hist.total_uplink());
    let rps = rounds as f64 / dt;
    let up_mib = wire_hist.ledger.total_uplink_wire_bytes() as f64 / (1 << 20) as f64;
    println!(
        "  {rounds} rounds in {dt:.2}s → {rps:.2} rounds/s \
         ({:.2}M updates/s, {up_mib:.1} MiB uplink on the wire, {} agents; bit-identical)",
        rps * m as f64 / 1e6,
        fleet_opts.agents
    );
    rep.text("transport_kind", transport);
    rep.num("transport_clients", m as f64);
    rep.num("transport_dim", de as f64);
    rep.num("transport_rounds_per_sec", rps);
    rep.num("transport_uplink_wire_mib", up_mib);
    rep.num("transport_fleet_updates", stats.updates_sent as f64);
}

/// §14: the sharded aggregation tree — a 100,000-virtual-client cohort
/// multiplexed through aggregator shards over loopback sockets, every
/// shard folding its slice into a local `VoteAccumulator` and streaming
/// one merged frame per round to the root. Participation is 0.3 so the
/// per-round cohort (30,000) stays under the 15-bit streaming plane cap
/// (`MAX_STREAM_MSGS` = 32,767) that the shard wire frame inherits.
/// Asserts the tree's `RunHistory` is bit-identical to the in-process
/// engine before recording throughput.
fn bench_shard(rep: &mut Report, smoke: bool) {
    use sparsignd::net;

    let m = 100_000;
    let shards = if smoke { 2 } else { 4 };
    let d = 1 << 10;
    let rounds = if smoke { 2 } else { 5 };
    let env = SynthEnv { d, m };
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation: 0.3,
        eval_every: 0,
        seed: 14,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let init = vec![0.0f32; d];
    let in_process = run.run(&env, init.clone(), &|_p| (0.0, 0.0));

    let uds = cfg!(unix);
    let transport = if uds { "uds" } else { "tcp" };
    println!(
        "\n-- shard tree: {m} virtual clients through {shards} aggregator shards \
         over {transport} (participation 0.3, d = {d}) --"
    );
    let serve_opts = net::ServeOptions::new(net::client::loopback_endpoint(uds));
    let fleet_opts = net::FleetOptions::default();
    let eval = |_p: &[f32]| (0.0, 0.0);
    let t0 = std::time::Instant::now();
    let (wire_hist, stats, shard_stats) = net::run_loopback_sharded(
        &run,
        &env,
        init,
        &eval,
        serve_opts,
        &fleet_opts,
        shards,
        uds,
    )
    .expect("sharded loopback");
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(
        in_process.final_params, wire_hist.final_params,
        "sharded run diverged from the in-process engine"
    );
    assert_eq!(in_process.total_uplink(), wire_hist.total_uplink());
    assert!(
        wire_hist.ledger.total_shard_uplink_wire_bytes() > 0,
        "no shard-tier traffic recorded — the tree did not carry the round"
    );
    let folded: u64 = shard_stats.iter().map(|s| s.updates_folded).sum();
    let rps = rounds as f64 / dt;
    let shard_up_kib = wire_hist.ledger.total_shard_uplink_wire_bytes() as f64 / 1024.0;
    let client_up_mib = wire_hist.ledger.total_uplink_wire_bytes() as f64 / (1 << 20) as f64;
    println!(
        "  {rounds} rounds in {dt:.2}s → {rps:.2} rounds/s \
         ({:.2}M updates/s folded at the shard tier; client uplink {client_up_mib:.1} MiB \
         → root uplink {shard_up_kib:.1} KiB merged; bit-identical)",
        folded as f64 / dt / 1e6
    );
    rep.num("shard_clients", m as f64);
    rep.num("shard_count", shards as f64);
    rep.num("shard_dim", d as f64);
    rep.num("shard_rounds_per_sec", rps);
    rep.num("shard_updates_folded", folded as f64);
    rep.num("shard_root_uplink_kib", shard_up_kib);
    rep.num("shard_fleet_updates", stats.updates_sent as f64);
    if let Some(mib) = vm_hwm_mib() {
        println!("  peak RSS (VmHWM proxy): {mib:.1} MiB");
        rep.num("shard_peak_rss_mib", mib);
    }
}

/// §16: the streaming data plane. Builds a 100k-client `.sgds` store,
/// then (a) walks every manifest range gathering rows straight off the
/// mapping — `data_store_rows_per_sec` — and (b) reruns the sharded
/// 100k-virtual-client cohort of `bench_shard` with the store-backed
/// `ClassifierEnv` as the gradient source, bit-diffed against the
/// in-process engine. Runs directly after `bench_shard` on purpose:
/// VmHWM is a monotone process-wide high-water mark, so the `≤ 2×`
/// assert below says "mapping and streaming the store added at most one
/// more baseline's worth of peak memory on top of the synthetic run".
fn bench_store(rep: &mut Report, smoke: bool) {
    use sparsignd::coordinator::ClassifierEnv;
    use sparsignd::data::{
        write_store, DirichletPartitioner, ShardStore, SyntheticSpec, SyntheticTask,
    };
    use sparsignd::model::ModelKind;
    use sparsignd::net;

    let m = 100_000;
    let dim = if smoke { 16 } else { 32 };
    let rows_per_client = if smoke { 1 } else { 2 };
    let shards = if smoke { 2 } else { 4 };
    let rounds = if smoke { 2 } else { 3 };
    let batch = if smoke { 4 } else { 8 };
    let baseline_rss = vm_hwm_mib();

    let path = std::env::temp_dir()
        .join(format!("sparsignd-bench-store-{}.sgds", std::process::id()));
    {
        // Scoped so the in-RAM task and the encode buffer are freed
        // before training: the run below must live off the mapping.
        let task = SyntheticTask::generate(
            SyntheticSpec {
                dim,
                classes: 10,
                modes: 1,
                separation: 1.8,
                noise: 0.25,
                label_noise: 0.0,
                train: m * rows_per_client,
                test: 5_000,
            },
            41,
        );
        let fed = DirichletPartitioner { alpha: 0.5, workers: m }
            .partition_exact(&task.train, &mut Pcg64::seed_from(42));
        write_store(&path, &task.train, &task.test, &fed, 0.5, 41).expect("write store");
    }
    let store = ShardStore::open(&path).expect("open store");
    let info = store.info();
    println!(
        "\n-- data store: {m} client shards, {} train rows, dim {dim} \
         ({:.1} MiB mapped) --",
        info.rows_train,
        info.file_bytes as f64 / (1 << 20) as f64
    );

    // (a) Streaming gather: every row of every client range, in manifest
    // order, straight off the mapping.
    let env = ClassifierEnv::from_store(
        &store,
        ModelKind::Linear { inputs: store.dim(), classes: store.classes() }.build(),
        batch,
    );
    let passes = if smoke { 2 } else { 5 };
    let t0 = std::time::Instant::now();
    let mut acc = 0.0f32;
    for _ in 0..passes {
        for w in 0..env.fed.workers() {
            for j in 0..env.fed.shard_len(w) {
                let row = env.train.row(env.fed.index(w, j));
                acc += row[0] + row[dim - 1];
            }
        }
    }
    std::hint::black_box(acc);
    let rows_streamed = info.rows_train * passes;
    let rows_per_sec = rows_streamed as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  streaming gather: {:.2}M rows/s ({passes} passes over the manifest)",
        rows_per_sec / 1e6
    );
    rep.num("data_store_rows_per_sec", rows_per_sec);

    // (b) The 100k-client sharded cohort, trained off the store.
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.05 },
        rounds,
        participation: 0.3,
        eval_every: 0,
        seed: 43,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let init = env.init_params(&mut Pcg64::seed_from(44));
    let in_process = run.run(&env, init.clone(), &|_p| (0.0, 0.0));
    let uds = cfg!(unix);
    let serve_opts = net::ServeOptions::new(net::client::loopback_endpoint(uds));
    let fleet_opts = net::FleetOptions::default();
    let t0 = std::time::Instant::now();
    let (wire_hist, _stats, _shard_stats) = net::run_loopback_sharded(
        &run,
        &env,
        init,
        &|_p| (0.0, 0.0),
        serve_opts,
        &fleet_opts,
        shards,
        uds,
    )
    .expect("store-backed sharded loopback");
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(
        in_process.final_params, wire_hist.final_params,
        "store-backed sharded run diverged from the in-process engine"
    );
    let rps = rounds as f64 / dt;
    println!(
        "  {rounds} rounds through {shards} shards in {dt:.2}s → {rps:.2} rounds/s \
         (store-fed cohort, bit-identical)"
    );
    rep.num("store_shard_clients", m as f64);
    rep.num("store_shard_rounds_per_sec", rps);
    if let Some(mib) = vm_hwm_mib() {
        rep.num("store_shard_peak_rss_mib", mib);
        if let Some(base) = baseline_rss {
            println!("  peak RSS {mib:.1} MiB vs {base:.1} MiB synthetic baseline");
            assert!(
                mib <= base * 2.0,
                "store-backed peak RSS {mib:.1} MiB exceeds 2x the synthetic \
                 baseline {base:.1} MiB"
            );
        }
    }
    drop(env);
    drop(store);
    let _ = std::fs::remove_file(&path);
}

/// §12: coordinator snapshot write/load at d = 1e5 — the elastic-resume
/// overhead a production deployment pays every k rounds. Write includes
/// the full atomic dance (temp file + fsync + rename); load includes
/// the hostile-input revalidation pass.
fn bench_snapshot(rep: &mut Report, smoke: bool) {
    use sparsignd::coordinator::{CommLedger, RoundComm, RoundReport};
    use sparsignd::snapshot::{CoordinatorSnapshot, SnapPhase};

    let d = 100_000;
    let rounds_done = if smoke { 50 } else { 200 };
    println!("\n-- coordinator snapshot (d = {d}, {rounds_done} rounds of history) --");
    let mut rng = Pcg64::seed_from(31);
    let mut params = vec![0.0f32; d];
    rng.fill_normal(&mut params, 0.0, 0.1);
    let mut residual = vec![0.0f32; d];
    rng.fill_normal(&mut residual, 0.0, 0.01);
    let mut ledger = CommLedger::with_capacity(rounds_done);
    let reports: Vec<RoundReport> = (0..rounds_done)
        .map(|t| {
            ledger.record(RoundComm {
                uplink_bits: 2.0 * d as f64,
                downlink_bits: 32.0,
                senders: 100,
                uplink_nnz: d / 2,
                uplink_wire_bytes: (d / 4) as u64,
                downlink_wire_bytes: 4 * d as u64,
                shard_uplink_wire_bytes: 0,
                shard_downlink_wire_bytes: 0,
                stragglers: 0,
            });
            RoundReport {
                round: t,
                lr: 0.01,
                train_loss: 1.0 / (t + 1) as f64,
                eval: (t % 10 == 9).then_some((0.5, 0.8)),
                uplink_bits: 2.0 * d as f64,
                downlink_bits: 32.0,
                cum_uplink_bits: 2.0 * d as f64 * (t + 1) as f64,
            }
        })
        .collect();
    let snap = CoordinatorSnapshot {
        fingerprint: 0x5150_5150_5150_5150,
        dim: d,
        workers: 100,
        rounds_total: rounds_done + 1,
        phase: SnapPhase::Broadcast(rounds_done - 1),
        selection: sparsignd::coordinator::SelectionSnapshot::LegacyRaw(
            Pcg64::seed_from(32).to_raw(),
        ),
        params,
        residual: Some(residual),
        reports,
        ledger,
    };
    let bytes = snap.encode().len();
    let path = std::env::temp_dir()
        .join(format!("sparsignd-bench-snap-{}.bin", std::process::id()));
    let iters = if smoke { 10 } else { 50 };

    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        snap.save(&path).expect("snapshot save");
    }
    let write_ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;

    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(CoordinatorSnapshot::load(&path).expect("snapshot load"));
    }
    let load_ms = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
    let back = CoordinatorSnapshot::load(&path).expect("snapshot load");
    assert_eq!(back, snap, "snapshot round-trip must be bit-identical");
    let _ = std::fs::remove_file(&path);

    println!(
        "  {:.1} KiB/file | write {write_ms:>7.2} ms (atomic: tmp+fsync+rename) | \
         load {load_ms:>7.2} ms (CRC + revalidate)",
        bytes as f64 / 1024.0
    );
    rep.num("snapshot_dim", d as f64);
    rep.num("snapshot_bytes", bytes as f64);
    rep.num("snapshot_write_ms", write_ms);
    rep.num("snapshot_load_ms", load_ms);
}

fn bench_golomb(d: usize) {
    println!("\n-- Golomb position coding (d = {d}) --");
    let mut rng = Pcg64::seed_from(4);
    for p in [0.01, 0.1] {
        let idx: Vec<usize> = (0..d).filter(|_| rng.bernoulli(p)).collect();
        let label = format!("encode p={p} (nnz={})", idx.len());
        common::throughput(&label, idx.len().max(1), 200, || {
            std::hint::black_box(golomb::encode_indices(&idx, d));
        });
        let (bytes, _) = golomb::encode_indices(&idx, d);
        let label = format!("decode p={p}");
        common::throughput(&label, idx.len().max(1), 200, || {
            std::hint::black_box(golomb::decode_indices(&bytes));
        });
    }
}

fn bench_gemm(rep: &mut Report, smoke: bool) {
    println!(
        "\n-- packed GEMM (kernel: {}) vs portable vs pre-PR scalar --",
        linalg::kernel_name()
    );
    let mut rng = Pcg64::seed_from(5);
    let mut scratch = GemmScratch::new();
    let flop_budget = if smoke { 3e8 } else { 2e9 };
    for (m, k, n) in [(64, 784, 256), (128, 256, 128), (256, 256, 256)] {
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut c = vec![0.0f32; m * n];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let iters = (flop_budget / flops).max(3.0) as usize;
        let time = |f: &mut dyn FnMut()| {
            f(); // warmup
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                f();
            }
            flops * iters as f64 / t0.elapsed().as_secs_f64() / 1e9
        };
        let packed = time(&mut || {
            matmul(&mut c, &a, &b, m, k, n);
            std::hint::black_box(&c);
        });
        let portable = time(&mut || {
            gemm_with_portable(
                &mut scratch,
                &mut c,
                &a,
                MatLayout::Normal,
                &b,
                MatLayout::Normal,
                m,
                k,
                n,
                false,
                Epilogue::None,
            );
            std::hint::black_box(&c);
        });
        let scalar = time(&mut || {
            scalar_baseline::matmul(&mut c, &a, &b, m, k, n);
            std::hint::black_box(&c);
        });
        println!(
            "  gemm {m}x{k}x{n}: packed {packed:>6.2} | portable {portable:>6.2} | \
             pre-PR scalar {scalar:>6.2} GFLOP/s  ({:.2}x vs scalar, {iters} iters)",
            packed / scalar
        );
        rep.num(&format!("gemm_{m}x{k}x{n}_gflops"), packed);
        rep.num(&format!("gemm_{m}x{k}x{n}_portable_gflops"), portable);
        rep.num(&format!("gemm_{m}x{k}x{n}_scalar_gflops"), scalar);
    }
}

fn bench_loss_grad(rep: &mut Report, smoke: bool) {
    println!("\n-- Mlp::loss_grad — paper §C.2 784-256-128-10, batch 64 --");
    let widths = [784usize, 256, 128, 10];
    let model = Mlp::new(784, vec![256, 128], 10);
    let mut rng = Pcg64::seed_from(6);
    let params = model.init(&mut rng);
    let batch = 64;
    let mut x = vec![0.0f32; batch * 784];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<usize> = (0..batch).map(|i| i % 10).collect();
    let mut g_base = vec![0.0f32; model.dim()];
    let mut g_ws = vec![0.0f32; model.dim()];
    let mut ws = ModelWorkspace::new();
    let iters = if smoke { 30 } else { 300 };

    // Cross-check the baseline copy before timing anything.
    let l_base = scalar_baseline::mlp_loss_grad(&widths, &params, &x, &y, &mut g_base);
    let l_ws = model.loss_grad_ws(&params, &x, &y, &mut g_ws, &mut ws);
    assert!(
        (l_base - l_ws).abs() < 1e-4,
        "baseline loss {l_base} vs workspace loss {l_ws}"
    );
    for (i, (a, b)) in g_base.iter().zip(&g_ws).enumerate() {
        let denom = a.abs().max(b.abs()).max(1e-3);
        assert!(
            (a - b).abs() / denom < 1e-2,
            "grad[{i}]: baseline {a} vs workspace {b}"
        );
    }

    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(scalar_baseline::mlp_loss_grad(
            &widths, &params, &x, &y, &mut g_base,
        ));
    }
    let us_base = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;

    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(model.loss_grad_ws(&params, &x, &y, &mut g_ws, &mut ws));
    }
    let us_ws = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;

    let speedup = us_base / us_ws;
    println!(
        "  pre-PR scalar {us_base:>8.1} µs | packed+workspace {us_ws:>8.1} µs | \
         speedup {speedup:.2}x (target ≥2x, {iters} iters)"
    );
    rep.num("loss_grad_scalar_us", us_base);
    rep.num("loss_grad_ws_us", us_ws);
    rep.num("loss_grad_speedup", speedup);
}

fn bench_pjrt() {
    println!("\n-- PJRT worker step (AOT mlp_fmnist_grad, batch 64) --");
    let Ok(rt) = sparsignd::runtime::Runtime::cpu("artifacts") else {
        println!("  artifacts/ or pjrt feature missing (skipped)");
        return;
    };
    let Ok(spec) = rt.registry().spec("mlp_fmnist_grad") else {
        println!("  mlp_fmnist_grad unmanifested (skipped)");
        return;
    };
    let dim = spec.inputs[0].dims[0] as usize;
    let batch = spec.inputs[1].dims[0] as usize;
    let feat = spec.inputs[1].dims[1] as usize;
    let classes = spec.inputs[2].dims[1] as usize;
    let mut rng = Pcg64::seed_from(6);
    let mut params = vec![0.0f32; dim];
    rng.fill_normal(&mut params, 0.0, 0.05);
    let mut x = vec![0.0f32; batch * feat];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut y = vec![0.0f32; batch * classes];
    for i in 0..batch {
        y[i * classes + rng.index(classes)] = 1.0;
    }
    let inputs = [
        sparsignd::runtime::literal_f32(&params, &[dim as i64]).unwrap(),
        sparsignd::runtime::literal_f32(&x, &[batch as i64, feat as i64]).unwrap(),
        sparsignd::runtime::literal_f32(&y, &[batch as i64, classes as i64]).unwrap(),
    ];
    // Warmup (includes compile).
    rt.execute("mlp_fmnist_grad", &inputs).unwrap();
    let iters = 30;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(rt.execute("mlp_fmnist_grad", &inputs).unwrap());
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
    let flops = 6.0 * batch as f64 * dim as f64; // fwd+bwd ≈ 3 GEMM passes
    println!(
        "  grad step: {per:>7.2} ms  (~{:.2} GFLOP/s effective)",
        flops / (per / 1e3) / 1e9
    );
    // Fused grad+sparsign variant (L1 kernel in the same module).
    if rt.registry().spec("mlp_fmnist_grad_sparsign_b1").is_ok() {
        let mut fused_inputs = inputs.to_vec();
        fused_inputs.push(sparsignd::runtime::literal_u32(&[1, 2], &[2]).unwrap());
        rt.execute("mlp_fmnist_grad_sparsign_b1", &fused_inputs).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(rt.execute("mlp_fmnist_grad_sparsign_b1", &fused_inputs).unwrap());
        }
        let fused = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
        println!(
            "  grad+sparsign (fused): {fused:>7.2} ms  (overhead {:+.1}% vs grad alone)",
            (fused / per - 1.0) * 100.0
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut rep = Report::new();
    rep.text("kernel", linalg::kernel_name());
    rep.text("mode", if smoke { "smoke" } else { "full" });
    if smoke {
        println!("## §Perf hot paths (smoke configuration)");
        bench_compressors(1 << 14);
        bench_aggregation(1 << 13, 32);
        bench_engine(&mut rep, 1 << 15, 16, 2);
        bench_engine_10k(&mut rep, true);
        bench_transport(&mut rep, true);
        bench_shard(&mut rep, true);
        bench_store(&mut rep, true);
        bench_snapshot(&mut rep, true);
        bench_golomb(1 << 14);
        bench_gemm(&mut rep, true);
        bench_loss_grad(&mut rep, true);
    } else {
        println!("## §Perf hot paths (single core unless noted)");
        let d = 1 << 20; // ~1M coords ≈ VGG-9-scale gradient
        bench_compressors(d);
        bench_aggregation(1 << 16, 100);
        bench_engine(&mut rep, 1 << 20, 100, 2);
        bench_engine_10k(&mut rep, false);
        bench_transport(&mut rep, false);
        bench_shard(&mut rep, false);
        bench_store(&mut rep, false);
        bench_snapshot(&mut rep, false);
        bench_golomb(1 << 20);
        bench_gemm(&mut rep, false);
        bench_loss_grad(&mut rep, false);
        bench_pjrt();
    }
    if let Some(path) = json_path {
        rep.write(&path);
    }
}
