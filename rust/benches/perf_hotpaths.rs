//! §Perf hot-path microbenchmarks — the numbers recorded in
//! EXPERIMENTS.md §Perf come from this bench.
//!
//! Hot paths (DESIGN.md §8):
//!   1. compressors (per-coordinate work, every worker every round)
//!   2. majority-vote / mean aggregation over M ternary messages —
//!      word-parallel packed vote counting vs the seed's dense-i8 decode
//!   3. the threaded round engine vs the serial reference (bit-identical)
//!   4. Golomb encode/decode of sparse supports
//!   5. the blocked GEMM behind the pure-rust models
//!   6. PJRT end-to-end worker step (when artifacts are present)
//!
//! `cargo bench --bench perf_hotpaths` runs the full configuration;
//! `-- --smoke` (or `PERF_SMOKE=1`) shrinks every section for CI.

#[path = "common/mod.rs"]
mod common;

use sparsignd::compressors::{
    CompressedGrad, Compressor, CompressorKind, NoisySignCompressor, NormKind,
    QsgdCompressor, ScaledSignCompressor, SignCompressor, SparsignCompressor,
    TernGradCompressor,
};
use sparsignd::coding::golomb;
use sparsignd::coordinator::{Algorithm, AggregationRule, GradientSource, TrainingRun};
use sparsignd::optim::LrSchedule;
use sparsignd::util::linalg::matmul;
use sparsignd::util::rng::Pcg64;

fn bench_compressors(d: usize) {
    println!("\n-- compressors (d = {d}) --");
    let mut rng = Pcg64::seed_from(1);
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut g, 0.0, 0.1);
    let iters = 50;

    let run = |label: &str, comp: &mut dyn Compressor| {
        let mut r = Pcg64::seed_from(2);
        common::throughput(label, d, iters, || {
            let msg = comp.compress(&g, &mut r);
            std::hint::black_box(msg.bits());
        });
    };
    run("sign", &mut SignCompressor);
    run("scaled-sign", &mut ScaledSignCompressor);
    run("noisy-sign(0.01)", &mut NoisySignCompressor { noise_std: 0.01 });
    run("sparsign(B=1)", &mut SparsignCompressor { budget: 1.0 });
    run("sparsign(B=0.1)", &mut SparsignCompressor { budget: 0.1 });
    run("terngrad", &mut TernGradCompressor);
    run("qsgd(s=1,l2)", &mut QsgdCompressor { levels: 1, norm: NormKind::L2 });
    run("qsgd(s=255,l2)", &mut QsgdCompressor { levels: 255, norm: NormKind::L2 });
}

/// The seed's aggregation hot path, kept verbatim as the before/after
/// baseline: every message is a dense `Vec<i8>` widened to f32 per
/// coordinate, then averaged and sign-compressed.
fn seed_dense_i8_majority_vote(msgs: &[Vec<i8>]) -> Vec<f32> {
    let d = msgs[0].len();
    let mut avg = vec![0.0f32; d];
    for q in msgs {
        for (a, &qi) in avg.iter_mut().zip(q.iter()) {
            *a += qi as f32;
        }
    }
    let inv = 1.0 / msgs.len() as f32;
    for v in avg.iter_mut() {
        let x = *v * inv;
        *v = if x > 0.0 {
            1.0
        } else if x < 0.0 {
            -1.0
        } else {
            0.0
        };
    }
    avg
}

fn bench_aggregation(d: usize, m: usize) {
    println!("\n-- aggregation over M = {m} ternary messages (d = {d}) --");
    let mut rng = Pcg64::seed_from(3);
    // ~50% density, matching a mid-training sparsign(B≈1) round.
    let codes: Vec<Vec<i8>> = (0..m)
        .map(|_| {
            (0..d)
                .map(|_| match rng.index(4) {
                    0 => 1i8,
                    1 => -1i8,
                    _ => 0i8,
                })
                .collect()
        })
        .collect();
    let iters = 20;
    let base = common::throughput("MajorityVote (seed dense-i8 baseline)", d * m, iters, || {
        std::hint::black_box(seed_dense_i8_majority_vote(&codes));
    });
    let msgs: Vec<CompressedGrad> = codes
        .iter()
        .map(|q| CompressedGrad::ternary_from_codes(q, 1.0, 0.0))
        .collect();
    let i8_bytes = d * m;
    let packed_bytes = 2 * 8 * ((d + 63) / 64) * m;
    println!(
        "  message memory: dense-i8 {:.1} MiB → packed {:.1} MiB ({}x)",
        i8_bytes as f64 / (1 << 20) as f64,
        packed_bytes as f64 / (1 << 20) as f64,
        i8_bytes / packed_bytes.max(1)
    );
    for rule in [AggregationRule::MajorityVote, AggregationRule::ScaledSign, AggregationRule::Mean]
    {
        let meps = common::throughput(&format!("{rule:?} (packed word-parallel)"), d * m, iters, || {
            std::hint::black_box(rule.aggregate(&msgs, None));
        });
        if rule == AggregationRule::MajorityVote {
            println!("  => MajorityVote speedup vs seed baseline: {:.2}x", meps / base);
        }
    }
}

/// Synthetic gradient source for the engine bench: deterministic per
/// `(worker, round)` RNG stream, O(d) fill, no model evaluation — isolates
/// engine + compression + aggregation wall-clock.
struct SynthEnv {
    d: usize,
    m: usize,
}

impl GradientSource for SynthEnv {
    fn dim(&self) -> usize {
        self.d
    }

    fn sample_grad(
        &self,
        _worker: usize,
        _params: &[f32],
        rng: &mut Pcg64,
        out: &mut [f32],
    ) -> f32 {
        // Two uniform f32s in [-0.5, 0.5) per raw u64.
        let pairs = out.len() / 2;
        const INV: f32 = 1.0 / 4_294_967_296.0;
        for i in 0..pairs {
            let r = rng.next_u64();
            out[2 * i] = (r as u32) as f32 * INV - 0.5;
            out[2 * i + 1] = (r >> 32) as f32 * INV - 0.5;
        }
        if out.len() % 2 == 1 {
            let n = out.len();
            out[n - 1] = rng.f32() - 0.5;
        }
        1.0
    }

    fn workers(&self) -> usize {
        self.m
    }
}

fn bench_engine(d: usize, m: usize, rounds: usize) {
    println!("\n-- round engine: {m}-worker CompressedGd, d = {d}, {rounds} rounds --");
    let env = SynthEnv { d, m };
    let mk_run = |threads: Option<usize>| TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation: 1.0,
        eval_every: 0,
        seed: 9,
        attack: None,
        allow_stateful_with_sampling: false,
        threads,
    };
    let eval = |_p: &[f32]| (0.0, 0.0);
    let init = vec![0.0f32; d];

    let t0 = std::time::Instant::now();
    let serial = mk_run(Some(1)).run(&env, init.clone(), &eval);
    let t_serial = t0.elapsed().as_secs_f64();

    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t0 = std::time::Instant::now();
    let threaded = mk_run(None).run(&env, init, &eval);
    let t_par = t0.elapsed().as_secs_f64();

    assert_eq!(
        serial.final_params, threaded.final_params,
        "threaded engine diverged from serial reference"
    );
    assert_eq!(serial.total_uplink(), threaded.total_uplink());
    println!(
        "  serial {t_serial:.3}s | threaded({hw}) {t_par:.3}s | speedup {:.2}x (RunHistory bit-identical)",
        t_serial / t_par
    );
}

fn bench_golomb(d: usize) {
    println!("\n-- Golomb position coding (d = {d}) --");
    let mut rng = Pcg64::seed_from(4);
    for p in [0.01, 0.1] {
        let idx: Vec<usize> = (0..d).filter(|_| rng.bernoulli(p)).collect();
        let label = format!("encode p={p} (nnz={})", idx.len());
        common::throughput(&label, idx.len().max(1), 200, || {
            std::hint::black_box(golomb::encode_indices(&idx, d));
        });
        let (bytes, _) = golomb::encode_indices(&idx, d);
        let label = format!("decode p={p}");
        common::throughput(&label, idx.len().max(1), 200, || {
            std::hint::black_box(golomb::decode_indices(&bytes));
        });
    }
}

fn bench_gemm() {
    println!("\n-- blocked GEMM (pure-rust model hot path) --");
    let mut rng = Pcg64::seed_from(5);
    for (m, k, n) in [(64, 784, 256), (128, 256, 128), (256, 256, 256)] {
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        let mut c = vec![0.0f32; m * n];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let iters = (2e9 / flops).max(3.0) as usize;
        // warmup
        matmul(&mut c, &a, &b, m, k, n);
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            matmul(&mut c, &a, &b, m, k, n);
            std::hint::black_box(&c);
        }
        let dt = t0.elapsed().as_secs_f64();
        let gflops = flops * iters as f64 / dt / 1e9;
        println!("  gemm {m}x{k}x{n}: {gflops:>6.2} GFLOP/s ({iters} iters)");
    }
}

fn bench_pjrt() {
    println!("\n-- PJRT worker step (AOT mlp_fmnist_grad, batch 64) --");
    let Ok(rt) = sparsignd::runtime::Runtime::cpu("artifacts") else {
        println!("  artifacts/ or pjrt feature missing (skipped)");
        return;
    };
    let Ok(spec) = rt.registry().spec("mlp_fmnist_grad") else {
        println!("  mlp_fmnist_grad unmanifested (skipped)");
        return;
    };
    let dim = spec.inputs[0].dims[0] as usize;
    let batch = spec.inputs[1].dims[0] as usize;
    let feat = spec.inputs[1].dims[1] as usize;
    let classes = spec.inputs[2].dims[1] as usize;
    let mut rng = Pcg64::seed_from(6);
    let mut params = vec![0.0f32; dim];
    rng.fill_normal(&mut params, 0.0, 0.05);
    let mut x = vec![0.0f32; batch * feat];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut y = vec![0.0f32; batch * classes];
    for i in 0..batch {
        y[i * classes + rng.index(classes)] = 1.0;
    }
    let inputs = [
        sparsignd::runtime::literal_f32(&params, &[dim as i64]).unwrap(),
        sparsignd::runtime::literal_f32(&x, &[batch as i64, feat as i64]).unwrap(),
        sparsignd::runtime::literal_f32(&y, &[batch as i64, classes as i64]).unwrap(),
    ];
    // Warmup (includes compile).
    rt.execute("mlp_fmnist_grad", &inputs).unwrap();
    let iters = 30;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(rt.execute("mlp_fmnist_grad", &inputs).unwrap());
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
    let flops = 6.0 * batch as f64 * dim as f64; // fwd+bwd ≈ 3 GEMM passes
    println!(
        "  grad step: {per:>7.2} ms  (~{:.2} GFLOP/s effective)",
        flops / (per / 1e3) / 1e9
    );
    // Fused grad+sparsign variant (L1 kernel in the same module).
    if rt.registry().spec("mlp_fmnist_grad_sparsign_b1").is_ok() {
        let mut fused_inputs = inputs.to_vec();
        fused_inputs.push(sparsignd::runtime::literal_u32(&[1, 2], &[2]).unwrap());
        rt.execute("mlp_fmnist_grad_sparsign_b1", &fused_inputs).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(rt.execute("mlp_fmnist_grad_sparsign_b1", &fused_inputs).unwrap());
        }
        let fused = t0.elapsed().as_secs_f64() / iters as f64 * 1e3;
        println!(
            "  grad+sparsign (fused): {fused:>7.2} ms  (overhead {:+.1}% vs grad alone)",
            (fused / per - 1.0) * 100.0
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        println!("## §Perf hot paths (smoke configuration)");
        bench_compressors(1 << 14);
        bench_aggregation(1 << 13, 32);
        bench_engine(1 << 15, 16, 2);
        bench_golomb(1 << 14);
        return;
    }
    println!("## §Perf hot paths (single core unless noted)");
    let d = 1 << 20; // ~1M coords ≈ VGG-9-scale gradient
    bench_compressors(d);
    bench_aggregation(1 << 16, 100);
    bench_engine(1 << 20, 100, 2);
    bench_golomb(1 << 20);
    bench_gemm();
    bench_pjrt();
}
