//! Regenerates Tables 4–7: CIFAR-100(-like), EF-SPARSIGNSGD vs FedCom
//! across heterogeneity levels α ∈ {0.1, 0.3, 0.6, 1.0}.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::{run_classification, tables4_7_configs};

fn main() {
    let alphas = [0.1, 0.3, 0.6, 1.0];
    let configs = tables4_7_configs(common::paper_scale(), &alphas);
    for cfg in &configs {
        let report = common::timed(&cfg.name, || run_classification(cfg));
        println!("{}", report.table());
        // Shape: at every α, EF-sparsign's final accuracy beats FedCom's
        // best, at lower uplink (the paper's across-the-board result).
        let fedcom_best = report.summaries[..3]
            .iter()
            .map(|s| s.final_acc_mean)
            .fold(f64::NEG_INFINITY, f64::max);
        let ef_best = report.summaries[3..]
            .iter()
            .map(|s| s.final_acc_mean)
            .fold(f64::NEG_INFINITY, f64::max);
        let fedcom_bits = report.summaries[..3]
            .iter()
            .map(|s| s.total_uplink_mean)
            .fold(f64::INFINITY, f64::min);
        let ef_bits = report.summaries[3..]
            .iter()
            .map(|s| s.total_uplink_mean)
            .fold(f64::INFINITY, f64::min);
        println!(
            "α={}: EF best acc {ef_best:.3} vs FedCom {fedcom_best:.3}; \
             min uplink EF {ef_bits:.2e} vs FedCom {fedcom_bits:.2e}\n",
            cfg.alpha
        );
        assert!(
            ef_bits < fedcom_bits,
            "α={}: EF uplink should undercut FedCom",
            cfg.alpha
        );
        assert!(
            ef_best >= fedcom_best - 0.04,
            "α={}: EF accuracy {ef_best:.3} should be comparable to FedCom {fedcom_best:.3}",
            cfg.alpha
        );
    }
    common::paper_reference(
        "Tables 4–7 (CIFAR-100; rounds/bits to 40%)",
        &[
            ("α=0.1: FedCom-Local20", "40.65±0.67%   4225 rounds   1.77e10 bits"),
            ("α=0.1: EF-sparsign-Local10", "46.65±0.43%   1125 rounds   1.52e9 bits"),
            ("α=0.3: EF-sparsign-Local10", "52.37±0.31%    825 rounds   1.12e9 bits"),
            ("α=0.6: EF-sparsign-Local10", "52.59±0.06%    875 rounds   1.15e9 bits"),
            ("α=1.0: EF-sparsign-Local10", "52.17±0.22%    875 rounds   1.10e9 bits"),
            ("(EF-sparsign beats FedCom at every α)", ""),
        ],
    );
    println!("shape check PASSED: EF-sparsign cheaper than FedCom at every α");
}
