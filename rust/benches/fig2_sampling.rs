//! Regenerates Fig. 2: worker-sampling impact — sparsign B = 0.01 at
//! 5% / 10% / 50% participation vs deterministic sign at 100%.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::run_fig2;

fn main() {
    let rounds = if common::paper_scale() { 10_000 } else { 3_000 };
    let series = common::timed("fig2 sweep", || run_fig2(rounds, 0.01, 7));
    println!("## Fig. 2 (reproduced) — {rounds} rounds, lr 0.01");
    println!(
        "{:<30} {:>18} {:>12} {:>14}",
        "series", "mean wrong-agg", "F(start)", "F(end)"
    );
    for s in &series {
        println!(
            "{:<30} {:>18.3} {:>12.2} {:>14.2}",
            s.label,
            s.mean_wrong_agg(),
            s.fvalue.first().unwrap(),
            s.final_value()
        );
    }
    common::paper_reference(
        "Fig. 2",
        &[
            ("Deterministic sign (all workers)", "wrong-agg ≈ 1, diverges"),
            ("sparsign: more workers sampled", "lower wrong-agg, faster convergence (Remark 3)"),
        ],
    );
    // Shape: every sparsign series beats 1/2; more sampling is not worse.
    for s in &series[1..] {
        assert!(s.mean_wrong_agg() < 0.5, "{}", s.label);
    }
    let w5 = series[1].mean_wrong_agg();
    let w50 = series[3].mean_wrong_agg();
    assert!(w50 <= w5 + 0.02, "sampling should reduce wrong-agg: 5%={w5:.3} 50%={w50:.3}");
    assert!(series[3].final_value() <= series[1].final_value() + 0.5);
    println!("shape check PASSED: wrong-agg decreases with participation");
}
