//! Regenerates Fig. 1: probability of wrong aggregation + Rosenbrock value
//! for deterministic sign vs sparsign B ∈ {0.01, 0.1}, 10/100 workers
//! selected per round under the eq. (11) adversarial population.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::run_fig1;

fn main() {
    let rounds = if common::paper_scale() { 10_000 } else { 3_000 };
    let series = common::timed("fig1 sweep", || run_fig1(rounds, 0.01, 7));
    println!("## Fig. 1 (reproduced) — {rounds} rounds, lr 0.01, p_s = 0.1");
    println!(
        "{:<28} {:>18} {:>12} {:>14}",
        "series", "mean wrong-agg", "F(start)", "F(end)"
    );
    for s in &series {
        println!(
            "{:<28} {:>18.3} {:>12.2} {:>14.2}",
            s.label,
            s.mean_wrong_agg(),
            s.fvalue.first().unwrap(),
            s.final_value()
        );
    }
    common::paper_reference(
        "Fig. 1",
        &[
            ("Deterministic sign: wrong-aggregation probability", "≈ 1, diverges"),
            ("sparsign B ∈ {0.01, 0.1}: wrong-aggregation", "< 1/2, converges"),
        ],
    );
    assert!(series[0].mean_wrong_agg() > 0.9);
    assert!(series[1].mean_wrong_agg() < 0.5 && series[2].mean_wrong_agg() < 0.5);
    assert!(series[0].final_value() > *series[0].fvalue.first().unwrap());
    assert!(series[2].final_value() < *series[2].fvalue.first().unwrap());
    println!("shape check PASSED: sign diverges (wrong-agg ≈ 1), sparsign converges (< 1/2)");
}
