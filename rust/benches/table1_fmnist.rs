//! Regenerates Table 1: Fashion-MNIST(-like), α = 0.1, M = 100 (fast: 30)
//! workers, full participation — all eight algorithm rows with final
//! accuracy, rounds-to-target and Golomb-accounted uplink bits.

#[path = "common/mod.rs"]
mod common;

use sparsignd::experiments::{run_classification, table1_config};

fn main() {
    let cfg = table1_config(common::paper_scale());
    let report = common::timed("table1 sweep", || run_classification(&cfg));
    println!("{}", report.table());
    common::paper_reference(
        "Table 1 (Fashion-MNIST, α = 0.1; rounds/bits to 74%)",
        &[
            ("signSGD", "74.44±0.71%   193 rounds   4.56e7 bits"),
            ("Scaled signSGD", "69.61±1.99%   N.A."),
            ("Noisy signSGD", "77.84±0.37%   79 rounds    1.88e7 bits"),
            ("1-bit L2 norm QSGD", "79.05±1.22%   75 rounds    1.98e5 bits"),
            ("1-bit Linf norm QSGD", "80.07±0.75%   68 rounds    1.13e6 bits"),
            ("TernGrad", "79.17±1.41%   66 rounds    4.34e5 bits"),
            ("sparsignSGD (B=1)", "79.05±0.39%   65 rounds    8.19e5 bits"),
            ("EF-sparsignSGD (Bl=10,Bg=1,τ=1)", "80.75±0.20%   65 rounds    1.93e5 bits"),
        ],
    );
    // Shape checks: the ternary/sparsign family transmits far fewer bits
    // than dense-1-bit signSGD per round, and EF-sparsign is the best or
    // near-best final accuracy.
    let bits_per_round = |i: usize| report.summaries[i].total_uplink_mean / cfg.rounds as f64;
    let sign_bits = bits_per_round(0);
    let sparsign_bits = bits_per_round(6);
    assert!(
        sparsign_bits < sign_bits,
        "sparsign uplink/round {sparsign_bits:.0} should undercut signSGD {sign_bits:.0}"
    );
    let best = report
        .summaries
        .iter()
        .map(|s| s.final_acc_mean)
        .fold(f64::NEG_INFINITY, f64::max);
    let ef = report.summaries[7].final_acc_mean;
    assert!(ef >= best - 0.08, "EF-sparsign {ef:.3} should be near the best {best:.3}");
    println!("shape check PASSED: sparsign family cheaper than dense sign; EF-sparsign competitive");
}
