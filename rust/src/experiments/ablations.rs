//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. **Budget sweep** — the accuracy/communication trade-off of
//!    sparsign's `B` (and the Remark 7 clipping regime at large B).
//! 2. **Server error feedback** — Algorithm 2 with the eq. (8) residual
//!    on vs off.
//! 3. **Position coding** — Golomb (eq. 12) vs dense log2(3) vs raw
//!    32-bit indices for ternary messages.
//! 4. **Stochastic-sign family** — sparsign vs sto-SIGN vs SSDM
//!    (momentum; stateful) under full participation.

use crate::coding::cost::golomb_bits_per_index;
use crate::compressors::CompressorKind;
use crate::config::ExperimentConfig;
use crate::coordinator::{AggregationRule, Algorithm, TrainingRun};
use crate::experiments::build_env;
use crate::metrics::TablePrinter;
use crate::optim::LrSchedule;
use crate::util::rng::Pcg64;

/// One ablation row: label → (final acc, total uplink bits).
pub type AblationRow = (String, f64, f64);

fn run_one(
    cfg: &ExperimentConfig,
    alg: Algorithm,
    lr: f64,
    rounds: usize,
) -> (f64, f64) {
    let env = build_env(cfg, 0xab1a);
    let mut init_rng = Pcg64::new(0, 0x1217);
    let init = env.init_params(&mut init_rng);
    let run = TrainingRun {
        algorithm: alg,
        schedule: LrSchedule::Const { lr },
        rounds,
        participation: 1.0,
        eval_every: 0,
        seed: 0,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    (hist.final_eval().unwrap().1, hist.total_uplink())
}

/// Ablation 1: sparsign budget sweep.
pub fn budget_sweep(rounds: usize) -> Vec<AblationRow> {
    let cfg = ExperimentConfig::fast_preset();
    let mut out = Vec::new();
    for &b in &[0.01f32, 0.1, 1.0, 10.0, 100.0] {
        let (acc, bits) = run_one(
            &cfg,
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: b },
                aggregation: AggregationRule::MajorityVote,
            },
            0.01,
            rounds,
        );
        out.push((format!("B={b}"), acc, bits));
    }
    // Auto-density variant for comparison.
    let (acc, bits) = run_one(
        &cfg,
        Algorithm::CompressedGd {
            compressor: CompressorKind::SparsignAuto { target_density: 0.1 },
            aggregation: AggregationRule::MajorityVote,
        },
        0.01,
        rounds,
    );
    out.push(("auto(p=0.1)".into(), acc, bits));
    out
}

/// Ablation 2: Algorithm 2 with and without the server residual.
pub fn server_ef_ablation(rounds: usize) -> Vec<AblationRow> {
    let cfg = ExperimentConfig::fast_preset();
    let mut out = Vec::new();
    for (label, server_ef) in [("with server EF (eq. 8)", true), ("without server EF", false)] {
        let (acc, bits) = run_one(
            &cfg,
            Algorithm::EfSparsign {
                b_local: 10.0,
                b_global: 1.0,
                tau: 2,
                server_lr_scale: None,
                server_ef,
            },
            0.02,
            rounds,
        );
        out.push((label.to_string(), acc, bits));
    }
    out
}

/// Ablation 3: ternary-position coding schemes — bits per coordinate at
/// each density (pure accounting, no training).
pub fn coding_ablation() -> Vec<(f64, f64, f64, f64)> {
    // (density, golomb bits/coord, dense log2(3), 32-bit indices)
    [0.001, 0.01, 0.05, 0.1, 0.3, 0.5]
        .iter()
        .map(|&p| {
            let golomb = p * (golomb_bits_per_index(p) + 1.0);
            let dense = (3.0f64).log2();
            let raw_idx = p * (32.0 + 1.0);
            (p, golomb, dense, raw_idx)
        })
        .collect()
}

/// Ablation 4: the stochastic-sign family head-to-head.
pub fn sign_family_ablation(rounds: usize) -> Vec<AblationRow> {
    let cfg = ExperimentConfig::fast_preset();
    let entries: Vec<(CompressorKind, f64)> = vec![
        (CompressorKind::Sign, 0.01),
        (CompressorKind::Sparsign { budget: 1.0 }, 0.01),
        (CompressorKind::StoSign { b: 1.0 }, 0.01),
        (CompressorKind::Ssdm { beta: 0.3 }, 0.01),
    ];
    entries
        .into_iter()
        .map(|(kind, lr)| {
            let label = kind.label();
            let (acc, bits) = run_one(
                &cfg,
                Algorithm::CompressedGd {
                    compressor: kind,
                    aggregation: AggregationRule::MajorityVote,
                },
                lr,
                rounds,
            );
            (label, acc, bits)
        })
        .collect()
}

/// Render all ablations as tables.
pub fn render_all(rounds: usize) -> String {
    let mut out = String::new();
    let mut t = TablePrinter::new(
        "Ablation: sparsign budget B (fast task, majority vote)",
        &["Budget", "Final acc", "Total uplink bits"],
    );
    for (label, acc, bits) in budget_sweep(rounds) {
        t.add_row(vec![label, format!("{:.1}%", 100.0 * acc), format!("{bits:.2e}")]);
    }
    out.push_str(&t.render());

    let mut t = TablePrinter::new(
        "Ablation: Algorithm 2 server error feedback",
        &["Variant", "Final acc", "Total uplink bits"],
    );
    for (label, acc, bits) in server_ef_ablation(rounds) {
        t.add_row(vec![label, format!("{:.1}%", 100.0 * acc), format!("{bits:.2e}")]);
    }
    out.push_str(&t.render());

    let mut t = TablePrinter::new(
        "Ablation: ternary position coding (bits per coordinate)",
        &["Density", "Golomb eq.(12)", "Dense log2(3)", "32-bit indices"],
    );
    for (p, g, d, r) in coding_ablation() {
        t.add_row(vec![
            format!("{p}"),
            format!("{g:.3}"),
            format!("{d:.3}"),
            format!("{r:.3}"),
        ]);
    }
    out.push_str(&t.render());

    let mut t = TablePrinter::new(
        "Ablation: stochastic-sign family (full participation)",
        &["Compressor", "Final acc", "Total uplink bits"],
    );
    for (label, acc, bits) in sign_family_ablation(rounds) {
        t.add_row(vec![label, format!("{:.1}%", 100.0 * acc), format!("{bits:.2e}")]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_sweep_bits_monotone() {
        let rows = budget_sweep(30);
        // Uplink grows with B until clipping saturates.
        assert!(rows[0].2 < rows[2].2, "B=0.01 bits {} vs B=1 bits {}", rows[0].2, rows[2].2);
        assert!(rows[2].2 < rows[4].2 * 1.01);
        // Everything produced finite, sane numbers.
        for (label, acc, bits) in &rows {
            assert!(acc.is_finite() && bits.is_finite(), "{label}");
        }
    }

    #[test]
    fn coding_golomb_beats_dense_when_sparse() {
        for (p, golomb, dense, raw) in coding_ablation() {
            if p <= 0.1 {
                assert!(golomb < dense, "p={p}: golomb {golomb} vs dense {dense}");
                assert!(golomb < raw, "p={p}: golomb {golomb} vs raw {raw}");
            }
        }
        // At p = 0.5 the two are within a whisker (Golomb b̄ = 2 ⇒
        // 1.5 bits/coord vs log2(3) ≈ 1.585) — the regime where dense
        // ternary coding becomes competitive.
        let (_, g, d, _) = coding_ablation()[5];
        assert!((g - d).abs() < 0.15, "p=0.5: golomb {g} vs dense {d}");
    }

    #[test]
    fn server_ef_helps() {
        let rows = server_ef_ablation(60);
        let with = rows[0].1;
        let without = rows[1].1;
        assert!(
            with >= without - 0.02,
            "server EF should not hurt: with {with:.3} vs without {without:.3}"
        );
    }

    #[test]
    fn sign_family_all_learn() {
        for (label, acc, _) in sign_family_ablation(100) {
            assert!(acc > 0.3, "{label}: acc {acc}");
        }
    }
}
