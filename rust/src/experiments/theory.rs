//! Theorem 1 / Corollary 1 validation: Monte-Carlo wrong-aggregation
//! probability vs the closed-form bound `[1 − (√q̄ − √p̄)²]^M`.

use crate::util::rng::Pcg64;
use crate::util::sign0;

/// Result of one bound check.
#[derive(Clone, Debug)]
pub struct BoundCheck {
    pub m: usize,
    pub budget: f64,
    pub p_bar: f64,
    pub q_bar: f64,
    pub empirical: f64,
    pub bound: f64,
}

/// Closed-form Theorem 1 bound.
pub fn theorem1_bound(p_bar: f64, q_bar: f64, m: usize) -> f64 {
    assert!(q_bar > p_bar, "Theorem 1 requires q̄ > p̄");
    let delta = q_bar.sqrt() - p_bar.sqrt();
    (1.0 - delta * delta).powi(m as i32)
}

/// Corollary 1's p̄/q̄ for sparsign with budget B and sampling prob p_s
/// over fixed scalars `u`.
pub fn corollary1_rates(u: &[f64], budget: f64, p_s: f64) -> (f64, f64) {
    let m = u.len() as f64;
    let true_sign = sign0(u.iter().sum::<f64>() as f32) as f64;
    let mut p_bar = 0.0;
    let mut q_bar = 0.0;
    for &um in u {
        let keep = (um.abs() * budget).min(1.0) * p_s;
        if sign0(um as f32) as f64 == true_sign {
            q_bar += keep;
        } else if um != 0.0 {
            p_bar += keep;
        }
    }
    (p_bar / m, q_bar / m)
}

/// Monte-Carlo estimate of the wrong-aggregation probability for sparsign
/// over fixed scalars `u` with worker sampling.
pub fn empirical_wrong_aggregation(
    u: &[f64],
    budget: f64,
    p_s: f64,
    trials: usize,
    rng: &mut Pcg64,
) -> f64 {
    let true_sign = sign0(u.iter().sum::<f64>() as f32);
    assert!(true_sign != 0.0, "need a non-zero true mean");
    let mut wrong = 0usize;
    for _ in 0..trials {
        let mut total = 0i64;
        for &um in u {
            if !rng.bernoulli(p_s) {
                continue; // worker not sampled this round
            }
            let p = (um.abs() * budget).min(1.0);
            if rng.bernoulli(p) {
                total += if um > 0.0 { 1 } else { -1 };
            }
        }
        // Wrong aggregation: the aggregated sign opposes the true sign
        // (Theorem 1 counts sign(Σ q̂) ≠ sign(Σ u); we follow the proof's
        // event {Σ X_m ≥ 0} which includes ties).
        let agg_wrong = if true_sign > 0.0 { total <= 0 } else { total >= 0 };
        if agg_wrong {
            wrong += 1;
        }
    }
    wrong as f64 / trials as f64
}

/// Run the bound check across a sweep of (M, B) with the eq. (11)-style
/// adversarial scalar population (`neg_frac` of workers sign-flipped).
pub fn sweep(
    ms: &[usize],
    budgets: &[f64],
    neg_frac: f64,
    trials: usize,
    seed: u64,
) -> Vec<BoundCheck> {
    let mut out = Vec::new();
    let mut rng = Pcg64::new(seed, 0x7e0);
    for &m in ms {
        // Fixed scalars: negatives of magnitude ~1, positives sized so the
        // sum is positive (the Rosenbrock eq. (11) structure).
        let negs = (m as f64 * neg_frac) as usize;
        let mut u = vec![0.0f64; m];
        let mut neg_sum = 0.0;
        for v in u.iter_mut().take(negs) {
            let mag = 0.5 + rng.f64();
            *v = -mag;
            neg_sum += mag;
        }
        let target = 1.0 + neg_sum;
        let pos = m - negs;
        for v in u.iter_mut().skip(negs) {
            *v = target / pos as f64;
        }
        for &b in budgets {
            let (p_bar, q_bar) = corollary1_rates(&u, b, 1.0);
            if q_bar <= p_bar {
                continue;
            }
            let emp = empirical_wrong_aggregation(&u, b, 1.0, trials, &mut rng);
            out.push(BoundCheck {
                m,
                budget: b,
                p_bar,
                q_bar,
                empirical: emp,
                bound: theorem1_bound(p_bar, q_bar, m),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_holds_across_sweep() {
        let checks = sweep(&[20, 50, 100, 200], &[0.05, 0.2, 0.5], 0.8, 4_000, 3);
        assert!(!checks.is_empty());
        for c in &checks {
            assert!(
                c.empirical <= c.bound + 0.02,
                "M={} B={}: empirical {:.4} exceeds bound {:.4}",
                c.m,
                c.budget,
                c.empirical,
                c.bound
            );
        }
    }

    #[test]
    fn bound_decreases_with_m() {
        let b1 = theorem1_bound(0.1, 0.3, 10);
        let b2 = theorem1_bound(0.1, 0.3, 100);
        assert!(b2 < b1);
    }

    #[test]
    fn corollary_rates_favor_majority_mass() {
        // 80% sign-flipped workers but positive total mass ⇒ q̄ > p̄ (the
        // magnitude-weighting at the heart of the paper).
        let mut u = vec![-0.5f64; 8];
        u.extend(vec![2.5f64; 2]); // sum = +1
        let (p, q) = corollary1_rates(&u, 0.2, 1.0);
        assert!(q > p, "q̄={q} p̄={p}");
    }

    #[test]
    fn deterministic_sign_violates_condition() {
        // With B→∞-style clipping (B huge) every worker transmits, so
        // p̄ ∝ count of wrong-sign workers — majority wrong ⇒ q̄ < p̄ and
        // Theorem 1 does not apply (exactly the signSGD failure).
        let mut u = vec![-0.5f64; 8];
        u.extend(vec![2.5f64; 2]);
        let (p, q) = corollary1_rates(&u, 1e9, 1.0);
        assert!(q < p, "clipped regime should favor the (wrong) majority");
    }

    #[test]
    #[should_panic(expected = "q̄ > p̄")]
    fn bound_requires_condition() {
        theorem1_bound(0.3, 0.2, 10);
    }
}
