//! Figures 1 & 2: probability of wrong aggregation + objective value on
//! the d=10 Rosenbrock function with the eq. (11) adversarial population
//! (80 of 100 workers see sign-flipped scaled objectives).

use crate::compressors::CompressorKind;
use crate::coordinator::{
    Algorithm, AggregationRule, RosenbrockEnv, TrainingRun,
};
use crate::model::rosenbrock::{Rosenbrock, ScaledObjectiveWorkers};
use crate::optim::LrSchedule;
use crate::util::rng::Pcg64;

/// One series of Fig. 1 / Fig. 2.
#[derive(Clone, Debug)]
pub struct RosenbrockSeries {
    pub label: String,
    /// Per-round fraction of coordinates whose aggregated sign disagrees
    /// with the true gradient sign (the paper's "probability of wrong
    /// aggregation").
    pub wrong_agg: Vec<f64>,
    /// Objective value F(w^{(t)}) per round.
    pub fvalue: Vec<f64>,
}

impl RosenbrockSeries {
    pub fn mean_wrong_agg(&self) -> f64 {
        crate::util::stats::mean(&self.wrong_agg)
    }

    pub fn final_value(&self) -> f64 {
        *self.fvalue.last().unwrap_or(&f64::NAN)
    }
}

/// Run one (compressor, participation) Rosenbrock series.
pub fn run_series(
    label: &str,
    compressor: CompressorKind,
    participation: f64,
    rounds: usize,
    lr: f64,
    seed: u64,
) -> RosenbrockSeries {
    let f = Rosenbrock::new(10);
    let mut rng = Pcg64::new(seed, 0x0f15);
    // Eq. (11) population: 80/100 sign-flipped workers with small
    // magnitude mass (see `generate_scaled` docs — the regime where the
    // magnitude information sparsign preserves identifies the truth).
    let env = RosenbrockEnv {
        f,
        scales: ScaledObjectiveWorkers::generate_scaled(100, 80, 0.01, &mut rng),
        noise_std: 0.0,
    };
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor,
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr },
        rounds,
        participation,
        eval_every: 1,
        seed,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let mut wrong_agg = Vec::with_capacity(rounds);
    let mut fvalue = Vec::with_capacity(rounds);
    let mut true_g = vec![0.0f32; 10];
    let mut probe = |_t: usize, params: &[f32], update: &[f32]| {
        env.f.grad(params, &mut true_g);
        let mut wrong = 0usize;
        let mut total = 0usize;
        for (u, g) in update.iter().zip(&true_g) {
            if *g != 0.0 {
                total += 1;
                // A zero aggregate (tie / all-sparsified) is not a *wrong*
                // direction; only an opposing sign counts, matching Thm 1's
                // event {sign(Σq̂) ≠ sign(Σu)} under the sign(0)=0 output.
                if *u != 0.0 && (*u > 0.0) != (*g > 0.0) {
                    wrong += 1;
                }
            }
        }
        wrong_agg.push(wrong as f64 / total.max(1) as f64);
        fvalue.push(env.f.value(params));
    };
    let eval = |p: &[f32]| (env.f.value(p), 0.0);
    // x0 = 0 (F(0) = d−1 = 9, the starting value visible in the paper's
    // Fig. 1 plot); gradients there are O(1), the regime where the B ∈
    // {0.01, 0.1} budgets operate below the Remark 7 clipping threshold.
    run.run_probed(&env, vec![0.0; 10], &eval, Some(&mut probe));
    RosenbrockSeries { label: label.to_string(), wrong_agg, fvalue }
}

/// Fig. 1: deterministic sign vs sparsign B ∈ {0.01, 0.1}; 10/100 workers
/// selected per round.
pub fn run_fig1(rounds: usize, lr: f64, seed: u64) -> Vec<RosenbrockSeries> {
    vec![
        run_series("Deterministic Sign", CompressorKind::Sign, 0.1, rounds, lr, seed),
        run_series(
            "sparsign B=0.01",
            CompressorKind::Sparsign { budget: 0.01 },
            0.1,
            rounds,
            lr,
            seed,
        ),
        run_series(
            "sparsign B=0.1",
            CompressorKind::Sparsign { budget: 0.1 },
            0.1,
            rounds,
            lr,
            seed,
        ),
    ]
}

/// Fig. 2: worker-sampling impact — sparsign B=0.01 at 5%/10%/50%
/// participation vs deterministic sign with full participation.
pub fn run_fig2(rounds: usize, lr: f64, seed: u64) -> Vec<RosenbrockSeries> {
    let mut out = vec![run_series(
        "Deterministic Sign (100%)",
        CompressorKind::Sign,
        1.0,
        rounds,
        lr,
        seed,
    )];
    for ps in [0.05, 0.10, 0.50] {
        out.push(run_series(
            &format!("sparsign B=0.01 ({}%)", (ps * 100.0) as u32),
            CompressorKind::Sparsign { budget: 0.01 },
            ps,
            rounds,
            lr,
            seed,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_reproduces() {
        // The paper's Fig. 1 headline: deterministic sign has wrong-agg
        // probability ≈ 1 and diverges; sparsign stays < 1/2 and makes
        // progress.
        let series = run_fig1(2_000, 0.01, 7);
        let sign = &series[0];
        let spar = &series[2]; // B = 0.1
        assert!(
            sign.mean_wrong_agg() > 0.9,
            "sign wrong-agg {:.3} should be ≈1",
            sign.mean_wrong_agg()
        );
        assert!(
            spar.mean_wrong_agg() < 0.5,
            "sparsign wrong-agg {:.3} should be < 1/2",
            spar.mean_wrong_agg()
        );
        let f0 = 9.0; // F(x0 = 0) with d = 10
        assert!(
            sign.final_value() > 10.0 * f0,
            "sign should diverge: {} vs start {}",
            sign.final_value(),
            f0
        );
        assert!(
            spar.final_value() < f0,
            "sparsign should descend: {} vs start {}",
            spar.final_value(),
            f0
        );
    }

    #[test]
    fn fig2_more_sampling_is_better() {
        let series = run_fig2(1_000, 0.01, 11);
        // Wrong-agg probability decreases as participation grows (Remark 3).
        let p5 = series[1].mean_wrong_agg();
        let p50 = series[3].mean_wrong_agg();
        assert!(
            p50 <= p5 + 0.02,
            "50% sampling ({p50:.3}) should not be worse than 5% ({p5:.3})"
        );
        // And all sparsign series stay below 1/2.
        for s in &series[1..] {
            assert!(s.mean_wrong_agg() < 0.5, "{}: {:.3}", s.label, s.mean_wrong_agg());
        }
    }
}
