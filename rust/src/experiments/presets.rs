//! Config builders for the paper's Tables 1–7 and Fig. 3.
//!
//! Every builder takes `paper_scale`: `false` (default) sizes the workload
//! for the single-core sandbox — same class counts, same algorithm
//! rosters, same heterogeneity protocol, smaller feature dims / sample
//! counts / round budgets; `true` reproduces the paper's configuration
//! verbatim (784/3072-dim inputs, M=100, 200–5000 rounds) for hardware
//! that can afford it. Accuracy *targets* differ between the scales
//! because the synthetic tasks saturate at different levels; the
//! comparison structure (who reaches the target first, at what uplink
//! cost) is scale-stable.

use crate::compressors::{CompressorKind, NormKind};
use crate::config::{ExperimentConfig, ScheduleKind, TaskSpec};
use crate::coordinator::{AggregationRule, Algorithm};
use crate::model::ModelKind;

/// The Table 1/2 algorithm roster (§6.2 + Appendix B), in paper order.
/// `sign_lr`/`mean_lr` are the tuned learning rates for the
/// majority-vote-updated rows (unit-magnitude steps) vs the mean-updated
/// unbiased rows (gradient-magnitude steps) — the paper likewise tunes η
/// per algorithm from a grid.
fn paper_roster(sign_lr: f64, mean_lr: f64, ef_lr: f64) -> (Vec<Algorithm>, Vec<Option<f64>>) {
    use AggregationRule::{MajorityVote, Mean};
    use CompressorKind::{NoisySign, Qsgd, Sign, Sparsign, TernGrad};
    let rows: Vec<(Algorithm, f64)> = vec![
        (
            Algorithm::CompressedGd { compressor: Sign, aggregation: MajorityVote },
            sign_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: CompressorKind::ScaledSign,
                aggregation: Mean,
            },
            mean_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: NoisySign { noise_std: 0.01 },
                aggregation: MajorityVote,
            },
            sign_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: Qsgd { levels: 1, norm: NormKind::L2 },
                aggregation: Mean,
            },
            mean_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: Qsgd { levels: 1, norm: NormKind::Linf },
                aggregation: Mean,
            },
            mean_lr,
        ),
        (
            Algorithm::CompressedGd { compressor: TernGrad, aggregation: Mean },
            mean_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: Sparsign { budget: 1.0 },
                aggregation: MajorityVote,
            },
            sign_lr,
        ),
        (
            Algorithm::EfSparsign {
                b_local: 10.0,
                b_global: 1.0,
                tau: 1,
                server_lr_scale: None,
                server_ef: true,
            },
            ef_lr,
        ),
    ];
    let lrs = rows.iter().map(|(_, lr)| Some(*lr)).collect();
    (rows.into_iter().map(|(a, _)| a).collect(), lrs)
}

/// Table 1: Fashion-MNIST, α = 0.1, full participation, MLP (§C.2).
pub fn table1_config(paper_scale: bool) -> ExperimentConfig {
    let (algorithms, lr_overrides) = paper_roster(0.01, 0.5, 0.05);
    if paper_scale {
        ExperimentConfig {
            name: "Table 1: Fashion-MNIST (alpha=0.1)".into(),
            task: TaskSpec::FmnistLike,
            alpha: 0.1,
            workers: 100,
            participation: 1.0,
            model: ModelKind::paper_fmnist_mlp(10),
            algorithms,
            lr_overrides,
            rounds: 200,
            batch: 128,
            eval_every: 1,
            seeds: vec![0, 1, 2],
            lr: 0.005,
            schedule: ScheduleKind::Const,
            targets: vec![0.74],
            data_scale: 1.0,
            dim_override: None,
        }
    } else {
        ExperimentConfig {
            name: "Table 1 (fast): fmnist-like (alpha=0.1)".into(),
            task: TaskSpec::Custom { dim: 256, classes: 10, train: 6_000, test: 1_500 },
            alpha: 0.1,
            workers: 30,
            participation: 1.0,
            model: ModelKind::Mlp { inputs: 256, hidden: vec![64], classes: 10 },
            algorithms,
            lr_overrides,
            rounds: 300,
            batch: 32,
            eval_every: 10,
            seeds: vec![0, 1],
            lr: 0.01,
            schedule: ScheduleKind::Const,
            targets: vec![0.45, 0.55],
            data_scale: 1.0,
            dim_override: None,
        }
    }
}

/// Table 2: CIFAR-10, α = 0.5, 20% participation.
pub fn table2_config(paper_scale: bool) -> ExperimentConfig {
    let (algorithms, lr_overrides) = if paper_scale {
        paper_roster(0.005, 0.1, 0.01)
    } else {
        paper_roster(0.01, 0.5, 0.05)
    };
    if paper_scale {
        ExperimentConfig {
            name: "Table 2: CIFAR-10 (alpha=0.5, 20% participation)".into(),
            task: TaskSpec::Cifar10Like,
            alpha: 0.5,
            workers: 100,
            participation: 0.2,
            model: ModelKind::Mlp { inputs: 3072, hidden: vec![512, 256], classes: 10 },
            algorithms,
            lr_overrides,
            rounds: 3_000,
            batch: 32,
            eval_every: 25,
            seeds: vec![0, 1, 2],
            lr: 0.005,
            schedule: ScheduleKind::PaperCifar10,
            targets: vec![0.55, 0.74],
            data_scale: 1.0,
            dim_override: None,
        }
    } else {
        ExperimentConfig {
            name: "Table 2 (fast): cifar10-like (alpha=0.5, 20% participation)".into(),
            task: TaskSpec::Custom { dim: 384, classes: 10, train: 6_000, test: 1_500 },
            alpha: 0.5,
            workers: 50,
            participation: 0.2,
            model: ModelKind::Mlp { inputs: 384, hidden: vec![96], classes: 10 },
            algorithms,
            lr_overrides,
            rounds: 400,
            batch: 32,
            eval_every: 10,
            seeds: vec![0, 1],
            lr: 0.01,
            schedule: ScheduleKind::Const,
            targets: vec![0.45, 0.55],
            data_scale: 1.0,
            dim_override: None,
        }
    }
}

/// Table 3 / Fig. 3 roster: EF-SPARSIGNSGD vs FedCom, τ ∈ {5, 10, 20}.
fn local_update_roster() -> (Vec<Algorithm>, Vec<Option<f64>>) {
    let taus = [5usize, 10, 20];
    let mut algorithms = Vec::new();
    let mut lrs = Vec::new();
    for &tau in &taus {
        algorithms.push(Algorithm::FedCom { tau, levels: 255 });
        lrs.push(Some(0.05));
    }
    for &tau in &taus {
        algorithms.push(Algorithm::EfSparsign {
            b_local: 10.0,
            b_global: 1.0,
            tau,
            server_lr_scale: None,
            server_ef: true,
        });
        lrs.push(Some(0.002));
    }
    (algorithms, lrs)
}

/// Table 3: CIFAR-10, α = 0.5 — impact of local steps.
pub fn table3_config(paper_scale: bool) -> ExperimentConfig {
    let (algorithms, lr_overrides) = local_update_roster();
    let mut cfg = table2_config(paper_scale);
    cfg.name = if paper_scale {
        "Table 3: CIFAR-10 local steps (alpha=0.5)".into()
    } else {
        "Table 3 (fast): cifar10-like local steps (alpha=0.5)".into()
    };
    cfg.algorithms = algorithms;
    cfg.lr_overrides = lr_overrides;
    if !paper_scale {
        cfg.rounds = 150;
        cfg.eval_every = 5;
        cfg.seeds = vec![0];
    }
    cfg
}

/// Fig. 3 uses the Table 3 sweep's eval curves (accuracy vs rounds and vs
/// uplink bits).
pub fn fig3_config(paper_scale: bool) -> ExperimentConfig {
    let mut cfg = table3_config(paper_scale);
    cfg.name = cfg.name.replace("Table 3", "Fig. 3");
    cfg
}

/// Tables 4–7: CIFAR-100 across α ∈ {0.1, 0.3, 0.6, 1.0}.
pub fn tables4_7_configs(paper_scale: bool, alphas: &[f64]) -> Vec<ExperimentConfig> {
    alphas
        .iter()
        .map(|&alpha| {
            let (algorithms, lr_overrides) = local_update_roster();
            if paper_scale {
                ExperimentConfig {
                    name: format!("Tables 4-7: CIFAR-100 (alpha={alpha})"),
                    task: TaskSpec::Cifar100Like,
                    alpha,
                    workers: 100,
                    participation: 0.2,
                    model: ModelKind::Mlp {
                        inputs: 3072,
                        hidden: vec![1024, 1024],
                        classes: 100,
                    },
                    algorithms,
                    lr_overrides,
                    rounds: 5_000,
                    batch: 32,
                    eval_every: 25,
                    seeds: vec![0, 1, 2],
                    lr: 0.005,
                    schedule: ScheduleKind::PaperCifar100,
                    targets: vec![0.40],
                    data_scale: 1.0,
                    dim_override: None,
                }
            } else {
                ExperimentConfig {
                    name: format!("Tables 4-7 (fast): cifar100-like (alpha={alpha})"),
                    task: TaskSpec::Custom {
                        dim: 256,
                        classes: 100,
                        train: 8_000,
                        test: 2_000,
                    },
                    alpha,
                    workers: 40,
                    participation: 0.25,
                    model: ModelKind::Mlp { inputs: 256, hidden: vec![96], classes: 100 },
                    algorithms,
                    lr_overrides,
                    rounds: 200,
                    batch: 32,
                    eval_every: 10,
                    seeds: vec![0],
                    lr: 0.01,
                    schedule: ScheduleKind::Const,
                    targets: vec![0.08],
                    data_scale: 1.0,
                    dim_override: None,
                }
            }
        })
        .collect()
}

/// The Byzantine-robustness roster (DESIGN.md §13, EXPERIMENTS.md attack
/// tables): the paper's Remark 2(4) claim is that majority-vote sparsign
/// caps a malicious worker's influence at ±1 per coordinate, while
/// magnitude-sharing compressors aggregated by mean (TernGrad, QSGD) hand
/// an attacker the whole update norm. Rows pair each family with its
/// aggregation rule under identical attacks.
fn robustness_roster(sign_lr: f64, mean_lr: f64) -> (Vec<Algorithm>, Vec<Option<f64>>) {
    use AggregationRule::{MajorityVote, Mean};
    use CompressorKind::{Qsgd, Sign, Sparsign, TernGrad};
    let rows: Vec<(Algorithm, f64)> = vec![
        (
            Algorithm::CompressedGd { compressor: Sign, aggregation: MajorityVote },
            sign_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: Sparsign { budget: 1.0 },
                aggregation: MajorityVote,
            },
            sign_lr,
        ),
        (
            Algorithm::CompressedGd { compressor: TernGrad, aggregation: Mean },
            mean_lr,
        ),
        (
            Algorithm::CompressedGd {
                compressor: Qsgd { levels: 1, norm: NormKind::L2 },
                aggregation: Mean,
            },
            mean_lr,
        ),
    ];
    let lrs = rows.iter().map(|(_, lr)| Some(*lr)).collect();
    (rows.into_iter().map(|(a, _)| a).collect(), lrs)
}

/// Convergence-under-attack sweep: colluding sign-flip cohorts at
/// increasing fractions, plus a scale-inflation cohort (the attack
/// Remark 2(4) singles out). One config per attack spec, shared roster,
/// so each rendered table is a column of the EXPERIMENTS.md §"attack
/// tables" grid.
pub fn attack_sweep_configs(paper_scale: bool) -> Vec<ExperimentConfig> {
    let specs: &[&str] = &[
        "collusive:10%",
        "collusive:20%",
        "collusive:30%",
        "rescale:20%:1e4",
        "signflip:20%",
    ];
    specs
        .iter()
        .map(|&spec| {
            let (algorithms, lr_overrides) = robustness_roster(0.01, 0.5);
            let mut cfg = table1_config(paper_scale);
            cfg.name = if paper_scale {
                format!("Attack sweep: Fashion-MNIST under {spec}")
            } else {
                format!("Attack sweep (fast): fmnist-like under {spec}")
            };
            cfg.algorithms = algorithms;
            cfg.lr_overrides = lr_overrides;
            cfg.attack = Some(spec.to_string());
            if !paper_scale {
                cfg.rounds = 200;
                cfg.seeds = vec![0, 1];
            }
            cfg
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attack_sweep_covers_collusion_fractions_and_rescale() {
        let cfgs = attack_sweep_configs(false);
        assert_eq!(cfgs.len(), 5);
        for cfg in &cfgs {
            cfg.validate().unwrap();
            assert!(cfg.attack.is_some());
            let labels: Vec<String> = cfg.algorithms.iter().map(|a| a.label()).collect();
            assert!(labels.iter().any(|l| l.contains("sparsignSGD")));
            assert!(labels.iter().any(|l| l.contains("TernGrad")));
        }
        assert_eq!(cfgs[2].attack.as_deref(), Some("collusive:30%"));
        assert!(cfgs[3].attack.as_deref().unwrap().starts_with("rescale"));
    }

    #[test]
    fn all_presets_validate() {
        for paper in [false, true] {
            table1_config(paper).validate().unwrap();
            table2_config(paper).validate().unwrap();
            table3_config(paper).validate().unwrap();
            fig3_config(paper).validate().unwrap();
            for c in tables4_7_configs(paper, &[0.1, 0.3, 0.6, 1.0]) {
                c.validate().unwrap();
            }
        }
    }

    #[test]
    fn table1_roster_matches_paper_rows() {
        let cfg = table1_config(true);
        let labels: Vec<String> = cfg.algorithms.iter().map(|a| a.label()).collect();
        assert_eq!(labels.len(), 8);
        assert!(labels[0].contains("signSGD"));
        assert!(labels[3].contains("L2 norm QSGD"));
        assert!(labels[4].contains("Linf norm QSGD"));
        assert!(labels[5].contains("TernGrad"));
        assert!(labels[6].contains("sparsignSGD(B=1)"));
        assert!(labels[7].contains("EF-sparsignSGD"));
        assert_eq!(cfg.workers, 100);
        assert_eq!(cfg.batch, 128);
        assert_eq!(cfg.rounds, 200);
    }

    #[test]
    fn table3_has_both_families_across_taus() {
        let cfg = table3_config(false);
        let labels: Vec<String> = cfg.algorithms.iter().map(|a| a.label()).collect();
        for tau in [5, 10, 20] {
            assert!(labels.iter().any(|l| l == &format!("FedCom-Local{tau}(8bit)")));
            assert!(labels.iter().any(|l| l.contains(&format!("tau={tau}"))));
        }
    }

    #[test]
    fn paper_scale_matches_paper_dimensions() {
        let t2 = table2_config(true);
        assert_eq!(t2.rounds, 3_000);
        assert_eq!(t2.participation, 0.2);
        let t47 = tables4_7_configs(true, &[0.1]);
        assert_eq!(t47[0].rounds, 5_000);
        assert_eq!(t47[0].task, TaskSpec::Cifar100Like);
    }
}
