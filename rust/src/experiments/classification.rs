//! Shared classification-experiment runner: config → task → partition →
//! (algorithm × seed) sweep → paper-style table + figure series.

use crate::config::ExperimentConfig;
use crate::coordinator::{ClassifierEnv, RunHistory, TrainingRun};
use crate::data::{partition_report, DirichletPartitioner, SyntheticTask};
use crate::metrics::{RunSummary, TablePrinter};
use crate::model::ModelKind;
use crate::util::rng::Pcg64;

/// Output of one experiment sweep.
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    pub title: String,
    pub summaries: Vec<RunSummary>,
    /// Per-algorithm eval curves `(round, acc, cum_uplink_bits)` from the
    /// first seed (the Fig. 3 series).
    pub series: Vec<(String, Vec<(usize, f64, f64)>)>,
    /// Heterogeneity diagnostics of the generated partition.
    pub mean_max_class_fraction: f64,
    rendered: String,
}

impl ExperimentReport {
    /// The rendered paper-style table.
    pub fn table(&self) -> &str {
        &self.rendered
    }
}

/// Build the environment a config describes (deterministic in
/// `cfg` + `seed`).
pub fn build_env(cfg: &ExperimentConfig, data_seed: u64) -> ClassifierEnv {
    let mut spec = cfg.task.synthetic_spec().scaled(cfg.data_scale);
    if let Some(dim) = cfg.dim_override {
        spec = spec.with_dim(dim);
    }
    let task = SyntheticTask::generate(spec, data_seed);
    let mut prng = Pcg64::new(data_seed, 0x9a27);
    let fed = DirichletPartitioner { alpha: cfg.alpha, workers: cfg.workers }
        .partition(&task.train, &mut prng);
    let model = build_model(&cfg.model);
    ClassifierEnv::new(model, task.train, task.test, fed, cfg.batch)
}

/// Build a model from config, loading AOT artifacts when asked.
pub fn build_model(kind: &ModelKind) -> Box<dyn crate::model::Model> {
    match kind {
        ModelKind::Hlo { artifact, inputs, classes } => {
            let runtime = std::rc::Rc::new(
                crate::runtime::Runtime::cpu("artifacts")
                    .expect("artifacts/ missing — run `make artifacts`"),
            );
            // Hidden widths for the shipped artifacts (layout contract with
            // python/compile/aot.py).
            let hidden = match artifact.as_str() {
                "mlp_fmnist" => vec![256, 128],
                "mlp_small" => vec![32],
                other => panic!("unknown HLO artifact stem '{other}'"),
            };
            Box::new(
                crate::runtime::HloModel::load(
                    runtime,
                    artifact,
                    *inputs,
                    hidden,
                    *classes,
                )
                .expect("loading HLO model"),
            )
        }
        other => other.build(),
    }
}

/// Run the full sweep a config describes.
pub fn run_classification(cfg: &ExperimentConfig) -> ExperimentReport {
    run_classification_with(cfg, &|seed| build_env(cfg, seed ^ 0xda7a))
}

/// [`run_classification`] with a caller-supplied environment builder
/// (called once per seed with the run seed). The synthetic path folds the
/// seed into the generator; the store-backed paper-parity runner plugs in
/// [`ClassifierEnv::from_store`] here and ignores it — the dataset and
/// partition are pinned by the `.sgds` file, only init/sampling re-roll.
pub fn run_classification_with(
    cfg: &ExperimentConfig,
    build: &dyn Fn(u64) -> ClassifierEnv,
) -> ExperimentReport {
    cfg.validate().unwrap_or_else(|e| panic!("invalid config '{}': {e}", cfg.name));
    let mut table = TablePrinter::new(
        format!(
            "{} (task={}, α={}, M={}, p_s={}, {} rounds)",
            cfg.name,
            cfg.task.label(),
            cfg.alpha,
            cfg.workers,
            cfg.participation,
            cfg.rounds
        ),
        &[
            "Algorithm",
            "Final accuracy",
            &format!(
                "Rounds to {}",
                cfg.targets
                    .iter()
                    .map(|t| format!("{}%", (t * 100.0) as u32))
                    .collect::<Vec<_>>()
                    .join("/")
            ),
            "Uplink bits to target",
        ],
    );
    let mut summaries = Vec::new();
    let mut series = Vec::new();
    let mut hetero = 0.0;
    for (ai, alg) in cfg.algorithms.iter().enumerate() {
        let lr = cfg
            .lr_overrides
            .get(ai)
            .copied()
            .flatten()
            .unwrap_or(cfg.lr);
        let mut runs: Vec<RunHistory> = Vec::with_capacity(cfg.seeds.len());
        for &seed in &cfg.seeds {
            let env = build(seed);
            if runs.is_empty() {
                let rep = partition_report(&env.train, &env.fed);
                hetero = rep.mean_max_fraction;
            }
            let mut init_rng = Pcg64::new(seed, 0x1217);
            let init = env.init_params(&mut init_rng);
            let run = TrainingRun {
                algorithm: alg.clone(),
                schedule: cfg.schedule.build(lr),
                rounds: cfg.rounds,
                participation: cfg.participation,
                eval_every: cfg.eval_every,
                seed,
                // Cohort membership re-rolls per seed so the sweep's mean
                // does not hinge on which data shards the attacker drew.
                attack: cfg.attack.as_deref().map(|spec| {
                    crate::coordinator::AttackPlan::parse(spec, cfg.workers, seed)
                        .unwrap_or_else(|e| panic!("invalid attack spec '{spec}': {e}"))
                }),
                selection: cfg.selection,
                allow_stateful_with_sampling: false,
                // HLO-backed models run on the Rc/RefCell PJRT cache,
                // which is single-threaded by contract; pure-rust models
                // get the full parallel round engine.
                threads: if matches!(cfg.model, crate::model::ModelKind::Hlo { .. }) {
                    Some(1)
                } else {
                    None
                },
            };
            runs.push(run.run(&env, init, &|p| env.evaluate(p)));
        }
        let summary = RunSummary::from_runs(&runs, &cfg.targets);
        table.add_summary(&summary);
        series.push((summary.label.clone(), runs[0].eval_series()));
        summaries.push(summary);
    }
    let rendered = table.render();
    ExperimentReport {
        title: cfg.name.clone(),
        summaries,
        series,
        mean_max_class_fraction: hetero,
        rendered,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_preset_end_to_end() {
        let mut cfg = ExperimentConfig::fast_preset();
        cfg.seeds = vec![0];
        let report = run_classification(&cfg);
        assert_eq!(report.summaries.len(), cfg.algorithms.len());
        assert!(report.table().contains("Algorithm"));
        assert!(report.mean_max_class_fraction > 0.0);
        // All three core algorithms learn the fast task.
        for s in &report.summaries {
            assert!(s.final_acc_mean > 0.45, "{}: {}", s.label, s.final_acc_mean);
        }
        // Series align with summaries.
        assert_eq!(report.series.len(), report.summaries.len());
        assert!(!report.series[0].1.is_empty());
    }
}
