//! Paper-parity runner: the accuracy-vs-communication experiments on
//! **real datasets** streamed from an `.sgds` store, reported against the
//! paper's published targets (EXPERIMENTS.md §Paper-parity keeps the
//! running table; the CI `dataset-parity` job asserts the committed
//! accuracy floor on Fashion-MNIST).
//!
//! The reproduction protocol per dataset is exactly the preset configs —
//! Table 1 (Fashion-MNIST, α=0.1, M=100, 200 rounds, batch 128, constant
//! LR), Table 2 (CIFAR-10, α=0.5, 20% participation, 3000 rounds,
//! [`crate::optim::LrSchedule::paper_cifar10`]), Tables 4–7 (CIFAR-100,
//! 5000 rounds, [`crate::optim::LrSchedule::paper_cifar100`]) — with the
//! dataset, partition, and heterogeneity pinned by the store manifest
//! rather than re-rolled per seed: only model init and batch sampling
//! vary across seeds, matching how the paper re-runs on a fixed split.

use crate::config::ExperimentConfig;
use crate::coordinator::ClassifierEnv;
use crate::data::ShardStore;
use crate::experiments::classification::run_classification_with;
use crate::experiments::{table1_config, table2_config, tables4_7_configs, ExperimentReport};
use crate::metrics::TablePrinter;
use crate::model::ModelKind;

/// The paper's headline accuracy target for a dataset — the top target the
/// preset configs commit to (Table 1 / Table 2 / Tables 4–7) — plus the
/// table it comes from.
pub fn paper_reference(dataset: &str) -> Option<(&'static str, f64)> {
    match dataset {
        "fmnist" => Some(("Table 1", 0.74)),
        "cifar10" => Some(("Table 2", 0.74)),
        "cifar100" => Some(("Tables 4-7", 0.40)),
        _ => None,
    }
}

/// The paper-scale protocol config for a dataset (roster, rounds, batch,
/// LR schedule, targets). The caller may shrink rounds/seeds/roster for
/// short-horizon CI runs; the dataset/partition fields are overridden by
/// the store at run time.
pub fn parity_config(dataset: &str) -> Result<ExperimentConfig, String> {
    match dataset {
        "fmnist" => Ok(table1_config(true)),
        "cifar10" => Ok(table2_config(true)),
        "cifar100" => Ok(tables4_7_configs(true, &[0.3]).remove(0)),
        other => Err(format!("unknown parity dataset '{other}' (fmnist|cifar10|cifar100)")),
    }
}

/// Keep only the roster rows whose label contains one of `patterns`
/// (case-sensitive substring match) — how CI trims the 8-row paper roster
/// to a short-horizon subset. Errors if nothing survives.
pub fn retain_algorithms(cfg: &mut ExperimentConfig, patterns: &[&str]) -> Result<(), String> {
    let keep: Vec<bool> = cfg
        .algorithms
        .iter()
        .map(|a| {
            let label = a.label();
            patterns.iter().any(|p| label.contains(p))
        })
        .collect();
    if !keep.iter().any(|&k| k) {
        return Err(format!("no roster row matches {patterns:?}"));
    }
    let mut it = keep.iter();
    cfg.algorithms.retain(|_| *it.next().unwrap());
    if !cfg.lr_overrides.is_empty() {
        let mut it = keep.iter();
        cfg.lr_overrides.retain(|_| *it.next().unwrap());
    }
    Ok(())
}

/// Outcome of a parity run: the standard sweep report plus the
/// ours-vs-paper table and the best final accuracy (what the CI floor
/// gates on).
pub struct ParityOutcome {
    pub report: ExperimentReport,
    /// Rendered "ours vs paper" table for EXPERIMENTS.md.
    pub parity_table: String,
    /// Best final accuracy across roster rows (mean over seeds).
    pub best_acc: f64,
}

/// Run the parity sweep for `cfg` over an open store. `hidden` selects the
/// model: empty ⇒ linear softmax, otherwise an MLP with those widths
/// (input/class dims always come from the store).
pub fn run_parity(
    store: &ShardStore,
    mut cfg: ExperimentConfig,
    dataset: &str,
    hidden: &[usize],
) -> ParityOutcome {
    let info = store.info();
    cfg.model = if hidden.is_empty() {
        ModelKind::Linear { inputs: store.dim(), classes: store.classes() }
    } else {
        ModelKind::Mlp { inputs: store.dim(), hidden: hidden.to_vec(), classes: store.classes() }
    };
    // Partition fields travel with the store; mirror them into the config
    // so titles and attack-plan population sizes agree with the env.
    cfg.alpha = info.alpha;
    cfg.workers = info.clients;
    let model = cfg.model.clone();
    let batch = cfg.batch;
    let report = run_classification_with(&cfg, &|_seed| {
        ClassifierEnv::from_store(store, model.build(), batch)
    });

    let (table_name, target) = paper_reference(dataset).unwrap_or(("?", f64::NAN));
    let mut table = TablePrinter::new(
        format!(
            "Paper parity: {dataset} ({} clients, alpha={}, {} rounds, batch {})",
            info.clients, info.alpha, cfg.rounds, cfg.batch
        ),
        &["Algorithm", "Final acc (ours)", &format!("Paper target ({table_name})"), "Delta"],
    );
    let mut best_acc = 0.0f64;
    for s in &report.summaries {
        best_acc = best_acc.max(s.final_acc_mean);
        table.add_row(vec![
            s.label.clone(),
            format!("{:.4}", s.final_acc_mean),
            format!("{target:.2}"),
            format!("{:+.4}", s.final_acc_mean - target),
        ]);
    }
    ParityOutcome { report, parity_table: table.render(), best_acc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{write_store, DirichletPartitioner, SyntheticSpec, SyntheticTask};
    use crate::util::rng::Pcg64;

    #[test]
    fn parity_configs_resolve_and_validate() {
        for d in ["fmnist", "cifar10", "cifar100"] {
            let cfg = parity_config(d).unwrap();
            cfg.validate().unwrap();
            assert!(paper_reference(d).is_some());
        }
        assert!(parity_config("mnist-ception").is_err());
    }

    #[test]
    fn retain_algorithms_trims_roster_and_lrs() {
        let mut cfg = parity_config("fmnist").unwrap();
        let before = cfg.algorithms.len();
        retain_algorithms(&mut cfg, &["sparsignSGD"]).unwrap();
        assert!(!cfg.algorithms.is_empty() && cfg.algorithms.len() < before);
        assert_eq!(cfg.lr_overrides.len(), cfg.algorithms.len());
        cfg.validate().unwrap();
        assert!(retain_algorithms(&mut cfg, &["no-such-algorithm"]).is_err());
    }

    #[test]
    fn short_horizon_parity_learns_on_a_store() {
        // End-to-end: synthetic task → .sgds → store-backed parity sweep.
        let task = SyntheticTask::generate(
            SyntheticSpec { train: 600, test: 120, ..SyntheticSpec::fmnist_like().with_dim(24) },
            13,
        );
        let fed = DirichletPartitioner { alpha: 0.5, workers: 12 }
            .partition_exact(&task.train, &mut Pcg64::seed_from(2));
        let dir = std::env::temp_dir().join(format!("sgds_parity_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.sgds");
        write_store(&path, &task.train, &task.test, &fed, 0.5, 2).unwrap();
        let store = ShardStore::open(&path).unwrap();

        let mut cfg = parity_config("fmnist").unwrap();
        retain_algorithms(&mut cfg, &["sparsignSGD(B=1)"]).unwrap();
        cfg.rounds = 60;
        cfg.eval_every = 10;
        cfg.seeds = vec![0];
        cfg.batch = 16;
        let out = run_parity(&store, cfg, "fmnist", &[]);
        assert!(out.parity_table.contains("Paper target"));
        assert!(
            out.best_acc > 0.25,
            "store-backed run should beat 10-class chance: {}",
            out.best_acc
        );
        std::fs::remove_file(&path).unwrap();
        let _ = std::fs::remove_dir(&dir);
    }
}
