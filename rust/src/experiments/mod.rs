//! Experiment harnesses — one per paper table/figure (see DESIGN.md §5 for
//! the index). Each harness:
//!
//! 1. generates the workload (synthetic task + Dirichlet(α) partition, or
//!    the §6.1 scaled-objective Rosenbrock population),
//! 2. runs every algorithm row over the configured seeds,
//! 3. prints the paper-style table / emits the figure series as CSV.
//!
//! Sizes default to the `fast` presets tuned for this single-core sandbox;
//! `--paper-scale` switches to the paper's full configuration (same code
//! path, more compute). The *shape* of the results — which algorithm wins,
//! whether signSGD collapses under heterogeneity, the bits-to-target
//! ordering — is the reproduction target (DESIGN.md §3).

pub mod ablations;
pub mod classification;
pub mod parity;
mod presets;
mod rosenbrock;
pub mod theory;

pub use classification::{
    build_env, run_classification, run_classification_with, ExperimentReport,
};
pub use parity::{paper_reference, parity_config, retain_algorithms, run_parity, ParityOutcome};
pub use presets::{
    attack_sweep_configs, fig3_config, table1_config, table2_config, table3_config,
    tables4_7_configs,
};
pub use rosenbrock::{run_fig1, run_fig2, RosenbrockSeries};
