//! Summary statistics used by the experiment harnesses (mean ± std over
//! seeds, exactly the format the paper's tables report).

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Median (averages the middle pair for even n; 0.0 for empty).
///
/// Sorts with `total_cmp`, so a NaN in a metric series (a diverged run's
/// loss, a 0/0 accuracy) can no longer panic the reporting path the way
/// `partial_cmp().unwrap()` did. Under the IEEE total order NaNs sort to
/// the *extremes* — sign-bit-set NaNs (e.g. x86's 0.0/0.0) before
/// `-inf`, positive NaNs after `+inf` — so a NaN minority skews which
/// finite element is picked rather than crashing.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Running mean/variance accumulator (Welford). Numerically stable for the
/// long metric streams the coordinator emits.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Format `mean ± std` in the paper's percentage style, e.g. `79.05±0.39%`.
pub fn fmt_pct(mean: f64, std: f64) -> String {
    format!("{:.2}±{:.2}%", 100.0 * mean, 100.0 * std)
}

/// Format a bit count in the paper's scientific style, e.g. `8.19e5`.
pub fn fmt_bits(bits: f64) -> String {
    if bits <= 0.0 {
        return "0".to_string();
    }
    let exp = bits.log10().floor();
    let mant = bits / 10f64.powf(exp);
    format!("{:.2}e{}", mant, exp as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert!((sem(&xs) - 1.2909944 / 2.0).abs() < 1e-6);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn median_survives_nan_inputs() {
        // A NaN in the series must not panic. total_cmp sends positive
        // NaNs past +inf…
        assert_eq!(median(&[3.0, f64::NAN, 1.0, 2.0, f64::NAN]), 3.0);
        assert_eq!(median(&[f64::NAN, 1.0, 5.0]), 5.0);
        // …and sign-bit-set NaNs (what 0.0/0.0 produces on x86) below
        // -inf, shifting the pick the other way — still no panic.
        assert_eq!(median(&[-f64::NAN, 1.0, 5.0]), 1.0);
        // All-NaN input degrades to NaN rather than panicking.
        assert!(median(&[f64::NAN, f64::NAN]).is_nan());
        // Mixed infinities keep their total order.
        assert_eq!(median(&[f64::INFINITY, 0.0, f64::NEG_INFINITY]), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [0.5, 1.5, -2.0, 3.25, 8.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_pct(0.7905, 0.0039), "79.05±0.39%");
        assert_eq!(fmt_bits(8.19e5), "8.19e5");
        assert_eq!(fmt_bits(0.0), "0");
        assert_eq!(fmt_bits(1.0), "1.00e0");
    }
}
