//! Deterministic pseudo-random number generation and the distributions the
//! paper's experiments need.
//!
//! The core generator is PCG-XSL-RR 128/64 (O'Neill 2014) — a small, fast,
//! statistically strong PRNG with cheap jump-ahead via stream selection.
//! On top of it we provide the distributions used across the stack:
//!
//! * `Uniform`  — worker sampling, sparsign Bernoulli draws, QSGD levels.
//! * `Normal`   — Gaussian-mixture synthetic data, noisy signSGD, init.
//! * `Gamma`    — Marsaglia–Tsang, the building block for `Dirichlet`.
//! * `Dirichlet`— the Hsu et al. (2019) non-IID label-skew partitioner.
//!
//! Determinism contract: every component of the system derives its RNG from
//! an experiment seed via [`Pcg64::derive`], so entire federated runs replay
//! bit-exactly — the property-test suite depends on this.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Distinct streams are
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        // Extra scrambling so nearby seeds decorrelate quickly.
        for _ in 0..4 {
            rng.step();
        }
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seed_from(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator, labelled by `tag`. Used to
    /// hand every worker / round / module its own stream from one
    /// experiment seed.
    pub fn derive(&self, tag: u64) -> Pcg64 {
        // Mix the tag through splitmix64 so sequential tags give unrelated
        // streams.
        let mixed = splitmix64(tag ^ 0x9e37_79b9_7f4a_7c15);
        Pcg64::new(self.state as u64 ^ mixed, (self.state >> 64) as u64 ^ tag)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1) with 24 random bits.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Bernoulli draw with probability `p` (clamped to [0,1]).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        if p >= 1.0 {
            return true;
        }
        if p <= 0.0 {
            return false;
        }
        self.f64() < p
    }

    /// Both Box–Muller variates at once — §Perf fast path for bulk
    /// Gaussian noise (one ln/sqrt pair per two outputs).
    pub fn normal_pair(&mut self) -> (f64, f64) {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
                return (r * c, r * s);
            }
        }
    }

    /// Standard normal via Box–Muller (cos branch).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean / standard deviation, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill `out` with N(mean, std²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Fill `out` with U[0,1) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.f32();
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); the shape<1 case uses the
    /// standard boosting identity Gamma(a) = Gamma(a+1) * U^{1/a}.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive, got {shape}");
        if shape < 1.0 {
            let boost = self.f64().max(1e-300).powf(1.0 / shape);
            return self.gamma(shape + 1.0) * boost;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(α·1) sample of length `k`: normalized Gamma(α,1) draws.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        assert!(k > 0);
        let mut draws: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = draws.iter().sum();
        for d in draws.iter_mut() {
            *d /= sum;
        }
        draws
    }

    /// Draw an index from the categorical distribution given by `probs`
    /// (assumed to sum to ≈1; remainder mass lands on the last index).
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let u = self.f64();
        let mut cum = 0.0;
        for (i, p) in probs.iter().enumerate() {
            cum += p;
            if u < cum {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Export the generator's raw LCG state as four words
    /// (`[state_lo, state_hi, inc_lo, inc_hi]`) — the coordinator
    /// snapshot codec serializes the server-side selection stream this
    /// way so a resumed run continues the exact sequence
    /// (DESIGN.md §12).
    pub fn to_raw(&self) -> [u64; 4] {
        [
            self.state as u64,
            (self.state >> 64) as u64,
            self.inc as u64,
            (self.inc >> 64) as u64,
        ]
    }

    /// Rebuild a generator from [`Self::to_raw`] words. Returns `None`
    /// when the increment is even — every reachable PCG stream has an
    /// odd increment, so an even one can only come from a corrupt or
    /// hostile snapshot.
    pub fn from_raw(raw: [u64; 4]) -> Option<Pcg64> {
        if raw[2] & 1 == 0 {
            return None;
        }
        Some(Pcg64 {
            state: (raw[0] as u128) | ((raw[1] as u128) << 64),
            inc: (raw[2] as u128) | ((raw[3] as u128) << 64),
        })
    }

    /// Sample `k` distinct indices uniformly from `[0, n)` (partial
    /// Fisher–Yates; O(n) memory, O(k) swaps). Sorted output for
    /// reproducible iteration order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} of {n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            pool.swap(i, j);
        }
        let mut out = pool[..k].to_vec();
        out.sort_unstable();
        out
    }
}

/// A buffered stream of u32s over a [`Pcg64`]: one `next_u64` feeds two
/// draws. This is the §Perf fast path for the per-coordinate Bernoulli
/// tests in the ternary compressors — `value < threshold` against a
/// precomputed 2³²-scaled threshold replaces an f32 conversion + compare,
/// and halves the RNG work.
pub struct U32Stream<'a> {
    rng: &'a mut Pcg64,
    buf: u64,
    have: bool,
}

impl<'a> U32Stream<'a> {
    pub fn new(rng: &'a mut Pcg64) -> Self {
        Self { rng, buf: 0, have: false }
    }

    /// Next uniform u32.
    #[inline]
    pub fn next(&mut self) -> u32 {
        if self.have {
            self.have = false;
            (self.buf >> 32) as u32
        } else {
            self.buf = self.rng.next_u64();
            self.have = true;
            self.buf as u32
        }
    }

    /// Bernoulli draw against an f32 threshold scaled by 2³² (use
    /// [`bernoulli_threshold`] to build it): compares the raw u32 draw in
    /// float domain — one convert + one compare, no division. `thr ≤ 0`
    /// never fires; `thr ≥ 2³²` always fires (every u32 < 2³²), which is
    /// exactly the Remark 7 clipping behaviour.
    #[inline]
    pub fn bernoulli(&mut self, thr: f32) -> bool {
        (self.next() as f32) < thr
    }
}

/// Convert a probability to a `U32Stream::bernoulli` threshold
/// (`p · 2³²` in f32; the ~2⁻²⁴ relative rounding is far below the
/// statistical noise of any Bernoulli use).
#[inline]
pub fn bernoulli_threshold(p: f32) -> f32 {
    p * 4_294_967_296.0
}

// ---------------------------------------------------------------------------
// ChaCha20 — the hardened selection PRF (DESIGN.md §13).
//
// `Pcg64` is statistically strong but *cryptographically transparent*: its
// raw state is exported into coordinator snapshots (`to_raw`) and its
// output function is invertible enough that observed outputs leak the
// stream (the pcg-breaker line of work). Client selection is an
// adversarially relevant stream — a worker that predicts future rounds can
// time its misbehaviour — so the hardened selection mode replaces it with
// ChaCha20 used as a PRF: per-round key = PRF(root key, round), and only a
// one-way commitment to the root key ever leaves the process. This is a
// from-scratch implementation (the crate has zero dependencies); it is
// used as a deterministic PRF, not for interop, and its block function is
// pinned by golden tests below.

/// ChaCha quarter round.
#[inline]
fn chacha_qr(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

/// One ChaCha20 block: 256-bit key, 64-bit block counter, 64-bit nonce
/// (the original djb layout), 20 rounds, feed-forward add. The
/// feed-forward makes the block function one-way in the key, which is
/// what the selection commitment relies on.
pub fn chacha20_block(key: &[u32; 8], counter: u64, nonce: u64) -> [u32; 16] {
    let mut s: [u32; 16] = [
        0x6170_7865,
        0x3320_646e,
        0x7962_2d32,
        0x6b20_6574,
        key[0],
        key[1],
        key[2],
        key[3],
        key[4],
        key[5],
        key[6],
        key[7],
        counter as u32,
        (counter >> 32) as u32,
        nonce as u32,
        (nonce >> 32) as u32,
    ];
    let init = s;
    for _ in 0..10 {
        chacha_qr(&mut s, 0, 4, 8, 12);
        chacha_qr(&mut s, 1, 5, 9, 13);
        chacha_qr(&mut s, 2, 6, 10, 14);
        chacha_qr(&mut s, 3, 7, 11, 15);
        chacha_qr(&mut s, 0, 5, 10, 15);
        chacha_qr(&mut s, 1, 6, 11, 12);
        chacha_qr(&mut s, 2, 7, 8, 13);
        chacha_qr(&mut s, 3, 4, 9, 14);
    }
    for (w, i) in s.iter_mut().zip(init) {
        *w = w.wrapping_add(i);
    }
    s
}

/// A ChaCha20-keyed deterministic generator: the hardened selection
/// stream. Unlike [`Pcg64`] it deliberately exposes **no** raw-state
/// export — a `ChaChaRng` can only be rebuilt from the key it was built
/// from, never from observed state or outputs.
pub struct ChaChaRng {
    key: [u32; 8],
    nonce: u64,
    counter: u64,
    block: [u32; 16],
    idx: usize,
}

impl ChaChaRng {
    pub fn new(key: [u32; 8], nonce: u64) -> Self {
        Self { key, nonce, counter: 0, block: [0; 16], idx: 16 }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.idx == 16 {
            self.block = chacha20_block(&self.key, self.counter, self.nonce);
            self.counter = self.counter.wrapping_add(1);
            self.idx = 0;
        }
        let w = self.block[self.idx];
        self.idx += 1;
        w
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        lo | (hi << 32)
    }

    /// Uniform integer in `[0, bound)` (same Lemire method as [`Pcg64`]).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }
}

/// Expand a 64-bit run seed into a 256-bit ChaCha root key (domain
/// separated from every other seed use in the crate). The key has only
/// the seed's entropy — the hardened mode protects the selection stream
/// against *state disclosure and output observation*, not against a
/// guessable root seed (DESIGN.md §13 spells out this boundary).
pub fn selection_root_key(seed: u64) -> [u32; 8] {
    let mut out = [0u32; 8];
    let mut x = seed ^ 0x5e1e_c7ed_c0a1_17ed;
    for pair in out.chunks_mut(2) {
        x = splitmix64(x);
        pair[0] = x as u32;
        pair[1] = (x >> 32) as u32;
    }
    out
}

/// Nonce domains for the selection PRF uses of ChaCha20.
pub const SELECT_NONCE_COMMIT: u64 = 0x434f_4d4d_4954_0001; // commitment
pub const SELECT_NONCE_ROUND_KEY: u64 = 0x524b_4559_0000_0001; // per-round key
pub const SELECT_NONCE_STREAM: u64 = 0x5354_5245_414d_0001; // selection draws

/// One-way commitment to a selection root key: the first 256 bits of a
/// ChaCha20 block keyed by it. The feed-forward add makes recovering the
/// key from the commitment as hard as inverting the block function; the
/// commitment is what snapshots and the rendezvous broadcast carry
/// instead of raw RNG state.
pub fn selection_commitment(key: &[u32; 8]) -> [u64; 4] {
    let block = chacha20_block(key, 0, SELECT_NONCE_COMMIT);
    let mut out = [0u64; 4];
    for (o, pair) in out.iter_mut().zip(block.chunks(2)) {
        *o = pair[0] as u64 | ((pair[1] as u64) << 32);
    }
    out
}

/// Per-round selection key: PRF(root key, round). Stateless in the round
/// index, which is what makes hardened selection snapshot-free — a resume
/// recomputes any round's key from the (never-serialized) root key.
pub fn selection_round_key(root: &[u32; 8], round: u64) -> [u32; 8] {
    let block = chacha20_block(root, round, SELECT_NONCE_ROUND_KEY);
    let mut out = [0u32; 8];
    out.copy_from_slice(&block[..8]);
    out
}

/// splitmix64 — used for seed mixing only.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed_from(42);
        let mut b = Pcg64::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg64::seed_from(1);
        let mut b = Pcg64::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_is_independent() {
        let root = Pcg64::seed_from(7);
        let mut c1 = root.derive(0);
        let mut c2 = root.derive(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn raw_state_roundtrip_resumes_the_stream() {
        let mut a = Pcg64::seed_from(99).derive(0xfeed);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Pcg64::from_raw(a.to_raw()).expect("odd increment");
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Even increments are unreachable states and must be refused.
        let mut raw = Pcg64::seed_from(1).to_raw();
        raw[2] &= !1;
        assert!(Pcg64::from_raw(raw).is_none());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seed_from(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg64::seed_from(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Pcg64::seed_from(6);
        for &shape in &[0.3, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| rng.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentrates() {
        let mut rng = Pcg64::seed_from(7);
        let p = rng.dirichlet(0.1, 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Low α ⇒ skewed: the max component dominates.
        let mx = p.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 0.3, "α=0.1 should be skewed, max={mx}");
        // High α ⇒ near uniform on average.
        let mut acc = vec![0.0; 10];
        for _ in 0..200 {
            for (a, v) in acc.iter_mut().zip(rng.dirichlet(100.0, 10)) {
                *a += v;
            }
        }
        for a in acc {
            assert!((a / 200.0 - 0.1).abs() < 0.02);
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg64::seed_from(8);
        for _ in 0..50 {
            let s = rng.sample_indices(100, 20);
            assert_eq!(s.len(), 20);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn sample_indices_full_and_empty() {
        let mut rng = Pcg64::seed_from(9);
        assert_eq!(rng.sample_indices(5, 5), vec![0, 1, 2, 3, 4]);
        assert!(rng.sample_indices(5, 0).is_empty());
    }

    #[test]
    fn bernoulli_extremes() {
        let mut rng = Pcg64::seed_from(10);
        assert!(rng.bernoulli(1.5));
        assert!(!rng.bernoulli(-0.1));
        let hits = (0..10_000).filter(|_| rng.bernoulli(0.25)).count();
        assert!((hits as f64 - 2_500.0).abs() < 300.0);
    }

    #[test]
    fn categorical_hits_support() {
        let mut rng = Pcg64::seed_from(11);
        let probs = [0.0, 0.7, 0.3];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.categorical(&probs)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!((counts[1] as f64 - 7_000.0).abs() < 350.0);
    }

    #[test]
    fn chacha_block_is_deterministic_and_key_sensitive() {
        let k1 = selection_root_key(7);
        let k2 = selection_root_key(8);
        assert_eq!(chacha20_block(&k1, 0, 1), chacha20_block(&k1, 0, 1));
        assert_ne!(chacha20_block(&k1, 0, 1), chacha20_block(&k2, 0, 1));
        assert_ne!(chacha20_block(&k1, 0, 1), chacha20_block(&k1, 1, 1));
        assert_ne!(chacha20_block(&k1, 0, 1), chacha20_block(&k1, 0, 2));
    }

    #[test]
    fn chacha_rng_stream_uniformity() {
        let mut rng = ChaChaRng::new(selection_root_key(42), 9);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[rng.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn chacha_rng_replays_from_key_only() {
        let key = selection_root_key(1234);
        let mut a = ChaChaRng::new(key, 5);
        let mut b = ChaChaRng::new(key, 5);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = ChaChaRng::new(key, 6);
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn selection_commitment_hides_the_key() {
        let key = selection_root_key(99);
        let commit = selection_commitment(&key);
        assert_eq!(commit, selection_commitment(&key));
        assert_ne!(commit, selection_commitment(&selection_root_key(100)));
        // The commitment words must not simply restate the key words.
        let key_words: Vec<u64> = key
            .chunks(2)
            .map(|p| p[0] as u64 | ((p[1] as u64) << 32))
            .collect();
        for w in commit {
            assert!(!key_words.contains(&w), "commitment leaks a key word");
        }
    }

    #[test]
    fn round_keys_decorrelate_across_rounds() {
        let root = selection_root_key(3);
        let k0 = selection_round_key(&root, 0);
        let k1 = selection_round_key(&root, 1);
        assert_ne!(k0, k1);
        assert_eq!(k0, selection_round_key(&root, 0));
        // Derived round keys never equal the root key itself.
        assert_ne!(k0, root);
    }

    /// Pins the block function's exact output so an accidental edit to the
    /// round structure cannot slip through (the selection commitment and
    /// every hardened selection draw depend on these exact bits).
    #[test]
    fn chacha_block_golden() {
        // All-zero key/counter/nonce: the djb layout coincides with the
        // IETF layout here, so this is the published ChaCha20 zero-input
        // keystream (76 b8 e0 ad a0 f1 3d 90 …) as little-endian words.
        let zero = chacha20_block(&[0u32; 8], 0, 0);
        assert_eq!(
            zero,
            [
                0xade0_b876, 0x903d_f1a0, 0xe56a_5d40, 0x28bd_8653, 0xb819_d2bd, 0x1aed_8da0,
                0xccef_36a8, 0xc70d_778b, 0x7c59_41da, 0x8d48_5751, 0x3fe0_2477, 0x374a_d8b8,
                0xf4b8_436a, 0x1ca1_1815, 0x69b6_87c3, 0x8665_eeb2,
            ]
        );
        // Crate-specific derivation pins: the seed→key expansion and the
        // 64/64 counter/nonce split (verified against an independent
        // implementation at introduction).
        assert_eq!(
            selection_root_key(7),
            [
                0x9211_5837, 0x3040_2385, 0xae70_d8a7, 0x6faf_0c10, 0x9aac_5911, 0xbe42_f387,
                0xade2_6130, 0x56b4_f039,
            ]
        );
        let b = chacha20_block(&selection_root_key(7), 3, SELECT_NONCE_STREAM);
        assert_eq!(&b[..4], &[0x087e_a1de, 0xfac5_663e, 0xfd23_c2f7, 0xd1cd_ce4c]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from(12);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
