//! Foundational substrates: deterministic RNG + distributions, dense linear
//! algebra helpers, and summary statistics.
//!
//! Everything here is built from scratch (the sandbox registry only carries
//! the `xla` crate tree), deterministic given a seed, and exercised by unit
//! and property tests.

pub mod linalg;
pub mod rng;
pub mod stats;

/// Clamp `x` into `[lo, hi]`.
#[inline]
pub fn clamp(x: f32, lo: f32, hi: f32) -> f32 {
    x.max(lo).min(hi)
}

/// `sign(x)` with the deterministic convention `sign(0) = 0`, matching the
/// paper's ternary codomain (a zero coordinate transmits nothing).
#[inline]
pub fn sign0(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// `sign(x)` with the `sign(0) = +1` convention used by signSGD majority
/// vote implementations that must always transmit a bit.
#[inline]
pub fn sign1(x: f32) -> f32 {
    if x < 0.0 {
        -1.0
    } else {
        1.0
    }
}

/// ℓ1 norm.
pub fn l1_norm(v: &[f32]) -> f32 {
    v.iter().map(|x| x.abs()).sum()
}

/// ℓ1 norm accumulated in f64 — drift-free for large `d`: an f32 running
/// sum silently drops addends below half an ulp of the partial sum (the
/// server-side aggregation rules use this; see DESIGN.md §10).
pub fn l1_norm_f64(v: &[f32]) -> f64 {
    v.iter().map(|x| x.abs() as f64).sum()
}

/// ℓ2 norm.
pub fn l2_norm(v: &[f32]) -> f32 {
    v.iter().map(|x| x * x).sum::<f32>().sqrt()
}

/// ℓ∞ norm.
pub fn linf_norm(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Number of exactly-zero entries.
pub fn count_zeros(v: &[f32]) -> usize {
    v.iter().filter(|x| **x == 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_conventions() {
        assert_eq!(sign0(3.2), 1.0);
        assert_eq!(sign0(-0.1), -1.0);
        assert_eq!(sign0(0.0), 0.0);
        assert_eq!(sign1(0.0), 1.0);
        assert_eq!(sign1(-0.0), 1.0);
        assert_eq!(sign1(-2.0), -1.0);
    }

    #[test]
    fn norms() {
        let v = [3.0, -4.0];
        assert_eq!(l1_norm(&v), 7.0);
        assert_eq!(l1_norm_f64(&v), 7.0);
        assert_eq!(l2_norm(&v), 5.0);
        assert_eq!(linf_norm(&v), 4.0);
        assert_eq!(count_zeros(&[0.0, 1.0, 0.0]), 2);
    }

    #[test]
    fn l1_f64_keeps_low_order_mass() {
        // 16.0 head + 2²⁰ tail entries of 5e-7: every tail addend rounds
        // away in a sequential f32 sum but survives in f64.
        let mut v = vec![5e-7f32; (1 << 20) + 1];
        v[0] = 16.0;
        let exact = 16.0f64 + (1u64 << 20) as f64 * 5e-7f32 as f64;
        let got = l1_norm_f64(&v);
        assert!((got - exact).abs() / exact < 1e-9, "{got} vs {exact}");
        assert!((l1_norm(&v) as f64) < exact - 0.4, "f32 sum unexpectedly exact");
    }

    #[test]
    fn clamp_bounds() {
        assert_eq!(clamp(2.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp(-2.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp(0.5, 0.0, 1.0), 0.5);
    }
}
