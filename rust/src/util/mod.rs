//! Foundational substrates: deterministic RNG + distributions, dense linear
//! algebra helpers, and summary statistics.
//!
//! Everything here is built from scratch (the sandbox registry only carries
//! the `xla` crate tree), deterministic given a seed, and exercised by unit
//! and property tests.

pub mod linalg;
pub mod rng;
pub mod stats;

/// Clamp `x` into `[lo, hi]`.
#[inline]
pub fn clamp(x: f32, lo: f32, hi: f32) -> f32 {
    x.max(lo).min(hi)
}

/// `sign(x)` with the deterministic convention `sign(0) = 0`, matching the
/// paper's ternary codomain (a zero coordinate transmits nothing).
#[inline]
pub fn sign0(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// `sign(x)` with the `sign(0) = +1` convention used by signSGD majority
/// vote implementations that must always transmit a bit.
#[inline]
pub fn sign1(x: f32) -> f32 {
    if x < 0.0 {
        -1.0
    } else {
        1.0
    }
}

/// ℓ1 norm.
pub fn l1_norm(v: &[f32]) -> f32 {
    v.iter().map(|x| x.abs()).sum()
}

/// ℓ2 norm.
pub fn l2_norm(v: &[f32]) -> f32 {
    v.iter().map(|x| x * x).sum::<f32>().sqrt()
}

/// ℓ∞ norm.
pub fn linf_norm(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Number of exactly-zero entries.
pub fn count_zeros(v: &[f32]) -> usize {
    v.iter().filter(|x| **x == 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_conventions() {
        assert_eq!(sign0(3.2), 1.0);
        assert_eq!(sign0(-0.1), -1.0);
        assert_eq!(sign0(0.0), 0.0);
        assert_eq!(sign1(0.0), 1.0);
        assert_eq!(sign1(-0.0), 1.0);
        assert_eq!(sign1(-2.0), -1.0);
    }

    #[test]
    fn norms() {
        let v = [3.0, -4.0];
        assert_eq!(l1_norm(&v), 7.0);
        assert_eq!(l2_norm(&v), 5.0);
        assert_eq!(linf_norm(&v), 4.0);
        assert_eq!(count_zeros(&[0.0, 1.0, 0.0]), 2);
    }

    #[test]
    fn clamp_bounds() {
        assert_eq!(clamp(2.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp(-2.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp(0.5, 0.0, 1.0), 0.5);
    }
}
