//! Dense linear algebra for the pure-rust model substrate.
//!
//! The federated simulation runs every selected worker's forward/backward
//! pass on the CPU each round, so the GEMMs here are the single hottest
//! code path outside the compressors. The implementation is a BLIS-style
//! packed, register-tiled kernel over row-major `f32`:
//!
//! * operands are packed into contiguous `KC×MR` / `KC×NR` panels
//!   (zero-padded at the edges, so the microkernel never branches on
//!   remainders), which also absorbs transposed layouts — the same
//!   microkernel serves `A·B`, `Aᵀ·B` and `A·Bᵀ`;
//! * the 6×16 microkernel keeps twelve 8-wide FMA accumulator chains live;
//!   an explicit AVX2+FMA path is selected once per process via
//!   `is_x86_feature_detected!` with a portable autovectorizable fallback
//!   (see [`kernel_name`]);
//! * the store loop optionally fuses a bias-add (+ ReLU) epilogue on the
//!   final k-block, so an MLP layer makes a single pass over its output.
//!
//! Determinism contract (DESIGN.md §9): results are a pure function of the
//! inputs and the selected microkernel. The kernel choice is fixed for the
//! life of the process, so training runs are bit-identical across thread
//! counts and replays on the same machine/build; AVX2 (fused
//! multiply-add) and the portable path may differ by normal fp tolerance.

use std::cell::RefCell;
use std::sync::OnceLock;

/// Microkernel rows: independent FMA accumulator chains per vector lane.
const MR: usize = 6;
/// Microkernel columns: two 8-wide f32 vectors.
const NR: usize = 16;
/// Rows of A packed per block (multiple of MR).
const MC: usize = 96;
/// Depth (k) packed per block.
const KC: usize = 256;
/// Columns of B packed per block (multiple of NR).
const NC: usize = 256;

/// How an operand's logical matrix is stored.
///
/// `Normal`: the logical `r×c` matrix is stored row-major as given.
/// `Transpose`: the buffer holds the *transpose* (`c×r` row-major), i.e.
/// logical element `(i, j)` lives at `buf[j * r + i]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatLayout {
    Normal,
    Transpose,
}

/// Optional operation fused into the GEMM store loop on the final
/// k-block, saving a separate pass over the `m×n` output.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Plain store.
    None,
    /// `c[i, j] += bias[j]`.
    Bias(&'a [f32]),
    /// `c[i, j] = max(0, c[i, j] + bias[j])`.
    BiasRelu(&'a [f32]),
}

/// Reusable packing buffers for [`gemm_with`]. Sized lazily to the fixed
/// `MC×KC` / `KC×NC` block maxima, so steady-state calls allocate nothing.
#[derive(Default)]
pub struct GemmScratch {
    packed_a: Vec<f32>,
    packed_b: Vec<f32>,
}

impl GemmScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// `acc[i*NR + j] = Σ_p a_panel[p*MR + i] · b_panel[p*NR + j]` — panels
/// are the packed (zero-padded) strips, `acc` is an `MR×NR` scratch tile.
type Microkernel = unsafe fn(usize, *const f32, *const f32, *mut f32);

/// Portable microkernel: fixed-trip inner loops over `[f32; NR]` lanes
/// that LLVM autovectorizes on every target.
///
/// # Safety
/// `a` must point at `kc*MR` floats, `b` at `kc*NR`, `acc` at `MR*NR`.
unsafe fn microkernel_portable(kc: usize, a: *const f32, b: *const f32, acc: *mut f32) {
    let (a, b, acc) = unsafe {
        (
            std::slice::from_raw_parts(a, kc * MR),
            std::slice::from_raw_parts(b, kc * NR),
            std::slice::from_raw_parts_mut(acc, MR * NR),
        )
    };
    let mut c = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let ar = &a[p * MR..p * MR + MR];
        let br = &b[p * NR..p * NR + NR];
        for (ci, &ai) in c.iter_mut().zip(ar) {
            for (cj, &bj) in ci.iter_mut().zip(br) {
                *cj += ai * bj;
            }
        }
    }
    for (i, ci) in c.iter().enumerate() {
        acc[i * NR..(i + 1) * NR].copy_from_slice(ci);
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// AVX2+FMA 6×16 microkernel: 12 ymm accumulators, 2 B loads and one
    /// A broadcast per k step.
    ///
    /// # Safety
    /// Caller must have verified `avx2` + `fma` at runtime; pointer
    /// contracts as in `microkernel_portable`.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn microkernel_avx2(kc: usize, a: *const f32, b: *const f32, acc: *mut f32) {
        unsafe {
            let mut c: [[__m256; 2]; MR] = [[_mm256_setzero_ps(); 2]; MR];
            let mut ap = a;
            let mut bp = b;
            for _ in 0..kc {
                let b0 = _mm256_loadu_ps(bp);
                let b1 = _mm256_loadu_ps(bp.add(8));
                for (i, ci) in c.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*ap.add(i));
                    ci[0] = _mm256_fmadd_ps(av, b0, ci[0]);
                    ci[1] = _mm256_fmadd_ps(av, b1, ci[1]);
                }
                ap = ap.add(MR);
                bp = bp.add(NR);
            }
            for (i, ci) in c.iter().enumerate() {
                _mm256_storeu_ps(acc.add(i * NR), ci[0]);
                _mm256_storeu_ps(acc.add(i * NR + 8), ci[1]);
            }
        }
    }
}

fn detect_kernel() -> (Microkernel, &'static str) {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return (x86::microkernel_avx2 as Microkernel, "avx2+fma 6x16");
        }
    }
    (microkernel_portable as Microkernel, "portable 6x16")
}

fn active_kernel() -> (Microkernel, &'static str) {
    static KERNEL: OnceLock<(Microkernel, &'static str)> = OnceLock::new();
    *KERNEL.get_or_init(detect_kernel)
}

/// Name of the microkernel selected for this process (for bench logs).
pub fn kernel_name() -> &'static str {
    active_kernel().1
}

/// Pack the `ib×pb` block of logical `A` starting at `(i0, p0)` into
/// MR-row strips `[p*MR + i]`, zero-padded to full strips.
fn pack_a(
    dst: &mut Vec<f32>,
    a: &[f32],
    la: MatLayout,
    m: usize,
    k: usize,
    i0: usize,
    p0: usize,
    ib: usize,
    pb: usize,
) {
    let strips = ib.div_ceil(MR);
    dst.clear();
    dst.resize(strips * MR * pb, 0.0);
    for s in 0..strips {
        let base = s * MR * pb;
        let rows = MR.min(ib - s * MR);
        match la {
            MatLayout::Normal => {
                for i in 0..rows {
                    let src = &a[(i0 + s * MR + i) * k + p0..][..pb];
                    for (p, &v) in src.iter().enumerate() {
                        dst[base + p * MR + i] = v;
                    }
                }
            }
            MatLayout::Transpose => {
                for p in 0..pb {
                    let src = &a[(p0 + p) * m + i0 + s * MR..][..rows];
                    dst[base + p * MR..base + p * MR + rows].copy_from_slice(src);
                }
            }
        }
    }
}

/// Pack the `pb×jb` block of logical `B` starting at `(p0, j0)` into
/// NR-column strips `[p*NR + j]`, zero-padded to full strips.
fn pack_b(
    dst: &mut Vec<f32>,
    b: &[f32],
    lb: MatLayout,
    k: usize,
    n: usize,
    p0: usize,
    j0: usize,
    pb: usize,
    jb: usize,
) {
    let strips = jb.div_ceil(NR);
    dst.clear();
    dst.resize(strips * NR * pb, 0.0);
    for s in 0..strips {
        let base = s * NR * pb;
        let cols = NR.min(jb - s * NR);
        match lb {
            MatLayout::Normal => {
                for p in 0..pb {
                    let src = &b[(p0 + p) * n + j0 + s * NR..][..cols];
                    dst[base + p * NR..base + p * NR + cols].copy_from_slice(src);
                }
            }
            MatLayout::Transpose => {
                for j in 0..cols {
                    let src = &b[(j0 + s * NR + j) * k + p0..][..pb];
                    for (p, &v) in src.iter().enumerate() {
                        dst[base + p * NR + j] = v;
                    }
                }
            }
        }
    }
}

/// Write one `rows×cols` microtile into `c`, optionally accumulating the
/// previous contents and applying the epilogue on the final k-block.
#[inline]
fn store_tile(
    c: &mut [f32],
    n: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
    acc: &[f32; MR * NR],
    add_prev: bool,
    finalize: bool,
    epilogue: Epilogue<'_>,
) {
    for i in 0..rows {
        let off = (row0 + i) * n + col0;
        let crow = &mut c[off..off + cols];
        let arow = &acc[i * NR..i * NR + cols];
        if add_prev {
            for (cv, &av) in crow.iter_mut().zip(arow) {
                *cv += av;
            }
        } else {
            crow.copy_from_slice(arow);
        }
        if finalize {
            match epilogue {
                Epilogue::None => {}
                Epilogue::Bias(bias) => {
                    for (cv, &bv) in crow.iter_mut().zip(&bias[col0..col0 + cols]) {
                        *cv += bv;
                    }
                }
                Epilogue::BiasRelu(bias) => {
                    for (cv, &bv) in crow.iter_mut().zip(&bias[col0..col0 + cols]) {
                        let v = *cv + bv;
                        *cv = if v > 0.0 { v } else { 0.0 };
                    }
                }
            }
        }
    }
}

/// Apply `epilogue` to all of `c` — the degenerate `k == 0` path where no
/// microtile is ever stored.
fn epilogue_only(c: &mut [f32], n: usize, epilogue: Epilogue<'_>) {
    match epilogue {
        Epilogue::None => {}
        Epilogue::Bias(bias) => {
            for crow in c.chunks_exact_mut(n) {
                for (cv, &bv) in crow.iter_mut().zip(bias) {
                    *cv += bv;
                }
            }
        }
        Epilogue::BiasRelu(bias) => {
            for crow in c.chunks_exact_mut(n) {
                for (cv, &bv) in crow.iter_mut().zip(bias) {
                    let v = *cv + bv;
                    *cv = if v > 0.0 { v } else { 0.0 };
                }
            }
        }
    }
}

fn gemm_dispatch(
    scratch: &mut GemmScratch,
    c: &mut [f32],
    a: &[f32],
    la: MatLayout,
    b: &[f32],
    lb: MatLayout,
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
    epilogue: Epilogue<'_>,
    kernel: Microkernel,
) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    if let Epilogue::Bias(bias) | Epilogue::BiasRelu(bias) = epilogue {
        assert_eq!(bias.len(), n, "bias shape");
    }
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !accumulate {
            c.fill(0.0);
        }
        epilogue_only(c, n, epilogue);
        return;
    }
    let mut acc = [0.0f32; MR * NR];
    let mut j0 = 0;
    while j0 < n {
        let jb = NC.min(n - j0);
        let jstrips = jb.div_ceil(NR);
        let mut p0 = 0;
        while p0 < k {
            let pb = KC.min(k - p0);
            pack_b(&mut scratch.packed_b, b, lb, k, n, p0, j0, pb, jb);
            let add_prev = accumulate || p0 > 0;
            let finalize = p0 + pb == k;
            let mut i0 = 0;
            while i0 < m {
                let ib = MC.min(m - i0);
                let istrips = ib.div_ceil(MR);
                pack_a(&mut scratch.packed_a, a, la, m, k, i0, p0, ib, pb);
                for js in 0..jstrips {
                    let jr = j0 + js * NR;
                    let cols = NR.min(jb - js * NR);
                    let bpan = &scratch.packed_b[js * NR * pb..][..NR * pb];
                    for is in 0..istrips {
                        let ir = i0 + is * MR;
                        let rows = MR.min(ib - is * MR);
                        let apan = &scratch.packed_a[is * MR * pb..][..MR * pb];
                        // SAFETY: panels hold pb*MR / pb*NR packed floats
                        // (asserted by the slice bounds above) and `acc`
                        // is an MR×NR tile; the kernel was selected by
                        // `active_kernel` (CPU features verified) or is
                        // the portable fallback.
                        unsafe {
                            kernel(pb, apan.as_ptr(), bpan.as_ptr(), acc.as_mut_ptr());
                        }
                        store_tile(c, n, ir, jr, rows, cols, &acc, add_prev, finalize, epilogue);
                    }
                }
                i0 += MC;
            }
            p0 += KC;
        }
        j0 += NC;
    }
}

/// General packed GEMM: `c[m×n] (+)= op(a) · op(b)` with an optional
/// fused epilogue, using caller-owned packing scratch (zero steady-state
/// allocations). `la`/`lb` select the logical layout of each operand —
/// `a` is logically `m×k`, `b` logically `k×n` regardless of layout.
pub fn gemm_with(
    scratch: &mut GemmScratch,
    c: &mut [f32],
    a: &[f32],
    la: MatLayout,
    b: &[f32],
    lb: MatLayout,
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
    epilogue: Epilogue<'_>,
) {
    let (kernel, _) = active_kernel();
    gemm_dispatch(scratch, c, a, la, b, lb, m, k, n, accumulate, epilogue, kernel);
}

/// [`gemm_with`] pinned to the portable (non-SIMD) microkernel — used by
/// the property tests and the perf bench to compare dispatch paths.
#[doc(hidden)]
pub fn gemm_with_portable(
    scratch: &mut GemmScratch,
    c: &mut [f32],
    a: &[f32],
    la: MatLayout,
    b: &[f32],
    lb: MatLayout,
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
    epilogue: Epilogue<'_>,
) {
    gemm_dispatch(
        scratch,
        c,
        a,
        la,
        b,
        lb,
        m,
        k,
        n,
        accumulate,
        epilogue,
        microkernel_portable as Microkernel,
    );
}

thread_local! {
    /// Packing scratch for the legacy fixed-signature wrappers below, so
    /// call sites that do not thread a [`GemmScratch`] stay allocation-free
    /// in steady state too.
    static TLS_SCRATCH: RefCell<GemmScratch> = RefCell::new(GemmScratch::new());
}

fn with_tls_scratch(f: impl FnOnce(&mut GemmScratch)) {
    TLS_SCRATCH.with(|s| f(&mut s.borrow_mut()));
}

/// `c[m×n] += a[m×k] · b[k×n]`, all row-major.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    with_tls_scratch(|s| {
        gemm_with(
            s,
            c,
            a,
            MatLayout::Normal,
            b,
            MatLayout::Normal,
            m,
            k,
            n,
            true,
            Epilogue::None,
        )
    });
}

/// `c = a · b` (overwrites `c`).
pub fn matmul(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    with_tls_scratch(|s| {
        gemm_with(
            s,
            c,
            a,
            MatLayout::Normal,
            b,
            MatLayout::Normal,
            m,
            k,
            n,
            false,
            Epilogue::None,
        )
    });
}

/// `c[m×n] += aᵀ[m×k] · b[k×n]` where `a` is stored `k×m` row-major
/// (i.e. we multiply by the transpose of `a` without materializing it).
pub fn matmul_at_b(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    with_tls_scratch(|s| {
        gemm_with(
            s,
            c,
            a,
            MatLayout::Transpose,
            b,
            MatLayout::Normal,
            m,
            k,
            n,
            true,
            Epilogue::None,
        )
    });
}

/// `c[m×n] += a[m×k] · bᵀ[k×n]` where `b` is stored `n×k` row-major.
pub fn matmul_a_bt(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    with_tls_scratch(|s| {
        gemm_with(
            s,
            c,
            a,
            MatLayout::Normal,
            b,
            MatLayout::Transpose,
            m,
            k,
            n,
            true,
            Epilogue::None,
        )
    });
}

/// `y += alpha * x`, 8-lane unrolled so the fallback autovectorizes.
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    let chunks = y.len() / 8;
    let (yh, yt) = y.split_at_mut(chunks * 8);
    let (xh, xt) = x.split_at(chunks * 8);
    for (yc, xc) in yh.chunks_exact_mut(8).zip(xh.chunks_exact(8)) {
        for (yi, &xi) in yc.iter_mut().zip(xc) {
            *yi += alpha * xi;
        }
    }
    for (yi, &xi) in yt.iter_mut().zip(xt) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x` elementwise scale into place.
pub fn scale(y: &mut [f32], alpha: f32) {
    for yi in y.iter_mut() {
        *yi *= alpha;
    }
}

/// Dot product with eight independent accumulator chains (the scalar
/// single-chain loop serializes on the add latency; eight chains keep the
/// FMA pipes full and autovectorize).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let chunks = a.len() / 8;
    for (ac, bc) in a[..chunks * 8]
        .chunks_exact(8)
        .zip(b[..chunks * 8].chunks_exact(8))
    {
        for (l, (&x, &y)) in lanes.iter_mut().zip(ac.iter().zip(bc)) {
            *l += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
        tail += x * y;
    }
    let s01 = (lanes[0] + lanes[4]) + (lanes[1] + lanes[5]);
    let s23 = (lanes[2] + lanes[6]) + (lanes[3] + lanes[7]);
    (s01 + s23) + tail
}

/// Row-wise softmax in place over an `m×n` row-major matrix (numerically
/// stabilized; max and exp-sum reductions run four accumulator lanes).
pub fn softmax_rows(x: &mut [f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    for i in 0..m {
        let row = &mut x[i * n..(i + 1) * n];
        let chunks = row.len() / 4;
        let mut mx4 = [f32::NEG_INFINITY; 4];
        for r in row[..chunks * 4].chunks_exact(4) {
            for (mj, &v) in mx4.iter_mut().zip(r) {
                *mj = mj.max(v);
            }
        }
        let mut mx = mx4[0].max(mx4[1]).max(mx4[2]).max(mx4[3]);
        for &v in &row[chunks * 4..] {
            mx = mx.max(v);
        }
        let mut s4 = [0.0f32; 4];
        for r in row[..chunks * 4].chunks_exact_mut(4) {
            for (sj, v) in s4.iter_mut().zip(r) {
                *v = (*v - mx).exp();
                *sj += *v;
            }
        }
        let mut sum = (s4[0] + s4[2]) + (s4[1] + s4[3]);
        for v in &mut row[chunks * 4..] {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// ReLU forward in place; returns nothing (mask recomputed in backward from
/// the activations, which is exact for ReLU).
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward through ReLU: `dx = dy ⊙ 1[act > 0]`, in place on `dy`.
pub fn relu_backward(dy: &mut [f32], act: &[f32]) {
    assert_eq!(dy.len(), act.len());
    for (d, a) in dy.iter_mut().zip(act) {
        if *a <= 0.0 {
            *d = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32, label: &str) {
        assert_eq!(got.len(), want.len(), "{label}: length");
        for (i, (x, y)) in got.iter().zip(want).enumerate() {
            let denom = x.abs().max(y.abs()).max(1.0);
            assert!(
                (x - y).abs() / denom < tol,
                "{label}: elem {i}: {x} vs {y}"
            );
        }
    }

    /// Logical-layout materializers so every variant can be checked
    /// against the one naive row-major reference.
    fn store_a(logical: &[f32], m: usize, k: usize, la: MatLayout) -> Vec<f32> {
        match la {
            MatLayout::Normal => logical.to_vec(),
            MatLayout::Transpose => {
                let mut t = vec![0.0; m * k];
                for i in 0..m {
                    for p in 0..k {
                        t[p * m + i] = logical[i * k + p];
                    }
                }
                t
            }
        }
    }

    fn store_b(logical: &[f32], k: usize, n: usize, lb: MatLayout) -> Vec<f32> {
        match lb {
            MatLayout::Normal => logical.to_vec(),
            MatLayout::Transpose => {
                let mut t = vec![0.0; k * n];
                for p in 0..k {
                    for j in 0..n {
                        t[j * k + p] = logical[p * n + j];
                    }
                }
                t
            }
        }
    }

    /// Adversarial shapes: not multiples of the 6×16 tile, tiny rows,
    /// k=1/k=0, exact-tile shapes, and block-boundary straddles.
    fn adversarial_shapes() -> Vec<(usize, usize, usize)> {
        vec![
            (1, 1, 1),
            (1, 1, 17),
            (3, 5, 7),
            (2, 1, 1),
            (5, 0, 3),
            (4, 1, 9),
            (6, 16, 16),
            (7, 17, 33),
            (5, 3, 16),
            (6, 8, 15),
            (12, 64, 32),
            (13, 259, 40),
            (97, 7, 17),
            (64, 64, 64),
            (70, 130, 65),
            (96, 256, 256),
            (98, 257, 258),
        ]
    }

    #[test]
    fn gemm_all_layouts_match_naive_on_adversarial_shapes() {
        let mut rng = Pcg64::seed_from(11);
        let mut scratch = GemmScratch::new();
        for (m, k, n) in adversarial_shapes() {
            let mut la_buf = vec![0.0; m * k];
            let mut lb_buf = vec![0.0; k * n];
            rng.fill_normal(&mut la_buf, 0.0, 1.0);
            rng.fill_normal(&mut lb_buf, 0.0, 1.0);
            let want = naive_matmul(&la_buf, &lb_buf, m, k, n);
            for la in [MatLayout::Normal, MatLayout::Transpose] {
                for lb in [MatLayout::Normal, MatLayout::Transpose] {
                    let a = store_a(&la_buf, m, k, la);
                    let b = store_b(&lb_buf, k, n, lb);
                    let mut c = vec![7.5f32; m * n];
                    gemm_with(
                        &mut scratch,
                        &mut c,
                        &a,
                        la,
                        &b,
                        lb,
                        m,
                        k,
                        n,
                        false,
                        Epilogue::None,
                    );
                    assert_close(&c, &want, 1e-4, &format!("{m}x{k}x{n} {la:?}/{lb:?}"));
                }
            }
        }
    }

    #[test]
    fn portable_kernel_matches_active_kernel() {
        let mut rng = Pcg64::seed_from(12);
        let mut scratch = GemmScratch::new();
        for (m, k, n) in adversarial_shapes() {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![0.0f32; m * n];
            gemm_with(
                &mut scratch,
                &mut c1,
                &a,
                MatLayout::Normal,
                &b,
                MatLayout::Normal,
                m,
                k,
                n,
                false,
                Epilogue::None,
            );
            gemm_with_portable(
                &mut scratch,
                &mut c2,
                &a,
                MatLayout::Normal,
                &b,
                MatLayout::Normal,
                m,
                k,
                n,
                false,
                Epilogue::None,
            );
            assert_close(&c1, &c2, 1e-4, &format!("{m}x{k}x{n} simd-vs-portable"));
        }
    }

    #[test]
    fn gemm_accumulate_adds_to_existing_contents() {
        let mut rng = Pcg64::seed_from(13);
        let mut scratch = GemmScratch::new();
        let (m, k, n) = (7, 300, 19); // two k-blocks
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let mut c = vec![1.0f32; m * n];
        gemm_with(
            &mut scratch,
            &mut c,
            &a,
            MatLayout::Normal,
            &b,
            MatLayout::Normal,
            m,
            k,
            n,
            true,
            Epilogue::None,
        );
        let mut want = naive_matmul(&a, &b, m, k, n);
        for w in want.iter_mut() {
            *w += 1.0;
        }
        assert_close(&c, &want, 1e-4, "accumulate");
    }

    #[test]
    fn fused_bias_and_relu_epilogues_match_reference() {
        let mut rng = Pcg64::seed_from(14);
        let mut scratch = GemmScratch::new();
        for (m, k, n) in [(4, 5, 9), (7, 300, 19), (64, 784, 256)] {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            let mut bias = vec![0.0; n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            rng.fill_normal(&mut bias, 0.0, 1.0);
            let raw = naive_matmul(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            gemm_with(
                &mut scratch,
                &mut c,
                &a,
                MatLayout::Normal,
                &b,
                MatLayout::Normal,
                m,
                k,
                n,
                false,
                Epilogue::Bias(&bias),
            );
            let want: Vec<f32> = raw
                .iter()
                .enumerate()
                .map(|(i, v)| v + bias[i % n])
                .collect();
            assert_close(&c, &want, 1e-4, &format!("{m}x{k}x{n} bias"));

            let mut c = vec![0.0f32; m * n];
            gemm_with(
                &mut scratch,
                &mut c,
                &a,
                MatLayout::Normal,
                &b,
                MatLayout::Normal,
                m,
                k,
                n,
                false,
                Epilogue::BiasRelu(&bias),
            );
            let want: Vec<f32> = want.iter().map(|&v| v.max(0.0)).collect();
            assert_close(&c, &want, 1e-4, &format!("{m}x{k}x{n} bias+relu"));
        }
    }

    #[test]
    fn k_zero_respects_accumulate_and_epilogue() {
        let mut scratch = GemmScratch::new();
        let bias = [1.0f32, -2.0];
        let mut c = vec![5.0f32; 4]; // 2×2
        gemm_with(
            &mut scratch,
            &mut c,
            &[],
            MatLayout::Normal,
            &[],
            MatLayout::Normal,
            2,
            0,
            2,
            false,
            Epilogue::BiasRelu(&bias),
        );
        assert_eq!(c, vec![1.0, 0.0, 1.0, 0.0]);
        let mut c = vec![5.0f32; 4];
        gemm_with(
            &mut scratch,
            &mut c,
            &[],
            MatLayout::Normal,
            &[],
            MatLayout::Normal,
            2,
            0,
            2,
            true,
            Epilogue::None,
        );
        assert_eq!(c, vec![5.0; 4]);
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Pcg64::seed_from(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (70, 130, 65)] {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let mut c = vec![0.0; m * n];
            matmul(&mut c, &a, &b, m, k, n);
            let want = naive_matmul(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg64::seed_from(2);
        let (m, k, n) = (13, 21, 8);
        let mut at = vec![0.0; k * m]; // stores a as k×m (i.e. aᵀ view)
        let mut b = vec![0.0; k * n];
        rng.fill_normal(&mut at, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        // Materialize a = (aᵀ)ᵀ, m×k.
        let mut a = vec![0.0; m * k];
        for p in 0..k {
            for i in 0..m {
                a[i * k + p] = at[p * m + i];
            }
        }
        let mut c1 = vec![0.0; m * n];
        matmul_at_b(&mut c1, &at, &b, m, k, n);
        let c2 = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg64::seed_from(3);
        let (m, k, n) = (9, 14, 11);
        let mut a = vec![0.0; m * k];
        let mut bt = vec![0.0; n * k]; // b stored n×k (i.e. bᵀ view)
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut bt, 0.0, 1.0);
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let mut c1 = vec![0.0; m * n];
        matmul_a_bt(&mut c1, &a, &bt, m, k, n);
        let c2 = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1000.0];
        softmax_rows(&mut x, 2, 3);
        for i in 0..2 {
            let s: f32 = x[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Large logit dominates without NaN.
        assert!(x[5] > 0.999 && x[5].is_finite());
    }

    #[test]
    fn softmax_rows_wide_row_matches_naive() {
        let mut rng = Pcg64::seed_from(15);
        let (m, n) = (3, 37); // exercises the 4-lane chunks + tail
        let mut x = vec![0.0; m * n];
        rng.fill_normal(&mut x, 0.0, 3.0);
        let mut want = x.clone();
        for i in 0..m {
            let row = &mut want[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        softmax_rows(&mut x, m, n);
        for (a, b) in x.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn relu_and_backward() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut dy = vec![5.0, 5.0, 5.0];
        relu_backward(&mut dy, &x);
        assert_eq!(dy, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn axpy_dot_scale() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![7.0, 10.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![3.5, 5.0]);
    }

    #[test]
    fn dot_and_axpy_long_inputs_match_naive() {
        let mut rng = Pcg64::seed_from(16);
        let n = 1013; // not a multiple of 8
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let got = dot(&a, &b) as f64;
        assert!((got - want).abs() < 1e-2, "{got} vs {want}");
        let mut y1 = a.clone();
        axpy(&mut y1, 0.37, &b);
        for ((y, &x), &bb) in y1.iter().zip(&a).zip(&b) {
            assert!((y - (x + 0.37 * bb)).abs() < 1e-5);
        }
    }

    #[test]
    fn kernel_name_is_reported() {
        let name = kernel_name();
        assert!(name.contains("6x16"), "{name}");
    }
}
