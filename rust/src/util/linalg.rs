//! Dense linear algebra for the pure-rust model substrate.
//!
//! The federated simulation runs every selected worker's forward/backward
//! pass on the CPU each round, so the GEMMs here are the single hottest
//! code path outside the compressors. The implementation is a
//! cache-blocked, 4×4-register-tiled kernel over row-major `f32` — see
//! EXPERIMENTS.md §Perf for the measured before/after of each optimization
//! step.

/// Row-major matrix view helpers operate on plain `&[f32]` so model
/// parameters can live in one flat vector (required by the compressors,
/// which treat the gradient as a single `d`-dimensional vector).

/// `c[m×n] += a[m×k] · b[k×n]`, all row-major.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    // Cache blocking parameters tuned on the target core (see §Perf).
    const MC: usize = 64;
    const KC: usize = 256;
    const NC: usize = 256;
    let mut i0 = 0;
    while i0 < m {
        let ib = MC.min(m - i0);
        let mut p0 = 0;
        while p0 < k {
            let pb = KC.min(k - p0);
            let mut j0 = 0;
            while j0 < n {
                let jb = NC.min(n - j0);
                block_kernel(c, a, b, m, k, n, i0, p0, j0, ib, pb, jb);
                j0 += NC;
            }
            p0 += KC;
        }
        i0 += MC;
    }
}

/// `c = a · b` (overwrites `c`).
pub fn matmul(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    matmul_acc(c, a, b, m, k, n);
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn block_kernel(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    _m: usize,
    k: usize,
    n: usize,
    i0: usize,
    p0: usize,
    j0: usize,
    ib: usize,
    pb: usize,
    jb: usize,
) {
    // §Perf: 4-row register tile so the inner p-loop keeps 4 independent
    // FMA chains per vector lane; the rows are provably disjoint slices of
    // `c`, materialized via raw pointers to avoid per-p split_at_mut
    // shuffling (see EXPERIMENTS.md §Perf for the measured deltas).
    let mut i = 0;
    let cptr = c.as_mut_ptr();
    while i + 4 <= ib {
        let r0 = (i0 + i) * k + p0;
        let r1 = r0 + k;
        let r2 = r1 + k;
        let r3 = r2 + k;
        // SAFETY: the four row ranges [(i0+i+r)·n + j0, +jb) are disjoint
        // (distinct rows of an m×n matrix, jb ≤ n) and in-bounds.
        let (t0, t1, t2, t3) = unsafe {
            (
                std::slice::from_raw_parts_mut(cptr.add((i0 + i) * n + j0), jb),
                std::slice::from_raw_parts_mut(cptr.add((i0 + i + 1) * n + j0), jb),
                std::slice::from_raw_parts_mut(cptr.add((i0 + i + 2) * n + j0), jb),
                std::slice::from_raw_parts_mut(cptr.add((i0 + i + 3) * n + j0), jb),
            )
        };
        for p in 0..pb {
            let a0 = a[r0 + p];
            let a1 = a[r1 + p];
            let a2 = a[r2 + p];
            let a3 = a[r3 + p];
            let brow = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jb];
            for j in 0..jb {
                let bv = brow[j];
                t0[j] += a0 * bv;
                t1[j] += a1 * bv;
                t2[j] += a2 * bv;
                t3[j] += a3 * bv;
            }
        }
        i += 4;
    }
    while i < ib {
        let ra = (i0 + i) * k + p0;
        let rc = (i0 + i) * n + j0;
        for p in 0..pb {
            let a0 = a[ra + p];
            let brow = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jb];
            let crow = &mut c[rc..rc + jb];
            for j in 0..jb {
                crow[j] += a0 * brow[j];
            }
        }
        i += 1;
    }
}

/// `c[m×n] += aᵀ[m×k] · b[k×n]` where `a` is stored `k×m` row-major
/// (i.e. we multiply by the transpose of `a` without materializing it).
pub fn matmul_at_b(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), k * m, "aᵀ shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    // aᵀ·b: iterate p over k in the outer loop so both a and b stream
    // row-major; accumulates into c.
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..i * n + n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// `c[m×n] += a[m×k] · bᵀ[k×n]` where `b` is stored `n×k` row-major.
pub fn matmul_a_bt(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), n * k, "bᵀ shape");
    assert_eq!(c.len(), m * n, "c shape");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
        }
    }
}

/// `y += alpha * x`.
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x` elementwise scale into place.
pub fn scale(y: &mut [f32], alpha: f32) {
    for yi in y.iter_mut() {
        *yi *= alpha;
    }
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Row-wise softmax in place over an `m×n` row-major matrix
/// (numerically stabilized).
pub fn softmax_rows(x: &mut [f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    for i in 0..m {
        let row = &mut x[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// ReLU forward in place; returns nothing (mask recomputed in backward from
/// the activations, which is exact for ReLU).
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward through ReLU: `dx = dy ⊙ 1[act > 0]`, in place on `dy`.
pub fn relu_backward(dy: &mut [f32], act: &[f32]) {
    assert_eq!(dy.len(), act.len());
    for (d, a) in dy.iter_mut().zip(act) {
        if *a <= 0.0 {
            *d = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Pcg64::seed_from(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (70, 130, 65)] {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let mut c = vec![0.0; m * n];
            matmul(&mut c, &a, &b, m, k, n);
            let want = naive_matmul(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Pcg64::seed_from(2);
        let (m, k, n) = (13, 21, 8);
        let mut at = vec![0.0; k * m]; // stores a as k×m (i.e. aᵀ view)
        let mut b = vec![0.0; k * n];
        rng.fill_normal(&mut at, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        // Materialize a = (aᵀ)ᵀ, m×k.
        let mut a = vec![0.0; m * k];
        for p in 0..k {
            for i in 0..m {
                a[i * k + p] = at[p * m + i];
            }
        }
        let mut c1 = vec![0.0; m * n];
        matmul_at_b(&mut c1, &at, &b, m, k, n);
        let c2 = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Pcg64::seed_from(3);
        let (m, k, n) = (9, 14, 11);
        let mut a = vec![0.0; m * k];
        let mut bt = vec![0.0; n * k]; // b stored n×k (i.e. bᵀ view)
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut bt, 0.0, 1.0);
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let mut c1 = vec![0.0; m * n];
        matmul_a_bt(&mut c1, &a, &bt, m, k, n);
        let c2 = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1000.0];
        softmax_rows(&mut x, 2, 3);
        for i in 0..2 {
            let s: f32 = x[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Large logit dominates without NaN.
        assert!(x[5] > 0.999 && x[5].is_finite());
    }

    #[test]
    fn relu_and_backward() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut dy = vec![5.0, 5.0, 5.0];
        relu_backward(&mut dy, &x);
        assert_eq!(dy, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn axpy_dot_scale() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![7.0, 10.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![3.5, 5.0]);
    }
}
