//! Live observability registry: lock-cheap atomic counters/gauges fed
//! from the points where the coordinator / shard drivers already
//! observe the facts (`CommLedger` annotation, `PhaseTracker`
//! transitions, `RoundTable` close, `EventLog` emits), plus a
//! dependency-free Prometheus text-exposition encoder.
//!
//! # Contract
//!
//! * **Feeding is wait-free.** Every mutator is a single relaxed
//!   atomic op; the round hot path never takes a lock or allocates to
//!   update a metric. Like the [`EventLog`], observability must never
//!   fail — or slow — the run it observes.
//! * **Counters bit-match the ledger.** The driver feeds each counter
//!   at the *same call site*, with the *same value*, as the
//!   corresponding `CommLedger` annotation, so at run end
//!   `sparsignd_uplink_wire_bytes_total` equals
//!   `CommLedger::total_uplink_wire_bytes()` exactly (pinned by
//!   `tests/metrics_scrape.rs`).
//! * **Rendering reads live.** [`MetricsRegistry::render`] is called
//!   from the reactor's HTTP responder on the same thread that pumps
//!   the protocol; it only loads atomics and formats integers, so a
//!   scrape costs microseconds and can never stall a round close.
//!
//! Label grammar (DESIGN.md §17): every sample carries a `role` label
//! (`root` or `shard`), shard registries additionally carry
//! `shard="<index>"`, and the per-kind reject counter fans out over a
//! `kind` label matching the ledger's `rejects_by_kind` order.
//!
//! [`EventLog`]: crate::net::EventLog

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::REJECT_KINDS;

/// Round-phase gauge values (`sparsignd_round_phase`). A scraper can
/// map the number back through DESIGN.md §17's table.
pub mod phase {
    /// Waiting: rendezvous, or between rounds.
    pub const IDLE: u64 = 0;
    /// A `RoundOpen` broadcast is out.
    pub const OPEN: u64 = 1;
    /// Collecting updates for the open round.
    pub const AGGREGATE: u64 = 2;
    /// Folding + broadcasting the round result.
    pub const BROADCAST: u64 = 3;
    /// `Fin` sent; the run is over (the linger window scrapes this).
    pub const FINISHED: u64 = 4;
}

/// Reject-kind label values, in the ledger's `rejects_by_kind` /
/// [`RejectReason::index`] order.
///
/// [`RejectReason::index`]: crate::net::RejectReason::index
pub const REJECT_KIND_LABELS: [&str; REJECT_KINDS] =
    ["bad_round", "not_selected", "duplicate", "late", "unknown_worker", "wrong_client"];

/// The shared registry. Cloned as an `Arc` into the driver (writer) and
/// the reactor's scrape responder (reader); all fields are plain
/// `AtomicU64`s so neither side ever blocks the other.
#[derive(Debug)]
pub struct MetricsRegistry {
    /// Pre-rendered constant label set (e.g. `role="shard",shard="1"`).
    labels: String,

    // Gauges.
    round_phase: AtomicU64,
    round: AtomicU64,
    roster_workers: AtomicU64,
    cohort_size: AtomicU64,
    snapshot_age_rounds: AtomicU64,

    // Counters.
    rounds_closed: AtomicU64,
    stragglers: AtomicU64,
    heal_attempts: AtomicU64,
    upstream_reconnects: AtomicU64,
    uplink_wire_bytes: AtomicU64,
    downlink_wire_bytes: AtomicU64,
    shard_uplink_wire_bytes: AtomicU64,
    shard_downlink_wire_bytes: AtomicU64,
    rejects: [AtomicU64; REJECT_KINDS],
    scrapes: AtomicU64,
    scrapers_dropped: AtomicU64,
}

impl MetricsRegistry {
    fn with_labels(labels: String) -> Arc<Self> {
        Arc::new(MetricsRegistry {
            labels,
            round_phase: AtomicU64::new(phase::IDLE),
            round: AtomicU64::new(0),
            roster_workers: AtomicU64::new(0),
            cohort_size: AtomicU64::new(0),
            snapshot_age_rounds: AtomicU64::new(0),
            rounds_closed: AtomicU64::new(0),
            stragglers: AtomicU64::new(0),
            heal_attempts: AtomicU64::new(0),
            upstream_reconnects: AtomicU64::new(0),
            uplink_wire_bytes: AtomicU64::new(0),
            downlink_wire_bytes: AtomicU64::new(0),
            shard_uplink_wire_bytes: AtomicU64::new(0),
            shard_downlink_wire_bytes: AtomicU64::new(0),
            rejects: Default::default(),
            scrapes: AtomicU64::new(0),
            scrapers_dropped: AtomicU64::new(0),
        })
    }

    /// Registry for the root coordinator (`role="root"`).
    pub fn root() -> Arc<Self> {
        Self::with_labels("role=\"root\"".into())
    }

    /// Registry for aggregator shard `i` (`role="shard",shard="i"`).
    pub fn shard(i: usize) -> Arc<Self> {
        Self::with_labels(format!("role=\"shard\",shard=\"{i}\""))
    }

    // -- gauge mutators (one relaxed store each) ----------------------

    /// Set the round-phase gauge (a [`phase`] constant).
    pub fn set_phase(&self, p: u64) {
        self.round_phase.store(p, Ordering::Relaxed);
    }

    /// Set the current-round gauge (0-based round index).
    pub fn set_round(&self, t: u64) {
        self.round.store(t, Ordering::Relaxed);
    }

    /// A claim covered `n` more workers (rendezvous / reclaim).
    pub fn roster_add(&self, n: u64) {
        self.roster_workers.fetch_add(n, Ordering::Relaxed);
    }

    /// A dead connection released a claim over `n` workers.
    pub fn roster_sub(&self, n: u64) {
        // Saturating: a release can only follow a claim, but a metrics
        // bug must never panic the driver.
        let _ = self.roster_workers.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(n))
        });
    }

    /// Set the selected-cohort-size gauge for the open round.
    pub fn set_cohort(&self, n: u64) {
        self.cohort_size.store(n, Ordering::Relaxed);
    }

    /// Set the rounds-since-last-snapshot gauge.
    pub fn set_snapshot_age(&self, rounds: u64) {
        self.snapshot_age_rounds.store(rounds, Ordering::Relaxed);
    }

    // -- counter mutators ---------------------------------------------

    /// Fold one closed round's wire accounting — called at the exact
    /// `CommLedger::annotate_wire_tiered` call site with the same
    /// values, which is what makes the totals bit-match `history_json`.
    pub fn observe_round_close(
        &self,
        uplink_wire_bytes: u64,
        downlink_wire_bytes: u64,
        shard_uplink_wire_bytes: u64,
        shard_downlink_wire_bytes: u64,
        stragglers: u64,
    ) {
        self.rounds_closed.fetch_add(1, Ordering::Relaxed);
        self.uplink_wire_bytes.fetch_add(uplink_wire_bytes, Ordering::Relaxed);
        self.downlink_wire_bytes.fetch_add(downlink_wire_bytes, Ordering::Relaxed);
        self.shard_uplink_wire_bytes.fetch_add(shard_uplink_wire_bytes, Ordering::Relaxed);
        self.shard_downlink_wire_bytes.fetch_add(shard_downlink_wire_bytes, Ordering::Relaxed);
        self.stragglers.fetch_add(stragglers, Ordering::Relaxed);
    }

    /// Fold a typed-reject batch — called at the `CommLedger::add_rejects`
    /// call sites with the same array.
    pub fn add_rejects(&self, by_kind: &[u64; REJECT_KINDS]) {
        for (acc, &n) in self.rejects.iter().zip(by_kind) {
            if n > 0 {
                acc.fetch_add(n, Ordering::Relaxed);
            }
        }
    }

    /// Shard-tier downlink bytes received outside a round close (a
    /// shard counts upstream control frames per frame, since its
    /// downlink is not attributable to one local round).
    pub fn add_shard_downlink_wire_bytes(&self, n: u64) {
        self.shard_downlink_wire_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// One strict-healing re-open (`recoverage` event).
    pub fn inc_heal_attempt(&self) {
        self.heal_attempts.fetch_add(1, Ordering::Relaxed);
    }

    /// One shard→root redial after an upstream loss (shards only).
    pub fn inc_upstream_reconnect(&self) {
        self.upstream_reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// One successful `/metrics` response (fed by the reactor).
    pub fn inc_scrape(&self) {
        self.scrapes.fetch_add(1, Ordering::Relaxed);
    }

    /// One scraper connection dropped for hostility (oversized request,
    /// non-GET, unknown path, over the connection cap).
    pub fn inc_scraper_dropped(&self) {
        self.scrapers_dropped.fetch_add(1, Ordering::Relaxed);
    }

    // -- rendering ----------------------------------------------------

    /// Render the Prometheus text exposition (format 0.0.4). Pure
    /// atomic loads + integer formatting; no locks, no I/O.
    pub fn render(&self) -> String {
        let mut s = String::with_capacity(2048);
        let mut gauge = |name: &str, help: &str, v: u64| {
            sample(&mut s, name, help, "gauge", &self.labels, "", v);
        };
        gauge("sparsignd_round_phase", "Round phase (0 idle, 1 open, 2 aggregate, 3 broadcast, 4 finished).", self.round_phase.load(Ordering::Relaxed));
        gauge("sparsignd_round", "Current 0-based round index.", self.round.load(Ordering::Relaxed));
        gauge("sparsignd_roster_workers", "Workers covered by live connection claims.", self.roster_workers.load(Ordering::Relaxed));
        gauge("sparsignd_cohort_size", "Workers selected for the open round.", self.cohort_size.load(Ordering::Relaxed));
        gauge("sparsignd_snapshot_age_rounds", "Rounds closed since the last snapshot.", self.snapshot_age_rounds.load(Ordering::Relaxed));
        let mut counter = |name: &str, help: &str, v: u64| {
            sample(&mut s, name, help, "counter", &self.labels, "", v);
        };
        counter("sparsignd_rounds_closed_total", "Rounds closed (ledgered) by this process.", self.rounds_closed.load(Ordering::Relaxed));
        counter("sparsignd_stragglers_total", "Selected workers that missed a round close.", self.stragglers.load(Ordering::Relaxed));
        counter("sparsignd_heal_attempts_total", "Strict-healing round re-opens.", self.heal_attempts.load(Ordering::Relaxed));
        counter("sparsignd_upstream_reconnects_total", "Shard-to-root redials after an upstream loss.", self.upstream_reconnects.load(Ordering::Relaxed));
        counter("sparsignd_uplink_wire_bytes_total", "Client-tier uplink frame bytes in closed rounds.", self.uplink_wire_bytes.load(Ordering::Relaxed));
        counter("sparsignd_downlink_wire_bytes_total", "Client-tier downlink frame bytes in closed rounds.", self.downlink_wire_bytes.load(Ordering::Relaxed));
        counter("sparsignd_shard_uplink_wire_bytes_total", "Shard-tier uplink frame bytes in closed rounds.", self.shard_uplink_wire_bytes.load(Ordering::Relaxed));
        counter("sparsignd_shard_downlink_wire_bytes_total", "Shard-tier downlink frame bytes in closed rounds.", self.shard_downlink_wire_bytes.load(Ordering::Relaxed));
        counter("sparsignd_scrapes_total", "Successful /metrics responses.", self.scrapes.load(Ordering::Relaxed));
        counter("sparsignd_scrapers_dropped_total", "Scraper connections dropped for hostility.", self.scrapers_dropped.load(Ordering::Relaxed));
        // The per-kind reject counter is one family with a `kind` label.
        s.push_str("# HELP sparsignd_rejects_total Typed protocol rejects, by kind.\n");
        s.push_str("# TYPE sparsignd_rejects_total counter\n");
        for (kind, acc) in REJECT_KIND_LABELS.iter().zip(&self.rejects) {
            s.push_str(&format!(
                "sparsignd_rejects_total{{{},kind=\"{kind}\"}} {}\n",
                self.labels,
                acc.load(Ordering::Relaxed)
            ));
        }
        s
    }

    /// Snapshot of the per-kind reject counters (ledger order).
    pub fn rejects_by_kind(&self) -> [u64; REJECT_KINDS] {
        let mut out = [0u64; REJECT_KINDS];
        for (o, acc) in out.iter_mut().zip(&self.rejects) {
            *o = acc.load(Ordering::Relaxed);
        }
        out
    }
}

fn sample(
    s: &mut String,
    name: &str,
    help: &str,
    mtype: &str,
    labels: &str,
    extra: &str,
    v: u64,
) {
    s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {mtype}\n"));
    s.push_str(&format!("{name}{{{labels}{extra}}} {v}\n"));
}

/// One parsed exposition sample: metric name, `(label, value)` pairs,
/// integer sample value.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: u64,
}

/// Parse the subset of the Prometheus text format [`render`] emits
/// (`name{k="v",…} integer` lines; `#` comment lines skipped). Used by
/// the scrape tests and the soak supervisor's monotonicity check —
/// deliberately minimal, like [`parse_flat_json`].
///
/// [`render`]: MetricsRegistry::render
/// [`parse_flat_json`]: super::parse_flat_json
pub fn parse_exposition(body: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let open = line.find('{').ok_or_else(|| format!("no label block in {line:?}"))?;
        let close = line.rfind('}').ok_or_else(|| format!("no label close in {line:?}"))?;
        if close < open {
            return Err(format!("malformed label block in {line:?}"));
        }
        let name = line[..open].to_string();
        let mut labels = Vec::new();
        for pair in line[open + 1..close].split(',').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').ok_or_else(|| format!("bad label {pair:?}"))?;
            let v = v.strip_prefix('"').and_then(|v| v.strip_suffix('"'));
            let v = v.ok_or_else(|| format!("unquoted label value in {pair:?}"))?;
            labels.push((k.to_string(), v.to_string()));
        }
        let value = line[close + 1..]
            .trim()
            .parse::<u64>()
            .map_err(|_| format!("bad sample value in {line:?}"))?;
        out.push(Sample { name, labels, value });
    }
    Ok(out)
}

/// The value of `name` in a parsed exposition, requiring every label in
/// `want` to match. `None` if absent.
pub fn sample_value(samples: &[Sample], name: &str, want: &[(&str, &str)]) -> Option<u64> {
    samples
        .iter()
        .find(|s| {
            s.name == name
                && want.iter().all(|(k, v)| {
                    s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                })
        })
        .map(|s| s.value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_render_bit_exact_totals() {
        let r = MetricsRegistry::root();
        r.observe_round_close(100, 200, 30, 40, 2);
        r.observe_round_close(1, 2, 3, 4, 0);
        r.add_rejects(&[1, 0, 2, 0, 0, 0]);
        r.add_rejects(&[0, 0, 1, 0, 0, 5]);
        let samples = parse_exposition(&r.render()).expect("render parses");
        let root = [("role", "root")];
        assert_eq!(sample_value(&samples, "sparsignd_rounds_closed_total", &root), Some(2));
        assert_eq!(sample_value(&samples, "sparsignd_uplink_wire_bytes_total", &root), Some(101));
        assert_eq!(sample_value(&samples, "sparsignd_downlink_wire_bytes_total", &root), Some(202));
        assert_eq!(
            sample_value(&samples, "sparsignd_shard_uplink_wire_bytes_total", &root),
            Some(33)
        );
        assert_eq!(
            sample_value(&samples, "sparsignd_shard_downlink_wire_bytes_total", &root),
            Some(44)
        );
        assert_eq!(sample_value(&samples, "sparsignd_stragglers_total", &root), Some(2));
        assert_eq!(r.rejects_by_kind(), [1, 0, 3, 0, 0, 5]);
        assert_eq!(
            sample_value(
                &samples,
                "sparsignd_rejects_total",
                &[("role", "root"), ("kind", "duplicate")]
            ),
            Some(3)
        );
        assert_eq!(
            sample_value(
                &samples,
                "sparsignd_rejects_total",
                &[("role", "root"), ("kind", "wrong_client")]
            ),
            Some(5)
        );
    }

    #[test]
    fn label_grammar_distinguishes_roles_and_shards() {
        let s1 = MetricsRegistry::shard(1);
        s1.set_round(7);
        let body = s1.render();
        assert!(body.contains("sparsignd_round{role=\"shard\",shard=\"1\"} 7"));
        let samples = parse_exposition(&body).expect("parses");
        assert_eq!(
            sample_value(&samples, "sparsignd_round", &[("role", "shard"), ("shard", "1")]),
            Some(7)
        );
        assert_eq!(sample_value(&samples, "sparsignd_round", &[("shard", "0")]), None);
    }

    #[test]
    fn gauges_overwrite_and_roster_saturates() {
        let r = MetricsRegistry::root();
        r.set_phase(phase::AGGREGATE);
        r.set_cohort(32);
        r.set_snapshot_age(5);
        r.roster_add(10);
        r.roster_sub(4);
        r.roster_sub(100); // saturates at zero, never panics
        let samples = parse_exposition(&r.render()).expect("parses");
        let root = [("role", "root")];
        assert_eq!(sample_value(&samples, "sparsignd_round_phase", &root), Some(2));
        assert_eq!(sample_value(&samples, "sparsignd_cohort_size", &root), Some(32));
        assert_eq!(sample_value(&samples, "sparsignd_snapshot_age_rounds", &root), Some(5));
        assert_eq!(sample_value(&samples, "sparsignd_roster_workers", &root), Some(0));
    }

    #[test]
    fn exposition_has_help_and_type_per_family() {
        let body = MetricsRegistry::root().render();
        for family in [
            "sparsignd_round_phase",
            "sparsignd_rounds_closed_total",
            "sparsignd_rejects_total",
        ] {
            assert_eq!(
                body.matches(&format!("# HELP {family} ")).count(),
                1,
                "exactly one HELP line for {family}"
            );
            assert_eq!(body.matches(&format!("# TYPE {family} ")).count(), 1);
        }
        // Six kind-labelled samples share the one rejects family.
        assert_eq!(body.matches("sparsignd_rejects_total{").count(), REJECT_KINDS);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_exposition("metric_without_labels 3").is_err());
        assert!(parse_exposition("m{k=unquoted} 3").is_err());
        assert!(parse_exposition("m{k=\"v\"} not-a-number").is_err());
        assert!(parse_exposition("# just a comment\n").expect("comments ok").is_empty());
    }
}
