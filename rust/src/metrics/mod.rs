//! Run aggregation and table/CSV rendering for the experiment harnesses.
//!
//! The paper reports, per algorithm: final accuracy `mean±std` over seeds,
//! median communication rounds to a target accuracy, and the uplink bits
//! at that point. [`RunSummary`] computes exactly those from a set of
//! seeded [`RunHistory`]s and [`TablePrinter`] renders the paper-style
//! table.

pub mod registry;

use crate::coordinator::RunHistory;
use crate::util::stats::{self, fmt_bits, fmt_pct};

/// Aggregated results for one algorithm across seeds.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub label: String,
    pub seeds: usize,
    pub final_acc_mean: f64,
    pub final_acc_std: f64,
    /// Median rounds to each requested target (None = "N.A.": some seed
    /// never reached it — matching the paper's convention that the
    /// algorithm does not achieve the accuracy).
    pub rounds_to_target: Vec<Option<f64>>,
    /// Median uplink bits to each requested target.
    pub bits_to_target: Vec<Option<f64>>,
    pub targets: Vec<f64>,
    /// Mean total uplink over the whole run.
    pub total_uplink_mean: f64,
}

impl RunSummary {
    /// Summarize `runs` (one per seed) against accuracy `targets`.
    pub fn from_runs(runs: &[RunHistory], targets: &[f64]) -> Self {
        assert!(!runs.is_empty());
        let label = runs[0].label.clone();
        let accs: Vec<f64> = runs
            .iter()
            .map(|r| r.final_eval().map(|(_, a)| a).unwrap_or(0.0))
            .collect();
        let mut rounds_to_target = Vec::with_capacity(targets.len());
        let mut bits_to_target = Vec::with_capacity(targets.len());
        for &t in targets {
            let rr: Vec<Option<usize>> = runs.iter().map(|r| r.rounds_to_acc(t)).collect();
            if rr.iter().any(|x| x.is_none()) {
                rounds_to_target.push(None);
                bits_to_target.push(None);
            } else {
                let rv: Vec<f64> = rr.iter().map(|x| x.unwrap() as f64).collect();
                let bv: Vec<f64> =
                    runs.iter().map(|r| r.uplink_to_acc(t).unwrap()).collect();
                rounds_to_target.push(Some(stats::median(&rv)));
                bits_to_target.push(Some(stats::median(&bv)));
            }
        }
        RunSummary {
            label,
            seeds: runs.len(),
            final_acc_mean: stats::mean(&accs),
            final_acc_std: stats::std_dev(&accs),
            rounds_to_target,
            bits_to_target,
            targets: targets.to_vec(),
            total_uplink_mean: stats::mean(
                &runs.iter().map(|r| r.total_uplink()).collect::<Vec<_>>(),
            ),
        }
    }

    /// Row cells: label, final acc, rounds per target, bits per target.
    pub fn row(&self) -> Vec<String> {
        let mut cells = vec![
            self.label.clone(),
            fmt_pct(self.final_acc_mean, self.final_acc_std),
        ];
        let rounds: Vec<String> = self
            .rounds_to_target
            .iter()
            .map(|r| r.map(|v| format!("{v:.0}")).unwrap_or_else(|| "N.A.".into()))
            .collect();
        cells.push(rounds.join("/"));
        let bits: Vec<String> = self
            .bits_to_target
            .iter()
            .map(|b| b.map(fmt_bits).unwrap_or_else(|| "N.A.".into()))
            .collect();
        cells.push(bits.join("/"));
        cells
    }
}

/// Fixed-width table renderer (the harnesses print paper-style tables to
/// stdout and EXPERIMENTS.md records them).
#[derive(Clone, Debug, Default)]
pub struct TablePrinter {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn add_summary(&mut self, s: &RunSummary) {
        self.add_row(s.row());
    }

    /// Render as an aligned markdown-ish table (widths in *chars*, so
    /// multibyte cells like `±` align correctly).
    pub fn render(&self) -> String {
        let clen = |s: &str| s.chars().count();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| clen(h)).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(clen(c));
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_line = |cells: &[String], widths: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}", w = w))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        out.push_str(&fmt_line(&self.headers, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|", sep.join("-|-")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_line(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Minimal CSV emitter for figure series.
pub fn write_csv(path: &str, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", headers.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Bit-exact RunHistory JSON (the resume-equivalence CI artifact).
// ---------------------------------------------------------------------

/// Hex of an f64's bit pattern — two histories render to identical
/// strings iff they are bit-identical, which is what the CI
/// `resume-equivalence` job `cmp`s across the kill+resume boundary.
fn f64_bits(v: f64) -> String {
    format!("\"{:016x}\"", v.to_bits())
}

fn opt_eval(e: Option<(f64, f64)>) -> String {
    match e {
        None => "null".into(),
        Some((l, a)) => format!("[{}, {}]", f64_bits(l), f64_bits(a)),
    }
}

/// Render a [`RunHistory`] as JSON with every float as its exact bit
/// pattern (hex strings). Field-exact: reports, final parameters and
/// the full communication ledger — `cmp`-ing two of these is the
/// bit-identity check from DESIGN.md §12.
pub fn history_json(h: &RunHistory) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"label\": {:?},\n", h.label));
    s.push_str(&format!("  \"dim\": {},\n", h.dim));
    s.push_str("  \"final_params\": [");
    for (i, p) in h.final_params.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("\"{:08x}\"", p.to_bits()));
    }
    s.push_str("],\n  \"reports\": [\n");
    for (i, r) in h.reports.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"round\": {}, \"lr\": {}, \"train_loss\": {}, \"eval\": {}, \
             \"uplink_bits\": {}, \"downlink_bits\": {}, \"cum_uplink_bits\": {}}}{}\n",
            r.round,
            f64_bits(r.lr),
            f64_bits(r.train_loss),
            opt_eval(r.eval),
            f64_bits(r.uplink_bits),
            f64_bits(r.downlink_bits),
            f64_bits(r.cum_uplink_bits),
            if i + 1 < h.reports.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n  \"ledger\": [\n");
    let recs = h.ledger.records();
    for (i, rc) in recs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"uplink_bits\": {}, \"downlink_bits\": {}, \"senders\": {}, \
             \"uplink_nnz\": {}, \"uplink_wire_bytes\": {}, \"downlink_wire_bytes\": {}, \
             \"shard_uplink_wire_bytes\": {}, \"shard_downlink_wire_bytes\": {}, \
             \"stragglers\": {}}}{}\n",
            f64_bits(rc.uplink_bits),
            f64_bits(rc.downlink_bits),
            rc.senders,
            rc.uplink_nnz,
            rc.uplink_wire_bytes,
            rc.downlink_wire_bytes,
            rc.shard_uplink_wire_bytes,
            rc.shard_downlink_wire_bytes,
            rc.stragglers,
            if i + 1 < recs.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n  \"rejects_by_kind\": [");
    for (i, n) in h.ledger.rejects_by_kind().iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&n.to_string());
    }
    s.push_str("]\n}\n");
    s
}

/// Write [`history_json`] to `path`.
pub fn write_history_json(path: &str, h: &RunHistory) -> std::io::Result<()> {
    std::fs::write(path, history_json(h))
}

// ---------------------------------------------------------------------
// Flat benchmark-JSON parsing (the CI bench-trajectory gate).
// ---------------------------------------------------------------------

/// A value in the flat `BENCH_*.json` vocabulary.
#[derive(Clone, Debug, PartialEq)]
pub enum FlatVal {
    Num(f64),
    Str(String),
}

impl FlatVal {
    /// Numeric view (`None` for strings).
    pub fn num(&self) -> Option<f64> {
        match self {
            FlatVal::Num(v) => Some(*v),
            FlatVal::Str(_) => None,
        }
    }
}

/// Parse the flat `{"key": number-or-string, …}` JSON the perf bench
/// emits (`BENCH_hotpaths.json`). Deliberately minimal — one nesting
/// level, no escapes — matching the emitter exactly; anything else is a
/// descriptive error. Order-preserving so delta tables read like the
/// bench output.
pub fn parse_flat_json(s: &str) -> Result<Vec<(String, FlatVal)>, String> {
    let mut out = Vec::new();
    let b = s.as_bytes();
    let mut i = 0usize;
    let skip_ws = |i: &mut usize| {
        while *i < b.len() && (b[*i] as char).is_ascii_whitespace() {
            *i += 1;
        }
    };
    let parse_str = |i: &mut usize| -> Result<String, String> {
        if *i >= b.len() || b[*i] != b'"' {
            return Err(format!("expected '\"' at byte {i:?}", i = *i));
        }
        *i += 1;
        let start = *i;
        while *i < b.len() && b[*i] != b'"' {
            if b[*i] == b'\\' {
                return Err("escapes are not part of the flat-json vocabulary".into());
            }
            *i += 1;
        }
        if *i >= b.len() {
            return Err("unterminated string".into());
        }
        let v = String::from_utf8_lossy(&b[start..*i]).into_owned();
        *i += 1;
        Ok(v)
    };
    skip_ws(&mut i);
    if i >= b.len() || b[i] != b'{' {
        return Err("expected '{'".into());
    }
    i += 1;
    loop {
        skip_ws(&mut i);
        if i < b.len() && b[i] == b'}' {
            i += 1;
            break;
        }
        let key = parse_str(&mut i)?;
        skip_ws(&mut i);
        if i >= b.len() || b[i] != b':' {
            return Err(format!("expected ':' after key {key:?}"));
        }
        i += 1;
        skip_ws(&mut i);
        let val = if i < b.len() && b[i] == b'"' {
            FlatVal::Str(parse_str(&mut i)?)
        } else {
            let start = i;
            while i < b.len() && !matches!(b[i], b',' | b'}' | b'\n' | b' ' | b'\t' | b'\r') {
                i += 1;
            }
            let raw = std::str::from_utf8(&b[start..i]).map_err(|_| "non-utf8 number")?;
            FlatVal::Num(
                raw.parse::<f64>().map_err(|_| format!("bad number {raw:?} for key {key:?}"))?,
            )
        };
        out.push((key, val));
        skip_ws(&mut i);
        if i < b.len() && b[i] == b',' {
            i += 1;
            continue;
        }
        skip_ws(&mut i);
        if i < b.len() && b[i] == b'}' {
            i += 1;
            break;
        }
        if i >= b.len() {
            return Err("unterminated object".into());
        }
    }
    skip_ws(&mut i);
    if i != b.len() {
        return Err("trailing bytes after object".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{RoundReport, RunHistory};

    fn fake_run(accs: &[(usize, f64)], bits_per_round: f64, rounds: usize) -> RunHistory {
        let mut reports = Vec::new();
        let mut cum = 0.0;
        for t in 0..rounds {
            cum += bits_per_round;
            let eval = accs
                .iter()
                .find(|(r, _)| *r == t)
                .map(|(_, a)| (0.5, *a));
            reports.push(RoundReport {
                round: t,
                lr: 0.1,
                train_loss: 1.0,
                eval,
                uplink_bits: bits_per_round,
                downlink_bits: 1.0,
                cum_uplink_bits: cum,
            });
        }
        RunHistory {
            label: "fake".into(),
            dim: 4,
            reports,
            final_params: vec![],
            ledger: crate::coordinator::CommLedger::new(),
        }
    }

    #[test]
    fn summary_extracts_targets() {
        let r1 = fake_run(&[(4, 0.5), (9, 0.8)], 10.0, 10);
        let r2 = fake_run(&[(4, 0.6), (9, 0.9)], 10.0, 10);
        let s = RunSummary::from_runs(&[r1, r2], &[0.55, 0.75, 0.99]);
        assert_eq!(s.seeds, 2);
        assert!((s.final_acc_mean - 0.85).abs() < 1e-12);
        // Target 0.55: run1 reaches at round 10 (acc 0.8@t=9 → 1-based 10),
        // run2 at round 5. Median = 7.5.
        assert_eq!(s.rounds_to_target[0], Some(7.5));
        assert_eq!(s.rounds_to_target[1], Some(10.0));
        assert_eq!(s.rounds_to_target[2], None);
        assert!(s.bits_to_target[0].unwrap() > 0.0);
        let row = s.row();
        assert!(row[3].contains("N.A."));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TablePrinter::new("Table X", &["Algorithm", "Acc"]);
        t.add_row(vec!["signSGD".into(), "74.44±0.71%".into()]);
        t.add_row(vec!["a".into(), "b".into()]);
        let s = t.render();
        assert!(s.contains("## Table X"));
        assert!(s.contains("signSGD"));
        assert!(s.contains("74.44±0.71%"));
        // Every table line has the same rendered (char) width.
        let widths: Vec<usize> = s
            .lines()
            .filter(|l| l.starts_with('|'))
            .map(|l| l.chars().count())
            .collect();
        assert!(widths.len() >= 4);
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{widths:?}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = TablePrinter::new("t", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn history_json_is_bit_exact_and_distinguishes_ulps() {
        let a = fake_run(&[(1, 0.5)], 10.0, 2);
        let mut b = a.clone();
        assert_eq!(history_json(&a), history_json(&b));
        // A one-ulp nudge must change the rendering — decimal formatting
        // would round it away, hex bit patterns cannot.
        b.reports[0].train_loss = f64::from_bits(b.reports[0].train_loss.to_bits() + 1);
        assert_ne!(history_json(&a), history_json(&b));
        assert!(history_json(&a).contains("\"ledger\""));
        // Typed reject counters ride along; an honest run renders all zeros.
        assert!(history_json(&a).contains("\"rejects_by_kind\": [0, 0, 0, 0, 0, 0]"));
        let mut c = a.clone();
        c.ledger.add_rejects(&[0, 2, 0, 0, 1, 0]);
        assert!(history_json(&c).contains("\"rejects_by_kind\": [0, 2, 0, 0, 1, 0]"));
    }

    #[test]
    fn flat_json_roundtrips_the_bench_emitter_format() {
        let body = "{\n  \"kernel\": \"avx2+fma 6x16\",\n  \"gemm_gflops\": 41.125000,\n  \
                    \"neg\": -2.5\n}\n";
        let kv = parse_flat_json(body).expect("parse");
        assert_eq!(kv.len(), 3);
        assert_eq!(kv[0], ("kernel".into(), FlatVal::Str("avx2+fma 6x16".into())));
        assert_eq!(kv[1].1.num(), Some(41.125));
        assert_eq!(kv[2].1.num(), Some(-2.5));
        // Empty object and malformed bodies.
        assert!(parse_flat_json("{}").expect("empty").is_empty());
        assert!(parse_flat_json("{\"a\": }").is_err());
        assert!(parse_flat_json("[1]").is_err());
        assert!(parse_flat_json("{\"a\": 1} x").is_err());
    }
}
