//! Run aggregation and table/CSV rendering for the experiment harnesses.
//!
//! The paper reports, per algorithm: final accuracy `mean±std` over seeds,
//! median communication rounds to a target accuracy, and the uplink bits
//! at that point. [`RunSummary`] computes exactly those from a set of
//! seeded [`RunHistory`]s and [`TablePrinter`] renders the paper-style
//! table.

use crate::coordinator::RunHistory;
use crate::util::stats::{self, fmt_bits, fmt_pct};

/// Aggregated results for one algorithm across seeds.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub label: String,
    pub seeds: usize,
    pub final_acc_mean: f64,
    pub final_acc_std: f64,
    /// Median rounds to each requested target (None = "N.A.": some seed
    /// never reached it — matching the paper's convention that the
    /// algorithm does not achieve the accuracy).
    pub rounds_to_target: Vec<Option<f64>>,
    /// Median uplink bits to each requested target.
    pub bits_to_target: Vec<Option<f64>>,
    pub targets: Vec<f64>,
    /// Mean total uplink over the whole run.
    pub total_uplink_mean: f64,
}

impl RunSummary {
    /// Summarize `runs` (one per seed) against accuracy `targets`.
    pub fn from_runs(runs: &[RunHistory], targets: &[f64]) -> Self {
        assert!(!runs.is_empty());
        let label = runs[0].label.clone();
        let accs: Vec<f64> = runs
            .iter()
            .map(|r| r.final_eval().map(|(_, a)| a).unwrap_or(0.0))
            .collect();
        let mut rounds_to_target = Vec::with_capacity(targets.len());
        let mut bits_to_target = Vec::with_capacity(targets.len());
        for &t in targets {
            let rr: Vec<Option<usize>> = runs.iter().map(|r| r.rounds_to_acc(t)).collect();
            if rr.iter().any(|x| x.is_none()) {
                rounds_to_target.push(None);
                bits_to_target.push(None);
            } else {
                let rv: Vec<f64> = rr.iter().map(|x| x.unwrap() as f64).collect();
                let bv: Vec<f64> =
                    runs.iter().map(|r| r.uplink_to_acc(t).unwrap()).collect();
                rounds_to_target.push(Some(stats::median(&rv)));
                bits_to_target.push(Some(stats::median(&bv)));
            }
        }
        RunSummary {
            label,
            seeds: runs.len(),
            final_acc_mean: stats::mean(&accs),
            final_acc_std: stats::std_dev(&accs),
            rounds_to_target,
            bits_to_target,
            targets: targets.to_vec(),
            total_uplink_mean: stats::mean(
                &runs.iter().map(|r| r.total_uplink()).collect::<Vec<_>>(),
            ),
        }
    }

    /// Row cells: label, final acc, rounds per target, bits per target.
    pub fn row(&self) -> Vec<String> {
        let mut cells = vec![
            self.label.clone(),
            fmt_pct(self.final_acc_mean, self.final_acc_std),
        ];
        let rounds: Vec<String> = self
            .rounds_to_target
            .iter()
            .map(|r| r.map(|v| format!("{v:.0}")).unwrap_or_else(|| "N.A.".into()))
            .collect();
        cells.push(rounds.join("/"));
        let bits: Vec<String> = self
            .bits_to_target
            .iter()
            .map(|b| b.map(fmt_bits).unwrap_or_else(|| "N.A.".into()))
            .collect();
        cells.push(bits.join("/"));
        cells
    }
}

/// Fixed-width table renderer (the harnesses print paper-style tables to
/// stdout and EXPERIMENTS.md records them).
#[derive(Clone, Debug, Default)]
pub struct TablePrinter {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn add_summary(&mut self, s: &RunSummary) {
        self.add_row(s.row());
    }

    /// Render as an aligned markdown-ish table (widths in *chars*, so
    /// multibyte cells like `±` align correctly).
    pub fn render(&self) -> String {
        let clen = |s: &str| s.chars().count();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| clen(h)).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(clen(c));
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_line = |cells: &[String], widths: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}", w = w))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        out.push_str(&fmt_line(&self.headers, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|", sep.join("-|-")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_line(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Minimal CSV emitter for figure series.
pub fn write_csv(path: &str, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", headers.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{RoundReport, RunHistory};

    fn fake_run(accs: &[(usize, f64)], bits_per_round: f64, rounds: usize) -> RunHistory {
        let mut reports = Vec::new();
        let mut cum = 0.0;
        for t in 0..rounds {
            cum += bits_per_round;
            let eval = accs
                .iter()
                .find(|(r, _)| *r == t)
                .map(|(_, a)| (0.5, *a));
            reports.push(RoundReport {
                round: t,
                lr: 0.1,
                train_loss: 1.0,
                eval,
                uplink_bits: bits_per_round,
                downlink_bits: 1.0,
                cum_uplink_bits: cum,
            });
        }
        RunHistory {
            label: "fake".into(),
            dim: 4,
            reports,
            final_params: vec![],
            ledger: crate::coordinator::CommLedger::new(),
        }
    }

    #[test]
    fn summary_extracts_targets() {
        let r1 = fake_run(&[(4, 0.5), (9, 0.8)], 10.0, 10);
        let r2 = fake_run(&[(4, 0.6), (9, 0.9)], 10.0, 10);
        let s = RunSummary::from_runs(&[r1, r2], &[0.55, 0.75, 0.99]);
        assert_eq!(s.seeds, 2);
        assert!((s.final_acc_mean - 0.85).abs() < 1e-12);
        // Target 0.55: run1 reaches at round 10 (acc 0.8@t=9 → 1-based 10),
        // run2 at round 5. Median = 7.5.
        assert_eq!(s.rounds_to_target[0], Some(7.5));
        assert_eq!(s.rounds_to_target[1], Some(10.0));
        assert_eq!(s.rounds_to_target[2], None);
        assert!(s.bits_to_target[0].unwrap() > 0.0);
        let row = s.row();
        assert!(row[3].contains("N.A."));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TablePrinter::new("Table X", &["Algorithm", "Acc"]);
        t.add_row(vec!["signSGD".into(), "74.44±0.71%".into()]);
        t.add_row(vec!["a".into(), "b".into()]);
        let s = t.render();
        assert!(s.contains("## Table X"));
        assert!(s.contains("signSGD"));
        assert!(s.contains("74.44±0.71%"));
        // Every table line has the same rendered (char) width.
        let widths: Vec<usize> = s
            .lines()
            .filter(|l| l.starts_with('|'))
            .map(|l| l.chars().count())
            .collect();
        assert!(widths.len() >= 4);
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{widths:?}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = TablePrinter::new("t", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }
}
