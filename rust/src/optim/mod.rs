//! Optimizer substrate: learning-rate schedules and the SGD step used by
//! workers and the server.
//!
//! The federated algorithms themselves (Alg. 1, Alg. 2, FedAvg, FedCom)
//! live in [`crate::coordinator`]; this module provides the pieces they
//! share.

use crate::util::linalg::axpy;

/// Learning-rate schedule over communication rounds, matching the paper's
/// experimental setups (§6.2, Appendix D).
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    /// Fixed η (Fashion-MNIST).
    Const { lr: f64 },
    /// η halved at round `at` (CIFAR-10: ÷2 at round 1500).
    StepDecay { lr: f64, at: usize, factor: f64 },
    /// η divided by `factors[i]` from `milestones[i]` on
    /// (CIFAR-100: ÷2, ÷5, ÷10 at rounds 1000/3000/4500).
    MultiStep { lr: f64, milestones: Vec<usize>, factors: Vec<f64> },
    /// Theory-mode schedule η = 1/√(T·d) from Theorem 2.
    TheoryRate { total_rounds: usize, dim: usize },
}

impl LrSchedule {
    /// Learning rate at communication round `t` (0-based).
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const { lr } => *lr,
            LrSchedule::StepDecay { lr, at, factor } => {
                if t >= *at {
                    lr / factor
                } else {
                    *lr
                }
            }
            LrSchedule::MultiStep { lr, milestones, factors } => {
                assert_eq!(milestones.len(), factors.len());
                let mut cur = *lr;
                for (m, f) in milestones.iter().zip(factors) {
                    if t >= *m {
                        cur = lr / f;
                    }
                }
                cur
            }
            LrSchedule::TheoryRate { total_rounds, dim } => {
                1.0 / ((*total_rounds as f64) * (*dim as f64)).sqrt()
            }
        }
    }

    /// The paper's CIFAR-10 schedule.
    pub fn paper_cifar10(lr: f64) -> Self {
        LrSchedule::StepDecay { lr, at: 1_500, factor: 2.0 }
    }

    /// The paper's CIFAR-100 schedule.
    pub fn paper_cifar100(lr: f64) -> Self {
        LrSchedule::MultiStep {
            lr,
            milestones: vec![1_000, 3_000, 4_500],
            factors: vec![2.0, 5.0, 10.0],
        }
    }
}

/// In-place SGD step `params ← params − lr·update`.
#[inline]
pub fn sgd_step(params: &mut [f32], lr: f32, update: &[f32]) {
    axpy(params, -lr, update);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = LrSchedule::Const { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn step_decay_boundary() {
        let s = LrSchedule::paper_cifar10(0.2);
        assert_eq!(s.at(1_499), 0.2);
        assert_eq!(s.at(1_500), 0.1);
        assert_eq!(s.at(3_000), 0.1);
    }

    #[test]
    fn multistep_cifar100() {
        let s = LrSchedule::paper_cifar100(1.0);
        assert_eq!(s.at(999), 1.0);
        assert_eq!(s.at(1_000), 0.5);
        assert_eq!(s.at(2_999), 0.5);
        assert_eq!(s.at(3_000), 0.2);
        assert_eq!(s.at(4_500), 0.1);
    }

    #[test]
    fn theory_rate() {
        let s = LrSchedule::TheoryRate { total_rounds: 100, dim: 4 };
        assert!((s.at(0) - 0.05).abs() < 1e-12);
        assert_eq!(s.at(0), s.at(99));
    }

    #[test]
    fn sgd_step_moves_against_gradient() {
        let mut p = vec![1.0f32, -1.0];
        sgd_step(&mut p, 0.5, &[2.0, -2.0]);
        assert_eq!(p, vec![0.0, 0.0]);
    }
}
