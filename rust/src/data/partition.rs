//! Dirichlet(α) non-IID partitioning — the heterogeneity protocol of
//! Hsu et al. (2019) that the paper's §6.2 uses:
//!
//! > "a vector of length C that follows the Dirichlet distribution Dir(α)
//! > is generated [per worker] … each element specifies the proportion of
//! > training examples that belong to the corresponding class."
//!
//! Low α ⇒ each worker sees a few classes (severe skew); large α ⇒ IID.
//!
//! Two variants are provided: [`DirichletPartitioner::partition`] (the
//! original sampler — shards may overlap when `n < M·⌈n/M⌉`) and
//! [`DirichletPartitioner::partition_exact`], which draws **without
//! replacement** so shards are disjoint, exhaustive, and nonempty by
//! construction — the form required by the `.sgds` store manifest.

use super::{Dataset, FederatedDataset};
use crate::util::rng::Pcg64;

/// Dirichlet label-skew partitioner.
#[derive(Clone, Copy, Debug)]
pub struct DirichletPartitioner {
    /// Concentration α > 0 (the paper sweeps {0.1, 0.3, 0.5, 0.6, 1.0}).
    pub alpha: f64,
    /// Number of workers M.
    pub workers: usize,
}

impl DirichletPartitioner {
    fn check(&self, data: &Dataset) {
        assert!(self.alpha > 0.0, "Dirichlet α must be > 0, got {}", self.alpha);
        assert!(self.workers > 0, "need at least one worker");
        assert!(!data.is_empty(), "cannot partition an empty dataset");
    }

    /// Shuffled per-class index pools.
    fn pools(&self, data: &Dataset, rng: &mut Pcg64) -> Vec<Vec<usize>> {
        let classes = data.classes;
        let mut pools: Vec<Vec<usize>> = vec![Vec::new(); classes];
        for (i, &y) in data.y.iter().enumerate() {
            assert!(y < classes, "label {y} out of range");
            pools[y].push(i);
        }
        for pool in pools.iter_mut() {
            rng.shuffle(pool);
        }
        pools
    }

    /// Partition `data` into `self.workers` shards.
    ///
    /// Each worker draws class proportions `p ~ Dir(α·1_C)` and receives
    /// `⌈n/M⌉` examples sampled class-by-class from per-class pools
    /// (without replacement while a pool lasts, then cycling the pool —
    /// bounded deviation from the drawn proportions, never an empty
    /// shard).
    pub fn partition(&self, data: &Dataset, rng: &mut Pcg64) -> FederatedDataset {
        self.check(data);
        let classes = data.classes;
        let pools = self.pools(data, rng);
        let mut cursor = vec![0usize; classes];
        let present: Vec<usize> =
            (0..classes).filter(|&c| !pools[c].is_empty()).collect();
        assert!(!present.is_empty());

        let per_worker = data.len().div_ceil(self.workers);
        let mut shards = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            let p = rng.dirichlet(self.alpha, classes);
            // Mask out absent classes, renormalize.
            let mut probs = vec![0.0f64; classes];
            let mut z = 0.0;
            for &c in &present {
                probs[c] = p[c];
                z += p[c];
            }
            if z <= 0.0 {
                // Degenerate draw: fall back to uniform over present.
                for &c in &present {
                    probs[c] = 1.0 / present.len() as f64;
                }
            } else {
                for v in probs.iter_mut() {
                    *v /= z;
                }
            }
            let mut shard = Vec::with_capacity(per_worker);
            for _ in 0..per_worker {
                let c = rng.categorical(&probs);
                let c = if pools[c].is_empty() { present[rng.index(present.len())] } else { c };
                let pool = &pools[c];
                let idx = pool[cursor[c] % pool.len()];
                cursor[c] += 1;
                shard.push(idx);
            }
            shards.push(shard);
        }
        FederatedDataset::from_shards(shards)
    }

    /// Partition `data` into disjoint, exhaustive, **nonempty** shards.
    ///
    /// Shard sizes are fixed up front (the first `n mod M` workers get
    /// `⌈n/M⌉` examples, the rest `⌊n/M⌋` — the round-robin backfill that
    /// guarantees no worker draws zero samples even at extreme α); each
    /// worker then fills its quota by Dirichlet(α) class draws from the
    /// per-class pools **without replacement**, renormalizing over the
    /// classes that still have stock. Every train row lands in exactly
    /// one shard, which is what [`super::encode_store`] requires of a
    /// store manifest. Requires `n ≥ M`.
    pub fn partition_exact(&self, data: &Dataset, rng: &mut Pcg64) -> FederatedDataset {
        self.check(data);
        assert!(
            data.len() >= self.workers,
            "need at least one example per worker: n={} < M={}",
            data.len(),
            self.workers
        );
        let classes = data.classes;
        let pools = self.pools(data, rng);
        let mut cursor = vec![0usize; classes];
        let n = data.len();
        let base = n / self.workers;
        let extra = n % self.workers;

        let mut probs = vec![0.0f64; classes];
        let mut shards = Vec::with_capacity(self.workers);
        for m in 0..self.workers {
            let quota = base + usize::from(m < extra);
            let p = rng.dirichlet(self.alpha, classes);
            let mut shard = Vec::with_capacity(quota);
            for _ in 0..quota {
                // Renormalize over classes with remaining stock; pools
                // drain as we go, so this is recomputed per draw.
                let mut z = 0.0;
                let mut avail = 0usize;
                for c in 0..classes {
                    if cursor[c] < pools[c].len() {
                        probs[c] = p[c];
                        z += p[c];
                        avail += 1;
                    } else {
                        probs[c] = 0.0;
                    }
                }
                debug_assert!(avail > 0, "pools drained before quotas were met");
                if z <= 0.0 {
                    let u = 1.0 / avail as f64;
                    for c in 0..classes {
                        probs[c] = if cursor[c] < pools[c].len() { u } else { 0.0 };
                    }
                } else {
                    for v in probs.iter_mut() {
                        *v /= z;
                    }
                }
                let c = rng.categorical(&probs);
                debug_assert!(cursor[c] < pools[c].len());
                shard.push(pools[c][cursor[c]]);
                cursor[c] += 1;
            }
            shards.push(shard);
        }
        // Defensive guard (unreachable with the fixed quotas above, which
        // are ≥ 1 whenever n ≥ M): backfill any empty shard from the
        // largest one so downstream code never sees an empty client.
        for m in 0..shards.len() {
            if shards[m].is_empty() {
                let donor = (0..shards.len())
                    .max_by_key(|&d| shards[d].len())
                    .expect("at least one shard");
                let moved = shards[donor].pop().expect("donor shard nonempty");
                shards[m].push(moved);
            }
        }
        FederatedDataset::from_shards(shards)
    }
}

/// Heterogeneity diagnostics for a partition.
#[derive(Clone, Debug)]
pub struct PartitionReport {
    /// Per-worker class histograms (fractions).
    pub class_fractions: Vec<Vec<f64>>,
    /// Mean across workers of the max class fraction (1.0 ⇒ single-class
    /// workers; 1/C ⇒ perfectly uniform).
    pub mean_max_fraction: f64,
    /// Average total-variation distance from the global class marginal.
    pub mean_tv_distance: f64,
}

/// Compute skew diagnostics for `fed` over `data`.
pub fn partition_report(data: &Dataset, fed: &FederatedDataset) -> PartitionReport {
    let classes = data.classes;
    let mut global = vec![0.0f64; classes];
    for &y in &data.y {
        global[y] += 1.0;
    }
    let n = data.len() as f64;
    for g in global.iter_mut() {
        *g /= n;
    }
    let mut class_fractions = Vec::with_capacity(fed.workers());
    let mut max_sum = 0.0;
    let mut tv_sum = 0.0;
    for m in 0..fed.workers() {
        let mut hist = vec![0.0f64; classes];
        for i in fed.shard_indices(m) {
            hist[data.y[i]] += 1.0;
        }
        let total = fed.shard_len(m).max(1) as f64;
        for h in hist.iter_mut() {
            *h /= total;
        }
        max_sum += hist.iter().cloned().fold(0.0, f64::max);
        tv_sum += 0.5
            * hist
                .iter()
                .zip(&global)
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>();
        class_fractions.push(hist);
    }
    let m = fed.workers() as f64;
    PartitionReport {
        class_fractions,
        mean_max_fraction: max_sum / m,
        mean_tv_distance: tv_sum / m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{SyntheticSpec, SyntheticTask};

    fn task() -> Dataset {
        SyntheticTask::generate(
            SyntheticSpec {
                dim: 8,
                classes: 10,
                modes: 1,
                separation: 1.0,
                noise: 0.1,
                label_noise: 0.0,
                train: 2_000,
                test: 10,
            },
            11,
        )
        .train
    }

    #[test]
    fn shards_cover_and_are_nonempty() {
        let data = task();
        let part = DirichletPartitioner { alpha: 0.5, workers: 20 };
        let mut rng = Pcg64::seed_from(1);
        let fed = part.partition(&data, &mut rng);
        assert_eq!(fed.workers(), 20);
        assert!((0..fed.workers()).all(|m| fed.shard_len(m) > 0));
        assert!(fed.total() >= data.len());
        for m in 0..fed.workers() {
            assert!(fed.shard_indices(m).all(|i| i < data.len()));
        }
    }

    #[test]
    fn low_alpha_is_more_skewed_than_high_alpha() {
        let data = task();
        let mut rng = Pcg64::seed_from(2);
        let skew_low = {
            let fed = DirichletPartitioner { alpha: 0.1, workers: 50 }.partition(&data, &mut rng);
            partition_report(&data, &fed).mean_max_fraction
        };
        let skew_high = {
            let fed = DirichletPartitioner { alpha: 100.0, workers: 50 }.partition(&data, &mut rng);
            partition_report(&data, &fed).mean_max_fraction
        };
        assert!(
            skew_low > skew_high + 0.2,
            "α=0.1 skew {skew_low} vs α=100 skew {skew_high}"
        );
        // α→∞ approaches the global marginal (0.1 per class here).
        assert!(skew_high < 0.25, "{skew_high}");
    }

    #[test]
    fn tv_distance_monotone_in_alpha() {
        let data = task();
        let mut rng = Pcg64::seed_from(3);
        let mut prev = f64::INFINITY;
        for &alpha in &[0.1, 1.0, 10.0, 100.0] {
            let fed =
                DirichletPartitioner { alpha, workers: 50 }.partition(&data, &mut rng);
            let tv = partition_report(&data, &fed).mean_tv_distance;
            assert!(tv < prev + 0.05, "α={alpha}: tv {tv} prev {prev}");
            prev = tv;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = task();
        let part = DirichletPartitioner { alpha: 0.3, workers: 10 };
        let a = part.partition(&data, &mut Pcg64::seed_from(4));
        let b = part.partition(&data, &mut Pcg64::seed_from(4));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "α must be > 0")]
    fn rejects_bad_alpha() {
        let data = task();
        DirichletPartitioner { alpha: 0.0, workers: 2 }
            .partition(&data, &mut Pcg64::seed_from(5));
    }

    #[test]
    fn single_worker_gets_everything() {
        let data = task();
        let fed = DirichletPartitioner { alpha: 1.0, workers: 1 }
            .partition(&data, &mut Pcg64::seed_from(6));
        assert_eq!(fed.shard_len(0), data.len());
    }

    #[test]
    fn exact_partition_is_disjoint_exhaustive_and_skews_with_alpha() {
        let data = task();
        let mut skews = Vec::new();
        // Both α extremes from the pin: 0.05 (near one-class shards) and
        // 100 (near IID). Either way, every row appears exactly once and
        // no shard is empty.
        for &alpha in &[0.05, 100.0] {
            let part = DirichletPartitioner { alpha, workers: 64 };
            let fed = part.partition_exact(&data, &mut Pcg64::seed_from(12));
            assert_eq!(fed.workers(), 64);
            assert_eq!(fed.total(), data.len());
            let mut seen = vec![false; data.len()];
            for m in 0..fed.workers() {
                assert!(fed.shard_len(m) > 0, "α={alpha}: empty shard {m}");
                for i in fed.shard_indices(m) {
                    assert!(!seen[i], "α={alpha}: row {i} assigned twice");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "α={alpha}: uncovered rows");
            skews.push(partition_report(&data, &fed).mean_max_fraction);
        }
        assert!(
            skews[0] > skews[1] + 0.2,
            "α=0.05 skew {} should exceed α=100 skew {}",
            skews[0],
            skews[1]
        );
    }

    #[test]
    fn exact_partition_deterministic_and_balanced() {
        let data = task();
        let part = DirichletPartitioner { alpha: 0.3, workers: 7 };
        let a = part.partition_exact(&data, &mut Pcg64::seed_from(4));
        let b = part.partition_exact(&data, &mut Pcg64::seed_from(4));
        assert_eq!(a, b);
        // Quotas differ by at most one example.
        let lens: Vec<usize> = (0..a.workers()).map(|m| a.shard_len(m)).collect();
        let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        assert!(hi - lo <= 1, "{lens:?}");
    }

    #[test]
    #[should_panic(expected = "at least one example per worker")]
    fn exact_partition_rejects_more_workers_than_rows() {
        let data = task();
        DirichletPartitioner { alpha: 1.0, workers: 4_000 }
            .partition_exact(&data, &mut Pcg64::seed_from(8));
    }
}
