//! Parsers for the raw dataset downloads the paper evaluates on —
//! IDX (Fashion-MNIST/MNIST) and the CIFAR-10/100 binary format — feeding
//! the `dataset` CLI converter. Inputs must be **pre-decompressed** (the
//! crate is dependency-free, so there is no gzip decoder; `gunzip` the
//! downloads first, as the CI `dataset-parity` job does).
//!
//! Both parsers follow the hostile-input policy: magic and counts are
//! validated against the true file length before any allocation sized by
//! a header field, labels are range-checked, and every failure is a typed
//! [`IngestError`] — never a panic.

use std::path::Path;

use super::Dataset;

/// IDX magic for a rank-3 u8 tensor (image files).
const IDX_IMAGES_MAGIC: u32 = 0x0000_0803;
/// IDX magic for a rank-1 u8 tensor (label files).
const IDX_LABELS_MAGIC: u32 = 0x0000_0801;

/// CIFAR binary row payload: 32×32×3 channel-planar bytes.
const CIFAR_PIXELS: usize = 3072;

/// Raw-input caps (far above any real corpus, far below an OOM).
const MAX_RAW_BYTES: u64 = 1 << 32;
const MAX_RAW_ROWS: usize = 1 << 24;
const MAX_RAW_DIM: usize = 1 << 22;

/// Typed raw-dataset parse failure.
#[derive(Debug)]
pub enum IngestError {
    /// Filesystem-level failure.
    Io(std::io::Error),
    /// File does not start with the expected IDX magic.
    BadMagic { got: u32, want: u32 },
    /// Structural mismatch (declared counts vs. byte length, caps, …).
    Malformed(&'static str),
    /// Image and label files disagree on the example count.
    CountMismatch { images: usize, labels: usize },
}

impl std::fmt::Display for IngestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IngestError::Io(e) => write!(f, "ingest io error: {e}"),
            IngestError::BadMagic { got, want } => {
                write!(f, "bad IDX magic {got:#010x} (want {want:#010x})")
            }
            IngestError::Malformed(what) => write!(f, "malformed raw dataset: {what}"),
            IngestError::CountMismatch { images, labels } => {
                write!(f, "image/label count mismatch: {images} images vs {labels} labels")
            }
        }
    }
}

impl std::error::Error for IngestError {}

impl From<std::io::Error> for IngestError {
    fn from(e: std::io::Error) -> Self {
        IngestError::Io(e)
    }
}

fn read_capped(path: &Path) -> Result<Vec<u8>, IngestError> {
    let len = std::fs::metadata(path)?.len();
    if len > MAX_RAW_BYTES {
        return Err(IngestError::Malformed("raw file exceeds size cap"));
    }
    Ok(std::fs::read(path)?)
}

fn u32be(bytes: &[u8], at: usize) -> Result<u32, IngestError> {
    let b = bytes
        .get(at..at + 4)
        .ok_or(IngestError::Malformed("truncated IDX header"))?;
    Ok(u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
}

/// Parse an IDX image/label file pair (e.g. Fashion-MNIST
/// `train-images-idx3-ubyte` + `train-labels-idx1-ubyte`) into a
/// [`Dataset`] with pixels scaled to `[0, 1]`.
pub fn load_idx_pair(images: &Path, labels: &Path, classes: usize) -> Result<Dataset, IngestError> {
    let img = read_capped(images)?;
    let magic = u32be(&img, 0)?;
    if magic != IDX_IMAGES_MAGIC {
        return Err(IngestError::BadMagic { got: magic, want: IDX_IMAGES_MAGIC });
    }
    let n = u32be(&img, 4)? as usize;
    let rows = u32be(&img, 8)? as usize;
    let cols = u32be(&img, 12)? as usize;
    if n > MAX_RAW_ROWS {
        return Err(IngestError::Malformed("IDX example count over cap"));
    }
    let dim = rows
        .checked_mul(cols)
        .filter(|d| (1..=MAX_RAW_DIM).contains(d))
        .ok_or(IngestError::Malformed("IDX image dims out of range"))?;
    let need = n
        .checked_mul(dim)
        .and_then(|v| v.checked_add(16))
        .ok_or(IngestError::Malformed("IDX size overflow"))?;
    if img.len() != need {
        return Err(IngestError::Malformed("IDX image payload length mismatch"));
    }

    let lab = read_capped(labels)?;
    let magic = u32be(&lab, 0)?;
    if magic != IDX_LABELS_MAGIC {
        return Err(IngestError::BadMagic { got: magic, want: IDX_LABELS_MAGIC });
    }
    let ln = u32be(&lab, 4)? as usize;
    if ln != n {
        return Err(IngestError::CountMismatch { images: n, labels: ln });
    }
    if lab.len() != ln.checked_add(8).ok_or(IngestError::Malformed("IDX size overflow"))? {
        return Err(IngestError::Malformed("IDX label payload length mismatch"));
    }

    let mut x = Vec::with_capacity(n * dim);
    for &b in &img[16..] {
        x.push(b as f32 / 255.0);
    }
    let mut y = Vec::with_capacity(n);
    for &b in &lab[8..] {
        let label = b as usize;
        if label >= classes {
            return Err(IngestError::Malformed("IDX label out of class range"));
        }
        y.push(label);
    }
    Ok(Dataset { x: x.into(), y, dim, classes })
}

/// Parse one or more CIFAR binary batch files (`data_batch_*.bin` /
/// `test_batch.bin` for CIFAR-10 with `label_bytes = 1`, `train.bin` /
/// `test.bin` for CIFAR-100 with `label_bytes = 2`, where the **last**
/// label byte is the fine label) into a [`Dataset`] with pixels scaled
/// to `[0, 1]`.
pub fn load_cifar_binary(
    paths: &[&Path],
    classes: usize,
    label_bytes: usize,
) -> Result<Dataset, IngestError> {
    if paths.is_empty() {
        return Err(IngestError::Malformed("no CIFAR batch files given"));
    }
    if !(1..=2).contains(&label_bytes) {
        return Err(IngestError::Malformed("CIFAR label width must be 1 or 2"));
    }
    let record = label_bytes + CIFAR_PIXELS;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for path in paths {
        let bytes = read_capped(path)?;
        if bytes.is_empty() || bytes.len() % record != 0 {
            return Err(IngestError::Malformed("CIFAR batch is not a whole number of records"));
        }
        let n = bytes.len() / record;
        if y.len() + n > MAX_RAW_ROWS {
            return Err(IngestError::Malformed("CIFAR example count over cap"));
        }
        x.reserve(n * CIFAR_PIXELS);
        y.reserve(n);
        for rec in bytes.chunks_exact(record) {
            let label = rec[label_bytes - 1] as usize;
            if label >= classes {
                return Err(IngestError::Malformed("CIFAR label out of class range"));
            }
            y.push(label);
            for &b in &rec[label_bytes..] {
                x.push(b as f32 / 255.0);
            }
        }
    }
    Ok(Dataset { x: x.into(), y, dim: CIFAR_PIXELS, classes })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("sgds_ingest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        p
    }

    fn idx_images(n: usize, rows: usize, cols: usize, fill: u8) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&IDX_IMAGES_MAGIC.to_be_bytes());
        v.extend_from_slice(&(n as u32).to_be_bytes());
        v.extend_from_slice(&(rows as u32).to_be_bytes());
        v.extend_from_slice(&(cols as u32).to_be_bytes());
        v.resize(16 + n * rows * cols, fill);
        v
    }

    fn idx_labels(labels: &[u8]) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&IDX_LABELS_MAGIC.to_be_bytes());
        v.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        v.extend_from_slice(labels);
        v
    }

    #[test]
    fn idx_roundtrip() {
        let img = tmp("ok-images", &idx_images(3, 2, 2, 128));
        let lab = tmp("ok-labels", &idx_labels(&[0, 1, 2]));
        let d = load_idx_pair(&img, &lab, 10).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim, 4);
        assert_eq!(d.y, vec![0, 1, 2]);
        assert!((d.row(0)[0] - 128.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn idx_rejects_bad_magic_truncation_and_label_range() {
        let mut bad = idx_images(2, 2, 2, 0);
        bad[0] = 0xff;
        let img = tmp("bad-magic", &bad);
        let lab = tmp("bm-labels", &idx_labels(&[0, 1]));
        assert!(matches!(
            load_idx_pair(&img, &lab, 10),
            Err(IngestError::BadMagic { .. })
        ));

        let mut short = idx_images(2, 2, 2, 0);
        short.pop();
        let img = tmp("short-images", &short);
        assert!(matches!(
            load_idx_pair(&img, &lab, 10),
            Err(IngestError::Malformed(_))
        ));

        let img = tmp("oor-images", &idx_images(2, 2, 2, 0));
        let lab = tmp("oor-labels", &idx_labels(&[0, 9]));
        assert!(matches!(
            load_idx_pair(&img, &lab, 4),
            Err(IngestError::Malformed(_))
        ));

        let lab = tmp("count-labels", &idx_labels(&[0]));
        assert!(matches!(
            load_idx_pair(&img, &lab, 10),
            Err(IngestError::CountMismatch { images: 2, labels: 1 })
        ));
    }

    #[test]
    fn cifar_binary_roundtrip_and_rejections() {
        // Two records, CIFAR-100 style (coarse byte then fine byte).
        let mut bytes = Vec::new();
        for (coarse, fine) in [(1u8, 7u8), (0, 3)] {
            bytes.push(coarse);
            bytes.push(fine);
            bytes.resize(bytes.len() + CIFAR_PIXELS, 255u8);
        }
        let p = tmp("c100.bin", &bytes);
        let d = load_cifar_binary(&[&p], 100, 2).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.dim, CIFAR_PIXELS);
        assert_eq!(d.y, vec![7, 3]);
        assert!((d.row(1)[0] - 1.0).abs() < 1e-6);

        let ragged = tmp("ragged.bin", &bytes[..bytes.len() - 1]);
        assert!(matches!(
            load_cifar_binary(&[&ragged], 100, 2),
            Err(IngestError::Malformed(_))
        ));
        assert!(matches!(
            load_cifar_binary(&[&p], 5, 2),
            Err(IngestError::Malformed(_))
        ));
    }
}
