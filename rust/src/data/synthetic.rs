//! Synthetic classification task generators.
//!
//! Each class is a mixture of `modes` Gaussian sub-clusters on the unit
//! sphere of `ℝ^dim`, with additive feature noise and optional label noise.
//! The presets mirror the paper's three benchmarks in input dimension and
//! class count so the gradient dimensionality, class-skew structure, and
//! comm-cost accounting all exercise the same regimes:
//!
//! * `fmnist_like`   — dim 784,  10 classes (three-layer MLP task, Table 1)
//! * `cifar10_like`  — dim 3072, 10 classes (Table 2 / 3, Fig. 3)
//! * `cifar100_like` — dim 3072, 100 classes (Tables 4–7)

use super::Dataset;
use crate::util::rng::Pcg64;

/// Parameters of a synthetic task.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub dim: usize,
    pub classes: usize,
    /// Sub-clusters per class (multi-modal classes make the task
    /// non-linearly separable, so MLPs beat linear models — keeps model
    /// capacity relevant, as in the paper's benchmarks).
    pub modes: usize,
    /// Distance scale of class centroids.
    pub separation: f32,
    /// Within-cluster feature noise.
    pub noise: f32,
    /// Fraction of labels resampled uniformly (irreducible error).
    pub label_noise: f64,
    pub train: usize,
    pub test: usize,
}

impl SyntheticSpec {
    pub fn fmnist_like() -> Self {
        Self {
            dim: 784,
            classes: 10,
            modes: 3,
            separation: 1.0,
            noise: 0.45,
            label_noise: 0.02,
            train: 10_000,
            test: 2_000,
        }
    }

    pub fn cifar10_like() -> Self {
        Self {
            dim: 3072,
            classes: 10,
            modes: 4,
            separation: 1.0,
            noise: 0.65,
            label_noise: 0.04,
            train: 10_000,
            test: 2_000,
        }
    }

    pub fn cifar100_like() -> Self {
        Self {
            dim: 3072,
            classes: 100,
            modes: 2,
            separation: 1.2,
            noise: 0.6,
            label_noise: 0.04,
            train: 20_000,
            test: 4_000,
        }
    }

    /// Shrink the task for fast presets / CI (keeps dim & classes, scales
    /// sample counts).
    pub fn scaled(mut self, factor: f64) -> Self {
        self.train = ((self.train as f64 * factor) as usize).max(self.classes * 4);
        self.test = ((self.test as f64 * factor) as usize).max(self.classes * 2);
        self
    }

    /// Override the feature dimension (used by fast presets to shrink the
    /// model while keeping the task's class structure).
    pub fn with_dim(mut self, dim: usize) -> Self {
        self.dim = dim;
        self
    }
}

/// A generated train/test pair.
#[derive(Clone, Debug)]
pub struct SyntheticTask {
    pub spec: SyntheticSpec,
    pub train: Dataset,
    pub test: Dataset,
}

impl SyntheticTask {
    /// Deterministically generate the task from `seed`.
    pub fn generate(spec: SyntheticSpec, seed: u64) -> Self {
        assert!(spec.dim > 0 && spec.classes > 1 && spec.modes > 0);
        let mut rng = Pcg64::new(seed, 0x5511_717e_7a5c);
        Self::generate_impl(spec, &mut rng)
    }

    fn generate_impl(spec: SyntheticSpec, rng: &mut Pcg64) -> Self {
        // Class/mode centroids: random Gaussian directions, normalized to
        // `separation`.
        let n_cent = spec.classes * spec.modes;
        let mut centroids = vec![0.0f32; n_cent * spec.dim];
        for c in 0..n_cent {
            let row = &mut centroids[c * spec.dim..(c + 1) * spec.dim];
            rng.fill_normal(row, 0.0, 1.0);
            let norm = crate::util::l2_norm(row).max(1e-6);
            let s = spec.separation / norm;
            for v in row.iter_mut() {
                *v *= s;
            }
        }
        let make_split = |n: usize, rng: &mut Pcg64| -> Dataset {
            let mut x = vec![0.0f32; n * spec.dim];
            let mut y = vec![0usize; n];
            for i in 0..n {
                let class = rng.index(spec.classes);
                let mode = rng.index(spec.modes);
                let cent = &centroids
                    [(class * spec.modes + mode) * spec.dim..(class * spec.modes + mode + 1) * spec.dim];
                let row = &mut x[i * spec.dim..(i + 1) * spec.dim];
                for (r, &c) in row.iter_mut().zip(cent) {
                    *r = c + rng.normal_f32(0.0, spec.noise);
                }
                y[i] = if rng.bernoulli(spec.label_noise) {
                    rng.index(spec.classes)
                } else {
                    class
                };
            }
            Dataset { x: x.into(), y, dim: spec.dim, classes: spec.classes }
        };
        let train = make_split(spec.train, rng);
        let test = make_split(spec.test, rng);
        SyntheticTask { spec, train, test }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SyntheticSpec {
        SyntheticSpec {
            dim: 16,
            classes: 4,
            modes: 2,
            separation: 1.5,
            noise: 0.2,
            label_noise: 0.0,
            train: 400,
            test: 100,
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = SyntheticTask::generate(small_spec(), 7);
        let b = SyntheticTask::generate(small_spec(), 7);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.y, b.train.y);
        let c = SyntheticTask::generate(small_spec(), 8);
        assert_ne!(a.train.x, c.train.x);
    }

    #[test]
    fn shapes_and_label_range() {
        let t = SyntheticTask::generate(small_spec(), 1);
        assert_eq!(t.train.len(), 400);
        assert_eq!(t.test.len(), 100);
        assert_eq!(t.train.x.len(), 400 * 16);
        assert!(t.train.y.iter().all(|&y| y < 4));
    }

    #[test]
    fn classes_are_separable_by_nearest_centroid() {
        // With low noise the class structure must be learnable: nearest
        // class-mean classification on train data should beat chance by a
        // wide margin.
        let t = SyntheticTask::generate(small_spec(), 3);
        let spec = &t.spec;
        // Estimate class means from train.
        let mut means = vec![0.0f64; spec.classes * spec.dim];
        let mut counts = vec![0usize; spec.classes];
        for i in 0..t.train.len() {
            let y = t.train.y[i];
            counts[y] += 1;
            for (m, &v) in means[y * spec.dim..(y + 1) * spec.dim].iter_mut().zip(t.train.row(i)) {
                *m += v as f64;
            }
        }
        for c in 0..spec.classes {
            for m in means[c * spec.dim..(c + 1) * spec.dim].iter_mut() {
                *m /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..t.test.len() {
            let row = t.test.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..spec.classes {
                let d: f64 = means[c * spec.dim..(c + 1) * spec.dim]
                    .iter()
                    .zip(row)
                    .map(|(m, &v)| (m - v as f64) * (m - v as f64))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == t.test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / t.test.len() as f64;
        assert!(acc > 0.5, "nearest-centroid acc {acc} barely above chance");
    }

    #[test]
    fn scaled_keeps_minimums() {
        let s = small_spec().scaled(0.001);
        assert!(s.train >= 16 && s.test >= 8);
    }

    #[test]
    fn presets_have_paper_dims() {
        assert_eq!(SyntheticSpec::fmnist_like().dim, 784);
        assert_eq!(SyntheticSpec::cifar10_like().dim, 3072);
        assert_eq!(SyntheticSpec::cifar100_like().classes, 100);
    }
}
