//! `.sgds` — the versioned, CRC-guarded, mmap-backed on-disk shard store.
//!
//! A store file holds one dataset (train + test split) plus an embedded
//! Dirichlet(α) partition manifest, laid out so the engine and the net
//! fleet can stream mini-batches **zero-copy** straight out of the file
//! mapping: train rows are written grouped by client, so each client's
//! shard is a contiguous `(start, len)` row range and
//! [`FederatedDataset::from_ranges`] needs O(clients) memory regardless of
//! dataset size.
//!
//! ## Grammar (all integers varint unless sized; see DESIGN.md §16)
//!
//! ```text
//! store   := magic:u32be("SGDS") version:u8(=1) kind:u8(=1)
//!            meta_len:varint meta[meta_len]
//!            pad (zero bytes to the next 64-byte file offset)
//!            features: (rows_train + rows_test) · dim × f32le   (train rows
//!                      grouped by client, then test rows)
//!            labels:   (rows_train + rows_test) × u32le
//!            crc:u32le                    (CRC-32 of every preceding byte)
//! meta    := dim rows_train rows_test classes clients
//!            alpha:f64le seed:u64le
//!            shard_len[clients]           (each ≥ 1, Σ == rows_train)
//! ```
//!
//! Shard *lengths* rather than `(start, end)` pairs make the manifest
//! disjoint and exhaustive **by construction** — ranges are derived by
//! running sum, so the only cross-field checks needed are `Σ len ==
//! rows_train` and `len ≥ 1`.
//!
//! ## Hostile-input discipline
//!
//! Loading follows the same policy as `net/wire` and `snapshot`: magic /
//! version / kind first, then the whole-file CRC, then semantic decoding
//! where every count is capped *before* any allocation and every derived
//! offset is revalidated against the true byte length (the file must be
//! exactly as long as the header implies — no trailing bytes). Any
//! violation is a typed [`StoreError`], never a panic. See
//! `tests/property_suite.rs` for the mutation/truncation fuzz pins.
//!
//! ## mmap safety argument
//!
//! The mapping is `PROT_READ` + `MAP_PRIVATE` over a file that is written
//! atomically (tmp + fsync + rename) and never modified in place, so no
//! writer aliases it. All reads go through slices bounded by the
//! validated header, the f32 view is only taken on little-endian targets
//! at 4-byte-aligned offsets (the feature block is 64-byte aligned in the
//! file and mappings are page-aligned; non-unix or misaligned fallbacks
//! copy into an owned `Vec<f32>`), and every [`MappedSlice`] holds an
//! `Arc` on the mapping so a view can never outlive it. Truncating a
//! store file while it is mapped is outside the threat model (as for any
//! mmap consumer); corruption at rest is caught by the CRC.

use std::path::Path;
use std::sync::Arc;

use super::{Dataset, FederatedDataset};
use crate::net::wire::{crc32, push_varint, Cursor, WireError};
use crate::snapshot::fingerprint_bytes;

/// First four bytes of every store file: `b"SGDS"`.
pub const STORE_MAGIC: u32 = u32::from_be_bytes(*b"SGDS");
/// Current store format version.
pub const STORE_VERSION: u8 = 1;
/// Kind byte: dense f32 classification dataset.
pub const KIND_DATASET: u8 = 1;

/// Decoder caps, enforced before any allocation.
pub const MAX_STORE_BYTES: u64 = 1 << 33;
const MAX_STORE_DIM: usize = 1 << 26;
const MAX_STORE_ROWS: usize = 1 << 28;
const MAX_STORE_CLIENTS: usize = 1 << 24;
const MAX_STORE_CLASSES: usize = 1 << 16;

/// Feature-block alignment (file offset); also the widest SIMD vector the
/// kernels use, so mapped rows can be loaded with aligned moves.
const FEATURE_ALIGN: usize = 64;

/// Typed store-load failure — the `.sgds` analogue of
/// [`crate::snapshot::SnapshotError`].
#[derive(Debug)]
pub enum StoreError {
    /// Filesystem-level failure (open/read/write/rename/fsync/mmap).
    Io(std::io::Error),
    /// Fewer bytes than the header implies.
    Truncated { need: usize, have: usize },
    /// First four bytes are not `b"SGDS"`.
    BadMagic { got: u32 },
    /// Unsupported format version.
    BadVersion { got: u8 },
    /// Unknown kind byte.
    BadKind { got: u8 },
    /// Whole-file checksum mismatch.
    BadCrc { want: u32, got: u32 },
    /// File (or declared block) exceeds a decoder cap.
    Oversized { len: u64, max: u64 },
    /// Structurally invalid (bad varint, cap violation, manifest not
    /// covering the train rows, label out of range, trailing bytes, …).
    Malformed(&'static str),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store io error: {e}"),
            StoreError::Truncated { need, have } => {
                write!(f, "truncated store: need {need} bytes, have {have}")
            }
            StoreError::BadMagic { got } => write!(f, "bad store magic {got:#010x}"),
            StoreError::BadVersion { got } => write!(f, "unsupported store version {got}"),
            StoreError::BadKind { got } => write!(f, "unknown store kind {got}"),
            StoreError::BadCrc { want, got } => {
                write!(f, "store crc mismatch: want {want:#010x}, got {got:#010x}")
            }
            StoreError::Oversized { len, max } => {
                write!(f, "store block of {len} bytes exceeds cap {max}")
            }
            StoreError::Malformed(what) => write!(f, "malformed store: {what}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

impl From<WireError> for StoreError {
    fn from(e: WireError) -> Self {
        match e {
            WireError::Truncated { need, have } => StoreError::Truncated { need, have },
            WireError::BadMagic { got } => StoreError::BadMagic { got },
            WireError::BadVersion { got } => StoreError::BadVersion { got },
            WireError::BadMsgType { got } => StoreError::BadKind { got },
            WireError::BadCrc { want, got } => StoreError::BadCrc { want, got },
            WireError::Oversized { len, max } => {
                StoreError::Oversized { len: len as u64, max: max as u64 }
            }
            WireError::Malformed(what) => StoreError::Malformed(what),
        }
    }
}

// ---------------------------------------------------------------------
// The byte mapping.

#[cfg(unix)]
mod sys {
    pub const PROT_READ: i32 = 0x1;
    pub const MAP_PRIVATE: i32 = 0x02;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

/// Owner of the raw store bytes: a read-only file mapping on unix, an
/// owned buffer otherwise (and for in-memory decodes).
enum Mapping {
    #[cfg(unix)]
    Mmap { ptr: *const u8, len: usize },
    Owned(Vec<u8>),
}

// SAFETY: the mapping is immutable for its whole lifetime — PROT_READ,
// MAP_PRIVATE, file written atomically and never modified in place — so
// shared references to its bytes are sound across threads.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    fn as_bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
            // bytes, unmapped only in `Drop`.
            Mapping::Mmap { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Mapping::Owned(v) => v,
        }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Mapping::Mmap { ptr, len } = *self {
            // SAFETY: exactly one munmap of the region mmap gave us.
            unsafe { sys::munmap(ptr as *mut u8, len) };
        }
    }
}

/// A zero-copy `&[f32]` view into an open store mapping. Cloning is
/// refcount-cheap; the `Arc` keeps the mapping alive so the view cannot
/// dangle. Constructed only on little-endian targets at 4-byte-aligned
/// offsets (checked), so the reinterpretation is always valid.
#[derive(Clone)]
pub struct MappedSlice {
    map: Arc<Mapping>,
    /// Byte offset of the f32 block inside the mapping.
    off: usize,
    /// Element (not byte) count.
    len: usize,
}

impl MappedSlice {
    pub fn as_slice(&self) -> &[f32] {
        let bytes = self.map.as_bytes();
        debug_assert!(self.off + self.len * 4 <= bytes.len());
        let ptr = bytes[self.off..].as_ptr();
        debug_assert_eq!(ptr as usize % std::mem::align_of::<f32>(), 0);
        // SAFETY: bounds and alignment validated at construction (and
        // re-asserted above); the mapping is immutable and outlives
        // `self` via the Arc; f32 has no invalid bit patterns.
        unsafe { std::slice::from_raw_parts(ptr as *const f32, self.len) }
    }
}

// ---------------------------------------------------------------------
// The parsed store.

/// Summary of an open store (what `dataset info` prints).
#[derive(Clone, Debug)]
pub struct StoreInfo {
    pub dim: usize,
    pub rows_train: usize,
    pub rows_test: usize,
    pub classes: usize,
    pub clients: usize,
    pub alpha: f64,
    pub seed: u64,
    pub file_bytes: usize,
    pub content_hash: u64,
    pub min_shard: usize,
    pub max_shard: usize,
}

impl StoreInfo {
    pub fn summary(&self) -> String {
        format!(
            "sgds v{STORE_VERSION}: {} train + {} test rows, dim {}, {} classes, \
             {} clients (shard {}..{} rows), alpha {}, seed {}, {} bytes, hash {:016x}",
            self.rows_train,
            self.rows_test,
            self.dim,
            self.classes,
            self.clients,
            self.min_shard,
            self.max_shard,
            self.alpha,
            self.seed,
            self.file_bytes,
            self.content_hash,
        )
    }
}

/// An open, fully validated `.sgds` store. All accessors are infallible:
/// every invariant was checked at load time.
pub struct ShardStore {
    map: Arc<Mapping>,
    dim: usize,
    rows_train: usize,
    rows_test: usize,
    classes: usize,
    alpha: f64,
    seed: u64,
    /// Per-client `(start, len)` row ranges, derived from the manifest.
    ranges: Vec<(usize, usize)>,
    /// Byte offset of the feature block (64-aligned).
    feat_off: usize,
    /// Byte offset of the label block.
    label_off: usize,
    /// FNV-1a 64 over the entire file — folded into
    /// [`crate::coordinator::GradientSource::env_fingerprint`] so a
    /// drifted fleet is refused at rendezvous.
    content_hash: u64,
}

impl ShardStore {
    /// Map and validate a store file.
    pub fn open(path: &Path) -> Result<Self, StoreError> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len > MAX_STORE_BYTES {
            return Err(StoreError::Oversized { len, max: MAX_STORE_BYTES });
        }
        if len == 0 {
            return Err(StoreError::Truncated { need: 11, have: 0 });
        }
        let map = Self::map_file(&file, len as usize)?;
        Self::decode(map)
    }

    #[cfg(unix)]
    fn map_file(file: &std::fs::File, len: usize) -> Result<Mapping, StoreError> {
        use std::os::unix::io::AsRawFd;
        // SAFETY: fd is open for the duration of the call; length is the
        // file's true size; flags request a private read-only mapping.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr.is_null() || ptr as isize == -1 {
            return Err(StoreError::Io(std::io::Error::last_os_error()));
        }
        Ok(Mapping::Mmap { ptr, len })
    }

    #[cfg(not(unix))]
    fn map_file(file: &std::fs::File, len: usize) -> Result<Mapping, StoreError> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = file;
        f.read_to_end(&mut buf)?;
        Ok(Mapping::Owned(buf))
    }

    /// Validate an in-memory store image (fuzz tests, non-file sources).
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self, StoreError> {
        if bytes.len() as u64 > MAX_STORE_BYTES {
            return Err(StoreError::Oversized { len: bytes.len() as u64, max: MAX_STORE_BYTES });
        }
        Self::decode(Mapping::Owned(bytes))
    }

    fn decode(map: Mapping) -> Result<Self, StoreError> {
        let bytes = map.as_bytes();
        // Smallest conceivable store: header + 1-byte meta-len + 4-byte crc.
        if bytes.len() < 11 {
            return Err(StoreError::Truncated { need: 11, have: bytes.len() });
        }
        let magic = u32::from_be_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        if magic != STORE_MAGIC {
            return Err(StoreError::BadMagic { got: magic });
        }
        if bytes[4] != STORE_VERSION {
            return Err(StoreError::BadVersion { got: bytes[4] });
        }
        if bytes[5] != KIND_DATASET {
            return Err(StoreError::BadKind { got: bytes[5] });
        }
        // Whole-file CRC before semantic decoding: a flipped bit anywhere
        // is caught here, so the field parsers below only ever see bytes
        // the producer wrote.
        let crc_at = bytes.len() - 4;
        let want = crc32(&bytes[..crc_at]);
        let got = u32::from_le_bytes([
            bytes[crc_at],
            bytes[crc_at + 1],
            bytes[crc_at + 2],
            bytes[crc_at + 3],
        ]);
        if want != got {
            return Err(StoreError::BadCrc { want, got });
        }

        let mut c = Cursor::new(&bytes[6..crc_at]);
        let meta_len = c.count(c.remaining(), "meta length exceeds file")?;
        let meta = c.take(meta_len)?;
        let meta_end = 6 + c.pos();

        let mut m = Cursor::new(meta);
        let dim = m.count(MAX_STORE_DIM, "store dim over cap")?;
        let rows_train = m.count(MAX_STORE_ROWS, "train rows over cap")?;
        let rows_test = m.count(MAX_STORE_ROWS, "test rows over cap")?;
        let classes = m.count(MAX_STORE_CLASSES, "classes over cap")?;
        let clients = m.count(MAX_STORE_CLIENTS, "clients over cap")?;
        if dim == 0 {
            return Err(StoreError::Malformed("dim must be >= 1"));
        }
        if rows_train == 0 || rows_test == 0 {
            return Err(StoreError::Malformed("train and test splits must be nonempty"));
        }
        if classes < 2 {
            return Err(StoreError::Malformed("need at least two classes"));
        }
        if clients == 0 {
            return Err(StoreError::Malformed("need at least one client"));
        }
        let alpha = m.f64()?;
        let seed = m.u64le()?;
        if !(alpha.is_finite() && alpha > 0.0) {
            return Err(StoreError::Malformed("manifest alpha must be finite and > 0"));
        }
        // Each shard length costs >= 1 manifest byte, so this bound makes
        // the Vec allocation below proportional to bytes actually present.
        if clients > m.remaining() {
            return Err(StoreError::Malformed("client count exceeds manifest bytes"));
        }
        let mut ranges = Vec::with_capacity(clients);
        let mut start = 0usize;
        for _ in 0..clients {
            let len = m.count(rows_train, "shard length exceeds train rows")?;
            if len == 0 {
                return Err(StoreError::Malformed("empty client shard in manifest"));
            }
            if len > rows_train - start {
                return Err(StoreError::Malformed("manifest overruns train rows"));
            }
            ranges.push((start, len));
            start += len;
        }
        if start != rows_train {
            return Err(StoreError::Malformed("manifest does not cover all train rows"));
        }
        m.done()?;

        // Cross-check the derived layout against the true byte length.
        let feat_off = meta_end.next_multiple_of(FEATURE_ALIGN);
        let rows = rows_train
            .checked_add(rows_test)
            .ok_or(StoreError::Malformed("row count overflow"))?;
        let feat_bytes = rows
            .checked_mul(dim)
            .and_then(|v| v.checked_mul(4))
            .ok_or(StoreError::Malformed("feature block overflow"))?;
        let label_off = feat_off
            .checked_add(feat_bytes)
            .ok_or(StoreError::Malformed("feature block overflow"))?;
        let total = label_off
            .checked_add(rows * 4)
            .and_then(|v| v.checked_add(4))
            .ok_or(StoreError::Malformed("label block overflow"))?;
        match total.cmp(&bytes.len()) {
            std::cmp::Ordering::Greater => {
                return Err(StoreError::Truncated { need: total, have: bytes.len() })
            }
            std::cmp::Ordering::Less => {
                return Err(StoreError::Malformed("trailing bytes after label block"))
            }
            std::cmp::Ordering::Equal => {}
        }
        if bytes[meta_end..feat_off].iter().any(|&b| b != 0) {
            return Err(StoreError::Malformed("nonzero padding before feature block"));
        }
        // Labels are validated here once so `labels()` below is infallible.
        let labels = &bytes[label_off..label_off + rows * 4];
        for chunk in labels.chunks_exact(4) {
            let y = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]) as usize;
            if y >= classes {
                return Err(StoreError::Malformed("label out of class range"));
            }
        }

        let content_hash = fingerprint_bytes(bytes);
        Ok(ShardStore {
            map: Arc::new(map),
            dim,
            rows_train,
            rows_test,
            classes,
            alpha,
            seed,
            ranges,
            feat_off,
            label_off,
            content_hash,
        })
    }

    pub fn content_hash(&self) -> u64 {
        self.content_hash
    }

    pub fn clients(&self) -> usize {
        self.ranges.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn info(&self) -> StoreInfo {
        let min_shard = self.ranges.iter().map(|&(_, l)| l).min().unwrap_or(0);
        let max_shard = self.ranges.iter().map(|&(_, l)| l).max().unwrap_or(0);
        StoreInfo {
            dim: self.dim,
            rows_train: self.rows_train,
            rows_test: self.rows_test,
            classes: self.classes,
            clients: self.ranges.len(),
            alpha: self.alpha,
            seed: self.seed,
            file_bytes: self.map.as_bytes().len(),
            content_hash: self.content_hash,
            min_shard,
            max_shard,
        }
    }

    /// Features for rows `[row0, row0 + rows)` — zero-copy on
    /// little-endian targets (the block is 4-byte aligned by
    /// construction), an owned decode otherwise.
    fn features(&self, row0: usize, rows: usize) -> super::Features {
        let off = self.feat_off + row0 * self.dim * 4;
        let len = rows * self.dim;
        let base = self.map.as_bytes()[off..].as_ptr() as usize;
        if cfg!(target_endian = "little") && base % std::mem::align_of::<f32>() == 0 {
            return super::Features::Mapped(MappedSlice { map: Arc::clone(&self.map), off, len });
        }
        let bytes = &self.map.as_bytes()[off..off + len * 4];
        let mut v = Vec::with_capacity(len);
        for chunk in bytes.chunks_exact(4) {
            v.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        super::Features::Owned(v)
    }

    fn labels(&self, row0: usize, rows: usize) -> Vec<usize> {
        let off = self.label_off + row0 * 4;
        let bytes = &self.map.as_bytes()[off..off + rows * 4];
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize)
            .collect()
    }

    /// The train split as a [`Dataset`] (features zero-copy where the
    /// target allows).
    pub fn train_dataset(&self) -> Dataset {
        Dataset {
            x: self.features(0, self.rows_train),
            y: self.labels(0, self.rows_train),
            dim: self.dim,
            classes: self.classes,
        }
    }

    /// The held-out test split.
    pub fn test_dataset(&self) -> Dataset {
        Dataset {
            x: self.features(self.rows_train, self.rows_test),
            y: self.labels(self.rows_train, self.rows_test),
            dim: self.dim,
            classes: self.classes,
        }
    }

    /// The embedded partition as per-client contiguous row ranges.
    pub fn federated(&self) -> FederatedDataset {
        FederatedDataset::from_ranges(self.ranges.clone())
    }
}

// ---------------------------------------------------------------------
// Writing.

/// Encode a store image: `train` rows are regrouped by client following
/// `fed`, which must cover every train row exactly once with nonempty
/// shards (use [`super::DirichletPartitioner::partition_exact`]).
pub fn encode_store(
    train: &Dataset,
    test: &Dataset,
    fed: &FederatedDataset,
    alpha: f64,
    seed: u64,
) -> Result<Vec<u8>, StoreError> {
    if train.dim != test.dim || train.classes != test.classes {
        return Err(StoreError::Malformed("train/test dim or classes mismatch"));
    }
    if train.dim == 0 || train.dim > MAX_STORE_DIM {
        return Err(StoreError::Malformed("dim out of range"));
    }
    if train.is_empty() || test.is_empty() {
        return Err(StoreError::Malformed("train and test splits must be nonempty"));
    }
    if train.len() > MAX_STORE_ROWS || test.len() > MAX_STORE_ROWS {
        return Err(StoreError::Malformed("row count over cap"));
    }
    if train.classes < 2 || train.classes > MAX_STORE_CLASSES {
        return Err(StoreError::Malformed("classes out of range"));
    }
    if fed.workers() == 0 || fed.workers() > MAX_STORE_CLIENTS {
        return Err(StoreError::Malformed("client count out of range"));
    }
    if !(alpha.is_finite() && alpha > 0.0) {
        return Err(StoreError::Malformed("manifest alpha must be finite and > 0"));
    }
    let mut seen = vec![false; train.len()];
    for m in 0..fed.workers() {
        if fed.shard_len(m) == 0 {
            return Err(StoreError::Malformed("empty client shard in manifest"));
        }
        for i in fed.shard_indices(m) {
            if i >= train.len() || seen[i] {
                return Err(StoreError::Malformed(
                    "manifest must cover each train row exactly once",
                ));
            }
            seen[i] = true;
        }
    }
    if !seen.iter().all(|&s| s) {
        return Err(StoreError::Malformed("manifest must cover each train row exactly once"));
    }

    let mut meta = Vec::new();
    push_varint(&mut meta, train.dim as u64);
    push_varint(&mut meta, train.len() as u64);
    push_varint(&mut meta, test.len() as u64);
    push_varint(&mut meta, train.classes as u64);
    push_varint(&mut meta, fed.workers() as u64);
    meta.extend_from_slice(&alpha.to_le_bytes());
    meta.extend_from_slice(&seed.to_le_bytes());
    for m in 0..fed.workers() {
        push_varint(&mut meta, fed.shard_len(m) as u64);
    }

    let rows = train.len() + test.len();
    let mut out = Vec::new();
    out.extend_from_slice(&STORE_MAGIC.to_be_bytes());
    out.push(STORE_VERSION);
    out.push(KIND_DATASET);
    push_varint(&mut out, meta.len() as u64);
    out.extend_from_slice(&meta);
    let feat_off = out.len().next_multiple_of(FEATURE_ALIGN);
    out.resize(feat_off, 0);
    out.reserve(rows * train.dim * 4 + rows * 4 + 4);
    for m in 0..fed.workers() {
        for i in fed.shard_indices(m) {
            for &v in train.row(i) {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    for i in 0..test.len() {
        for &v in test.row(i) {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    for m in 0..fed.workers() {
        for i in fed.shard_indices(m) {
            out.extend_from_slice(&(train.y[i] as u32).to_le_bytes());
        }
    }
    for &y in &test.y {
        out.extend_from_slice(&(y as u32).to_le_bytes());
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    if out.len() as u64 > MAX_STORE_BYTES {
        return Err(StoreError::Oversized { len: out.len() as u64, max: MAX_STORE_BYTES });
    }
    Ok(out)
}

/// Encode and atomically write a store (tmp + fsync + rename + parent
/// fsync, the [`crate::snapshot`] discipline), returning its content
/// hash.
pub fn write_store(
    path: &Path,
    train: &Dataset,
    test: &Dataset,
    fed: &FederatedDataset,
    alpha: f64,
    seed: u64,
) -> Result<u64, StoreError> {
    let bytes = encode_store(train, test, fed, alpha, seed)?;
    let hash = fingerprint_bytes(&bytes);
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(hash)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
    use crate::util::rng::Pcg64;

    fn small_store_bytes() -> (Vec<u8>, crate::data::SyntheticTask) {
        let task = SyntheticTask::generate(
            SyntheticSpec { train: 96, test: 16, ..SyntheticSpec::fmnist_like().with_dim(12) },
            7,
        );
        let part = DirichletPartitioner { alpha: 0.5, workers: 8 };
        let fed = part.partition_exact(&task.train, &mut Pcg64::seed_from(3));
        let bytes = encode_store(&task.train, &task.test, &fed, 0.5, 3).unwrap();
        (bytes, task)
    }

    #[test]
    fn roundtrip_preserves_rows_and_partition() {
        let (bytes, task) = small_store_bytes();
        let store = ShardStore::from_bytes(bytes).unwrap();
        assert_eq!(store.dim(), task.train.dim);
        assert_eq!(store.classes(), task.train.classes);
        assert_eq!(store.clients(), 8);
        let train = store.train_dataset();
        let test = store.test_dataset();
        assert_eq!(train.len(), task.train.len());
        assert_eq!(test.len(), task.test.len());
        // Test split is written in order; train rows are a permutation.
        assert_eq!(test.x, task.test.x);
        assert_eq!(test.y, task.test.y);
        let fed = store.federated();
        assert_eq!(fed.total(), task.train.len());
        // Multiset of (row, label) pairs must survive the regrouping.
        let mut got: Vec<(Vec<u32>, usize)> = (0..train.len())
            .map(|i| (train.row(i).iter().map(|v| v.to_bits()).collect(), train.y[i]))
            .collect();
        let mut want: Vec<(Vec<u32>, usize)> = (0..task.train.len())
            .map(|i| (task.train.row(i).iter().map(|v| v.to_bits()).collect(), task.train.y[i]))
            .collect();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn open_is_zero_copy_and_matches_from_bytes() {
        let (bytes, _) = small_store_bytes();
        let dir = std::env::temp_dir().join(format!("sgds_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.sgds");
        std::fs::write(&path, &bytes).unwrap();
        let a = ShardStore::open(&path).unwrap();
        let b = ShardStore::from_bytes(bytes).unwrap();
        assert_eq!(a.content_hash(), b.content_hash());
        let ta = a.train_dataset();
        let tb = b.train_dataset();
        assert_eq!(ta.x, tb.x);
        assert_eq!(ta.y, tb.y);
        #[cfg(all(unix, target_endian = "little"))]
        assert!(matches!(ta.x, crate::data::Features::Mapped(_)));
        std::fs::remove_file(&path).unwrap();
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn write_store_is_atomic_and_hash_stable() {
        let (bytes, task) = small_store_bytes();
        let dir = std::env::temp_dir().join(format!("sgds_test_w_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.sgds");
        let part = DirichletPartitioner { alpha: 0.5, workers: 8 };
        let fed = part.partition_exact(&task.train, &mut Pcg64::seed_from(3));
        let h = write_store(&path, &task.train, &task.test, &fed, 0.5, 3).unwrap();
        assert!(!path.with_extension("sgds.tmp").exists(), "tmp file left behind");
        let store = ShardStore::open(&path).unwrap();
        assert_eq!(store.content_hash(), h);
        assert_eq!(h, fingerprint_bytes(&bytes), "encoding must be deterministic");
        std::fs::remove_file(&path).unwrap();
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn rejects_duplicating_partition() {
        // The legacy partitioner may duplicate rows (it cycles pools);
        // encode_store must refuse such a manifest.
        let task = SyntheticTask::generate(
            SyntheticSpec { train: 10, test: 4, ..SyntheticSpec::fmnist_like().with_dim(4) },
            1,
        );
        let fed = FederatedDataset::from_shards(vec![vec![0, 1, 1], vec![2, 3]]);
        let err = encode_store(&task.train, &task.test, &fed, 0.5, 1).unwrap_err();
        assert!(matches!(err, StoreError::Malformed(_)), "{err}");
    }

    #[test]
    fn version_bump_is_refused() {
        let (mut bytes, _) = small_store_bytes();
        bytes[4] = STORE_VERSION + 1;
        let n = bytes.len();
        let crc = crc32(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        match ShardStore::from_bytes(bytes) {
            Err(StoreError::BadVersion { got }) => assert_eq!(got, STORE_VERSION + 1),
            other => panic!("expected BadVersion, got {:?}", other.err()),
        }
    }

    #[test]
    fn bad_crc_is_refused() {
        let (mut bytes, _) = small_store_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(ShardStore::from_bytes(bytes), Err(StoreError::BadCrc { .. })));
    }
}
