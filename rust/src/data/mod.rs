//! Datasets, federated partitioning, and the on-disk shard store.
//!
//! Two data paths feed the experiment suite:
//!
//! - **Synthetic classification tasks** ([`SyntheticTask`]) generated to
//!   stress the mechanism the paper studies — the sign/magnitude statistics
//!   of worker gradients under **Dirichlet(α) label skew** (Hsu et al.
//!   2019). They need no downloads, run in milliseconds, and are what the
//!   fast presets and most CI jobs use; DESIGN.md §3 argues when the
//!   substitution is sound.
//! - **Real image corpora** streamed from a versioned, CRC-guarded,
//!   mmap-backed `.sgds` store ([`ShardStore`], `data/store.rs`): the
//!   `dataset` CLI subcommand converts IDX / CIFAR-binary downloads
//!   (Fashion-MNIST, CIFAR-10/100) into store files whose embedded manifest
//!   pins a seeded Dirichlet(α) partition, and `train`/`serve`/`fleet
//!   --data` reproduce the paper's accuracy-vs-communication curves on them
//!   end-to-end (DESIGN.md §16, EXPERIMENTS.md §Paper-parity).
//!
//! The partitioner itself is exactly the paper's protocol and is shared by
//! both paths; [`Features`] lets a [`Dataset`] borrow its feature matrix
//! zero-copy from a store mapping instead of owning a heap copy.

mod ingest;
mod partition;
mod store;
mod synthetic;

pub use ingest::{load_cifar_binary, load_idx_pair, IngestError};
pub use partition::{partition_report, DirichletPartitioner, PartitionReport};
pub use store::{
    encode_store, write_store, MappedSlice, ShardStore, StoreError, StoreInfo, STORE_VERSION,
};
pub use synthetic::{SyntheticSpec, SyntheticTask};

use crate::util::rng::Pcg64;

/// Backing storage for a dataset's `n × dim` feature matrix: either an
/// owned heap vector (synthetic tasks, tests) or a zero-copy view into an
/// open [`ShardStore`] mapping (the mapping is kept alive by refcount, so
/// the view can never dangle).
#[derive(Clone)]
pub enum Features {
    /// Heap-owned features.
    Owned(Vec<f32>),
    /// Borrowed zero-copy from an `.sgds` mapping.
    Mapped(MappedSlice),
}

impl Features {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            Features::Owned(v) => v,
            Features::Mapped(m) => m.as_slice(),
        }
    }
}

impl std::ops::Deref for Features {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl From<Vec<f32>> for Features {
    fn from(v: Vec<f32>) -> Self {
        Features::Owned(v)
    }
}

impl PartialEq for Features {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for Features {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self {
            Features::Owned(_) => "owned",
            Features::Mapped(_) => "mapped",
        };
        write!(f, "Features({kind}, len={})", self.as_slice().len())
    }
}

/// A dense classification dataset (row-major features). The feature matrix
/// may be heap-owned or a zero-copy store mapping — see [`Features`]; the
/// read contract (`row`/`gather_into`) is identical either way.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n × dim` features.
    pub x: Features,
    /// `n` labels in `[0, classes)`.
    pub y: Vec<usize>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather rows `idx` into caller-provided scratch buffers (cleared,
    /// then filled) — the allocation-free hot path behind [`Self::gather`].
    /// Capacity is retained across calls, so steady-state batch assembly
    /// performs zero heap allocations.
    pub fn gather_into(&self, idx: &[usize], bx: &mut Vec<f32>, by: &mut Vec<usize>) {
        bx.clear();
        by.clear();
        bx.reserve(idx.len() * self.dim);
        by.reserve(idx.len());
        for &i in idx {
            bx.extend_from_slice(self.row(i));
            by.push(self.y[i]);
        }
    }

    /// Gather rows `idx` into a dense batch `(x, y)` (allocating
    /// convenience wrapper over [`Self::gather_into`]).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<usize>) {
        let mut bx = Vec::new();
        let mut by = Vec::new();
        self.gather_into(idx, &mut bx, &mut by);
        (bx, by)
    }
}

/// Reusable mini-batch assembly buffers (sampled indices + gathered
/// features/labels), owned per engine thread via
/// [`crate::model::ModelWorkspace`] so the per-round batch gather never
/// allocates in steady state.
#[derive(Default)]
pub struct BatchScratch {
    /// Sampled example indices.
    pub idx: Vec<usize>,
    /// Gathered `batch×dim` features.
    pub x: Vec<f32>,
    /// Gathered labels.
    pub y: Vec<usize>,
}

/// Per-worker shard membership: either explicit index lists (the in-memory
/// partitioner output, where shards may overlap when `n < M·⌈n/M⌉`) or
/// contiguous `(start, len)` ranges into a store whose rows were written
/// grouped by client — disjoint and exhaustive by construction, O(1)
/// memory per worker.
#[derive(Clone, Debug, PartialEq)]
enum ShardMap {
    Explicit(Vec<Vec<usize>>),
    Ranges(Vec<(usize, usize)>),
}

/// A dataset split across `M` workers: shard `m` names indices into the
/// shared base dataset. Cloning is cheap-ish (indices only; ranges are
/// O(M)) — the feature matrix is shared by reference at the engine level.
#[derive(Clone, Debug, PartialEq)]
pub struct FederatedDataset {
    shards: ShardMap,
}

impl FederatedDataset {
    /// Build from explicit per-worker index lists.
    pub fn from_shards(shards: Vec<Vec<usize>>) -> Self {
        FederatedDataset { shards: ShardMap::Explicit(shards) }
    }

    /// Build from contiguous per-worker `(start, len)` ranges (the store
    /// manifest representation).
    pub fn from_ranges(ranges: Vec<(usize, usize)>) -> Self {
        FederatedDataset { shards: ShardMap::Ranges(ranges) }
    }

    pub fn workers(&self) -> usize {
        match &self.shards {
            ShardMap::Explicit(s) => s.len(),
            ShardMap::Ranges(r) => r.len(),
        }
    }

    /// Number of examples held by worker `m`.
    pub fn shard_len(&self, m: usize) -> usize {
        match &self.shards {
            ShardMap::Explicit(s) => s[m].len(),
            ShardMap::Ranges(r) => r[m].1,
        }
    }

    /// The `j`-th example index of worker `m`.
    pub fn index(&self, m: usize, j: usize) -> usize {
        match &self.shards {
            ShardMap::Explicit(s) => s[m][j],
            ShardMap::Ranges(r) => {
                debug_assert!(j < r[m].1);
                r[m].0 + j
            }
        }
    }

    /// Iterate worker `m`'s example indices.
    pub fn shard_indices(&self, m: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.shard_len(m)).map(move |j| self.index(m, j))
    }

    /// Sample a mini-batch (with replacement, matching the paper's
    /// stochastic-gradient model) of `batch` indices from worker `m` into
    /// a caller-provided scratch buffer (cleared, then filled). The RNG
    /// draw sequence is identical to [`Self::sample_batch`], and — given
    /// equal shard lengths — identical across the two [`ShardMap`]
    /// representations, which is what keeps store-backed fleet runs
    /// bit-identical to the in-process engine.
    pub fn sample_batch_into(&self, m: usize, batch: usize, rng: &mut Pcg64, out: &mut Vec<usize>) {
        let len = self.shard_len(m);
        assert!(len > 0, "worker {m} has an empty shard");
        out.clear();
        out.reserve(batch);
        match &self.shards {
            ShardMap::Explicit(s) => {
                let shard = &s[m];
                for _ in 0..batch {
                    out.push(shard[rng.index(len)]);
                }
            }
            ShardMap::Ranges(r) => {
                let start = r[m].0;
                for _ in 0..batch {
                    out.push(start + rng.index(len));
                }
            }
        }
    }

    /// Allocating convenience wrapper over [`Self::sample_batch_into`].
    pub fn sample_batch(&self, m: usize, batch: usize, rng: &mut Pcg64) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_batch_into(m, batch, rng, &mut out);
        out
    }

    /// Total examples across shards.
    pub fn total(&self) -> usize {
        match &self.shards {
            ShardMap::Explicit(s) => s.iter().map(|s| s.len()).sum(),
            ShardMap::Ranges(r) => r.iter().map(|&(_, len)| len).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0].into(),
            y: vec![0, 1, 0],
            dim: 2,
            classes: 2,
        }
    }

    #[test]
    fn rows_and_gather() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.row(1), &[2.0, 3.0]);
        let (bx, by) = d.gather(&[2, 0]);
        assert_eq!(bx, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(by, vec![0, 0]);
    }

    #[test]
    fn batch_sampling_in_range() {
        let fed = FederatedDataset::from_shards(vec![vec![0, 2], vec![1]]);
        let mut rng = Pcg64::seed_from(1);
        let b = fed.sample_batch(0, 16, &mut rng);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|i| [0usize, 2].contains(i)));
        assert_eq!(fed.total(), 3);
    }

    #[test]
    fn into_variants_match_allocating_wrappers() {
        let d = tiny();
        let mut bx = vec![9.0f32; 1];
        let mut by = vec![7usize; 5];
        d.gather_into(&[2, 0], &mut bx, &mut by);
        assert_eq!(bx, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(by, vec![0, 0]);
        // Identical RNG draw sequence: same seed ⇒ same indices.
        let fed = FederatedDataset::from_shards(vec![vec![0, 1, 2]]);
        let a = fed.sample_batch(0, 8, &mut Pcg64::seed_from(9));
        let mut b = vec![42usize; 3];
        fed.sample_batch_into(0, 8, &mut Pcg64::seed_from(9), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn range_shards_draw_identically_to_explicit() {
        // A range shard and an explicit shard naming the same contiguous
        // indices must consume the RNG identically and yield the same
        // batches — the bit-identity contract behind `fleet --data`.
        let explicit = FederatedDataset::from_shards(vec![vec![5, 6, 7, 8], vec![9, 10]]);
        let ranges = FederatedDataset::from_ranges(vec![(5, 4), (9, 2)]);
        assert_eq!(explicit.workers(), ranges.workers());
        assert_eq!(explicit.total(), ranges.total());
        for m in 0..2 {
            assert_eq!(explicit.shard_len(m), ranges.shard_len(m));
            let a = explicit.sample_batch(m, 32, &mut Pcg64::seed_from(77));
            let b = ranges.sample_batch(m, 32, &mut Pcg64::seed_from(77));
            assert_eq!(a, b);
            let idx: Vec<usize> = ranges.shard_indices(m).collect();
            let want: Vec<usize> = explicit.shard_indices(m).collect();
            assert_eq!(idx, want);
        }
    }

    #[test]
    fn into_variants_match_allocating_wrappers_for_ranges() {
        let fed = FederatedDataset::from_ranges(vec![(3, 5)]);
        let a = fed.sample_batch(0, 8, &mut Pcg64::seed_from(9));
        let mut b = Vec::new();
        fed.sample_batch_into(0, 8, &mut Pcg64::seed_from(9), &mut b);
        assert_eq!(a, b);
        assert!(a.iter().all(|&i| (3..8).contains(&i)));
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn empty_shard_panics() {
        let fed = FederatedDataset::from_shards(vec![vec![]]);
        let mut rng = Pcg64::seed_from(2);
        fed.sample_batch(0, 1, &mut rng);
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn empty_range_shard_panics() {
        let fed = FederatedDataset::from_ranges(vec![(4, 0)]);
        let mut rng = Pcg64::seed_from(2);
        fed.sample_batch(0, 1, &mut rng);
    }
}
