//! Datasets and federated partitioning.
//!
//! The sandbox has no network access and none of the paper's image corpora,
//! so the experiment suite runs on **synthetic classification tasks**
//! generated to stress the same mechanism the paper studies: the sign/
//! magnitude statistics of worker gradients under **Dirichlet(α) label
//! skew** (Hsu et al. 2019) — see DESIGN.md §3 for the substitution
//! argument. The partitioner itself is exactly the paper's protocol and
//! works unchanged on real data.

mod partition;
mod synthetic;

pub use partition::{partition_report, DirichletPartitioner, PartitionReport};
pub use synthetic::{SyntheticSpec, SyntheticTask};

use crate::util::rng::Pcg64;

/// An in-memory dense classification dataset (row-major features).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n × dim` features.
    pub x: Vec<f32>,
    /// `n` labels in `[0, classes)`.
    pub y: Vec<usize>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather rows `idx` into caller-provided scratch buffers (cleared,
    /// then filled) — the allocation-free hot path behind [`Self::gather`].
    /// Capacity is retained across calls, so steady-state batch assembly
    /// performs zero heap allocations.
    pub fn gather_into(&self, idx: &[usize], bx: &mut Vec<f32>, by: &mut Vec<usize>) {
        bx.clear();
        by.clear();
        bx.reserve(idx.len() * self.dim);
        by.reserve(idx.len());
        for &i in idx {
            bx.extend_from_slice(self.row(i));
            by.push(self.y[i]);
        }
    }

    /// Gather rows `idx` into a dense batch `(x, y)` (allocating
    /// convenience wrapper over [`Self::gather_into`]).
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<usize>) {
        let mut bx = Vec::new();
        let mut by = Vec::new();
        self.gather_into(idx, &mut bx, &mut by);
        (bx, by)
    }
}

/// Reusable mini-batch assembly buffers (sampled indices + gathered
/// features/labels), owned per engine thread via
/// [`crate::model::ModelWorkspace`] so the per-round batch gather never
/// allocates in steady state.
#[derive(Default)]
pub struct BatchScratch {
    /// Sampled example indices.
    pub idx: Vec<usize>,
    /// Gathered `batch×dim` features.
    pub x: Vec<f32>,
    /// Gathered labels.
    pub y: Vec<usize>,
}

/// A dataset split across `M` workers: shard `m` holds indices into the
/// shared base dataset. Cloning is cheap-ish (indices only) — the feature
/// matrix is shared by reference at the engine level.
#[derive(Clone, Debug)]
pub struct FederatedDataset {
    /// Per-worker example indices.
    pub shards: Vec<Vec<usize>>,
}

impl FederatedDataset {
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Sample a mini-batch (with replacement, matching the paper's
    /// stochastic-gradient model) of `batch` indices from worker `m` into
    /// a caller-provided scratch buffer (cleared, then filled). The RNG
    /// draw sequence is identical to [`Self::sample_batch`].
    pub fn sample_batch_into(
        &self,
        m: usize,
        batch: usize,
        rng: &mut Pcg64,
        out: &mut Vec<usize>,
    ) {
        let shard = &self.shards[m];
        assert!(!shard.is_empty(), "worker {m} has an empty shard");
        out.clear();
        out.reserve(batch);
        for _ in 0..batch {
            out.push(shard[rng.index(shard.len())]);
        }
    }

    /// Allocating convenience wrapper over [`Self::sample_batch_into`].
    pub fn sample_batch(&self, m: usize, batch: usize, rng: &mut Pcg64) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_batch_into(m, batch, rng, &mut out);
        out
    }

    /// Total examples across shards.
    pub fn total(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            y: vec![0, 1, 0],
            dim: 2,
            classes: 2,
        }
    }

    #[test]
    fn rows_and_gather() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.row(1), &[2.0, 3.0]);
        let (bx, by) = d.gather(&[2, 0]);
        assert_eq!(bx, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(by, vec![0, 0]);
    }

    #[test]
    fn batch_sampling_in_range() {
        let fed = FederatedDataset { shards: vec![vec![0, 2], vec![1]] };
        let mut rng = Pcg64::seed_from(1);
        let b = fed.sample_batch(0, 16, &mut rng);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|i| [0usize, 2].contains(i)));
        assert_eq!(fed.total(), 3);
    }

    #[test]
    fn into_variants_match_allocating_wrappers() {
        let d = tiny();
        let mut bx = vec![9.0f32; 1];
        let mut by = vec![7usize; 5];
        d.gather_into(&[2, 0], &mut bx, &mut by);
        assert_eq!(bx, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(by, vec![0, 0]);
        // Identical RNG draw sequence: same seed ⇒ same indices.
        let fed = FederatedDataset { shards: vec![vec![0, 1, 2]] };
        let a = fed.sample_batch(0, 8, &mut Pcg64::seed_from(9));
        let mut b = vec![42usize; 3];
        fed.sample_batch_into(0, 8, &mut Pcg64::seed_from(9), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn empty_shard_panics() {
        let fed = FederatedDataset { shards: vec![vec![]] };
        let mut rng = Pcg64::seed_from(2);
        fed.sample_batch(0, 1, &mut rng);
    }
}
