//! Layered experiment configuration: presets → config file → CLI flags.
//!
//! The config system is deliberately plain-text (simple `key = value`
//! lines; the sandbox registry has no serde) but covers the full
//! experiment space: task preset, Dirichlet α, worker count and
//! participation, model, algorithm roster, rounds, schedules, seeds and
//! scale knobs. Every experiment harness consumes an [`ExperimentConfig`].

use crate::coordinator::{Algorithm, AttackPlan, SelectionMode};
pub use crate::coordinator::Algorithm as AlgorithmSpec;
use crate::data::SyntheticSpec;
use crate::model::ModelKind;
use crate::optim::LrSchedule;

/// Which benchmark task to generate.
#[derive(Clone, Debug, PartialEq)]
pub enum TaskSpec {
    FmnistLike,
    Cifar10Like,
    Cifar100Like,
    /// Fully custom synthetic task.
    Custom { dim: usize, classes: usize, train: usize, test: usize },
}

impl TaskSpec {
    pub fn synthetic_spec(&self) -> SyntheticSpec {
        match self {
            TaskSpec::FmnistLike => SyntheticSpec::fmnist_like(),
            TaskSpec::Cifar10Like => SyntheticSpec::cifar10_like(),
            TaskSpec::Cifar100Like => SyntheticSpec::cifar100_like(),
            TaskSpec::Custom { dim, classes, train, test } => SyntheticSpec {
                dim: *dim,
                classes: *classes,
                modes: 2,
                separation: 1.2,
                noise: 0.4,
                label_noise: 0.02,
                train: *train,
                test: *test,
            },
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TaskSpec::FmnistLike => "fmnist-like",
            TaskSpec::Cifar10Like => "cifar10-like",
            TaskSpec::Cifar100Like => "cifar100-like",
            TaskSpec::Custom { .. } => "custom",
        }
    }
}

/// Learning-rate schedule selection (resolved against `lr`).
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleKind {
    Const,
    PaperCifar10,
    PaperCifar100,
}

impl ScheduleKind {
    pub fn build(&self, lr: f64) -> LrSchedule {
        match self {
            ScheduleKind::Const => LrSchedule::Const { lr },
            ScheduleKind::PaperCifar10 => LrSchedule::paper_cifar10(lr),
            ScheduleKind::PaperCifar100 => LrSchedule::paper_cifar100(lr),
        }
    }
}

/// A complete experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub task: TaskSpec,
    /// Dirichlet concentration α (heterogeneity).
    pub alpha: f64,
    pub workers: usize,
    pub participation: f64,
    pub model: ModelKind,
    pub algorithms: Vec<Algorithm>,
    /// Optional per-algorithm learning-rate overrides (the paper tunes η
    /// per algorithm; empty = use `lr` for all, otherwise must match
    /// `algorithms` in length).
    pub lr_overrides: Vec<Option<f64>>,
    pub rounds: usize,
    pub batch: usize,
    pub eval_every: usize,
    pub seeds: Vec<u64>,
    pub lr: f64,
    pub schedule: ScheduleKind,
    /// Accuracy targets for the rounds/bits-to-target columns.
    pub targets: Vec<f64>,
    /// Dataset size multiplier (1.0 = preset size).
    pub data_scale: f64,
    /// Optional feature-dimension override (fast presets shrink the model).
    pub dim_override: Option<usize>,
    /// Byzantine attack spec (the [`AttackPlan::parse`] grammar, e.g.
    /// `collusive:30%` or `signflip:8,rescale:4:1e4`); `None` = honest run.
    /// The plan itself is built per seed at run time so cohort membership
    /// varies across the seed sweep.
    pub attack: Option<String>,
    /// Worker-selection stream (legacy Pcg64 vs hardened committed-seed).
    pub selection: SelectionMode,
}

impl ExperimentConfig {
    /// A fast smoke-scale preset: small task, linear model, three core
    /// algorithms — used by `examples/quickstart.rs` and CI.
    pub fn fast_preset() -> Self {
        use crate::compressors::CompressorKind;
        use crate::coordinator::AggregationRule;
        ExperimentConfig {
            name: "fast".into(),
            task: TaskSpec::Custom { dim: 32, classes: 5, train: 1_500, test: 400 },
            alpha: 0.3,
            workers: 20,
            participation: 1.0,
            model: ModelKind::Mlp { inputs: 32, hidden: vec![32], classes: 5 },
            algorithms: vec![
                Algorithm::CompressedGd {
                    compressor: CompressorKind::Sign,
                    aggregation: AggregationRule::MajorityVote,
                },
                Algorithm::CompressedGd {
                    compressor: CompressorKind::Sparsign { budget: 1.0 },
                    aggregation: AggregationRule::MajorityVote,
                },
                Algorithm::EfSparsign {
                    b_local: 10.0,
                    b_global: 1.0,
                    tau: 1,
                    server_lr_scale: None,
                    server_ef: true,
                },
            ],
            lr_overrides: Vec::new(),
            rounds: 100,
            batch: 32,
            eval_every: 10,
            seeds: vec![0, 1],
            lr: 0.02,
            schedule: ScheduleKind::Const,
            targets: vec![0.5, 0.7],
            data_scale: 1.0,
            dim_override: None,
            attack: None,
            selection: SelectionMode::default(),
        }
    }

    /// Apply a `key=value` override (from a config file line or CLI).
    /// Returns an error string for unknown keys / malformed values.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn parse<T: std::str::FromStr>(v: &str, key: &str) -> Result<T, String> {
            v.parse::<T>()
                .map_err(|_| format!("invalid value '{v}' for key '{key}'"))
        }
        match key {
            "name" => self.name = value.to_string(),
            "alpha" => self.alpha = parse(value, key)?,
            "workers" => self.workers = parse(value, key)?,
            "participation" => self.participation = parse(value, key)?,
            "rounds" => self.rounds = parse(value, key)?,
            "batch" => self.batch = parse(value, key)?,
            "eval_every" => self.eval_every = parse(value, key)?,
            "lr" => self.lr = parse(value, key)?,
            "data_scale" => self.data_scale = parse(value, key)?,
            "seeds" => {
                self.seeds = value
                    .split(',')
                    .map(|s| parse::<u64>(s.trim(), key))
                    .collect::<Result<Vec<_>, _>>()?;
            }
            "targets" => {
                self.targets = value
                    .split(',')
                    .map(|s| parse::<f64>(s.trim(), key))
                    .collect::<Result<Vec<_>, _>>()?;
            }
            "task" => {
                self.task = match value {
                    "fmnist" | "fmnist-like" => TaskSpec::FmnistLike,
                    "cifar10" | "cifar10-like" => TaskSpec::Cifar10Like,
                    "cifar100" | "cifar100-like" => TaskSpec::Cifar100Like,
                    other => return Err(format!("unknown task '{other}'")),
                };
            }
            "schedule" => {
                self.schedule = match value {
                    "const" => ScheduleKind::Const,
                    "cifar10" => ScheduleKind::PaperCifar10,
                    "cifar100" => ScheduleKind::PaperCifar100,
                    other => return Err(format!("unknown schedule '{other}'")),
                };
            }
            "attack" => {
                self.attack = match value {
                    "none" | "" => None,
                    spec => Some(spec.to_string()),
                };
            }
            "selection" => self.selection = parse_selection(value)?,
            other => return Err(format!("unknown config key '{other}'")),
        }
        Ok(())
    }

    /// Parse a config file body: `key = value` per line, `#` comments.
    pub fn apply_file(&mut self, body: &str) -> Result<(), String> {
        for (ln, raw) in body.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            self.apply_override(k.trim(), v.trim())
                .map_err(|e| format!("line {}: {e}", ln + 1))?;
        }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("workers must be > 0".into());
        }
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            return Err(format!("participation {} out of (0,1]", self.participation));
        }
        if self.rounds == 0 || self.batch == 0 {
            return Err("rounds and batch must be > 0".into());
        }
        if self.seeds.is_empty() {
            return Err("need at least one seed".into());
        }
        if self.algorithms.is_empty() {
            return Err("need at least one algorithm".into());
        }
        if !self.lr_overrides.is_empty() && self.lr_overrides.len() != self.algorithms.len() {
            return Err(format!(
                "lr_overrides has {} entries but there are {} algorithms",
                self.lr_overrides.len(),
                self.algorithms.len()
            ));
        }
        if !(self.data_scale > 0.0) {
            return Err("data_scale must be > 0".into());
        }
        if let Some(spec) = &self.attack {
            // Parse against the configured population so a bad spec fails
            // at validation, not mid-sweep.
            AttackPlan::parse(spec, self.workers, 0)
                .map_err(|e| format!("attack spec: {e}"))?;
        }
        Ok(())
    }
}

/// Shared `--selection` / `selection =` value grammar.
pub fn parse_selection(value: &str) -> Result<SelectionMode, String> {
    match value {
        "legacy" | "pcg" => Ok(SelectionMode::Legacy),
        "committed" | "hardened" => Ok(SelectionMode::Committed),
        other => Err(format!("unknown selection mode '{other}' (legacy|committed)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_preset_is_valid() {
        let c = ExperimentConfig::fast_preset();
        assert!(c.validate().is_ok());
        assert_eq!(c.task.label(), "custom");
    }

    #[test]
    fn overrides_apply() {
        let mut c = ExperimentConfig::fast_preset();
        c.apply_override("alpha", "0.7").unwrap();
        c.apply_override("rounds", "42").unwrap();
        c.apply_override("seeds", "3, 4, 5").unwrap();
        c.apply_override("task", "cifar100").unwrap();
        c.apply_override("schedule", "cifar100").unwrap();
        c.apply_override("attack", "collusive:25%").unwrap();
        c.apply_override("selection", "committed").unwrap();
        assert_eq!(c.alpha, 0.7);
        assert_eq!(c.rounds, 42);
        assert_eq!(c.seeds, vec![3, 4, 5]);
        assert_eq!(c.task, TaskSpec::Cifar100Like);
        assert_eq!(c.schedule, ScheduleKind::PaperCifar100);
        assert_eq!(c.attack.as_deref(), Some("collusive:25%"));
        assert_eq!(c.selection, SelectionMode::Committed);
        c.apply_override("attack", "none").unwrap();
        assert!(c.attack.is_none());
        assert!(c.apply_override("selection", "psychic").is_err());
    }

    #[test]
    fn bad_attack_spec_fails_validation_not_midrun() {
        let mut c = ExperimentConfig::fast_preset();
        c.attack = Some("warp:3".into());
        assert!(c.validate().unwrap_err().contains("attack spec"));
        c.attack = Some(format!("signflip:{}", c.workers + 1));
        assert!(c.validate().is_err());
        c.attack = Some("collusive:25%".into());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn unknown_key_and_bad_value_rejected() {
        let mut c = ExperimentConfig::fast_preset();
        assert!(c.apply_override("nope", "1").is_err());
        assert!(c.apply_override("rounds", "abc").is_err());
        assert!(c.apply_override("task", "imagenet").is_err());
    }

    #[test]
    fn file_parsing_with_comments() {
        let mut c = ExperimentConfig::fast_preset();
        c.apply_file("# comment\nalpha = 0.1\n\nrounds = 7 # trailing\n").unwrap();
        assert_eq!(c.alpha, 0.1);
        assert_eq!(c.rounds, 7);
        let err = c.apply_file("garbage line").unwrap_err();
        assert!(err.contains("line 1"));
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ExperimentConfig::fast_preset();
        c.participation = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::fast_preset();
        c.seeds.clear();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::fast_preset();
        c.algorithms.clear();
        assert!(c.validate().is_err());
    }
}
