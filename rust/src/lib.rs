//! # sparsignd
//!
//! A production-grade reproduction of *"Magnitude Matters: Fixing SIGNSGD
//! Through Magnitude-Aware Sparsification in the Presence of Data
//! Heterogeneity"* (Jin et al., cs.LG 2023).
//!
//! The crate implements the full federated-learning training stack the
//! paper evaluates:
//!
//! * **[`compressors`]** — the paper's `sparsign` magnitude-driven ternary
//!   compressor (Definition 1) plus every baseline it compares against
//!   (signSGD, scaled sign, noisy sign, QSGD variants, TernGrad, Top-k,
//!   Random-k, Threshold-v, STC), all with exact communication-bit
//!   accounting.
//! * **[`coordinator`]** — the L3 parameter server: Algorithm 1
//!   (SPARSIGNSGD) and Algorithm 2 (EF-SPARSIGNSGD with local updates and
//!   *server-side only* error feedback), worker sampling, majority-vote and
//!   α-approximate aggregation, a threaded simulation engine, a
//!   communication ledger, and adversarial attack injection.
//! * **[`model`] / [`data`] / [`optim`]** — the training substrates: pure
//!   rust models (softmax regression, MLP, CNN features, Rosenbrock),
//!   synthetic non-IID dataset generators with Dirichlet(α) label-skew
//!   partitioning (Hsu et al. 2019), SGD with the paper's learning-rate
//!   schedules, FedAvg and FedCom (Haddadpour et al. 2021) baselines.
//! * **[`runtime`]** — the PJRT bridge: loads JAX/Pallas models AOT-lowered
//!   to HLO text by `python/compile/aot.py` and executes them from the
//!   rust hot path (Python is never on the request path).
//! * **[`coding`]** — bit-level Golomb/Elias entropy coders implementing
//!   the paper's eq. (12) cost model for ternary gradient positions.
//! * **[`net`]** — the federation transport layer: a versioned wire
//!   codec (packed-ternary bitplanes as raw `u64` words, CRC-checked
//!   frames), a coordinator service over TCP/UDS feeding the streaming
//!   vote path, and a client-fleet driver whose loopback runs are
//!   bit-identical to the in-process engine.
//! * **[`snapshot`]** — elastic-federation checkpointing: a versioned,
//!   CRC-guarded coordinator snapshot (params, RNG streams, server EF
//!   residual, ledger, metrics history) written atomically, so a killed
//!   coordinator resumes with a `RunHistory` bit-identical to an
//!   uninterrupted run.
//! * **[`metrics::registry`]** — the live observability plane: a
//!   wait-free Prometheus-style [`MetricsRegistry`] every coordinator
//!   tier (root and shards) exposes over `GET /metrics` from the same
//!   reactor thread that runs the protocol, so a whole aggregation
//!   tree is scrape-able mid-run without a scrape ever delaying a
//!   round (DESIGN.md §17).
//! * **[`experiments`]** — one harness per paper table/figure (Fig. 1–3,
//!   Tables 1–7) that regenerates the reported rows/series.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sparsignd::config::ExperimentConfig;
//!
//! let cfg = ExperimentConfig::fast_preset();
//! let report = sparsignd::experiments::run_classification(&cfg);
//! println!("{}", report.table());
//! ```
//!
//! See `examples/quickstart.rs` for a complete runnable version, and
//! `DESIGN.md` for the paper → module map.
//!
//! [`MetricsRegistry`]: crate::metrics::registry::MetricsRegistry

pub mod cli;
pub mod coding;
pub mod compressors;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod net;
pub mod optim;
pub mod runtime;
pub mod snapshot;
pub mod testing;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::compressors::{
        Compressor, CompressorKind, CompressedGrad, SparsignCompressor,
    };
    pub use crate::metrics::registry::MetricsRegistry;
    pub use crate::net::{Endpoint, FleetOptions, ServeOptions, ShardOptions};
    pub use crate::snapshot::SnapshotPolicy;
    pub use crate::util::rng::Pcg64;
}
