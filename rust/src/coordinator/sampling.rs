//! Worker sampling — the `S^{(t)}` selection step of Algorithms 1 & 2.

use crate::util::rng::Pcg64;

/// Uniform-without-replacement worker sampler (the paper's protocol: "the
/// server selects a random set of workers", each with equal probability
/// `p_s = k/M` per round).
#[derive(Clone, Copy, Debug)]
pub struct WorkerSampler {
    /// Total worker population M.
    pub total: usize,
    /// Participation fraction `p_s ∈ (0, 1]`.
    pub participation: f64,
}

impl WorkerSampler {
    pub fn new(total: usize, participation: f64) -> Self {
        assert!(total > 0, "need at least one worker");
        assert!(
            participation > 0.0 && participation <= 1.0,
            "participation must be in (0,1], got {participation}"
        );
        Self { total, participation }
    }

    /// Number of workers selected each round (≥ 1).
    pub fn per_round(&self) -> usize {
        ((self.total as f64 * self.participation).round() as usize).clamp(1, self.total)
    }

    /// Draw this round's selected set (sorted, distinct).
    pub fn select(&self, rng: &mut Pcg64) -> Vec<usize> {
        let mut out = Vec::new();
        self.select_into(rng, &mut out);
        out
    }

    /// [`Self::select`] into a reusable buffer (cleared first) — the run
    /// loop's path; at full participation it draws nothing from `rng` and
    /// allocates nothing in steady state. Consumes the same RNG stream as
    /// `select`, so the two are interchangeable mid-run.
    pub fn select_into(&self, rng: &mut Pcg64, out: &mut Vec<usize>) {
        out.clear();
        let k = self.per_round();
        if k == self.total {
            out.extend(0..self.total);
        } else {
            out.extend_from_slice(&rng.sample_indices(self.total, k));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_selects_everyone() {
        let s = WorkerSampler::new(10, 1.0);
        let mut rng = Pcg64::seed_from(1);
        assert_eq!(s.select(&mut rng), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn partial_selection_size_and_range() {
        let s = WorkerSampler::new(100, 0.2);
        assert_eq!(s.per_round(), 20);
        let mut rng = Pcg64::seed_from(2);
        for _ in 0..20 {
            let sel = s.select(&mut rng);
            assert_eq!(sel.len(), 20);
            assert!(sel.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn selection_is_uniform_over_workers() {
        let s = WorkerSampler::new(50, 0.1);
        let mut rng = Pcg64::seed_from(3);
        let mut counts = vec![0usize; 50];
        let rounds = 10_000;
        for _ in 0..rounds {
            for i in s.select(&mut rng) {
                counts[i] += 1;
            }
        }
        let expect = rounds as f64 * 0.1;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 0.15 * expect,
                "worker {i} selected {c} times, expected ~{expect}"
            );
        }
    }

    #[test]
    fn select_into_matches_select() {
        let s = WorkerSampler::new(40, 0.3);
        let mut r1 = Pcg64::seed_from(7);
        let mut r2 = Pcg64::seed_from(7);
        let mut buf = vec![999usize; 5]; // stale contents must be cleared
        for _ in 0..10 {
            let a = s.select(&mut r1);
            s.select_into(&mut r2, &mut buf);
            assert_eq!(a, buf);
        }
    }

    #[test]
    fn tiny_participation_floors_to_one() {
        let s = WorkerSampler::new(10, 0.01);
        assert_eq!(s.per_round(), 1);
    }

    #[test]
    #[should_panic(expected = "participation must be in")]
    fn zero_participation_rejected() {
        WorkerSampler::new(10, 0.0);
    }
}
