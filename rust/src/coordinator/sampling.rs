//! Worker sampling — the `S^{(t)}` selection step of Algorithms 1 & 2 —
//! and the [`SelectionRng`] that drives it.
//!
//! Two selection modes exist (DESIGN.md §13):
//!
//! * **Legacy** — the original `Pcg64` stream derived from the run seed.
//!   Fast and statistically fine, but *predictable*: its raw state rides
//!   coordinator snapshots, and PCG output is invertible with known
//!   techniques (pcg-breaker), so any party that sees a snapshot — or
//!   enough raw outputs — can predict every future round's cohort.
//! * **Committed** — ChaCha20-based committed-seed sampling. The round-`t`
//!   cohort is drawn from a per-round key `PRF(root_key, t)`; the root key
//!   never leaves the process. Snapshots (and the rendezvous `Welcome`)
//!   carry only a one-way *commitment* to the root key plus the round
//!   counter, so disclosure of all serialized state predicts nothing.
//!
//! Legacy mode routes through the exact same `Pcg64` code path as before
//! the abstraction existed, so every bit-identity contract (engine
//! equivalence, loopback diff, snapshot resume) is unchanged.

use crate::util::rng::{
    selection_commitment, selection_root_key, selection_round_key, ChaChaRng, Pcg64,
    SELECT_NONCE_STREAM,
};

/// Uniform-without-replacement worker sampler (the paper's protocol: "the
/// server selects a random set of workers", each with equal probability
/// `p_s = k/M` per round).
#[derive(Clone, Copy, Debug)]
pub struct WorkerSampler {
    /// Total worker population M.
    pub total: usize,
    /// Participation fraction `p_s ∈ (0, 1]`.
    pub participation: f64,
}

impl WorkerSampler {
    pub fn new(total: usize, participation: f64) -> Self {
        assert!(total > 0, "need at least one worker");
        assert!(
            participation > 0.0 && participation <= 1.0,
            "participation must be in (0,1], got {participation}"
        );
        Self { total, participation }
    }

    /// Number of workers selected each round (≥ 1).
    pub fn per_round(&self) -> usize {
        ((self.total as f64 * self.participation).round() as usize).clamp(1, self.total)
    }

    /// Draw this round's selected set (sorted, distinct).
    pub fn select(&self, rng: &mut Pcg64) -> Vec<usize> {
        let mut out = Vec::new();
        self.select_into(rng, &mut out);
        out
    }

    /// [`Self::select`] into a reusable buffer (cleared first) — the run
    /// loop's path. At participation 1.0 the identity fast path writes
    /// `0..total` without drawing from `rng` and without touching the
    /// heap in steady state (`tests/zero_alloc_round.rs` pins the whole
    /// round). Consumes the same RNG stream as `select`, so the two are
    /// interchangeable mid-run.
    pub fn select_into(&self, rng: &mut Pcg64, out: &mut Vec<usize>) {
        out.clear();
        let k = self.per_round();
        if k == self.total {
            // Identity fast path: full participation selects everyone,
            // needs no randomness and no allocation.
            out.extend(0..self.total);
        } else {
            out.extend_from_slice(&rng.sample_indices(self.total, k));
        }
    }
}

/// Which selection stream a run uses. Part of the run configuration (and
/// its fingerprint): the two modes draw different cohorts under partial
/// participation, so a fleet and its coordinator must agree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SelectionMode {
    /// The original `Pcg64` stream (raw state serialized in snapshots).
    #[default]
    Legacy,
    /// Hardened ChaCha20 committed-seed sampling (DESIGN.md §13).
    Committed,
}

/// Serialized form of the selection state at a round boundary — what the
/// snapshot codec carries. Legacy exports raw RNG words (the historical
/// behaviour, and the attack surface the committed mode closes);
/// committed exports only the one-way commitment plus the round counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionSnapshot {
    /// Raw `Pcg64` state (`[state_lo, state_hi, inc_lo, inc_hi]`).
    LegacyRaw([u64; 4]),
    /// Commitment to the root key + rounds drawn so far. No generator
    /// state is recoverable from this.
    Committed { commitment: [u64; 4], round: u64 },
}

/// The server-side selection stream, in one of the two modes.
pub enum SelectionRng {
    Legacy(Pcg64),
    Committed(CommittedSelection),
}

/// Hardened committed-seed selection state: the root key (private to the
/// process), its public commitment, the next round counter, and a
/// reusable Fisher–Yates pool so partial-participation draws settle into
/// zero steady-state allocations.
pub struct CommittedSelection {
    root_key: [u32; 8],
    commitment: [u64; 4],
    round: u64,
    pool: Vec<usize>,
}

impl SelectionRng {
    /// Build the selection stream for `mode` from the run seed. Legacy
    /// derives the exact historical stream (`root.derive(0xfeed)`).
    pub fn from_seed(mode: SelectionMode, root: &Pcg64, seed: u64) -> Self {
        match mode {
            SelectionMode::Legacy => SelectionRng::Legacy(root.derive(0xfeed)),
            SelectionMode::Committed => {
                let root_key = selection_root_key(seed);
                SelectionRng::Committed(CommittedSelection {
                    root_key,
                    commitment: selection_commitment(&root_key),
                    round: 0,
                    pool: Vec::new(),
                })
            }
        }
    }

    pub fn mode(&self) -> SelectionMode {
        match self {
            SelectionRng::Legacy(_) => SelectionMode::Legacy,
            SelectionRng::Committed(_) => SelectionMode::Committed,
        }
    }

    /// Draw round `t`'s cohort into `out` (sorted, distinct; cleared
    /// first). Legacy ignores `t` — it is a sequential stream; committed
    /// keys every round independently, so any round can be (re)drawn
    /// from the root key alone.
    pub fn select_into(&mut self, sampler: &WorkerSampler, t: usize, out: &mut Vec<usize>) {
        match self {
            SelectionRng::Legacy(rng) => sampler.select_into(rng, out),
            SelectionRng::Committed(c) => c.select_into(sampler, t as u64, out),
        }
    }

    /// Raw generator state for serialization — `None` in committed mode
    /// *by construction*: the hardened selection stream has no exportable
    /// state (`tests/selection_attack.rs` pins the refusal).
    pub fn to_raw(&self) -> Option<[u64; 4]> {
        match self {
            SelectionRng::Legacy(rng) => Some(rng.to_raw()),
            SelectionRng::Committed(_) => None,
        }
    }

    /// The public commitment broadcast at rendezvous: the root-key
    /// commitment in committed mode, all-zero in legacy mode (legacy has
    /// nothing to commit to — its state is the secret it leaks).
    pub fn commitment(&self) -> [u64; 4] {
        match self {
            SelectionRng::Legacy(_) => [0; 4],
            SelectionRng::Committed(c) => c.commitment,
        }
    }

    /// Snapshot form at a round boundary (`round` = rounds completed).
    pub fn snapshot(&self, round: u64) -> SelectionSnapshot {
        match self {
            SelectionRng::Legacy(rng) => SelectionSnapshot::LegacyRaw(rng.to_raw()),
            SelectionRng::Committed(c) => {
                SelectionSnapshot::Committed { commitment: c.commitment, round }
            }
        }
    }

    /// Rebuild from a snapshot. Legacy restores the raw stream; committed
    /// re-derives the root key from the run seed and *verifies* it against
    /// the stored commitment — a snapshot from a different seed (or a
    /// tampered commitment) is refused rather than silently diverging.
    pub fn restore(
        mode: SelectionMode,
        seed: u64,
        snap: &SelectionSnapshot,
    ) -> Result<Self, &'static str> {
        match (mode, snap) {
            (SelectionMode::Legacy, SelectionSnapshot::LegacyRaw(raw)) => Pcg64::from_raw(*raw)
                .map(SelectionRng::Legacy)
                .ok_or("even selection-rng increment"),
            (SelectionMode::Committed, SelectionSnapshot::Committed { commitment, round }) => {
                let root_key = selection_root_key(seed);
                if selection_commitment(&root_key) != *commitment {
                    return Err("selection commitment does not match this run's seed");
                }
                Ok(SelectionRng::Committed(CommittedSelection {
                    root_key,
                    commitment: *commitment,
                    round: *round,
                    pool: Vec::new(),
                }))
            }
            _ => Err("snapshot selection mode differs from the run's"),
        }
    }
}

impl CommittedSelection {
    /// Rounds drawn so far (the committed mode's only mutable state).
    pub fn rounds_drawn(&self) -> u64 {
        self.round
    }

    fn select_into(&mut self, sampler: &WorkerSampler, t: u64, out: &mut Vec<usize>) {
        out.clear();
        let k = sampler.per_round();
        if k == sampler.total {
            // Same identity fast path as legacy: no draw, no allocation.
            out.extend(0..sampler.total);
        } else {
            let key = selection_round_key(&self.root_key, t);
            let mut rng = ChaChaRng::new(key, SELECT_NONCE_STREAM);
            // Partial Fisher–Yates over the reusable pool.
            self.pool.clear();
            self.pool.extend(0..sampler.total);
            for i in 0..k {
                let j = i + rng.index(sampler.total - i);
                self.pool.swap(i, j);
            }
            out.extend_from_slice(&self.pool[..k]);
            out.sort_unstable();
        }
        self.round = t + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_selects_everyone() {
        let s = WorkerSampler::new(10, 1.0);
        let mut rng = Pcg64::seed_from(1);
        assert_eq!(s.select(&mut rng), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn partial_selection_size_and_range() {
        let s = WorkerSampler::new(100, 0.2);
        assert_eq!(s.per_round(), 20);
        let mut rng = Pcg64::seed_from(2);
        for _ in 0..20 {
            let sel = s.select(&mut rng);
            assert_eq!(sel.len(), 20);
            assert!(sel.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn selection_is_uniform_over_workers() {
        let s = WorkerSampler::new(50, 0.1);
        let mut rng = Pcg64::seed_from(3);
        let mut counts = vec![0usize; 50];
        let rounds = 10_000;
        for _ in 0..rounds {
            for i in s.select(&mut rng) {
                counts[i] += 1;
            }
        }
        let expect = rounds as f64 * 0.1;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 0.15 * expect,
                "worker {i} selected {c} times, expected ~{expect}"
            );
        }
    }

    #[test]
    fn select_into_matches_select() {
        let s = WorkerSampler::new(40, 0.3);
        let mut r1 = Pcg64::seed_from(7);
        let mut r2 = Pcg64::seed_from(7);
        let mut buf = vec![999usize; 5]; // stale contents must be cleared
        for _ in 0..10 {
            let a = s.select(&mut r1);
            s.select_into(&mut r2, &mut buf);
            assert_eq!(a, buf);
        }
    }

    #[test]
    fn tiny_participation_floors_to_one() {
        let s = WorkerSampler::new(10, 0.01);
        assert_eq!(s.per_round(), 1);
    }

    #[test]
    #[should_panic(expected = "participation must be in")]
    fn zero_participation_rejected() {
        WorkerSampler::new(10, 0.0);
    }

    #[test]
    fn legacy_selection_rng_matches_historical_stream() {
        // The abstraction must not perturb the legacy stream: selecting
        // through SelectionRng::Legacy is bit-identical to the direct
        // `root.derive(0xfeed)` path every engine used before.
        let root = Pcg64::seed_from(77);
        let s = WorkerSampler::new(30, 0.4);
        let mut direct = root.derive(0xfeed);
        let mut sel = SelectionRng::from_seed(SelectionMode::Legacy, &root, 77);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for t in 0..12 {
            s.select_into(&mut direct, &mut a);
            sel.select_into(&s, t, &mut b);
            assert_eq!(a, b, "round {t}");
        }
    }

    #[test]
    fn committed_selection_is_deterministic_and_round_keyed() {
        let root = Pcg64::seed_from(5);
        let s = WorkerSampler::new(50, 0.2);
        let mut r1 = SelectionRng::from_seed(SelectionMode::Committed, &root, 5);
        let mut r2 = SelectionRng::from_seed(SelectionMode::Committed, &root, 5);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for t in 0..10 {
            r1.select_into(&s, t, &mut a);
            r2.select_into(&s, t, &mut b);
            assert_eq!(a, b);
            assert_eq!(a.len(), 10);
            for w in a.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
        // Round-keyed: drawing round 3 out of order reproduces it exactly.
        let mut r3 = SelectionRng::from_seed(SelectionMode::Committed, &root, 5);
        r3.select_into(&s, 3, &mut b);
        r1.select_into(&s, 3, &mut a);
        assert_eq!(a, b);
    }

    #[test]
    fn committed_selection_is_uniform() {
        let root = Pcg64::seed_from(6);
        let s = WorkerSampler::new(40, 0.25);
        let mut sel = SelectionRng::from_seed(SelectionMode::Committed, &root, 6);
        let mut counts = vec![0usize; 40];
        let mut buf = Vec::new();
        let rounds = 8_000;
        for t in 0..rounds {
            sel.select_into(&s, t, &mut buf);
            for &i in &buf {
                counts[i] += 1;
            }
        }
        let expect = rounds as f64 * 0.25;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 0.15 * expect,
                "worker {i} selected {c} times, expected ~{expect}"
            );
        }
    }

    #[test]
    fn committed_mode_exports_no_raw_state() {
        let root = Pcg64::seed_from(9);
        let legacy = SelectionRng::from_seed(SelectionMode::Legacy, &root, 9);
        let hardened = SelectionRng::from_seed(SelectionMode::Committed, &root, 9);
        assert!(legacy.to_raw().is_some());
        assert!(hardened.to_raw().is_none());
        assert_eq!(legacy.commitment(), [0; 4]);
        assert_ne!(hardened.commitment(), [0; 4]);
    }

    #[test]
    fn committed_restore_verifies_the_commitment() {
        let root = Pcg64::seed_from(11);
        let mut sel = SelectionRng::from_seed(SelectionMode::Committed, &root, 11);
        let s = WorkerSampler::new(20, 0.5);
        let mut buf = Vec::new();
        for t in 0..4 {
            sel.select_into(&s, t, &mut buf);
        }
        let snap = sel.snapshot(4);
        // Same seed restores and continues identically.
        let mut back = SelectionRng::restore(SelectionMode::Committed, 11, &snap).expect("restore");
        let mut expect = Vec::new();
        sel.select_into(&s, 4, &mut expect);
        back.select_into(&s, 4, &mut buf);
        assert_eq!(expect, buf);
        // A different seed fails the commitment check.
        assert!(SelectionRng::restore(SelectionMode::Committed, 12, &snap).is_err());
        // Mode mismatch is refused.
        assert!(SelectionRng::restore(SelectionMode::Legacy, 11, &snap).is_err());
    }
}
