//! Server-side aggregation rules `C(·)` from Algorithm 1 / Algorithm 2.

use crate::compressors::CompressedGrad;
use crate::util::l1_norm;

/// The aggregation rule applied to the averaged worker messages before
/// broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationRule {
    /// Majority vote: `C(x) = sign(x)` coordinate-wise (signSGD /
    /// SPARSIGNSGD; downlink is `d` bits). `sign(0) = 0` — a tied
    /// coordinate moves nothing, matching the ternary analysis.
    MajorityVote,
    /// Scaled sign: `C(x) = (‖x‖₁/d)·sign(x)` — the α-approximate
    /// compressor used by EF-SPARSIGNSGD's server (downlink `d + 32` bits).
    ScaledSign,
    /// Plain mean (no server compression; downlink `32·d` bits) — used by
    /// the unbiased baselines (QSGD, TernGrad, FedAvg, FedCom).
    Mean,
}

/// Result of server aggregation.
#[derive(Clone, Debug)]
pub struct Aggregate {
    /// The broadcast update `g̃` (dense, decoded).
    pub update: Vec<f32>,
    /// The pre-compression quantity `avg(Δ) + ẽ` — Algorithm 2's error
    /// feedback needs it to form `ẽ^{(t+1)} = raw − g̃` (eq. 8).
    pub raw: Vec<f32>,
    /// Downlink message size in bits.
    pub downlink_bits: f64,
}

impl AggregationRule {
    /// Average the worker messages and apply the rule.
    ///
    /// `pre_add` (the server error-feedback residual in Algorithm 2) is
    /// added to the average *before* compression; pass `None` for
    /// Algorithm 1.
    pub fn aggregate(&self, msgs: &[CompressedGrad], pre_add: Option<&[f32]>) -> Aggregate {
        assert!(!msgs.is_empty(), "aggregation over zero messages");
        let d = msgs[0].dim();
        assert!(
            msgs.iter().all(|m| m.dim() == d),
            "mismatched message dimensions"
        );
        let mut avg = vec![0.0f32; d];
        for m in msgs {
            m.add_into(&mut avg);
        }
        let inv = 1.0 / msgs.len() as f32;
        for v in avg.iter_mut() {
            *v *= inv;
        }
        if let Some(e) = pre_add {
            assert_eq!(e.len(), d, "error-feedback dim mismatch");
            for (a, &ei) in avg.iter_mut().zip(e) {
                *a += ei;
            }
        }
        let raw = avg.clone();
        match self {
            AggregationRule::MajorityVote => {
                for v in avg.iter_mut() {
                    *v = crate::util::sign0(*v);
                }
                Aggregate { update: avg, raw, downlink_bits: d as f64 }
            }
            AggregationRule::ScaledSign => {
                let scale = l1_norm(&avg) / d.max(1) as f32;
                for v in avg.iter_mut() {
                    *v = scale * crate::util::sign1(*v);
                }
                Aggregate { update: avg, raw, downlink_bits: d as f64 + 32.0 }
            }
            AggregationRule::Mean => {
                Aggregate { update: avg, raw, downlink_bits: 32.0 * d as f64 }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tern(q: Vec<i8>, scale: f32) -> CompressedGrad {
        CompressedGrad::Ternary { q, scale, bits: 0.0 }
    }

    #[test]
    fn majority_vote_basic() {
        let msgs = vec![
            tern(vec![1, -1, 0], 1.0),
            tern(vec![1, 1, 0], 1.0),
            tern(vec![-1, -1, 0], 1.0),
        ];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![1.0, -1.0, 0.0]);
        assert_eq!(agg.downlink_bits, 3.0);
    }

    #[test]
    fn majority_vote_tie_is_zero() {
        let msgs = vec![tern(vec![1], 1.0), tern(vec![-1], 1.0)];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![0.0]);
    }

    #[test]
    fn scaled_sign_magnitude() {
        let msgs = vec![tern(vec![1, -1, 1, 1], 2.0)];
        let agg = AggregationRule::ScaledSign.aggregate(&msgs, None);
        // avg = [2,-2,2,2]; ‖·‖₁/d = 2 ⇒ update = 2·sign.
        assert_eq!(agg.update, vec![2.0, -2.0, 2.0, 2.0]);
        assert_eq!(agg.downlink_bits, 36.0);
    }

    #[test]
    fn mean_is_exact_average() {
        let msgs = vec![
            CompressedGrad::Dense { v: vec![1.0, 3.0], bits: 0.0 },
            CompressedGrad::Dense { v: vec![3.0, 5.0], bits: 0.0 },
        ];
        let agg = AggregationRule::Mean.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![2.0, 4.0]);
        assert_eq!(agg.downlink_bits, 64.0);
    }

    #[test]
    fn pre_add_feeds_error_feedback() {
        let msgs = vec![tern(vec![1, 0], 1.0)];
        let e = vec![-2.0, 0.5];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, Some(&e));
        // avg + e = [-1, 0.5] ⇒ sign = [-1, 1].
        assert_eq!(agg.update, vec![-1.0, 1.0]);
        // `raw` carries the pre-compression average for the EF recursion.
        assert_eq!(agg.raw, vec![-1.0, 0.5]);
    }

    #[test]
    #[should_panic(expected = "zero messages")]
    fn empty_rejected() {
        AggregationRule::MajorityVote.aggregate(&[], None);
    }

    #[test]
    #[should_panic(expected = "mismatched message dimensions")]
    fn dim_mismatch_rejected() {
        let msgs = vec![tern(vec![1], 1.0), tern(vec![1, 1], 1.0)];
        AggregationRule::MajorityVote.aggregate(&msgs, None);
    }
}
