//! Server-side aggregation rules `C(·)` from Algorithm 1 / Algorithm 2.
//!
//! Hot path (DESIGN.md §8): when every worker message is packed ternary
//! with one shared positive scale — signSGD, noisy/sto-sign, SSDM and
//! sparsign all transmit `scale = 1` — the per-coordinate votes are
//! counted **word-parallel** over the `u64` bitplanes with carry-save
//! vertical counters, and the only per-coordinate f32 work left is the
//! single final pass that materializes the broadcast update. Messages with
//! heterogeneous scales (TernGrad, QSGD, STC) or dense payloads fall back
//! to the reference f32 accumulation.

use crate::compressors::{CompressedGrad, PackedTernary};
use crate::util::l1_norm;

/// The aggregation rule applied to the averaged worker messages before
/// broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationRule {
    /// Majority vote: `C(x) = sign(x)` coordinate-wise (signSGD /
    /// SPARSIGNSGD; downlink is `d` bits). `sign(0) = 0` — a tied
    /// coordinate moves nothing, matching the ternary analysis.
    MajorityVote,
    /// Scaled sign: `C(x) = (‖x‖₁/d)·sign(x)` — the α-approximate
    /// compressor used by EF-SPARSIGNSGD's server (downlink `d + 32` bits).
    ScaledSign,
    /// Plain mean (no server compression; downlink `32·d` bits) — used by
    /// the unbiased baselines (QSGD, TernGrad, FedAvg, FedCom).
    Mean,
}

/// Result of server aggregation.
#[derive(Clone, Debug)]
pub struct Aggregate {
    /// The broadcast update `g̃` (dense, decoded).
    pub update: Vec<f32>,
    /// The pre-compression quantity `avg(Δ) + ẽ` — Algorithm 2's error
    /// feedback needs it to form `ẽ^{(t+1)} = raw − g̃` (eq. 8).
    pub raw: Vec<f32>,
    /// Downlink message size in bits.
    pub downlink_bits: f64,
}

/// Word-parallel per-coordinate vote counting over packed ternary
/// messages: `counts[i] = Σ_m q_m[i]` with `q ∈ {-1,0,+1}`.
///
/// Positive and negative votes are accumulated into *vertical* (bit-sliced)
/// counters: plane `b` of the counter holds bit `b` of all 64 lane counts
/// of one word, so adding a message's 64-coordinate word is a ripple-carry
/// over at most `⌈log₂(M+1)⌉` planes — and the carry chain terminates after
/// ~2 planes on average, independent of message density. Empty support
/// words are skipped entirely, so sparse sparsign messages cost ~nothing.
///
/// Requires `msgs.len() ≤ i16::MAX`; the per-lane counts are exact.
pub fn vote_counts(packs: &[&PackedTernary], dim: usize) -> Vec<i16> {
    assert!(
        packs.len() <= i16::MAX as usize,
        "vote_counts supports at most {} messages, got {}",
        i16::MAX,
        packs.len()
    );
    let words = PackedTernary::words(dim);
    // Planes needed to hold counts up to M = packs.len().
    let planes = (usize::BITS - packs.len().leading_zeros()).max(1) as usize;
    let mut pos = vec![0u64; words * planes];
    let mut neg = vec![0u64; words * planes];
    for pack in packs {
        debug_assert_eq!(pack.dim(), dim);
        let mask = pack.mask_words();
        let sign = pack.sign_words();
        for w in 0..words {
            let m = mask[w];
            if m == 0 {
                continue;
            }
            let s = sign[w];
            vc_add(&mut pos[w * planes..(w + 1) * planes], m & !s);
            vc_add(&mut neg[w * planes..(w + 1) * planes], m & s);
        }
    }
    // Horizontal extraction: rebuild each lane's count from its bit-slices.
    let mut counts = vec![0i16; dim];
    for w in 0..words {
        let pw = &pos[w * planes..(w + 1) * planes];
        let nw = &neg[w * planes..(w + 1) * planes];
        if pw.iter().chain(nw.iter()).all(|&x| x == 0) {
            continue;
        }
        let base = w << 6;
        let lanes = (dim - base).min(PackedTernary::LANES);
        for j in 0..lanes {
            let mut cp = 0i16;
            let mut cn = 0i16;
            for (b, (&pb, &nb)) in pw.iter().zip(nw.iter()).enumerate() {
                cp |= (((pb >> j) & 1) as i16) << b;
                cn |= (((nb >> j) & 1) as i16) << b;
            }
            counts[base + j] = cp - cn;
        }
    }
    counts
}

/// Ripple-carry add of a 64-lane bit vector into a vertical counter.
#[inline]
fn vc_add(planes: &mut [u64], mut addend: u64) {
    for p in planes.iter_mut() {
        if addend == 0 {
            return;
        }
        let carry = *p & addend;
        *p ^= addend;
        addend = carry;
    }
    debug_assert_eq!(addend, 0, "vertical counter overflow");
}

/// When every message is packed ternary with the same positive scale,
/// return the packs and that scale — the vote-count fast-path predicate.
fn uniform_packed_ternary(msgs: &[CompressedGrad]) -> Option<(Vec<&PackedTernary>, f32)> {
    let mut packs = Vec::with_capacity(msgs.len());
    let mut scale: Option<f32> = None;
    for m in msgs {
        match m {
            CompressedGrad::Ternary { pack, .. } => {
                let s = pack.scale();
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                match scale {
                    None => scale = Some(s),
                    Some(prev) if prev == s => {}
                    _ => return None,
                }
                packs.push(pack);
            }
            CompressedGrad::Dense { .. } => return None,
        }
    }
    scale.map(|s| (packs, s))
}

impl AggregationRule {
    /// Average the worker messages and apply the rule.
    ///
    /// `pre_add` (the server error-feedback residual in Algorithm 2) is
    /// added to the average *before* compression; pass `None` for
    /// Algorithm 1.
    pub fn aggregate(&self, msgs: &[CompressedGrad], pre_add: Option<&[f32]>) -> Aggregate {
        assert!(!msgs.is_empty(), "aggregation over zero messages");
        let d = msgs[0].dim();
        assert!(
            msgs.iter().all(|m| m.dim() == d),
            "mismatched message dimensions"
        );
        let inv = 1.0 / msgs.len() as f32;
        let mut avg: Vec<f32>;
        if let Some((packs, scale)) =
            uniform_packed_ternary(msgs).filter(|_| msgs.len() <= i16::MAX as usize)
        {
            // Word-parallel path: integer votes, one f32 pass at the end.
            let counts = vote_counts(&packs, d);
            let k = scale * inv;
            avg = counts.iter().map(|&c| k * c as f32).collect();
        } else {
            // Reference path: dense f32 accumulation per message.
            avg = vec![0.0f32; d];
            for m in msgs {
                m.add_into(&mut avg);
            }
            for v in avg.iter_mut() {
                *v *= inv;
            }
        }
        if let Some(e) = pre_add {
            assert_eq!(e.len(), d, "error-feedback dim mismatch");
            for (a, &ei) in avg.iter_mut().zip(e) {
                *a += ei;
            }
        }
        let raw = avg.clone();
        match self {
            AggregationRule::MajorityVote => {
                for v in avg.iter_mut() {
                    *v = crate::util::sign0(*v);
                }
                Aggregate { update: avg, raw, downlink_bits: d as f64 }
            }
            AggregationRule::ScaledSign => {
                let scale = l1_norm(&avg) / d.max(1) as f32;
                for v in avg.iter_mut() {
                    *v = scale * crate::util::sign1(*v);
                }
                Aggregate { update: avg, raw, downlink_bits: d as f64 + 32.0 }
            }
            AggregationRule::Mean => {
                Aggregate { update: avg, raw, downlink_bits: 32.0 * d as f64 }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tern(q: Vec<i8>, scale: f32) -> CompressedGrad {
        CompressedGrad::ternary_from_codes(&q, scale, 0.0)
    }

    #[test]
    fn majority_vote_basic() {
        let msgs = vec![
            tern(vec![1, -1, 0], 1.0),
            tern(vec![1, 1, 0], 1.0),
            tern(vec![-1, -1, 0], 1.0),
        ];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![1.0, -1.0, 0.0]);
        assert_eq!(agg.downlink_bits, 3.0);
    }

    #[test]
    fn majority_vote_tie_is_zero() {
        let msgs = vec![tern(vec![1], 1.0), tern(vec![-1], 1.0)];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![0.0]);
    }

    #[test]
    fn scaled_sign_magnitude() {
        let msgs = vec![tern(vec![1, -1, 1, 1], 2.0)];
        let agg = AggregationRule::ScaledSign.aggregate(&msgs, None);
        // avg = [2,-2,2,2]; ‖·‖₁/d = 2 ⇒ update = 2·sign.
        assert_eq!(agg.update, vec![2.0, -2.0, 2.0, 2.0]);
        assert_eq!(agg.downlink_bits, 36.0);
    }

    #[test]
    fn mean_is_exact_average() {
        let msgs = vec![
            CompressedGrad::dense(vec![1.0, 3.0], 0.0),
            CompressedGrad::dense(vec![3.0, 5.0], 0.0),
        ];
        let agg = AggregationRule::Mean.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![2.0, 4.0]);
        assert_eq!(agg.downlink_bits, 64.0);
    }

    #[test]
    fn pre_add_feeds_error_feedback() {
        let msgs = vec![tern(vec![1, 0], 1.0)];
        let e = vec![-2.0, 0.5];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, Some(&e));
        // avg + e = [-1, 0.5] ⇒ sign = [-1, 1].
        assert_eq!(agg.update, vec![-1.0, 1.0]);
        // `raw` carries the pre-compression average for the EF recursion.
        assert_eq!(agg.raw, vec![-1.0, 0.5]);
    }

    #[test]
    fn vote_counts_matches_naive_sum() {
        let mut rng = Pcg64::seed_from(11);
        for _ in 0..50 {
            let d = 1 + rng.index(300);
            let m = 1 + rng.index(40);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect())
                .collect();
            let packs: Vec<PackedTernary> =
                codes.iter().map(|q| PackedTernary::from_codes(q, 1.0)).collect();
            let refs: Vec<&PackedTernary> = packs.iter().collect();
            let counts = vote_counts(&refs, d);
            for i in 0..d {
                let want: i32 = codes.iter().map(|q| q[i] as i32).sum();
                assert_eq!(counts[i] as i32, want, "coord {i} (d={d}, m={m})");
            }
        }
    }

    #[test]
    fn packed_fast_path_matches_dense_fallback() {
        // Same ternary payloads, once with uniform scale (fast path) and
        // once via the f32 reference accumulation — identical votes.
        let mut rng = Pcg64::seed_from(12);
        for _ in 0..20 {
            let d = 1 + rng.index(200);
            let m = 2 + rng.index(15);
            let msgs: Vec<CompressedGrad> = (0..m)
                .map(|_| {
                    let q: Vec<i8> = (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect();
                    tern(q, 1.0)
                })
                .collect();
            // Reference: decode every message and average in f32.
            let mut avg = vec![0.0f32; d];
            for msg in &msgs {
                msg.add_into(&mut avg);
            }
            for v in avg.iter_mut() {
                *v /= m as f32;
            }
            let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
            for i in 0..d {
                assert_eq!(agg.update[i], crate::util::sign0(avg[i]), "coord {i}");
            }
        }
    }

    #[test]
    fn mixed_scales_fall_back_to_reference_average() {
        // TernGrad-style per-worker scales must average exactly.
        let msgs = vec![tern(vec![1, -1], 2.0), tern(vec![1, 1], 4.0)];
        let agg = AggregationRule::Mean.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![3.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "zero messages")]
    fn empty_rejected() {
        AggregationRule::MajorityVote.aggregate(&[], None);
    }

    #[test]
    #[should_panic(expected = "mismatched message dimensions")]
    fn dim_mismatch_rejected() {
        let msgs = vec![tern(vec![1], 1.0), tern(vec![1, 1], 1.0)];
        AggregationRule::MajorityVote.aggregate(&msgs, None);
    }
}
