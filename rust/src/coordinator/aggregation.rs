//! Server-side aggregation rules `C(·)` from Algorithm 1 / Algorithm 2.
//!
//! Hot path (DESIGN.md §8, §10): when every worker message is packed
//! ternary with one shared positive scale — signSGD, noisy/sto-sign, SSDM
//! and sparsign all transmit `scale = 1` — the per-coordinate votes are
//! counted **word-parallel** over the `u64` bitplanes with carry-save
//! vertical counters ([`VoteAccumulator`]), and the only per-coordinate
//! f32 work left is the single final pass that materializes the broadcast
//! update. The accumulator is *mergeable*, so the streaming round engine
//! folds messages thread-locally as they are produced and merges
//! `threads` accumulators instead of buffering `n` messages. Messages
//! with heterogeneous scales (TernGrad, QSGD, STC) or dense payloads fall
//! back to the reference f32 accumulation.

use crate::compressors::{CompressedGrad, PackedTernary};
use crate::util::l1_norm_f64;

/// Exact-count capacity of the vote path: per-coordinate counts are
/// `i16`, so at most this many ternary messages can be folded into one
/// [`VoteAccumulator`] (or passed to [`vote_counts`]). Cohorts beyond it
/// keep the buffered f32 reference route — the round engine and the
/// `net` coordinator service both gate their streaming fast path on it.
pub const MAX_STREAM_MSGS: usize = i16::MAX as usize;

/// The aggregation rule applied to the averaged worker messages before
/// broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationRule {
    /// Majority vote: `C(x) = sign(x)` coordinate-wise (signSGD /
    /// SPARSIGNSGD; downlink is `d` bits). `sign(0) = 0` — a tied
    /// coordinate moves nothing, matching the ternary analysis.
    MajorityVote,
    /// Scaled sign: `C(x) = (‖x‖₁/d)·sign(x)` — the α-approximate
    /// compressor used by EF-SPARSIGNSGD's server (downlink `d + 32` bits).
    ScaledSign,
    /// Plain mean (no server compression; downlink `32·d` bits) — used by
    /// the unbiased baselines (QSGD, TernGrad, FedAvg, FedCom).
    Mean,
}

/// Result of server aggregation.
#[derive(Clone, Debug)]
pub struct Aggregate {
    /// The broadcast update `g̃` (dense, decoded).
    pub update: Vec<f32>,
    /// The pre-compression quantity `avg(Δ) + ẽ` — materialized only when
    /// `pre_add` was supplied, because only Algorithm 2's server error
    /// feedback reads it (to form `ẽ^{(t+1)} = raw − g̃`, eq. 8).
    pub raw: Option<Vec<f32>>,
    /// Downlink message size in bits.
    pub downlink_bits: f64,
}

/// Mergeable word-parallel vote counter — the streaming half of the
/// DESIGN.md §8.2 kernel (§10). Positive and negative votes are held in
/// *vertical* (bit-sliced) carry-save counters: plane `b` holds bit `b`
/// of all 64 lane counts of one word, so folding a message's word is a
/// ripple-carry over at most `⌈log₂(cap+1)⌉` planes (terminating after ~2
/// planes on average), and two accumulators merge with word-parallel
/// carry-save addition — O(words·planes) word ops, no per-coordinate
/// work. Votes are exact integers, so fold/merge order cannot change the
/// result: any sharding of a message multiset over any number of
/// accumulators yields counts bit-identical to single-shot
/// [`vote_counts`] (`tests/property_suite.rs`).
#[derive(Clone, Debug, Default)]
pub struct VoteAccumulator {
    dim: usize,
    planes: usize,
    msgs: usize,
    cap: usize,
    pos: Vec<u64>,
    neg: Vec<u64>,
}

impl VoteAccumulator {
    /// An empty accumulator; call [`Self::reset`] before folding.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn words(&self) -> usize {
        PackedTernary::words(self.dim)
    }

    /// Clear and size for up to `cap` messages over `dim` coordinates.
    /// Storage grows monotonically, so resetting to the same shape every
    /// round never reallocates (`tests/zero_alloc_round.rs`).
    pub fn reset(&mut self, dim: usize, cap: usize) {
        assert!(
            cap >= 1 && cap <= MAX_STREAM_MSGS,
            "vote accumulator supports 1..={MAX_STREAM_MSGS} messages, got {cap}"
        );
        self.dim = dim;
        self.cap = cap;
        self.planes = (usize::BITS - cap.leading_zeros()) as usize;
        self.msgs = 0;
        let len = self.words() * self.planes;
        self.pos.clear();
        self.pos.resize(len, 0);
        self.neg.clear();
        self.neg.resize(len, 0);
    }

    /// Messages folded in (directly or via [`Self::merge`]) so far.
    pub fn msgs(&self) -> usize {
        self.msgs
    }

    /// Coordinate dimension of the current `reset` shape.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Carry-save counter depth (`⌈log₂(cap+1)⌉`).
    pub fn planes(&self) -> usize {
        self.planes
    }

    /// Raw positive counter planes (`words(dim) · planes` words,
    /// per-word plane-major) — what a shard ships upstream verbatim.
    pub fn pos_planes(&self) -> &[u64] {
        &self.pos
    }

    /// Raw negative counter planes (layout as [`Self::pos_planes`]).
    pub fn neg_planes(&self) -> &[u64] {
        &self.neg
    }

    /// Fold one message's votes: `counts[i] += q[i]`. Empty support words
    /// are skipped, so sparse sparsign messages cost ~nothing.
    pub fn fold(&mut self, pack: &PackedTernary) {
        assert_eq!(pack.dim(), self.dim, "vote accumulator dim mismatch");
        assert!(self.msgs < self.cap, "vote accumulator capacity {} exceeded", self.cap);
        self.msgs += 1;
        let planes = self.planes;
        let mask = pack.mask_words();
        let sign = pack.sign_words();
        for w in 0..self.words() {
            let m = mask[w];
            if m == 0 {
                continue;
            }
            let s = sign[w];
            vc_add(&mut self.pos[w * planes..(w + 1) * planes], m & !s);
            vc_add(&mut self.neg[w * planes..(w + 1) * planes], m & s);
        }
    }

    /// Word-parallel merge of another accumulator: each of `other`'s
    /// planes carry-save-ripples into `self` starting at its own
    /// weight. `other` may be *shallower* (fewer planes — e.g. a shard
    /// sized for its local sub-cohort merging into a root sized for the
    /// whole selection); its counts are exact integers, so the merge is
    /// bit-identical to folding `other`'s messages here directly.
    pub fn merge(&mut self, other: &VoteAccumulator) {
        assert_eq!(self.dim, other.dim, "vote accumulator dim mismatch");
        assert!(
            other.planes <= self.planes,
            "merge source deeper ({} planes) than target ({})",
            other.planes,
            self.planes
        );
        assert!(
            self.msgs + other.msgs <= self.cap,
            "vote accumulator capacity {} exceeded by merge",
            self.cap
        );
        self.msgs += other.msgs;
        let sp = self.planes;
        let op = other.planes;
        for w in 0..self.words() {
            let sbase = w * sp;
            let obase = w * op;
            for b in 0..op {
                let pa = other.pos[obase + b];
                if pa != 0 {
                    vc_add(&mut self.pos[sbase + b..sbase + sp], pa);
                }
                let na = other.neg[obase + b];
                if na != 0 {
                    vc_add(&mut self.neg[sbase + b..sbase + sp], na);
                }
            }
        }
    }

    /// [`Self::merge`] from wire bytes: fold a decoded `ShardAgg`'s raw
    /// counter planes (little-endian `u64` words, per-word plane-major)
    /// carrying `msgs` messages at depth `planes`. Structural failures
    /// are typed errors (the root hangs up on the shard rather than
    /// panicking); *count* integrity inside the planes is the shard's
    /// responsibility — shards are trusted aggregation infrastructure
    /// (DESIGN.md §14.5), unlike clients.
    pub fn merge_wire_planes(
        &mut self,
        msgs: usize,
        planes: usize,
        pos: &[u8],
        neg: &[u8],
    ) -> Result<(), &'static str> {
        if planes > self.planes {
            return Err("shard planes exceed root accumulator depth");
        }
        match self.msgs.checked_add(msgs) {
            Some(total) if total <= self.cap => {}
            _ => return Err("shard merge exceeds accumulator capacity"),
        }
        let want = self.words() * planes * 8;
        if pos.len() != want || neg.len() != want {
            return Err("shard plane bytes disagree with dim/planes");
        }
        self.msgs += msgs;
        let sp = self.planes;
        for w in 0..self.words() {
            let sbase = w * sp;
            let obase = w * planes * 8;
            for b in 0..planes {
                let at = obase + b * 8;
                let pa = le_bytes_word(&pos[at..at + 8]);
                if pa != 0 {
                    vc_add(&mut self.pos[sbase + b..sbase + sp], pa);
                }
                let na = le_bytes_word(&neg[at..at + 8]);
                if na != 0 {
                    vc_add(&mut self.neg[sbase + b..sbase + sp], na);
                }
            }
        }
        Ok(())
    }

    /// Validate one wire ternary payload (little-endian mask/sign plane
    /// bytes) against `dim` without touching any accumulator state:
    /// exact word count, no mask bits beyond `dim`, sign support inside
    /// the mask. Returns the support popcount for the `nnz` cross-check.
    /// Split from [`Self::fold_wire_planes`] so the coordinator can
    /// validate *before* claiming the round-table slot and fold after —
    /// a rejected submission must leave the votes untouched.
    pub fn validate_wire_planes(
        dim: usize,
        mask: &[u8],
        sign: &[u8],
    ) -> Result<usize, &'static str> {
        let words = PackedTernary::words(dim);
        if mask.len() != words * 8 || sign.len() != words * 8 {
            return Err("plane byte count disagrees with dim");
        }
        let mut nnz = 0usize;
        for (w, (mb, sb)) in mask.chunks_exact(8).zip(sign.chunks_exact(8)).enumerate() {
            let m = le_bytes_word(mb);
            let s = le_bytes_word(sb);
            if s & !m != 0 {
                return Err("sign bit outside mask support");
            }
            if w == words - 1 {
                let used = dim - (words - 1) * PackedTernary::LANES;
                if used < PackedTernary::LANES && m >> used != 0 {
                    return Err("mask bits beyond dim");
                }
            }
            nnz += m.count_ones() as usize;
        }
        Ok(nnz)
    }

    /// Fold one message's votes straight from wire plane bytes — the
    /// zero-copy shard hot path (no intermediate [`PackedTernary`]).
    /// The caller must have validated the same bytes with
    /// [`Self::validate_wire_planes`] first; like [`Self::fold`], empty
    /// support words are skipped.
    pub fn fold_wire_planes(&mut self, mask: &[u8], sign: &[u8]) {
        assert_eq!(mask.len(), self.words() * 8, "plane byte count disagrees with dim");
        assert!(self.msgs < self.cap, "vote accumulator capacity {} exceeded", self.cap);
        self.msgs += 1;
        let planes = self.planes;
        for (w, (mb, sb)) in mask.chunks_exact(8).zip(sign.chunks_exact(8)).enumerate() {
            let m = le_bytes_word(mb);
            if m == 0 {
                continue;
            }
            let s = le_bytes_word(sb);
            vc_add(&mut self.pos[w * planes..(w + 1) * planes], m & !s);
            vc_add(&mut self.neg[w * planes..(w + 1) * planes], m & s);
        }
    }

    /// Horizontal extraction: rebuild every lane's exact count into
    /// `counts` (length `dim`). Per 64-lane word this runs an unrolled
    /// 8×8 word-transpose per 8-plane group (the private `transpose8`)
    /// instead of the per-bit shift loop — ~3 word ops per 8 lanes per
    /// group rather than `planes` shift+mask ops per lane.
    pub fn counts_into(&self, counts: &mut [i16]) {
        assert_eq!(counts.len(), self.dim, "counts buffer dim mismatch");
        let planes = self.planes;
        for w in 0..self.words() {
            let base = w << 6;
            let lanes = (self.dim - base).min(PackedTernary::LANES);
            let out = &mut counts[base..base + lanes];
            let pw = &self.pos[w * planes..(w + 1) * planes];
            let nw = &self.neg[w * planes..(w + 1) * planes];
            if pw.iter().chain(nw.iter()).all(|&x| x == 0) {
                out.fill(0);
                continue;
            }
            extract_word_counts(pw, nw, out);
        }
    }
}

/// Word-parallel per-coordinate vote counting over packed ternary
/// messages: `counts[i] = Σ_m q_m[i]` with `q ∈ {-1,0,+1}` — the
/// single-shot (buffered) entry point over [`VoteAccumulator`].
///
/// Requires `packs.len() ≤ i16::MAX`; the per-lane counts are exact.
pub fn vote_counts(packs: &[&PackedTernary], dim: usize) -> Vec<i16> {
    assert!(
        packs.len() <= MAX_STREAM_MSGS,
        "vote_counts supports at most {MAX_STREAM_MSGS} messages, got {}",
        packs.len()
    );
    let mut acc = VoteAccumulator::new();
    acc.reset(dim, packs.len().max(1));
    for pack in packs {
        debug_assert_eq!(pack.dim(), dim);
        acc.fold(pack);
    }
    let mut counts = vec![0i16; dim];
    acc.counts_into(&mut counts);
    counts
}

#[inline]
fn le_bytes_word(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Ripple-carry add of a 64-lane bit vector into a vertical counter.
#[inline]
fn vc_add(planes: &mut [u64], mut addend: u64) {
    for p in planes.iter_mut() {
        if addend == 0 {
            return;
        }
        let carry = *p & addend;
        *p ^= addend;
        addend = carry;
    }
    debug_assert_eq!(addend, 0, "vertical counter overflow");
}

/// 8×8 bit-matrix transpose (Hacker's Delight delta swaps): input byte
/// `r` holds row `r`; output byte `c` holds column `c`, i.e. output bit
/// `8c + r` = input bit `8r + c`.
#[inline]
fn transpose8(mut x: u64) -> u64 {
    let mut t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

/// Rebuild up to 64 lane counts from one word's vertical pos/neg planes.
/// For each 8-lane group and 8-plane group, one [`transpose8`] turns the
/// plane bytes into per-lane count bytes (bit `b` of output byte `j` =
/// plane `b`'s vote for lane `j`), which accumulate shifted by the plane
/// group's weight.
fn extract_word_counts(pw: &[u64], nw: &[u64], out: &mut [i16]) {
    let planes = pw.len();
    for (cg, chunk) in out.chunks_mut(8).enumerate() {
        let shift = (8 * cg) as u32;
        let mut cp = [0i16; 8];
        let mut cn = [0i16; 8];
        for (pg, lo) in (0..planes).step_by(8).enumerate() {
            let hi = (lo + 8).min(planes);
            let mut xp = 0u64;
            let mut xn = 0u64;
            for (row, p) in (lo..hi).enumerate() {
                xp |= ((pw[p] >> shift) & 0xff) << (8 * row);
                xn |= ((nw[p] >> shift) & 0xff) << (8 * row);
            }
            if xp == 0 && xn == 0 {
                continue;
            }
            let tp = transpose8(xp);
            let tn = transpose8(xn);
            let weight = (8 * pg) as u32;
            for j in 0..8 {
                cp[j] += (((tp >> (8 * j)) & 0xff) as i16) << weight;
                cn[j] += (((tn >> (8 * j)) & 0xff) as i16) << weight;
            }
        }
        for (o, (p, n)) in chunk.iter_mut().zip(cp.iter().zip(&cn)) {
            *o = p - n;
        }
    }
}

/// When every message is packed ternary with the same positive scale,
/// return the packs and that scale — the vote-count fast-path predicate.
fn uniform_packed_ternary(msgs: &[CompressedGrad]) -> Option<(Vec<&PackedTernary>, f32)> {
    let mut packs = Vec::with_capacity(msgs.len());
    let mut scale: Option<f32> = None;
    for m in msgs {
        match m {
            CompressedGrad::Ternary { pack, .. } => {
                let s = pack.scale();
                if !(s > 0.0) || !s.is_finite() {
                    return None;
                }
                match scale {
                    None => scale = Some(s),
                    Some(prev) if prev == s => {}
                    _ => return None,
                }
                packs.push(pack);
            }
            CompressedGrad::Dense { .. } => return None,
        }
    }
    scale.map(|s| (packs, s))
}

impl AggregationRule {
    /// Average the worker messages and apply the rule.
    ///
    /// `pre_add` (the server error-feedback residual in Algorithm 2) is
    /// added to the average *before* compression; pass `None` for
    /// Algorithm 1.
    pub fn aggregate(&self, msgs: &[CompressedGrad], pre_add: Option<&[f32]>) -> Aggregate {
        assert!(!msgs.is_empty(), "aggregation over zero messages");
        let d = msgs[0].dim();
        assert!(
            msgs.iter().all(|m| m.dim() == d),
            "mismatched message dimensions"
        );
        let inv = 1.0 / msgs.len() as f32;
        let mut avg: Vec<f32>;
        if let Some((packs, scale)) =
            uniform_packed_ternary(msgs).filter(|_| msgs.len() <= MAX_STREAM_MSGS)
        {
            // Word-parallel path: integer votes, one f32 pass at the end.
            let counts = vote_counts(&packs, d);
            let k = scale * inv;
            avg = counts.iter().map(|&c| k * c as f32).collect();
        } else {
            // Reference path: dense f32 accumulation per message.
            avg = vec![0.0f32; d];
            for m in msgs {
                m.add_into(&mut avg);
            }
            for v in avg.iter_mut() {
                *v *= inv;
            }
        }
        if let Some(e) = pre_add {
            assert_eq!(e.len(), d, "error-feedback dim mismatch");
            for (a, &ei) in avg.iter_mut().zip(e) {
                *a += ei;
            }
        }
        // Only the Algorithm 2 server EF recursion (the caller that
        // supplies `pre_add`) reads the pre-compression average; skip the
        // clone for everyone else.
        let raw = pre_add.map(|_| avg.clone());
        let downlink_bits = self.finalize_in_place(&mut avg);
        Aggregate { update: avg, raw, downlink_bits }
    }

    /// Build the broadcast update from exact integer vote counts — the
    /// streaming engine's server half, performing no heap allocation.
    /// `counts` must be the vote totals of `msgs` packed-ternary messages
    /// sharing decode scale `scale`; the update lands in `update` and the
    /// downlink bit cost is returned. Because votes are integers and the
    /// f32 finalize below is the exact code `aggregate` runs on its
    /// uniform packed-ternary fast path, the result is bit-identical to
    /// buffering the same message multiset.
    pub fn finalize_votes(
        &self,
        counts: &[i16],
        msgs: usize,
        scale: f32,
        update: &mut [f32],
    ) -> f64 {
        assert!(msgs > 0, "aggregation over zero messages");
        assert_eq!(counts.len(), update.len(), "counts/update dim mismatch");
        let inv = 1.0 / msgs as f32;
        let k = scale * inv;
        for (u, &c) in update.iter_mut().zip(counts) {
            *u = k * c as f32;
        }
        self.finalize_in_place(update)
    }

    /// Apply the rule to the dense pre-compression average in place and
    /// return the downlink bit cost — shared by [`Self::aggregate`] and
    /// [`Self::finalize_votes`] so the buffered and streaming engines
    /// produce bit-identical broadcasts.
    fn finalize_in_place(&self, avg: &mut [f32]) -> f64 {
        let d = avg.len();
        match self {
            AggregationRule::MajorityVote => {
                for v in avg.iter_mut() {
                    *v = crate::util::sign0(*v);
                }
                d as f64
            }
            AggregationRule::ScaledSign => {
                // ‖avg‖₁ accumulates in f64: an f32 running sum loses
                // low-order mass once the partial sum dwarfs the addends,
                // silently skewing the broadcast magnitude for large `d`
                // (same drift class PR 2 fixed in
                // `SparsignAutoCompressor::derived_budget`).
                let scale = (l1_norm_f64(avg) / d.max(1) as f64) as f32;
                for v in avg.iter_mut() {
                    *v = scale * crate::util::sign1(*v);
                }
                d as f64 + 32.0
            }
            AggregationRule::Mean => 32.0 * d as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tern(q: Vec<i8>, scale: f32) -> CompressedGrad {
        CompressedGrad::ternary_from_codes(&q, scale, 0.0)
    }

    #[test]
    fn majority_vote_basic() {
        let msgs = vec![
            tern(vec![1, -1, 0], 1.0),
            tern(vec![1, 1, 0], 1.0),
            tern(vec![-1, -1, 0], 1.0),
        ];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![1.0, -1.0, 0.0]);
        assert_eq!(agg.downlink_bits, 3.0);
    }

    #[test]
    fn majority_vote_tie_is_zero() {
        let msgs = vec![tern(vec![1], 1.0), tern(vec![-1], 1.0)];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![0.0]);
    }

    #[test]
    fn scaled_sign_magnitude() {
        let msgs = vec![tern(vec![1, -1, 1, 1], 2.0)];
        let agg = AggregationRule::ScaledSign.aggregate(&msgs, None);
        // avg = [2,-2,2,2]; ‖·‖₁/d = 2 ⇒ update = 2·sign.
        assert_eq!(agg.update, vec![2.0, -2.0, 2.0, 2.0]);
        assert_eq!(agg.downlink_bits, 36.0);
    }

    #[test]
    fn mean_is_exact_average() {
        let msgs = vec![
            CompressedGrad::dense(vec![1.0, 3.0], 0.0),
            CompressedGrad::dense(vec![3.0, 5.0], 0.0),
        ];
        let agg = AggregationRule::Mean.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![2.0, 4.0]);
        assert_eq!(agg.downlink_bits, 64.0);
    }

    #[test]
    fn pre_add_feeds_error_feedback() {
        let msgs = vec![tern(vec![1, 0], 1.0)];
        let e = vec![-2.0, 0.5];
        let agg = AggregationRule::MajorityVote.aggregate(&msgs, Some(&e));
        // avg + e = [-1, 0.5] ⇒ sign = [-1, 1].
        assert_eq!(agg.update, vec![-1.0, 1.0]);
        // `raw` carries the pre-compression average for the EF recursion.
        assert_eq!(agg.raw.as_deref(), Some(&[-1.0, 0.5][..]));
    }

    #[test]
    fn raw_is_materialized_only_for_error_feedback() {
        let msgs = vec![tern(vec![1, -1, 0], 1.0)];
        for rule in [
            AggregationRule::MajorityVote,
            AggregationRule::ScaledSign,
            AggregationRule::Mean,
        ] {
            assert!(rule.aggregate(&msgs, None).raw.is_none(), "{rule:?}");
            // The EF caller always sees the exact pre-compression average.
            let e = vec![0.25, 0.0, -3.0];
            let agg = rule.aggregate(&msgs, Some(&e));
            assert_eq!(agg.raw.as_deref(), Some(&[1.25, -1.0, -3.0][..]), "{rule:?}");
        }
    }

    #[test]
    fn scaled_sign_l1_accumulates_in_f64() {
        // Adversarial mass distribution (same shape as the PR 2
        // SparsignAuto regression): one 16.0 head followed by 2²¹ entries
        // of 5e-7. A sequential f32 sum stalls at 16 (5e-7 < ulp(16)/2),
        // shrinking the broadcast magnitude by ~6%; the f64 accumulator
        // keeps the full ‖avg‖₁ = 16 + 2²¹·5e-7 ≈ 17.049.
        let tiny = 5e-7f32;
        let d_tail = 1usize << 21;
        let mut v = vec![tiny; d_tail + 1];
        v[0] = 16.0;
        let d = v.len();
        let msgs = vec![CompressedGrad::dense(v, 0.0)];
        let agg = AggregationRule::ScaledSign.aggregate(&msgs, None);
        let want = ((16.0f64 + d_tail as f64 * tiny as f64) / d as f64) as f32;
        let got = agg.update[0];
        let rel = ((got - want) / want).abs();
        assert!(rel < 1e-4, "scale {got} vs f64-exact {want} (rel {rel:.2e})");
        let stalled = (16.0 / d as f32).abs();
        assert!(
            ((got - stalled) / stalled).abs() > 0.05,
            "scale {got} tracks the stalled f32 sum {stalled}"
        );
    }

    #[test]
    fn finalize_votes_matches_buffered_fast_path() {
        let mut rng = Pcg64::seed_from(21);
        for _ in 0..20 {
            let d = 1 + rng.index(300);
            let m = 1 + rng.index(40);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect())
                .collect();
            let msgs: Vec<CompressedGrad> = codes.iter().map(|q| tern(q.clone(), 1.0)).collect();
            let packs: Vec<&PackedTernary> = msgs
                .iter()
                .map(|msg| match msg {
                    CompressedGrad::Ternary { pack, .. } => pack,
                    _ => unreachable!(),
                })
                .collect();
            let counts = vote_counts(&packs, d);
            let mut update = vec![0.0f32; d];
            for rule in [
                AggregationRule::MajorityVote,
                AggregationRule::ScaledSign,
                AggregationRule::Mean,
            ] {
                let agg = rule.aggregate(&msgs, None);
                let downlink = rule.finalize_votes(&counts, m, 1.0, &mut update);
                assert_eq!(update, agg.update, "{rule:?} (d={d}, m={m})");
                assert_eq!(downlink, agg.downlink_bits, "{rule:?}");
            }
        }
    }

    #[test]
    fn accumulator_merge_equals_single_shot_across_plane_groups() {
        // m > 255 forces a 9-plane accumulator, crossing the 8-plane
        // word-transpose group boundary in the extraction.
        let mut rng = Pcg64::seed_from(22);
        let d = 100;
        let m = 300;
        let codes: Vec<Vec<i8>> = (0..m)
            .map(|_| (0..d).map(|_| [-1i8, -1, 0, 1, 1, 1][rng.index(6)]).collect())
            .collect();
        let packs: Vec<PackedTernary> =
            codes.iter().map(|q| PackedTernary::from_codes(q, 1.0)).collect();
        let refs: Vec<&PackedTernary> = packs.iter().collect();
        let want = vote_counts(&refs, d);
        let mut global = VoteAccumulator::new();
        global.reset(d, m);
        for shard in packs.chunks(37) {
            let mut local = VoteAccumulator::new();
            local.reset(d, m);
            for p in shard {
                local.fold(p);
            }
            global.merge(&local);
        }
        // Merging an empty accumulator is a no-op.
        let mut empty = VoteAccumulator::new();
        empty.reset(d, m);
        global.merge(&empty);
        assert_eq!(global.msgs(), m);
        let mut got = vec![0i16; d];
        global.counts_into(&mut got);
        assert_eq!(got, want);
        // A stale counts buffer is fully overwritten.
        let mut dirty = vec![i16::MAX; d];
        global.counts_into(&mut dirty);
        assert_eq!(dirty, want);
    }

    #[test]
    fn shallow_shard_accumulators_merge_bit_identically() {
        // The sharded-tree shape: each shard sizes its accumulator for
        // its *local* sub-cohort (fewer planes), the root for the whole
        // selection. Adversarial boundary splits — empty shards, a
        // single fat shard, one-message slivers — all merge to the
        // single-shot counts.
        let mut rng = Pcg64::seed_from(23);
        for trial in 0..20 {
            let d = 1 + rng.index(150);
            let m = 2 + rng.index(600);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, -1, 0, 1][rng.index(4)]).collect())
                .collect();
            let packs: Vec<PackedTernary> =
                codes.iter().map(|q| PackedTernary::from_codes(q, 1.0)).collect();
            let refs: Vec<&PackedTernary> = packs.iter().collect();
            let want = vote_counts(&refs, d);
            // Random split points, including degenerate ones.
            let mut cuts = vec![0, m];
            for _ in 0..rng.index(6) {
                cuts.push(rng.index(m + 1));
            }
            cuts.sort_unstable();
            let mut root = VoteAccumulator::new();
            root.reset(d, m);
            for span in cuts.windows(2) {
                let (lo, hi) = (span[0], span[1]);
                if lo == hi {
                    continue;
                }
                let mut shard = VoteAccumulator::new();
                shard.reset(d, hi - lo); // local capacity ⇒ shallower planes
                for p in &packs[lo..hi] {
                    shard.fold(p);
                }
                assert!(shard.planes() <= root.planes());
                root.merge(&shard);
            }
            assert_eq!(root.msgs(), m, "trial {trial}");
            let mut got = vec![0i16; d];
            root.counts_into(&mut got);
            assert_eq!(got, want, "trial {trial} (d={d}, m={m}, cuts={cuts:?})");
        }
    }

    #[test]
    fn wire_plane_fold_and_merge_match_pack_path() {
        let mut rng = Pcg64::seed_from(24);
        let d = 130; // straddles a word boundary (3 words, 2 used bits)
        let m = 9;
        let packs: Vec<PackedTernary> = (0..m)
            .map(|_| {
                let q: Vec<i8> = (0..d).map(|_| [-1i8, 0, 0, 1][rng.index(4)]).collect();
                PackedTernary::from_codes(&q, 1.0)
            })
            .collect();
        let refs: Vec<&PackedTernary> = packs.iter().collect();
        let want = vote_counts(&refs, d);
        // Shard side: fold from the wire-byte representation.
        let mut shard = VoteAccumulator::new();
        shard.reset(d, m);
        for p in &packs {
            let mask: Vec<u8> = p.mask_words().iter().flat_map(|w| w.to_le_bytes()).collect();
            let sign: Vec<u8> = p.sign_words().iter().flat_map(|w| w.to_le_bytes()).collect();
            let nnz = VoteAccumulator::validate_wire_planes(d, &mask, &sign).unwrap();
            assert_eq!(nnz, p.nnz());
            shard.fold_wire_planes(&mask, &sign);
        }
        // Root side: merge from the shard's serialized planes.
        let pos: Vec<u8> = shard.pos_planes().iter().flat_map(|w| w.to_le_bytes()).collect();
        let neg: Vec<u8> = shard.neg_planes().iter().flat_map(|w| w.to_le_bytes()).collect();
        let mut root = VoteAccumulator::new();
        root.reset(d, 3 * m); // deeper than the shard
        root.merge_wire_planes(m, shard.planes(), &pos, &neg).unwrap();
        assert_eq!(root.msgs(), m);
        let mut got = vec![0i16; d];
        root.counts_into(&mut got);
        assert_eq!(got, want);
        // Structural failures are typed errors, not panics.
        assert!(root.merge_wire_planes(1, root.planes() + 1, &pos, &neg).is_err());
        assert!(root.merge_wire_planes(usize::MAX, shard.planes(), &pos, &neg).is_err());
        assert!(root.merge_wire_planes(1, shard.planes(), &pos[..8], &neg).is_err());
    }

    #[test]
    fn wire_plane_validation_rejects_invariant_violations() {
        let d = 70; // 2 words, 6 used bits in the tail word
        let words = PackedTernary::words(d);
        let mut mask = vec![0u8; words * 8];
        let mut sign = vec![0u8; words * 8];
        mask[0] = 0b101;
        sign[0] = 0b001;
        assert_eq!(VoteAccumulator::validate_wire_planes(d, &mask, &sign).unwrap(), 2);
        // Sign outside mask.
        sign[0] = 0b010;
        assert!(VoteAccumulator::validate_wire_planes(d, &mask, &sign).is_err());
        sign[0] = 0;
        // Mask bit beyond dim (bit 70 = tail word bit 6).
        mask[8] = 1 << 6;
        assert!(VoteAccumulator::validate_wire_planes(d, &mask, &sign).is_err());
        mask[8] = 0;
        // Byte-count mismatch.
        assert!(VoteAccumulator::validate_wire_planes(d, &mask[..8], &sign).is_err());
    }

    #[test]
    fn vote_counts_matches_naive_sum() {
        let mut rng = Pcg64::seed_from(11);
        for _ in 0..50 {
            let d = 1 + rng.index(300);
            let m = 1 + rng.index(40);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect())
                .collect();
            let packs: Vec<PackedTernary> =
                codes.iter().map(|q| PackedTernary::from_codes(q, 1.0)).collect();
            let refs: Vec<&PackedTernary> = packs.iter().collect();
            let counts = vote_counts(&refs, d);
            for i in 0..d {
                let want: i32 = codes.iter().map(|q| q[i] as i32).sum();
                assert_eq!(counts[i] as i32, want, "coord {i} (d={d}, m={m})");
            }
        }
    }

    #[test]
    fn packed_fast_path_matches_dense_fallback() {
        // Same ternary payloads, once with uniform scale (fast path) and
        // once via the f32 reference accumulation — identical votes.
        let mut rng = Pcg64::seed_from(12);
        for _ in 0..20 {
            let d = 1 + rng.index(200);
            let m = 2 + rng.index(15);
            let msgs: Vec<CompressedGrad> = (0..m)
                .map(|_| {
                    let q: Vec<i8> = (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect();
                    tern(q, 1.0)
                })
                .collect();
            // Reference: decode every message and average in f32.
            let mut avg = vec![0.0f32; d];
            for msg in &msgs {
                msg.add_into(&mut avg);
            }
            for v in avg.iter_mut() {
                *v /= m as f32;
            }
            let agg = AggregationRule::MajorityVote.aggregate(&msgs, None);
            for i in 0..d {
                assert_eq!(agg.update[i], crate::util::sign0(avg[i]), "coord {i}");
            }
        }
    }

    #[test]
    fn mixed_scales_fall_back_to_reference_average() {
        // TernGrad-style per-worker scales must average exactly.
        let msgs = vec![tern(vec![1, -1], 2.0), tern(vec![1, 1], 4.0)];
        let agg = AggregationRule::Mean.aggregate(&msgs, None);
        assert_eq!(agg.update, vec![3.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "zero messages")]
    fn empty_rejected() {
        AggregationRule::MajorityVote.aggregate(&[], None);
    }

    #[test]
    #[should_panic(expected = "mismatched message dimensions")]
    fn dim_mismatch_rejected() {
        let msgs = vec![tern(vec![1], 1.0), tern(vec![1, 1], 1.0)];
        AggregationRule::MajorityVote.aggregate(&msgs, None);
    }
}
