//! Training environments: how a worker turns (params, its shard) into a
//! stochastic gradient, and how the server evaluates the global model.

use crate::data::{Dataset, FederatedDataset};
use crate::model::{Model, ModelWorkspace};
use crate::util::rng::Pcg64;

/// A source of per-worker stochastic gradients. `&self` so the engine can
/// fan workers out across threads; per-thread scratch (batch gather +
/// model workspace) is threaded in via [`Self::sample_grad_ws`].
pub trait GradientSource: Send + Sync {
    /// Gradient dimension `d`.
    fn dim(&self) -> usize;

    /// Write worker `m`'s stochastic gradient at `params` into `out`;
    /// returns the mini-batch loss.
    fn sample_grad(&self, worker: usize, params: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f32;

    /// [`Self::sample_grad`] with caller-owned scratch, the round engine's
    /// hot path: environments that assemble batches / run a model override
    /// this to reuse `ws` (zero steady-state allocations); sources with no
    /// intermediate state (Rosenbrock, synthetic benches) inherit the
    /// default, which ignores `ws`.
    fn sample_grad_ws(
        &self,
        worker: usize,
        params: &[f32],
        rng: &mut Pcg64,
        out: &mut [f32],
        ws: &mut ModelWorkspace,
    ) -> f32 {
        let _ = ws;
        self.sample_grad(worker, params, rng, out)
    }

    /// Number of workers.
    fn workers(&self) -> usize;

    /// True when `sample_grad` must never be called from more than one
    /// thread at a time (e.g. the PJRT-backed models, whose compile cache
    /// is `Rc`/`RefCell` by contract). The round engine clamps its worker
    /// fan-out to one thread when this is set — callers cannot opt out by
    /// forgetting a `threads` override.
    fn serial_only(&self) -> bool {
        false
    }

    /// Structural fingerprint of the data/environment this source draws
    /// gradients from, mixed into every coordinator snapshot's config
    /// fingerprint (DESIGN.md §12) so a resume refuses a rebuilt
    /// environment whose dataset, partition, or batch shape drifted —
    /// the `TrainingRun` alone cannot see those. Sources whose gradient
    /// distribution is fully determined by the run seed and the fields
    /// already fingerprinted (synthetic benches) may keep the default.
    fn env_fingerprint(&self) -> u64 {
        0
    }
}

/// Classification environment: a shared [`Model`], a Dirichlet-partitioned
/// training set, and a held-out test set.
pub struct ClassifierEnv {
    pub model: Box<dyn Model>,
    pub train: Dataset,
    pub test: Dataset,
    pub fed: FederatedDataset,
    pub batch: usize,
    /// Content hash of the `.sgds` store this environment streams from
    /// (`None` for in-memory synthetic data). Folded into
    /// [`GradientSource::env_fingerprint`] so a fleet client whose store
    /// file drifted — different download, different partition seed, bit
    /// rot that slipped past its local CRC check — is refused at
    /// rendezvous exactly like a drifted config.
    pub store_hash: Option<u64>,
}

impl ClassifierEnv {
    pub fn new(
        model: Box<dyn Model>,
        train: Dataset,
        test: Dataset,
        fed: FederatedDataset,
        batch: usize,
    ) -> Self {
        assert!(batch > 0);
        assert!(fed.workers() > 0);
        Self { model, train, test, fed, batch, store_hash: None }
    }

    /// Build an environment over an open `.sgds` store: zero-copy feature
    /// views into the mapping, the store's embedded Dirichlet partition,
    /// and the store content hash mixed into the environment fingerprint.
    pub fn from_store(
        store: &crate::data::ShardStore,
        model: Box<dyn Model>,
        batch: usize,
    ) -> Self {
        let mut env = Self::new(
            model,
            store.train_dataset(),
            store.test_dataset(),
            store.federated(),
            batch,
        );
        env.store_hash = Some(store.content_hash());
        env
    }

    /// Evaluate (loss, accuracy) on the test split, in chunks.
    pub fn evaluate(&self, params: &[f32]) -> (f64, f64) {
        self.evaluate_ws(params, &mut ModelWorkspace::new())
    }

    /// [`Self::evaluate`] with caller-owned scratch: one workspace serves
    /// every chunk (batch gather + model intermediates), so the whole
    /// eval pass allocates nothing after warm-up.
    pub fn evaluate_ws(&self, params: &[f32], ws: &mut ModelWorkspace) -> (f64, f64) {
        let n = self.test.len();
        assert!(n > 0, "empty test set");
        let chunk = 512usize;
        let mut loss = 0.0;
        let mut acc = 0.0;
        let mut seen = 0usize;
        let mut start = 0;
        // Move the gather scratch out so the model can borrow `ws` whole;
        // `BatchScratch::default()` is allocation-free.
        let mut batch = std::mem::take(&mut ws.batch);
        while start < n {
            let end = (start + chunk).min(n);
            batch.idx.clear();
            batch.idx.extend(start..end);
            self.test.gather_into(&batch.idx, &mut batch.x, &mut batch.y);
            let (l, a) = self.model.evaluate_ws(params, &batch.x, &batch.y, ws);
            let w = end - start;
            loss += l * w as f64;
            acc += a * w as f64;
            seen += w;
            start = end;
        }
        ws.batch = batch;
        (loss / seen as f64, acc / seen as f64)
    }

    /// Initialize model parameters.
    pub fn init_params(&self, rng: &mut Pcg64) -> Vec<f32> {
        self.model.init(rng)
    }
}

impl GradientSource for ClassifierEnv {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn sample_grad(&self, worker: usize, params: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f32 {
        self.sample_grad_ws(worker, params, rng, out, &mut ModelWorkspace::new())
    }

    fn sample_grad_ws(
        &self,
        worker: usize,
        params: &[f32],
        rng: &mut Pcg64,
        out: &mut [f32],
        ws: &mut ModelWorkspace,
    ) -> f32 {
        let mut batch = std::mem::take(&mut ws.batch);
        self.fed
            .sample_batch_into(worker, self.batch, rng, &mut batch.idx);
        self.train.gather_into(&batch.idx, &mut batch.x, &mut batch.y);
        let loss = self.model.loss_grad_ws(params, &batch.x, &batch.y, out, ws);
        ws.batch = batch;
        loss
    }

    fn workers(&self) -> usize {
        self.fed.workers()
    }

    fn serial_only(&self) -> bool {
        self.model.serial_only()
    }

    /// Structural hash of the dataset, partition and batch shape: dims,
    /// split sizes, per-worker shard sizes, every shard's first index,
    /// a stride-sampled slice of the training features (bit-exact) and
    /// labels — plus, for store-backed environments, the whole-file
    /// `.sgds` content hash. Cheap (cold path, O(workers + 64) work at
    /// build time) yet sensitive to
    /// the drifts a rebuilt environment can smuggle in — a different
    /// Dirichlet α reshapes the shards, a different generator seed moves
    /// the sampled feature bits, a different `--batch` changes the batch
    /// field directly.
    fn env_fingerprint(&self) -> u64 {
        let mut buf: Vec<u8> = Vec::with_capacity(1024);
        let mut push = |buf: &mut Vec<u8>, v: u64| buf.extend_from_slice(&v.to_le_bytes());
        push(&mut buf, self.train.dim as u64);
        push(&mut buf, self.train.classes as u64);
        push(&mut buf, self.train.len() as u64);
        push(&mut buf, self.test.len() as u64);
        push(&mut buf, self.batch as u64);
        push(&mut buf, self.fed.workers() as u64);
        for m in 0..self.fed.workers() {
            let len = self.fed.shard_len(m);
            push(&mut buf, len as u64);
            push(&mut buf, if len > 0 { self.fed.index(m, 0) as u64 } else { 0 });
        }
        let stride = (self.train.x.len() / 64).max(1);
        for i in (0..self.train.x.len()).step_by(stride) {
            push(&mut buf, self.train.x[i].to_bits() as u64);
        }
        let stride = (self.train.y.len() / 64).max(1);
        for i in (0..self.train.y.len()).step_by(stride) {
            push(&mut buf, self.train.y[i] as u64);
        }
        if let Some(h) = self.store_hash {
            push(&mut buf, 1);
            push(&mut buf, h);
        }
        crate::snapshot::fingerprint_bytes(&buf)
    }
}

/// Rosenbrock environment (§6.1): deterministic scaled objectives per
/// eq. (11), optional gradient noise.
pub struct RosenbrockEnv {
    pub f: crate::model::rosenbrock::Rosenbrock,
    pub scales: crate::model::rosenbrock::ScaledObjectiveWorkers,
    pub noise_std: f32,
}

impl GradientSource for RosenbrockEnv {
    fn dim(&self) -> usize {
        self.f.n
    }

    fn sample_grad(&self, worker: usize, params: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f32 {
        self.scales
            .worker_grad(&self.f, worker, params, self.noise_std, rng, out);
        self.f.value(params) as f32
    }

    fn workers(&self) -> usize {
        self.scales.workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
    use crate::model::ModelKind;

    pub(crate) fn tiny_env() -> ClassifierEnv {
        let task = SyntheticTask::generate(
            SyntheticSpec {
                dim: 12,
                classes: 3,
                modes: 1,
                separation: 1.5,
                noise: 0.2,
                label_noise: 0.0,
                train: 300,
                test: 90,
            },
            5,
        );
        let mut rng = Pcg64::seed_from(6);
        let fed = DirichletPartitioner { alpha: 0.5, workers: 8 }.partition(&task.train, &mut rng);
        ClassifierEnv::new(
            ModelKind::Linear { inputs: 12, classes: 3 }.build(),
            task.train,
            task.test,
            fed,
            16,
        )
    }

    #[test]
    fn grad_matches_model_dim_and_runs() {
        let env = tiny_env();
        let mut rng = Pcg64::seed_from(1);
        let params = env.init_params(&mut rng);
        let mut g = vec![0.0; env.dim()];
        let loss = env.sample_grad(3, &params, &mut rng, &mut g);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(g.iter().any(|&v| v != 0.0));
        assert_eq!(env.workers(), 8);
    }

    #[test]
    fn workspace_grad_path_matches_allocating_path() {
        let env = tiny_env();
        let mut rng = Pcg64::seed_from(9);
        let params = env.init_params(&mut rng);
        let mut ws = ModelWorkspace::new();
        for w in 0..env.workers() {
            let mut g1 = vec![0.0; env.dim()];
            let mut g2 = vec![0.0; env.dim()];
            let l1 = env.sample_grad(w, &params, &mut Pcg64::seed_from(100 + w as u64), &mut g1);
            let l2 = env.sample_grad_ws(
                w,
                &params,
                &mut Pcg64::seed_from(100 + w as u64),
                &mut g2,
                &mut ws,
            );
            assert_eq!(l1, l2, "worker {w}");
            assert_eq!(g1, g2, "worker {w}");
        }
        // Workspace eval matches the throwaway-workspace eval bitwise.
        assert_eq!(env.evaluate(&params), env.evaluate_ws(&params, &mut ws));
    }

    #[test]
    fn evaluate_chunking_consistent() {
        let env = tiny_env();
        let mut rng = Pcg64::seed_from(2);
        let params = env.init_params(&mut rng);
        // Direct single-shot eval for comparison.
        let idx: Vec<usize> = (0..env.test.len()).collect();
        let (bx, by) = env.test.gather(&idx);
        let (l1, a1) = env.model.evaluate(&params, &bx, &by);
        let (l2, a2) = env.evaluate(&params);
        assert!((l1 - l2).abs() < 1e-9);
        assert!((a1 - a2).abs() < 1e-9);
    }

    #[test]
    fn rosenbrock_env_grads_scale() {
        use crate::model::rosenbrock::{Rosenbrock, ScaledObjectiveWorkers};
        let mut rng = Pcg64::seed_from(3);
        let env = RosenbrockEnv {
            f: Rosenbrock::new(10),
            scales: ScaledObjectiveWorkers::generate(10, 4, &mut rng),
            noise_std: 0.0,
        };
        let x = env.f.start();
        let mut g0 = vec![0.0; 10];
        let mut g1 = vec![0.0; 10];
        env.sample_grad(0, &x, &mut rng, &mut g0);
        env.sample_grad(1, &x, &mut rng, &mut g1);
        // Gradients are collinear (scaled versions of the same ∇F).
        let ratio = g0[0] / g1[0];
        for i in 1..10 {
            if g1[i].abs() > 1e-6 {
                assert!((g0[i] / g1[i] - ratio).abs() < 1e-3);
            }
        }
    }
}
