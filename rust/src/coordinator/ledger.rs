//! Communication accounting: every bit that crosses the (simulated)
//! network is recorded here, per round and per direction. The paper's
//! "communication overhead" columns are uplink (worker → server) totals.
//!
//! Two layers of accounting coexist per round:
//!
//! * **payload-bit estimates** (`uplink_bits` / `downlink_bits`) — the
//!   paper's eq. (12) cost model, attached to every message at
//!   compression time. These are what the tables/figures report, and
//!   they are identical between the in-process engine and a `net`
//!   transport run (the equivalence tests compare them).
//! * **wire bytes** (`uplink_wire_bytes` / `downlink_wire_bytes`) —
//!   actual framed bytes (header + varints + CRC included) observed by
//!   the `net` coordinator service. Zero for in-process runs. Downlink
//!   wire bytes count the real per-connection fan-out, unlike the
//!   single-copy `downlink_bits` convention.
//!
//! `stragglers` counts selected workers whose update missed the round
//! deadline (or whose client died mid-round) in a transport run; the
//! in-process engine never records any.

use crate::compressors::CompressedGrad;

/// Number of distinct typed reject kinds the `net` protocol can answer a
/// hostile or confused frame with (`net::wire::RejectReason`: BadRound,
/// NotSelected, Duplicate, Late, UnknownWorker, WrongClient — in that
/// index order). The ledger stays `net`-agnostic and records counts by
/// index; the transport layer owns the mapping.
pub const REJECT_KINDS: usize = 6;

/// Per-round communication record.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundComm {
    /// Worker → server bits this round (summed over selected workers).
    pub uplink_bits: f64,
    /// Server → worker bits this round (one broadcast message; the paper
    /// counts a single copy, not per-recipient fan-out).
    pub downlink_bits: f64,
    /// Number of workers that transmitted.
    pub senders: usize,
    /// Total non-zero coordinates across the round's uplink messages
    /// (reads the count cached at message construction — no payload
    /// rescan).
    pub uplink_nnz: usize,
    /// Actual framed bytes received uplink (accepted update frames,
    /// including frame header + CRC overhead). Zero in-process.
    pub uplink_wire_bytes: u64,
    /// Actual framed bytes broadcast downlink (per-connection fan-out of
    /// the round-open frame). Zero in-process.
    pub downlink_wire_bytes: u64,
    /// Shard-tier uplink bytes: the merged accumulator frames the root
    /// received from its aggregator shards (DESIGN.md §14). Zero for
    /// in-process and flat transport runs.
    pub shard_uplink_wire_bytes: u64,
    /// Shard-tier downlink bytes: round-open frames the root sent to
    /// shard connections (which relay them to clients; the relayed
    /// fan-out is counted in `downlink_wire_bytes`).
    pub shard_downlink_wire_bytes: u64,
    /// Selected workers that failed to deliver before the round closed.
    pub stragglers: usize,
}

impl RoundComm {
    /// Build a round record from the uplink message set.
    pub fn from_msgs(msgs: &[CompressedGrad], downlink_bits: f64) -> Self {
        RoundComm {
            uplink_bits: msgs.iter().map(|m| m.bits()).sum(),
            downlink_bits,
            senders: msgs.len(),
            uplink_nnz: msgs.iter().map(|m| m.nnz()).sum(),
            ..RoundComm::default()
        }
    }
}

/// Cumulative communication ledger.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommLedger {
    rounds: Vec<RoundComm>,
    /// Cumulative typed rejects the coordinator issued, indexed by reject
    /// kind ([`REJECT_KINDS`]). All-zero for in-process runs (nothing to
    /// reject) and for honest transport runs; adversarial tests assert
    /// exactly which defense fired from these counters.
    rejects_by_kind: [u64; REJECT_KINDS],
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a ledger from per-round records — the snapshot restore
    /// path (`crate::snapshot`), which revalidated the records on load.
    pub fn from_records(rounds: Vec<RoundComm>) -> Self {
        Self { rounds, rejects_by_kind: [0; REJECT_KINDS] }
    }

    /// [`Self::from_records`] plus restored reject counters (snapshot v2).
    pub fn from_records_with_rejects(
        rounds: Vec<RoundComm>,
        rejects_by_kind: [u64; REJECT_KINDS],
    ) -> Self {
        Self { rounds, rejects_by_kind }
    }

    /// Add typed-reject observations (the `net` coordinator folds the
    /// round's per-kind counts in after each round closes).
    pub fn add_rejects(&mut self, by_kind: &[u64; REJECT_KINDS]) {
        for (acc, &n) in self.rejects_by_kind.iter_mut().zip(by_kind) {
            *acc += n;
        }
    }

    /// Cumulative typed rejects by kind index.
    pub fn rejects_by_kind(&self) -> &[u64; REJECT_KINDS] {
        &self.rejects_by_kind
    }

    /// Total typed rejects across all kinds.
    pub fn total_rejects(&self) -> u64 {
        self.rejects_by_kind.iter().sum()
    }

    /// Reserve room for `additional` further records (the resume path's
    /// equivalent of [`Self::with_capacity`]: restored ledgers get their
    /// remaining-rounds headroom up front so steady-state rounds never
    /// reallocate mid-round).
    pub fn reserve(&mut self, additional: usize) {
        self.rounds.reserve(additional);
    }

    /// Every recorded round, in round order — the snapshot serializer
    /// reads these verbatim so a restored ledger is field-identical.
    pub fn records(&self) -> &[RoundComm] {
        &self.rounds
    }

    /// Ledger with room for `rounds` records — the run loop preallocates
    /// so steady-state rounds never reallocate the record vector
    /// (`tests/zero_alloc_round.rs`).
    pub fn with_capacity(rounds: usize) -> Self {
        Self { rounds: Vec::with_capacity(rounds), rejects_by_kind: [0; REJECT_KINDS] }
    }

    pub fn record(&mut self, round: RoundComm) {
        self.rounds.push(round);
    }

    /// Attach wire-level observations to an already-recorded round — the
    /// `net` coordinator calls this right after the shared round tail
    /// (`RoundLoop::finish_round`) records the payload-bit estimates, so
    /// the estimate and wire layers never diverge on round indices.
    pub fn annotate_wire(
        &mut self,
        t: usize,
        uplink_wire_bytes: u64,
        downlink_wire_bytes: u64,
        stragglers: usize,
    ) {
        self.annotate_wire_tiered(t, uplink_wire_bytes, downlink_wire_bytes, stragglers, 0, 0);
    }

    /// [`Self::annotate_wire`] with the shard tier split out: client-tier
    /// bytes (direct connections plus what the shards fronted) land in
    /// the classic columns, root↔shard traffic in the `shard_*` ones.
    pub fn annotate_wire_tiered(
        &mut self,
        t: usize,
        uplink_wire_bytes: u64,
        downlink_wire_bytes: u64,
        stragglers: usize,
        shard_uplink_wire_bytes: u64,
        shard_downlink_wire_bytes: u64,
    ) {
        let r = self
            .rounds
            .get_mut(t)
            .unwrap_or_else(|| panic!("annotate_wire: round {t} not recorded yet"));
        r.uplink_wire_bytes = uplink_wire_bytes;
        r.downlink_wire_bytes = downlink_wire_bytes;
        r.shard_uplink_wire_bytes = shard_uplink_wire_bytes;
        r.shard_downlink_wire_bytes = shard_downlink_wire_bytes;
        r.stragglers = stragglers;
    }

    pub fn rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Total uplink bits so far.
    pub fn total_uplink(&self) -> f64 {
        self.rounds.iter().map(|r| r.uplink_bits).sum()
    }

    /// Total downlink bits so far.
    pub fn total_downlink(&self) -> f64 {
        self.rounds.iter().map(|r| r.downlink_bits).sum()
    }

    /// Total framed uplink bytes so far (zero for in-process runs).
    pub fn total_uplink_wire_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.uplink_wire_bytes).sum()
    }

    /// Total framed downlink bytes so far (zero for in-process runs).
    pub fn total_downlink_wire_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.downlink_wire_bytes).sum()
    }

    /// Total shard-tier uplink bytes so far (zero without shards).
    pub fn total_shard_uplink_wire_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.shard_uplink_wire_bytes).sum()
    }

    /// Total shard-tier downlink bytes so far (zero without shards).
    pub fn total_shard_downlink_wire_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.shard_downlink_wire_bytes).sum()
    }

    /// Total deadline-missed (or mid-round-dropped) selected workers.
    pub fn total_stragglers(&self) -> usize {
        self.rounds.iter().map(|r| r.stragglers).sum()
    }

    /// Cumulative uplink bits after round `t` (inclusive, 0-based).
    pub fn uplink_through(&self, t: usize) -> f64 {
        self.rounds[..=t.min(self.rounds.len().saturating_sub(1))]
            .iter()
            .map(|r| r.uplink_bits)
            .sum()
    }

    pub fn get(&self, t: usize) -> Option<&RoundComm> {
        self.rounds.get(t)
    }

    /// Mean uplink bits per round.
    pub fn mean_uplink_per_round(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.total_uplink() / self.rounds.len() as f64
        }
    }

    /// Total non-zero coordinates transmitted uplink so far.
    pub fn total_uplink_nnz(&self) -> usize {
        self.rounds.iter().map(|r| r.uplink_nnz).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut l = CommLedger::new();
        l.record(RoundComm {
            uplink_bits: 100.0,
            downlink_bits: 10.0,
            senders: 5,
            uplink_nnz: 40,
            ..RoundComm::default()
        });
        l.record(RoundComm {
            uplink_bits: 50.0,
            downlink_bits: 10.0,
            senders: 5,
            uplink_nnz: 20,
            ..RoundComm::default()
        });
        assert_eq!(l.rounds(), 2);
        assert_eq!(l.total_uplink(), 150.0);
        assert_eq!(l.total_downlink(), 20.0);
        assert_eq!(l.uplink_through(0), 100.0);
        assert_eq!(l.uplink_through(1), 150.0);
        assert_eq!(l.mean_uplink_per_round(), 75.0);
        assert_eq!(l.total_uplink_nnz(), 60);
        // No wire layer recorded: totals stay zero.
        assert_eq!(l.total_uplink_wire_bytes(), 0);
        assert_eq!(l.total_downlink_wire_bytes(), 0);
        assert_eq!(l.total_stragglers(), 0);
    }

    #[test]
    fn empty_ledger() {
        let l = CommLedger::new();
        assert_eq!(l.total_uplink(), 0.0);
        assert_eq!(l.mean_uplink_per_round(), 0.0);
        assert!(l.get(0).is_none());
        assert_eq!(l.total_uplink_nnz(), 0);
    }

    #[test]
    fn from_msgs_uses_cached_counts() {
        let msgs = vec![
            CompressedGrad::ternary_from_codes(&[1, 0, -1, 0], 1.0, 12.0),
            CompressedGrad::dense(vec![0.0, 2.0, 0.0, 3.0], 64.0),
        ];
        let rc = RoundComm::from_msgs(&msgs, 4.0);
        assert_eq!(rc.uplink_bits, 76.0);
        assert_eq!(rc.downlink_bits, 4.0);
        assert_eq!(rc.senders, 2);
        assert_eq!(rc.uplink_nnz, 4);
        assert_eq!(rc.uplink_wire_bytes, 0);
        assert_eq!(rc.stragglers, 0);
    }

    #[test]
    fn annotate_wire_amends_recorded_rounds() {
        let mut l = CommLedger::new();
        l.record(RoundComm { uplink_bits: 10.0, senders: 2, ..RoundComm::default() });
        l.record(RoundComm { uplink_bits: 20.0, senders: 2, ..RoundComm::default() });
        l.annotate_wire(0, 128, 64, 0);
        l.annotate_wire_tiered(1, 100, 64, 1, 40, 24);
        assert_eq!(l.total_uplink_wire_bytes(), 228);
        assert_eq!(l.total_downlink_wire_bytes(), 128);
        assert_eq!(l.total_shard_uplink_wire_bytes(), 40);
        assert_eq!(l.total_shard_downlink_wire_bytes(), 24);
        assert_eq!(l.total_stragglers(), 1);
        // The flat annotation leaves the shard tier zeroed.
        assert_eq!(l.get(0).unwrap().shard_uplink_wire_bytes, 0);
        // Payload-bit estimates are untouched by the wire layer.
        assert_eq!(l.total_uplink(), 30.0);
    }

    #[test]
    #[should_panic(expected = "not recorded yet")]
    fn annotate_wire_requires_recorded_round() {
        let mut l = CommLedger::new();
        l.annotate_wire(0, 1, 1, 0);
    }

    #[test]
    fn reject_counters_accumulate_by_kind() {
        let mut l = CommLedger::new();
        assert_eq!(l.total_rejects(), 0);
        l.add_rejects(&[1, 0, 2, 0, 0, 0]);
        l.add_rejects(&[0, 0, 1, 3, 0, 0]);
        assert_eq!(l.rejects_by_kind(), &[1, 0, 3, 3, 0, 0]);
        assert_eq!(l.total_rejects(), 7);
        let restored =
            CommLedger::from_records_with_rejects(l.records().to_vec(), *l.rejects_by_kind());
        assert_eq!(restored, l);
    }
}
