//! Communication accounting: every bit that crosses the (simulated)
//! network is recorded here, per round and per direction. The paper's
//! "communication overhead" columns are uplink (worker → server) totals.

use crate::compressors::CompressedGrad;

/// Per-round communication record.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundComm {
    /// Worker → server bits this round (summed over selected workers).
    pub uplink_bits: f64,
    /// Server → worker bits this round (one broadcast message; the paper
    /// counts a single copy, not per-recipient fan-out).
    pub downlink_bits: f64,
    /// Number of workers that transmitted.
    pub senders: usize,
    /// Total non-zero coordinates across the round's uplink messages
    /// (reads the count cached at message construction — no payload
    /// rescan).
    pub uplink_nnz: usize,
}

impl RoundComm {
    /// Build a round record from the uplink message set.
    pub fn from_msgs(msgs: &[CompressedGrad], downlink_bits: f64) -> Self {
        RoundComm {
            uplink_bits: msgs.iter().map(|m| m.bits()).sum(),
            downlink_bits,
            senders: msgs.len(),
            uplink_nnz: msgs.iter().map(|m| m.nnz()).sum(),
        }
    }
}

/// Cumulative communication ledger.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    rounds: Vec<RoundComm>,
}

impl CommLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ledger with room for `rounds` records — the run loop preallocates
    /// so steady-state rounds never reallocate the record vector
    /// (`tests/zero_alloc_round.rs`).
    pub fn with_capacity(rounds: usize) -> Self {
        Self { rounds: Vec::with_capacity(rounds) }
    }

    pub fn record(&mut self, round: RoundComm) {
        self.rounds.push(round);
    }

    pub fn rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Total uplink bits so far.
    pub fn total_uplink(&self) -> f64 {
        self.rounds.iter().map(|r| r.uplink_bits).sum()
    }

    /// Total downlink bits so far.
    pub fn total_downlink(&self) -> f64 {
        self.rounds.iter().map(|r| r.downlink_bits).sum()
    }

    /// Cumulative uplink bits after round `t` (inclusive, 0-based).
    pub fn uplink_through(&self, t: usize) -> f64 {
        self.rounds[..=t.min(self.rounds.len().saturating_sub(1))]
            .iter()
            .map(|r| r.uplink_bits)
            .sum()
    }

    pub fn get(&self, t: usize) -> Option<&RoundComm> {
        self.rounds.get(t)
    }

    /// Mean uplink bits per round.
    pub fn mean_uplink_per_round(&self) -> f64 {
        if self.rounds.is_empty() {
            0.0
        } else {
            self.total_uplink() / self.rounds.len() as f64
        }
    }

    /// Total non-zero coordinates transmitted uplink so far.
    pub fn total_uplink_nnz(&self) -> usize {
        self.rounds.iter().map(|r| r.uplink_nnz).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_accumulate() {
        let mut l = CommLedger::new();
        l.record(RoundComm {
            uplink_bits: 100.0,
            downlink_bits: 10.0,
            senders: 5,
            uplink_nnz: 40,
        });
        l.record(RoundComm {
            uplink_bits: 50.0,
            downlink_bits: 10.0,
            senders: 5,
            uplink_nnz: 20,
        });
        assert_eq!(l.rounds(), 2);
        assert_eq!(l.total_uplink(), 150.0);
        assert_eq!(l.total_downlink(), 20.0);
        assert_eq!(l.uplink_through(0), 100.0);
        assert_eq!(l.uplink_through(1), 150.0);
        assert_eq!(l.mean_uplink_per_round(), 75.0);
        assert_eq!(l.total_uplink_nnz(), 60);
    }

    #[test]
    fn empty_ledger() {
        let l = CommLedger::new();
        assert_eq!(l.total_uplink(), 0.0);
        assert_eq!(l.mean_uplink_per_round(), 0.0);
        assert!(l.get(0).is_none());
        assert_eq!(l.total_uplink_nnz(), 0);
    }

    #[test]
    fn from_msgs_uses_cached_counts() {
        let msgs = vec![
            CompressedGrad::ternary_from_codes(&[1, 0, -1, 0], 1.0, 12.0),
            CompressedGrad::dense(vec![0.0, 2.0, 0.0, 3.0], 64.0),
        ];
        let rc = RoundComm::from_msgs(&msgs, 4.0);
        assert_eq!(rc.uplink_bits, 76.0);
        assert_eq!(rc.downlink_bits, 4.0);
        assert_eq!(rc.senders, 2);
        assert_eq!(rc.uplink_nnz, 4);
    }
}
