//! The selection-prediction attack demonstrator (DESIGN.md §13).
//!
//! Client selection is adversarially relevant state: a worker that knows
//! it will (or will not) be selected in future rounds can time its
//! misbehaviour, save its poisoned update for rounds where the honest
//! majority is thin, or sell its slot. This module implements the
//! attacker against both selection modes, so the hardened mode's claim is
//! tested against a concrete adversary rather than asserted.
//!
//! The attacker model ([`SelectionAttacker`]) gets everything a realistic
//! insider sees:
//!
//! 1. **The serialized coordinator state** — snapshot files leak through
//!    backups, shared disks and crash dumps. Legacy snapshots embed the
//!    raw `Pcg64` words, so [`SelectionAttacker::predict_from_snapshot`]
//!    clones the generator and plays the selection stream forward:
//!    prediction is exact, forever. (With raw *outputs* instead of raw
//!    state, the same end state is reachable via PCG state-recovery —
//!    the pcg-breaker line of work inverts XSL-RR by enumerating the
//!    64 possible rotations per output and solving the known-multiplier
//!    LCG; we take the state directly since the snapshot hands it over.)
//! 2. **The full selection transcript** — every past cohort, observable
//!    by any participant. Because the whole stream is a deterministic
//!    function of the run's 64-bit seed, a *guessable* seed (`--seed 7`)
//!    falls to transcript replay over a candidate-seed budget
//!    ([`SelectionAttacker::recover_seed`]) in either mode. The hardened
//!    mode does not — cannot — fix weak seeds; it fixes state disclosure.
//!    DESIGN.md §13 states this boundary explicitly.
//!
//! Against the committed mode, (1) finds only a one-way commitment plus a
//! round counter — no generator state exists to read — and (2) still
//! requires the true seed inside the attacker's budget. With a seed
//! outside the budget, the attacker's best remaining strategy is a blind
//! guess, and `tests/selection_attack.rs` pins its overlap with the true
//! cohort at chance level.

use super::sampling::{SelectionMode, SelectionRng, SelectionSnapshot, WorkerSampler};
use crate::snapshot::CoordinatorSnapshot;
use crate::util::rng::Pcg64;

/// The adversary: a participant holding the public run shape (worker
/// population, participation), the observed selection transcript, and
/// whatever serialized coordinator state it could obtain.
pub struct SelectionAttacker {
    /// Worker population M (public: every client knows the roster size).
    pub workers: usize,
    /// Participation fraction (public: cohort sizes are observed).
    pub participation: f64,
    /// Observed cohorts for rounds `0..transcript.len()`.
    pub transcript: Vec<Vec<usize>>,
}

impl SelectionAttacker {
    /// Predict the cohorts of rounds `next..next + horizon` from a stolen
    /// snapshot, where `next` is the snapshot's next round.
    ///
    /// Legacy snapshots carry the raw selection-RNG words: the attacker
    /// rebuilds the generator and the prediction is **exact**. Committed
    /// snapshots carry only the root-key commitment — one-way by
    /// construction — so this returns `None`: there is no state to clone.
    pub fn predict_from_snapshot(
        &self,
        snap: &CoordinatorSnapshot,
        horizon: usize,
    ) -> Option<Vec<Vec<usize>>> {
        match snap.selection {
            SelectionSnapshot::LegacyRaw(raw) => {
                let rng = Pcg64::from_raw(raw)?;
                let mut sel = SelectionRng::Legacy(rng);
                let sampler = WorkerSampler::new(self.workers, self.participation);
                let next = snap.next_round();
                let mut out = Vec::with_capacity(horizon);
                let mut buf = Vec::new();
                for t in next..next + horizon {
                    sel.select_into(&sampler, t, &mut buf);
                    out.push(buf.clone());
                }
                Some(out)
            }
            // The commitment is a truncated ChaCha20 compression of the
            // root key; inverting it is inverting the block function.
            SelectionSnapshot::Committed { .. } => None,
        }
    }

    /// Transcript-replay seed recovery: enumerate candidate seeds in
    /// `budget`, replay each candidate's selection stream in `mode`, and
    /// return the first seed whose stream reproduces the entire observed
    /// transcript. Models the low-entropy-seed reality (`--seed 7`);
    /// works against *both* modes when the true seed is in budget, and
    /// against neither when it is not — which is why the hardened mode's
    /// defense is measured against snapshot disclosure, not seed
    /// guessing.
    pub fn recover_seed(
        &self,
        mode: SelectionMode,
        budget: std::ops::Range<u64>,
    ) -> Option<u64> {
        if self.transcript.is_empty() {
            return None;
        }
        let sampler = WorkerSampler::new(self.workers, self.participation);
        let mut buf = Vec::new();
        'seeds: for seed in budget {
            let root = Pcg64::new(seed, 0xc0_0e_d1);
            let mut sel = SelectionRng::from_seed(mode, &root, seed);
            for (t, observed) in self.transcript.iter().enumerate() {
                sel.select_into(&sampler, t, &mut buf);
                if &buf != observed {
                    continue 'seeds;
                }
            }
            return Some(seed);
        }
        None
    }

    /// Predict rounds `start..start + horizon` from a recovered seed.
    pub fn predict_from_seed(
        &self,
        mode: SelectionMode,
        seed: u64,
        start: usize,
        horizon: usize,
    ) -> Vec<Vec<usize>> {
        let sampler = WorkerSampler::new(self.workers, self.participation);
        let root = Pcg64::new(seed, 0xc0_0e_d1);
        let mut sel = SelectionRng::from_seed(mode, &root, seed);
        let mut buf = Vec::new();
        // Legacy is sequential: burn the transcript prefix to reach
        // `start`. Committed is round-keyed, but replaying the prefix is
        // harmless and keeps one code path.
        for t in 0..start {
            sel.select_into(&sampler, t, &mut buf);
        }
        let mut out = Vec::with_capacity(horizon);
        for t in start..start + horizon {
            sel.select_into(&sampler, t, &mut buf);
            out.push(buf.clone());
        }
        out
    }

    /// Overlap |prediction ∩ truth| — the attacker's score for one round.
    /// A blind guess of k workers out of M expects k²/M.
    pub fn overlap(prediction: &[usize], truth: &[usize]) -> usize {
        // Both are sorted.
        let mut i = 0;
        let mut hits = 0;
        for &p in prediction {
            while i < truth.len() && truth[i] < p {
                i += 1;
            }
            if i < truth.len() && truth[i] == p {
                hits += 1;
                i += 1;
            }
        }
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn transcript(
        mode: SelectionMode,
        seed: u64,
        m: usize,
        p: f64,
        rounds: usize,
    ) -> Vec<Vec<usize>> {
        let sampler = WorkerSampler::new(m, p);
        let root = Pcg64::new(seed, 0xc0_0e_d1);
        let mut sel = SelectionRng::from_seed(mode, &root, seed);
        let mut buf = Vec::new();
        (0..rounds)
            .map(|t| {
                sel.select_into(&sampler, t, &mut buf);
                buf.clone()
            })
            .collect()
    }

    #[test]
    fn low_entropy_seed_falls_to_transcript_replay_in_both_modes() {
        for mode in [SelectionMode::Legacy, SelectionMode::Committed] {
            let obs = transcript(mode, 42, 60, 0.25, 6);
            let attacker =
                SelectionAttacker { workers: 60, participation: 0.25, transcript: obs };
            assert_eq!(attacker.recover_seed(mode, 0..1000), Some(42), "{mode:?}");
            let predicted = attacker.predict_from_seed(mode, 42, 6, 3);
            let truth = transcript(mode, 42, 60, 0.25, 9);
            assert_eq!(predicted.as_slice(), &truth[6..9], "{mode:?}");
        }
    }

    #[test]
    fn out_of_budget_seed_is_not_recovered() {
        let seed = 0x9e37_79b9_7f4a_7c15;
        let obs = transcript(SelectionMode::Committed, seed, 60, 0.25, 6);
        let attacker = SelectionAttacker { workers: 60, participation: 0.25, transcript: obs };
        assert_eq!(attacker.recover_seed(SelectionMode::Committed, 0..4096), None);
    }

    #[test]
    fn overlap_counts_sorted_intersection() {
        assert_eq!(SelectionAttacker::overlap(&[1, 3, 5], &[3, 4, 5]), 2);
        assert_eq!(SelectionAttacker::overlap(&[], &[1]), 0);
        assert_eq!(SelectionAttacker::overlap(&[1, 2], &[3, 4]), 0);
    }
}
