//! The persistent worker pool behind the round engine (DESIGN.md §10).
//!
//! Before this module, `TrainingRun::run_probed` spawned and joined a
//! fresh `std::thread::scope` every round — at 10k-worker scale that is
//! `threads × rounds` thread spawns plus a `Vec<CompressedGrad>`
//! buffering every message. The pool replaces it with `threads`
//! long-lived workers created once per run and parked on a condvar
//! between rounds:
//!
//! 1. the coordinator publishes a [`RoundJob`] (raw views of the round's
//!    coordinator-owned buffers) through the [`JobCell`],
//! 2. [`PoolGate::open`] bumps the epoch and wakes every worker,
//! 3. each worker processes its disjoint slot chunk ([`chunk_bounds`])
//!    and calls [`PoolGate::finish`],
//! 4. [`PoolGate::wait_done`] returns to the coordinator once every chunk
//!    is in; only then does the coordinator touch the round buffers
//!    again.
//!
//! The gate's mutex/condvar pair is the only synchronization: job
//! publication happens-before `open`'s epoch bump and workers' slot
//! writes happen-before `wait_done`'s return, because both sides go
//! through the gate mutex. Steady-state rounds allocate nothing and
//! spawn nothing (`tests/zero_alloc_round.rs`). If a worker panics, its
//! [`AbortGuard`] poisons the gate so the coordinator panics out of
//! `wait_done` instead of deadlocking.

use crate::compressors::CompressedGrad;
use std::cell::UnsafeCell;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Contiguous slot range owned by pool thread `ti` of `threads` for an
/// `n`-slot round — the same chunking the pre-pool scoped engine used,
/// so per-thread work sets are unchanged. Threads past the last chunk
/// receive an empty range.
pub fn chunk_bounds(n: usize, threads: usize, ti: usize) -> (usize, usize) {
    let chunk = n.div_ceil(threads.max(1));
    ((ti * chunk).min(n), ((ti + 1) * chunk).min(n))
}

struct GateState {
    /// Round generation counter; bumped by [`PoolGate::open`].
    epoch: u64,
    /// Workers still running the current epoch.
    remaining: usize,
    /// Set by [`PoolGate::shutdown`] (and by poisoning): workers exit.
    shutdown: bool,
    /// A worker panicked mid-round; the coordinator must abort.
    poisoned: bool,
}

/// Coordinator ⇄ worker handoff: an epoch counter workers park on and a
/// completion latch the coordinator waits on.
pub struct PoolGate {
    state: Mutex<GateState>,
    /// Coordinator → workers: a new round was published (or shutdown).
    start: Condvar,
    /// Workers → coordinator: a chunk finished (or the gate poisoned).
    done: Condvar,
}

impl Default for PoolGate {
    fn default() -> Self {
        Self::new()
    }
}

impl PoolGate {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(GateState {
                epoch: 0,
                remaining: 0,
                shutdown: false,
                poisoned: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        }
    }

    /// Acquire the gate state, ignoring std mutex poisoning: the gate has
    /// its own `poisoned` flag with abort semantics, every critical
    /// section leaves `GateState` consistent, and several callers run
    /// during unwinding ([`AbortGuard`], [`ShutdownGuard`]) where a
    /// `PoisonError` panic would be a process-aborting double panic.
    fn lock(&self) -> MutexGuard<'_, GateState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Coordinator: publish the round to `threads` workers and wake them.
    /// Must follow a `wait_done` (no worker may still be running).
    pub fn open(&self, threads: usize) {
        let mut st = self.lock();
        debug_assert_eq!(st.remaining, 0, "open() with workers still running");
        st.epoch += 1;
        st.remaining = threads;
        drop(st);
        self.start.notify_all();
    }

    /// Coordinator: block until every worker finished the current round.
    /// Panics if a worker panicked (see [`AbortGuard`]).
    pub fn wait_done(&self) {
        let mut st = self.lock();
        while st.remaining != 0 && !st.poisoned {
            st = self.done.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        let poisoned = st.poisoned;
        // Release the gate before unwinding so the cleanup guards (which
        // re-lock it) never double-panic.
        drop(st);
        if poisoned {
            panic!("pool worker thread panicked");
        }
    }

    /// Coordinator: wake every parked worker for exit. Idempotent.
    pub fn shutdown(&self) {
        let mut st = self.lock();
        st.shutdown = true;
        drop(st);
        self.start.notify_all();
    }

    /// Worker: park until the epoch advances past `seen` (returning the
    /// new epoch) or the pool shuts down (returning `None`).
    pub fn await_round(&self, seen: u64) -> Option<u64> {
        let mut st = self.lock();
        loop {
            if st.shutdown {
                return None;
            }
            if st.epoch > seen {
                return Some(st.epoch);
            }
            st = self.start.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Worker: mark this thread's chunk of the current epoch complete.
    pub fn finish(&self) {
        let mut st = self.lock();
        debug_assert!(st.remaining > 0, "finish() without a matching open()");
        st.remaining -= 1;
        if st.remaining == 0 {
            drop(st);
            self.done.notify_one();
        }
    }

    /// Abort the run from a panicking worker: wake the coordinator (which
    /// re-panics out of `wait_done`) and every parked sibling (which
    /// exits via `shutdown`), so the enclosing `thread::scope` can join.
    fn poison(&self) {
        let mut st = self.lock();
        st.poisoned = true;
        st.shutdown = true;
        drop(st);
        self.done.notify_all();
        self.start.notify_all();
    }

    /// RAII guard for a worker's round loop: if the worker unwinds, the
    /// guard poisons the gate on drop.
    pub fn abort_guard(&self) -> AbortGuard<'_> {
        AbortGuard(self)
    }
}

/// See [`PoolGate::abort_guard`].
pub struct AbortGuard<'a>(&'a PoolGate);

impl Drop for AbortGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// RAII for the coordinator side: shuts the gate down when the round
/// loop exits — normally or by unwinding. A panicking coordinator must
/// still wake parked workers, or the enclosing `thread::scope` would
/// join them forever.
pub struct ShutdownGuard<'a>(pub &'a PoolGate);

impl Drop for ShutdownGuard<'_> {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

/// One round's work order: the round inputs plus raw views of the
/// coordinator-owned slot buffers. Copied into each worker; the accessors
/// rebuild slices. Raw pointers rather than borrows because the buffers
/// are re-borrowed mutably by the coordinator between rounds — validity
/// is guaranteed by the gate protocol, not by lifetimes.
#[derive(Clone, Copy)]
pub struct RoundJob {
    /// Round index `t`.
    pub t: usize,
    /// This round's learning rate.
    pub lr: f64,
    /// Fold votes into per-thread accumulators instead of buffering
    /// messages (the unit-scale packed-ternary fast path).
    pub streaming: bool,
    /// Selected worker count (the slot count).
    pub n: usize,
    selected: *const usize,
    params: *const f32,
    params_len: usize,
    losses: *mut f64,
    bits: *mut f64,
    nnz: *mut usize,
    msgs: *mut Option<CompressedGrad>,
}

// SAFETY: the raw views are only dereferenced by pool workers between
// `open` and their `finish`, on disjoint slot ranges (`outputs`), while
// the coordinator is parked in `wait_done` — see the module docs.
unsafe impl Send for RoundJob {}

impl RoundJob {
    /// Capture raw views of the round's buffers. Every slot array must
    /// cover exactly the `selected.len()` slots of this round.
    pub fn new(
        t: usize,
        lr: f64,
        streaming: bool,
        selected: &[usize],
        params: &[f32],
        losses: &mut [f64],
        bits: &mut [f64],
        nnz: &mut [usize],
        msgs: &mut [Option<CompressedGrad>],
    ) -> Self {
        let n = selected.len();
        assert_eq!(losses.len(), n, "losses slot count");
        assert_eq!(bits.len(), n, "bits slot count");
        assert_eq!(nnz.len(), n, "nnz slot count");
        assert_eq!(msgs.len(), n, "msgs slot count");
        Self {
            t,
            lr,
            streaming,
            n,
            selected: selected.as_ptr(),
            params: params.as_ptr(),
            params_len: params.len(),
            losses: losses.as_mut_ptr(),
            bits: bits.as_mut_ptr(),
            nnz: nnz.as_mut_ptr(),
            msgs: msgs.as_mut_ptr(),
        }
    }

    /// This round's selected worker ids.
    pub fn selected(&self) -> &[usize] {
        // SAFETY: valid for the round per the module protocol.
        unsafe { std::slice::from_raw_parts(self.selected, self.n) }
    }

    /// The broadcast model parameters.
    pub fn params(&self) -> &[f32] {
        // SAFETY: valid for the round per the module protocol.
        unsafe { std::slice::from_raw_parts(self.params, self.params_len) }
    }

    /// Mutable slot outputs for `lo..hi`.
    ///
    /// # Safety
    /// The caller must be the only thread touching slots `lo..hi` for the
    /// current epoch (the engine hands each pool thread the disjoint
    /// [`chunk_bounds`] range), and the coordinator must not access the
    /// buffers until it has observed this thread's [`PoolGate::finish`].
    pub unsafe fn outputs(&self, lo: usize, hi: usize) -> SlotOutputs<'_> {
        assert!(lo <= hi && hi <= self.n, "slot range {lo}..{hi} out of {}", self.n);
        // SAFETY: disjointness and quiescence per the contract above.
        unsafe {
            SlotOutputs {
                losses: std::slice::from_raw_parts_mut(self.losses.add(lo), hi - lo),
                bits: std::slice::from_raw_parts_mut(self.bits.add(lo), hi - lo),
                nnz: std::slice::from_raw_parts_mut(self.nnz.add(lo), hi - lo),
                msgs: std::slice::from_raw_parts_mut(self.msgs.add(lo), hi - lo),
            }
        }
    }
}

/// The per-slot output views a pool worker fills for its chunk: the
/// order-sensitive scalars (reduced by the coordinator in selection
/// order) and, on the buffered route, the message slots themselves.
pub struct SlotOutputs<'a> {
    pub losses: &'a mut [f64],
    pub bits: &'a mut [f64],
    pub nnz: &'a mut [usize],
    pub msgs: &'a mut [Option<CompressedGrad>],
}

/// Single-slot mailbox for the current round's [`RoundJob`].
///
/// Protocol: the coordinator publishes strictly between `wait_done` and
/// `open` (no worker running), and workers read only after `await_round`
/// observed the epoch bump — both sides pass through the gate mutex, so
/// the unsynchronized cell never races.
pub struct JobCell {
    job: UnsafeCell<Option<RoundJob>>,
}

// SAFETY: accesses are serialized by the PoolGate protocol above.
unsafe impl Sync for JobCell {}

impl Default for JobCell {
    fn default() -> Self {
        Self::new()
    }
}

impl JobCell {
    pub fn new() -> Self {
        Self { job: UnsafeCell::new(None) }
    }

    /// Coordinator side; must not be called while any worker is running.
    pub fn publish(&self, job: RoundJob) {
        // SAFETY: no worker reads between `wait_done` and `open`.
        unsafe { *self.job.get() = Some(job) }
    }

    /// Worker side; call only after `await_round` returned a new epoch.
    pub fn read(&self) -> RoundJob {
        // SAFETY: the coordinator only writes while workers are parked.
        unsafe { (*self.job.get()).expect("no round job published") }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunking_covers_all_slots_disjointly() {
        for n in [0usize, 1, 2, 5, 7, 64, 101] {
            for threads in [1usize, 2, 3, 4, 7, 16] {
                let mut covered = 0usize;
                let mut prev_hi = 0usize;
                for ti in 0..threads {
                    let (lo, hi) = chunk_bounds(n, threads, ti);
                    assert!(lo <= hi && hi <= n, "n={n} threads={threads} ti={ti}");
                    assert_eq!(lo, prev_hi, "chunks must be contiguous (ti={ti})");
                    covered += hi - lo;
                    prev_hi = hi;
                }
                assert_eq!(covered, n, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn gate_runs_epochs_and_shuts_down() {
        let gate = PoolGate::new();
        let threads = 3;
        let rounds = 5;
        let hits: Vec<AtomicUsize> = (0..threads).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|s| {
            for hit in &hits {
                let gate = &gate;
                s.spawn(move || {
                    let mut seen = 0u64;
                    while let Some(epoch) = gate.await_round(seen) {
                        seen = epoch;
                        hit.fetch_add(1, Ordering::SeqCst);
                        gate.finish();
                    }
                });
            }
            for _ in 0..rounds {
                gate.open(threads);
                gate.wait_done();
            }
            gate.shutdown();
        });
        for hit in &hits {
            assert_eq!(hit.load(Ordering::SeqCst), rounds);
        }
    }

    #[test]
    fn job_roundtrips_slot_views() {
        let selected = vec![4usize, 7, 9];
        let params = vec![1.0f32, 2.0];
        let mut losses = vec![0.0f64; 3];
        let mut bits = vec![0.0f64; 3];
        let mut nnz = vec![0usize; 3];
        let mut msgs: Vec<Option<CompressedGrad>> = vec![None, None, None];
        let cell = JobCell::new();
        cell.publish(RoundJob::new(
            2,
            0.5,
            true,
            &selected,
            &params,
            &mut losses,
            &mut bits,
            &mut nnz,
            &mut msgs,
        ));
        let job = cell.read();
        assert_eq!(job.t, 2);
        assert_eq!(job.n, 3);
        assert!(job.streaming);
        assert_eq!(job.selected(), &[4, 7, 9]);
        assert_eq!(job.params(), &[1.0, 2.0]);
        // SAFETY: single-threaded test, disjoint ranges.
        let out = unsafe { job.outputs(1, 3) };
        out.losses[0] = 1.5;
        out.nnz[1] = 8;
        drop(out);
        let out = unsafe { job.outputs(0, 1) };
        out.bits[0] = 64.0;
        drop(out);
        assert_eq!(losses, vec![0.0, 1.5, 0.0]);
        assert_eq!(nnz, vec![0, 0, 8]);
        assert_eq!(bits, vec![64.0, 0.0, 0.0]);
    }
}
