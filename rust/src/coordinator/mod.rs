//! The L3 coordinator: the paper's training protocols.
//!
//! * [`Algorithm::CompressedGd`] — Algorithm 1 (one compressed gradient
//!   per selected worker per round, server aggregation `C(·)`, broadcast).
//!   With `compressor = sparsign` and `aggregation = MajorityVote` this is
//!   **SPARSIGNSGD**; with the other compressor kinds it instantiates every
//!   baseline row of Tables 1–2.
//! * [`Algorithm::EfSparsign`] — Algorithm 2 (**EF-SPARSIGNSGD**): τ local
//!   sparsign steps per worker (budget `B_l`), a sparsign-compressed model
//!   update (budget `B_g`), and *server-side* error feedback (eq. 8) around
//!   the scaled-sign α-approximate broadcast compressor.
//! * [`Algorithm::FedAvg`] / [`Algorithm::FedCom`] — the local-update
//!   baselines of Table 3 / Fig. 3 (FedCom = FedAvg + s-level QSGD on the
//!   model delta; Haddadpour et al. 2021).
//!
//! The engine is fully deterministic given the run seed: worker `m` at
//! round `t` draws from a stream derived as `root.derive(t‖m)`, so runs
//! replay bit-exactly **regardless of execution order** — which is what
//! makes the round engine's worker fan-out safe. `TrainingRun::run`
//! builds a **persistent pool** of `TrainingRun::threads` workers
//! (default: `available_parallelism`) once per run; each round the
//! selected workers are sharded across the parked pool threads
//! (the crate-private `pool` module, DESIGN.md §10). On the unit-scale packed-ternary fast path
//! each pool thread folds its messages into a thread-local
//! [`VoteAccumulator`] as they are produced and the accumulators merge —
//! votes are exact integers, so the counts are independent of fold and
//! merge order — while the order-sensitive f64 scalars (losses, bits)
//! land in index-addressed slots and are reduced on the coordinator
//! thread in selection order. `RunHistory` is therefore bit-identical to
//! a serial (`threads = Some(1)`) run for every algorithm
//! (`tests/engine_equivalence.rs`), and a steady-state fast-path round at
//! full participation performs zero heap allocations and zero thread
//! spawns (`tests/zero_alloc_round.rs`; partial participation draws a
//! fresh selection per round in `WorkerSampler::select_into`).

pub mod aggregation;
pub mod attacks;
pub mod env;
pub mod ledger;
pub(crate) mod pool;
pub mod prediction;
pub mod sampling;

pub use aggregation::{vote_counts, Aggregate, AggregationRule, VoteAccumulator, MAX_STREAM_MSGS};
pub use pool::chunk_bounds;
pub use attacks::{Attack, AttackPlan, Cohort};
pub use env::{ClassifierEnv, GradientSource, RosenbrockEnv};
pub use ledger::{CommLedger, RoundComm, REJECT_KINDS};
pub use sampling::{SelectionMode, SelectionRng, SelectionSnapshot, WorkerSampler};

use crate::compressors::{
    CompressedGrad, Compressor, CompressorKind, NormKind, PackedTernary,
    QsgdCompressor, SparsignCompressor,
};
use crate::optim::{sgd_step, LrSchedule};
use crate::snapshot::{CoordinatorSnapshot, SnapPhase, SnapshotError, SnapshotPolicy};
use crate::util::rng::Pcg64;
use std::sync::Mutex;

/// Federated training algorithm.
#[derive(Clone, Debug)]
pub enum Algorithm {
    /// Algorithm 1: compressed distributed SGD with worker sampling.
    CompressedGd { compressor: CompressorKind, aggregation: AggregationRule },
    /// Algorithm 2: EF-SPARSIGNSGD with τ local updates; `server_lr_scale`
    /// is the η multiplier (Theorem 3 sets η = τ, the default when None).
    EfSparsign {
        b_local: f32,
        b_global: f32,
        tau: usize,
        server_lr_scale: Option<f64>,
        /// Ablation switch: `false` disables the eq. (8) server residual
        /// (the update becomes plain scaled-sign of the round average).
        server_ef: bool,
    },
    /// FedAvg (McMahan et al. 2017): τ full-precision local steps,
    /// uncompressed model-delta upload.
    FedAvg { tau: usize },
    /// FedCom (Haddadpour et al. 2021): FedAvg + s-level QSGD on the
    /// uploaded delta (the paper uses s=255, i.e. 8-bit).
    FedCom { tau: usize, levels: u32 },
}

impl Algorithm {
    /// Table-row label matching the paper's naming.
    pub fn label(&self) -> String {
        match self {
            Algorithm::CompressedGd { compressor, .. } => compressor.label(),
            Algorithm::EfSparsign { b_local, b_global, tau, .. } => {
                format!("EF-sparsignSGD(Bl={b_local},Bg={b_global},tau={tau})")
            }
            Algorithm::FedAvg { tau } => format!("FedAvg-Local{tau}"),
            Algorithm::FedCom { tau, levels } => {
                let bits = (*levels as f64 + 1.0).log2().ceil() as u32;
                format!("FedCom-Local{tau}({bits}bit)")
            }
        }
    }

    /// Local steps per round.
    pub fn tau(&self) -> usize {
        match self {
            Algorithm::CompressedGd { .. } => 1,
            Algorithm::EfSparsign { tau, .. }
            | Algorithm::FedAvg { tau }
            | Algorithm::FedCom { tau, .. } => *tau,
        }
    }

    /// True when every uplink message is packed ternary with decode scale
    /// exactly 1.0 — the buffered-fallback predicate (DESIGN.md §10):
    /// when it holds, the pool engine streams votes into per-thread
    /// accumulators instead of buffering `n` messages. Mixed-scale/dense
    /// compressors, the FedAvg/FedCom delta uploads, and Algorithm 2
    /// (whose server EF recursion consumes the buffered pre-compression
    /// average) all keep the buffered reference route.
    fn streams_unit_ternary(&self) -> bool {
        match self {
            Algorithm::CompressedGd { compressor, .. } => compressor.streams_unit_ternary(),
            _ => false,
        }
    }
}

/// Per-round metrics. `PartialEq` compares every field exactly — the
/// snapshot-resume equivalence tests diff restored histories field-wise.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundReport {
    pub round: usize,
    pub lr: f64,
    /// Mean mini-batch loss over participating workers (first local step).
    pub train_loss: f64,
    /// `(test_loss, test_accuracy)` when this was an eval round.
    pub eval: Option<(f64, f64)>,
    pub uplink_bits: f64,
    pub downlink_bits: f64,
    /// Cumulative uplink bits through this round.
    pub cum_uplink_bits: f64,
}

/// Full run output.
#[derive(Clone, Debug)]
pub struct RunHistory {
    pub label: String,
    pub dim: usize,
    pub reports: Vec<RoundReport>,
    pub final_params: Vec<f32>,
    /// Per-round communication ledger (bits + non-zero counts, built from
    /// the per-message caches — no payload rescans).
    pub ledger: CommLedger,
}

impl RunHistory {
    /// Last recorded evaluation `(loss, acc)`.
    pub fn final_eval(&self) -> Option<(f64, f64)> {
        self.reports.iter().rev().find_map(|r| r.eval)
    }

    /// First round (1-based, as the paper reports) whose evaluation
    /// accuracy reaches `target`.
    pub fn rounds_to_acc(&self, target: f64) -> Option<usize> {
        self.reports
            .iter()
            .find(|r| r.eval.map(|(_, a)| a >= target).unwrap_or(false))
            .map(|r| r.round + 1)
    }

    /// Cumulative uplink bits when accuracy first reaches `target`.
    pub fn uplink_to_acc(&self, target: f64) -> Option<f64> {
        self.reports
            .iter()
            .find(|r| r.eval.map(|(_, a)| a >= target).unwrap_or(false))
            .map(|r| r.cum_uplink_bits)
    }

    /// Evaluation series `(round, acc, cum_uplink_bits)` for the Fig. 3
    /// style curves.
    pub fn eval_series(&self) -> Vec<(usize, f64, f64)> {
        self.reports
            .iter()
            .filter_map(|r| r.eval.map(|(_, a)| (r.round + 1, a, r.cum_uplink_bits)))
            .collect()
    }

    /// Total uplink bits over the run.
    pub fn total_uplink(&self) -> f64 {
        self.reports.last().map(|r| r.cum_uplink_bits).unwrap_or(0.0)
    }
}

/// Inspection hook invoked once per round *before* the model update:
/// `(round, params, aggregated_update)`. Used by the Fig. 1/2 harness to
/// measure the probability of wrong aggregation.
pub type RoundProbe<'a> = &'a mut dyn FnMut(usize, &[f32], &[f32]);

/// A configured training run (the `FederatedServer` driver).
pub struct TrainingRun {
    pub algorithm: Algorithm,
    pub schedule: LrSchedule,
    pub rounds: usize,
    /// Worker participation fraction `p_s` per round.
    pub participation: f64,
    /// Evaluate every k rounds (and always on the final round). 0 ⇒ only
    /// the final round.
    pub eval_every: usize,
    pub seed: u64,
    pub attack: Option<AttackPlan>,
    /// How the per-round worker cohort is drawn: the legacy `Pcg64`
    /// stream or the hardened ChaCha20 committed-seed mode
    /// (DESIGN.md §13). Part of the config fingerprint.
    pub selection: SelectionMode,
    /// Permit stateful (worker-EF) compressors under partial
    /// participation — off by default because that is exactly the broken
    /// configuration the paper identifies; enable only to demonstrate it.
    pub allow_stateful_with_sampling: bool,
    /// Worker fan-out threads per round; `None` ⇒ `available_parallelism`.
    /// `Some(1)` forces the serial reference engine. Any value yields a
    /// bit-identical `RunHistory` (see the module docs).
    pub threads: Option<usize>,
}

/// Alias kept for API symmetry with the docs ("the federated server").
pub type FederatedServer = TrainingRun;

/// Per-worker compressor bank: the stateful EF/SSDM baselines keep their
/// residual/momentum behind the per-slot mutexes (uncontended — each
/// worker is visited by exactly one thread per round).
pub(crate) type WorkerComps = Vec<Mutex<Box<dyn Compressor>>>;

/// Per-thread scratch reused across rounds — the seed engine allocated
/// `params.clone()`, `accum` and the gradient buffer per worker per round.
/// `model` extends this to the full worker-side hot path: batch gather,
/// activations, deltas and GEMM packing buffers, so a steady-state
/// `loss_grad` performs zero heap allocations (`tests/zero_alloc.rs`).
/// Crate-visible because the `net` client fleet runs the same worker
/// loop remotely.
pub(crate) struct WorkerScratch {
    grad: Vec<f32>,
    wm: Vec<f32>,
    accum: Vec<f32>,
    model: crate::model::ModelWorkspace,
}

impl WorkerScratch {
    pub(crate) fn new(d: usize) -> Self {
        Self {
            grad: vec![0.0; d],
            wm: vec![0.0; d],
            accum: vec![0.0; d],
            model: crate::model::ModelWorkspace::new(),
        }
    }
}

/// Server-side round state, allocated once per run (DESIGN.md §10): the
/// selection buffer, the vote-count/update buffers, the per-slot
/// order-sensitive scalar arrays, and the buffered-route message slots.
/// On the streaming fast path a steady-state round touches none of the
/// heap (`tests/zero_alloc_round.rs`). Crate-visible because the `net`
/// coordinator service fills the same slots from decoded frames.
pub(crate) struct ServerScratch {
    /// This round's selected worker ids (`WorkerSampler::select_into`).
    pub(crate) selected: Vec<usize>,
    /// Merged per-coordinate vote counts (streaming route).
    pub(crate) counts: Vec<i16>,
    /// The broadcast update `g̃`.
    pub(crate) update: Vec<f32>,
    /// Per-slot first-local-step losses (reduced in selection order).
    pub(crate) losses: Vec<f64>,
    /// Per-slot uplink bit costs (streaming route; buffered messages
    /// carry their own).
    pub(crate) bits: Vec<f64>,
    /// Per-slot uplink non-zero counts (streaming route).
    pub(crate) nnz: Vec<usize>,
    /// Message slots for the buffered reference route; stay `None` on the
    /// streaming route.
    pub(crate) msgs: Vec<Option<CompressedGrad>>,
}

impl ServerScratch {
    fn new(d: usize, n_max: usize) -> Self {
        Self {
            selected: Vec::with_capacity(n_max),
            counts: vec![0; d],
            update: vec![0.0; d],
            losses: vec![0.0; n_max],
            bits: vec![0.0; n_max],
            nnz: vec![0; n_max],
            msgs: vec![None; n_max],
        }
    }
}

/// The coordinator's per-round tail, shared by the serial reference
/// engine, the pool engine and the `net` coordinator service: ordered
/// scalar reduction, aggregation dispatch (streaming finalize vs
/// buffered reference), the Algorithm 2 EF recursion, the probe, the
/// model step, and the round report. The transport server reuses this
/// struct verbatim, which is what makes a wire run's `RunHistory`
/// bit-identical to the in-process engine by construction.
pub(crate) struct RoundLoop<'a> {
    run: &'a TrainingRun,
    d: usize,
    /// Unit-scale packed-ternary fast path active (pool engine / net
    /// coordinator).
    streaming: bool,
    /// Environment fingerprint mixed into snapshot fingerprints (0 when
    /// the caller does not snapshot).
    env_tag: u64,
    sampler: WorkerSampler,
    select_rng: SelectionRng,
    pub(crate) server: ServerScratch,
    /// Algorithm 2's server error-feedback residual `ẽ`.
    server_residual: Vec<f32>,
    pub(crate) params: Vec<f32>,
    reports: Vec<RoundReport>,
    cum_uplink: f64,
    pub(crate) ledger: CommLedger,
}

impl<'a> RoundLoop<'a> {
    /// Build the per-run server state: worker sampler + selection RNG
    /// (derived from the run seed exactly as every engine does), slot
    /// buffers sized for the per-round cohort, and the initial model.
    pub(crate) fn new(
        run: &'a TrainingRun,
        d: usize,
        m: usize,
        streaming: bool,
        env_tag: u64,
        init: Vec<f32>,
    ) -> Self {
        assert_eq!(init.len(), d, "init params dim mismatch");
        assert!(run.rounds > 0, "need at least one round");
        let sampler = WorkerSampler::new(m, run.participation);
        let n_max = sampler.per_round();
        RoundLoop {
            run,
            d,
            streaming,
            env_tag,
            sampler,
            select_rng: SelectionRng::from_seed(run.selection, &run.root_rng(), run.seed),
            server: ServerScratch::new(d, n_max),
            server_residual: vec![0.0; d],
            params: init,
            reports: Vec::with_capacity(run.rounds),
            cum_uplink: 0.0,
            ledger: CommLedger::with_capacity(run.rounds),
        }
    }

    /// Draw round `t`'s worker selection; returns the slot count. Legacy
    /// mode ignores `t` (sequential stream); committed mode keys the draw
    /// by it.
    pub(crate) fn select(&mut self, t: usize) -> usize {
        self.select_rng.select_into(&self.sampler, t, &mut self.server.selected);
        self.server.selected.len()
    }

    /// The selection commitment broadcast at rendezvous (all-zero in
    /// legacy mode — there is nothing sound to commit to).
    pub(crate) fn selection_commitment(&self) -> [u64; 4] {
        self.select_rng.commitment()
    }

    /// Everything after the round's worker fan-out filled the slots.
    pub(crate) fn finish_round(
        &mut self,
        t: usize,
        lr: f64,
        n: usize,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
        probe: &mut Option<RoundProbe<'_>>,
    ) {
        let run = self.run;
        // Deterministic reduction in selection order (f64 sums are
        // order-sensitive; this keeps them independent of thread count).
        let loss_sum: f64 = self.server.losses[..n].iter().sum();
        let mut uplink = 0.0f64;
        let mut round_nnz = 0usize;

        // ---- Server aggregation --------------------------------------
        let (scale, downlink) = if self.streaming {
            for (&b, &z) in self.server.bits[..n].iter().zip(&self.server.nnz[..n]) {
                uplink += b;
                round_nnz += z;
            }
            let Algorithm::CompressedGd { aggregation, .. } = &run.algorithm else {
                unreachable!("streaming engine requires CompressedGd");
            };
            let downlink =
                aggregation.finalize_votes(&self.server.counts, n, 1.0, &mut self.server.update);
            (lr as f32, downlink)
        } else {
            let msgs: Vec<CompressedGrad> = self.server.msgs[..n]
                .iter_mut()
                .map(|slot| slot.take().expect("worker slot not filled"))
                .collect();
            for msg in &msgs {
                uplink += msg.bits();
                round_nnz += msg.nnz();
            }
            let (update, scale, downlink) = match &run.algorithm {
                Algorithm::CompressedGd { aggregation, .. } => {
                    let agg = aggregation.aggregate(&msgs, None);
                    (agg.update, lr as f32, agg.downlink_bits)
                }
                Algorithm::EfSparsign { tau, server_lr_scale, server_ef, .. } => {
                    let residual = server_ef.then_some(self.server_residual.as_slice());
                    let agg = AggregationRule::ScaledSign.aggregate(&msgs, residual);
                    if *server_ef {
                        // ẽ^{(t+1)} = raw − g̃  (eq. 8).
                        let raw = agg.raw.as_ref().expect("EF aggregation must materialize raw");
                        for ((e, &r), &u) in
                            self.server_residual.iter_mut().zip(raw).zip(&agg.update)
                        {
                            *e = r - u;
                        }
                    }
                    let eta = server_lr_scale.unwrap_or(*tau as f64);
                    (agg.update, (eta * lr) as f32, agg.downlink_bits)
                }
                Algorithm::FedAvg { .. } | Algorithm::FedCom { .. } => {
                    let agg = AggregationRule::Mean.aggregate(&msgs, None);
                    // Global step γ = 1: w ← w − mean(Δ) = mean(w_m).
                    (agg.update, 1.0, 32.0 * self.d as f64)
                }
            };
            self.server.update = update;
            (scale, downlink)
        };

        self.ledger.record(RoundComm {
            uplink_bits: uplink,
            downlink_bits: downlink,
            senders: n,
            uplink_nnz: round_nnz,
            ..RoundComm::default()
        });
        if let Some(p) = probe.as_mut() {
            p(t, &self.params, &self.server.update);
        }
        sgd_step(&mut self.params, scale, &self.server.update);

        self.cum_uplink += uplink;
        let do_eval = if run.eval_every == 0 {
            t + 1 == run.rounds
        } else {
            (t + 1) % run.eval_every == 0 || t + 1 == run.rounds
        };
        self.reports.push(RoundReport {
            round: t,
            lr,
            train_loss: loss_sum / n as f64,
            eval: if do_eval { Some(eval(&self.params)) } else { None },
            uplink_bits: uplink,
            downlink_bits: downlink,
            cum_uplink_bits: self.cum_uplink,
        });
    }

    pub(crate) fn into_history(self, label: String, dim: usize) -> RunHistory {
        RunHistory {
            label,
            dim,
            reports: self.reports,
            final_params: self.params,
            ledger: self.ledger,
        }
    }

    /// First round this loop will run: 0 for a fresh run, the snapshot's
    /// next round after a restore.
    pub(crate) fn start_round(&self) -> usize {
        self.reports.len()
    }

    /// Capture the full server-side state at the current round boundary
    /// (DESIGN.md §12). Everything a bit-identical resume needs is here:
    /// the worker streams are derived per `(seed, round, worker)` and
    /// never persist, so params + selection stream + residual + history
    /// are the complete stateful surface.
    pub(crate) fn to_snapshot(&self) -> CoordinatorSnapshot {
        let next = self.reports.len();
        CoordinatorSnapshot {
            fingerprint: self.run.config_fingerprint(self.d, self.sampler.total, self.env_tag),
            dim: self.d,
            workers: self.sampler.total,
            rounds_total: self.run.rounds,
            phase: if next == 0 { SnapPhase::Standby } else { SnapPhase::Broadcast(next - 1) },
            selection: self.select_rng.snapshot(next as u64),
            params: self.params.clone(),
            residual: matches!(self.run.algorithm, Algorithm::EfSparsign { .. })
                .then(|| self.server_residual.clone()),
            reports: self.reports.clone(),
            ledger: self.ledger.clone(),
        }
    }

    /// Write a periodic snapshot if the policy says one is due after
    /// round `t` completed.
    pub(crate) fn maybe_snapshot(
        &self,
        policy: Option<&SnapshotPolicy>,
        t: usize,
    ) -> Result<(), SnapshotError> {
        if let Some(p) = policy {
            if p.due(t + 1, self.run.rounds) {
                self.to_snapshot().save(&p.path)?;
            }
        }
        Ok(())
    }

    /// Rebuild the per-run server state from a (file-validated) snapshot.
    /// Cross-checks the snapshot against *this* run's configuration —
    /// shape, round budget and the config fingerprint — so a resume can
    /// never silently continue a different experiment.
    pub(crate) fn resume(
        run: &'a TrainingRun,
        d: usize,
        m: usize,
        streaming: bool,
        env_tag: u64,
        snap: CoordinatorSnapshot,
    ) -> Result<Self, SnapshotError> {
        if snap.dim != d || snap.workers != m {
            return Err(SnapshotError::Incompatible(format!(
                "snapshot shape {}d/{}w vs run {d}d/{m}w",
                snap.dim, snap.workers
            )));
        }
        if snap.rounds_total != run.rounds {
            return Err(SnapshotError::Incompatible(format!(
                "snapshot run length {} vs configured {}",
                snap.rounds_total, run.rounds
            )));
        }
        let want = run.config_fingerprint(d, m, env_tag);
        if snap.fingerprint != want {
            return Err(SnapshotError::Incompatible(format!(
                "config fingerprint {:#018x} != this run's {:#018x} (algorithm, schedule, \
                 rounds, participation, eval cadence, seed, attack plan and the data \
                 environment must all match)",
                snap.fingerprint, want
            )));
        }
        let select_rng = SelectionRng::restore(run.selection, run.seed, &snap.selection)
            .map_err(SnapshotError::Malformed)?;
        let is_ef = matches!(run.algorithm, Algorithm::EfSparsign { .. });
        let server_residual = match (snap.residual, is_ef) {
            (Some(r), true) => r,
            (None, false) => vec![0.0; d],
            (Some(_), false) => {
                return Err(SnapshotError::Incompatible(
                    "snapshot carries a server residual but this algorithm keeps none".into(),
                ))
            }
            (None, true) => {
                return Err(SnapshotError::Incompatible(
                    "EF-sparsign resume requires the server residual".into(),
                ))
            }
        };
        let sampler = WorkerSampler::new(m, run.participation);
        let n_max = sampler.per_round();
        let cum_uplink = snap.reports.last().map(|r| r.cum_uplink_bits).unwrap_or(0.0);
        let mut reports = snap.reports;
        reports.reserve(run.rounds.saturating_sub(reports.len()));
        // Same headroom for the restored ledger, upholding the
        // `CommLedger::with_capacity` no-mid-round-reallocation contract
        // on the resumed tail.
        let mut ledger = snap.ledger;
        ledger.reserve(run.rounds.saturating_sub(ledger.rounds()));
        Ok(RoundLoop {
            run,
            d,
            streaming,
            env_tag,
            sampler,
            select_rng,
            server: ServerScratch::new(d, n_max),
            server_residual,
            params: snap.params,
            reports,
            cum_uplink,
            ledger,
        })
    }
}

impl TrainingRun {
    /// Minimal constructor with the common defaults.
    pub fn new(algorithm: Algorithm, schedule: LrSchedule, rounds: usize) -> Self {
        Self {
            algorithm,
            schedule,
            rounds,
            participation: 1.0,
            eval_every: 10,
            seed: 0,
            attack: None,
            selection: SelectionMode::default(),
            allow_stateful_with_sampling: false,
            threads: None,
        }
    }

    /// Execute the run on `env`, starting from `init` parameters,
    /// evaluating with `eval` (return `(loss, acc)`).
    pub fn run(
        &self,
        env: &dyn GradientSource,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
    ) -> RunHistory {
        self.run_probed(env, init, eval, None)
    }

    /// Effective worker fan-out width for this run. Environments that are
    /// single-threaded by contract (PJRT-backed models) force 1 regardless
    /// of the requested width.
    fn engine_threads(&self, env: &dyn GradientSource, workers_per_round: usize) -> usize {
        if env.serial_only() {
            return 1;
        }
        let hw = self
            .threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .max(1);
        hw.min(workers_per_round.max(1))
    }

    /// The run's root RNG stream — every engine (serial, pool, and the
    /// `net` client fleet) derives worker/selection streams from this
    /// exact constant, which is what keeps them replay-identical.
    pub(crate) fn root_rng(&self) -> Pcg64 {
        Pcg64::new(self.seed, 0xc0_0e_d1)
    }

    /// Instantiate `count` per-worker compressor objects (empty for the
    /// local-update algorithms, which compress inline). The `net` client
    /// fleet builds one bank per hosted worker range.
    pub(crate) fn build_worker_comps(&self, d: usize, count: usize) -> WorkerComps {
        match &self.algorithm {
            Algorithm::CompressedGd { compressor, .. } => {
                (0..count).map(|_| Mutex::new(compressor.build(d))).collect()
            }
            _ => Vec::new(),
        }
    }

    /// Refuse the stateful-compressor × worker-sampling configuration the
    /// paper identifies as broken, unless explicitly overridden. Shared
    /// by the in-process engines and the `net` client fleet.
    pub(crate) fn reject_stateful_sampling(&self, comps: &WorkerComps) {
        if let Some(c) = comps.first() {
            let c = c.lock().expect("compressor lock");
            if c.requires_worker_state()
                && self.participation < 1.0
                && !self.allow_stateful_with_sampling
            {
                panic!(
                    "compressor '{}' keeps worker-side state and participation is {} < 1: \
                     this is the configuration the paper shows to be broken \
                     (stale error feedback); set allow_stateful_with_sampling \
                     to run it anyway",
                    c.name(),
                    self.participation
                );
            }
        }
    }

    /// Stable hash of everything that determines this run's trajectory:
    /// algorithm, schedule, rounds, participation, eval cadence, seed,
    /// attack plan, model dimension, worker population, plus the
    /// environment's own structural fingerprint
    /// ([`GradientSource::env_fingerprint`] — dataset/partition/batch
    /// drift the run config alone cannot see). Stamped into every
    /// snapshot and checked on resume, so a snapshot can only continue
    /// the exact run that wrote it; the `net` rendezvous additionally
    /// exchanges the `env_tag = 0` form in `Hello` so a coordinator
    /// refuses a fleet built from drifted flags. Public so out-of-crate
    /// clients can speak the handshake.
    pub fn config_fingerprint(&self, d: usize, m: usize, env_tag: u64) -> u64 {
        let desc = format!(
            "alg={:?};sched={:?};rounds={};participation={:016x};eval_every={};seed={};\
             attack={:?};sel={:?};d={d};m={m};env={env_tag:016x}",
            self.algorithm,
            self.schedule,
            self.rounds,
            self.participation.to_bits(),
            self.eval_every,
            self.seed,
            self.attack,
            self.selection,
        );
        crate::snapshot::fingerprint_bytes(desc.as_bytes())
    }

    /// Snapshotting covers the full server-side state; worker-side state
    /// (the EF/SSDM baselines) lives in the clients and cannot ride a
    /// coordinator snapshot — refuse with a typed error rather than
    /// resume into silently-stale worker residuals. Shared by the
    /// in-process engines and the `net` coordinator service.
    pub(crate) fn require_snapshot_support(
        &self,
        comps: &WorkerComps,
    ) -> Result<(), SnapshotError> {
        if let Some(c) = comps.first() {
            if c.lock().expect("compressor lock").requires_worker_state() {
                return Err(SnapshotError::Unsupported(
                    "stateful worker compressors (EF/SSDM) keep client-side state a \
                     coordinator snapshot cannot carry",
                ));
            }
        }
        Ok(())
    }

    /// True when the coordinator should stream votes into a
    /// [`VoteAccumulator`] for an `n_max`-worker cohort instead of
    /// buffering messages — the DESIGN.md §10 predicate, reused verbatim
    /// by the `net` coordinator service (both routes are pinned
    /// bit-identical, so the transport server streams whenever legal).
    pub(crate) fn streams_votes(&self, n_max: usize) -> bool {
        n_max <= MAX_STREAM_MSGS && self.algorithm.streams_unit_ternary()
    }

    /// One worker's round: derive its RNG stream, sample the gradient(s),
    /// apply the attack, compress — returns the uplink message and the
    /// first-local-step loss. Pure in `(t, w, params)` given the run seed,
    /// so it can execute on any thread in any order — or, via the `net`
    /// client fleet, in any process. `comp` is the worker's own
    /// compressor slot (required for [`Algorithm::CompressedGd`], unused
    /// by the local-update algorithms).
    pub(crate) fn worker_round(
        &self,
        env: &dyn GradientSource,
        t: usize,
        w: usize,
        lr: f64,
        params: &[f32],
        root: &Pcg64,
        comp: Option<&Mutex<Box<dyn Compressor>>>,
        scratch: &mut WorkerScratch,
    ) -> (CompressedGrad, f64) {
        let d = params.len();
        let mut wrng = root.derive(((t as u64) << 24) | w as u64);
        match &self.algorithm {
            Algorithm::CompressedGd { .. } => {
                let loss =
                    env.sample_grad_ws(w, params, &mut wrng, &mut scratch.grad, &mut scratch.model);
                if let Some(plan) = &self.attack {
                    plan.apply(t, w, &mut scratch.grad, &mut wrng);
                }
                let msg = comp
                    .expect("CompressedGd worker requires its compressor slot")
                    .lock()
                    .expect("worker compressor lock poisoned")
                    .compress(&scratch.grad, &mut wrng);
                (msg, loss as f64)
            }
            Algorithm::EfSparsign { b_local, b_global, tau, .. } => {
                let mut local = SparsignCompressor { budget: *b_local };
                scratch.wm.copy_from_slice(params);
                scratch.accum.fill(0.0);
                let mut first_loss = 0.0f64;
                for c in 0..*tau {
                    let loss = env.sample_grad_ws(
                        w,
                        &scratch.wm,
                        &mut wrng,
                        &mut scratch.grad,
                        &mut scratch.model,
                    );
                    if c == 0 {
                        first_loss = loss as f64;
                    }
                    if let Some(plan) = &self.attack {
                        plan.apply(t, w, &mut scratch.grad, &mut wrng);
                    }
                    let q = local.compress(&scratch.grad, &mut wrng);
                    // wm ← wm − η_L·q ; accum ← accum + q.
                    if let CompressedGrad::Ternary { pack, .. } = &q {
                        let eta_l = lr as f32;
                        let s = pack.scale();
                        let wm = &mut scratch.wm;
                        let accum = &mut scratch.accum;
                        pack.for_each_nonzero(|i, sgn| {
                            let qf = s * sgn as f32;
                            wm[i] -= eta_l * qf;
                            accum[i] += qf;
                        });
                    }
                }
                let mut global = SparsignCompressor { budget: *b_global };
                let delta = global.compress(&scratch.accum, &mut wrng);
                (delta, first_loss)
            }
            Algorithm::FedAvg { tau } | Algorithm::FedCom { tau, .. } => {
                scratch.wm.copy_from_slice(params);
                let mut first_loss = 0.0f64;
                for c in 0..*tau {
                    let loss = env.sample_grad_ws(
                        w,
                        &scratch.wm,
                        &mut wrng,
                        &mut scratch.grad,
                        &mut scratch.model,
                    );
                    if c == 0 {
                        first_loss = loss as f64;
                    }
                    if let Some(plan) = &self.attack {
                        plan.apply(t, w, &mut scratch.grad, &mut wrng);
                    }
                    sgd_step(&mut scratch.wm, lr as f32, &scratch.grad);
                }
                // Upload Δ = w − w_m (so the server's mean recovers the
                // FedAvg parameter average). FedAvg's Δ IS the message
                // payload, so it owns a fresh Vec; FedCom's Δ is consumed
                // by the quantizer and reuses the per-thread scratch.
                let msg = match &self.algorithm {
                    Algorithm::FedAvg { .. } => {
                        let delta: Vec<f32> =
                            params.iter().zip(&scratch.wm).map(|(a, b)| a - b).collect();
                        CompressedGrad::dense(delta, 32.0 * d as f64)
                    }
                    Algorithm::FedCom { levels, .. } => {
                        for ((dst, &p), &wi) in
                            scratch.accum.iter_mut().zip(params).zip(&scratch.wm)
                        {
                            *dst = p - wi;
                        }
                        let mut q =
                            QsgdCompressor { levels: *levels, norm: NormKind::L2 };
                        q.compress(&scratch.accum, &mut wrng)
                    }
                    _ => unreachable!(),
                };
                (msg, first_loss)
            }
        }
    }

    /// Streaming variant of [`Self::worker_round`] for the unit-scale
    /// packed-ternary fast path (`Algorithm::CompressedGd` only): emits
    /// into the caller's reusable `pack` — no message allocation — and
    /// returns `(loss, uplink_bits)`. Consumes the exact RNG stream
    /// `worker_round` would, so the two routes replay bit-identically.
    fn worker_round_streaming(
        &self,
        env: &dyn GradientSource,
        t: usize,
        w: usize,
        params: &[f32],
        root: &Pcg64,
        comp: &Mutex<Box<dyn Compressor>>,
        scratch: &mut WorkerScratch,
        pack: &mut PackedTernary,
    ) -> (f64, f64) {
        debug_assert!(matches!(self.algorithm, Algorithm::CompressedGd { .. }));
        let mut wrng = root.derive(((t as u64) << 24) | w as u64);
        let loss = env.sample_grad_ws(w, params, &mut wrng, &mut scratch.grad, &mut scratch.model);
        if let Some(plan) = &self.attack {
            plan.apply(t, w, &mut scratch.grad, &mut wrng);
        }
        let bits = comp
            .lock()
            .expect("worker compressor lock poisoned")
            .compress_ternary_into(&scratch.grad, &mut wrng, pack)
            .expect("streaming round engine requires a unit-scale ternary compressor");
        debug_assert_eq!(pack.scale(), 1.0);
        (loss as f64, bits)
    }

    /// [`TrainingRun::run`] with an optional per-round probe.
    pub fn run_probed(
        &self,
        env: &dyn GradientSource,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
        probe: Option<RoundProbe<'_>>,
    ) -> RunHistory {
        self.run_engine(env, EngineStart::Fresh(init), eval, probe, None)
            .expect("a run without a snapshot policy performs no fallible IO")
    }

    /// [`TrainingRun::run`] with periodic coordinator snapshots
    /// (DESIGN.md §12): after every `policy.every` completed rounds the
    /// full server-side state is written atomically to `policy.path`.
    /// Snapshotting never perturbs the run — the returned `RunHistory`
    /// is bit-identical to a plain [`TrainingRun::run`]
    /// (`tests/snapshot_resume.rs`).
    pub fn run_snapshotted(
        &self,
        env: &dyn GradientSource,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
        policy: &SnapshotPolicy,
    ) -> Result<RunHistory, SnapshotError> {
        assert!(
            policy.every > 0,
            "in-process runs need a periodic snapshot cadence (every ≥ 1)"
        );
        self.run_engine(env, EngineStart::Fresh(init), eval, None, Some(policy))
    }

    /// Continue a run from a restored [`CoordinatorSnapshot`]: rounds
    /// `snap.next_round()..rounds` execute on the restored state, and the
    /// resulting `RunHistory` (restored prefix + fresh tail) is
    /// bit-identical to an uninterrupted run — the determinism contract
    /// makes the snapshot a complete cut of the server state.
    pub fn resume_from(
        &self,
        env: &dyn GradientSource,
        snap: CoordinatorSnapshot,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
        policy: Option<&SnapshotPolicy>,
    ) -> Result<RunHistory, SnapshotError> {
        self.run_engine(env, EngineStart::Resume(snap), eval, None, policy)
    }

    /// The engine proper, shared by every in-process entry point.
    fn run_engine(
        &self,
        env: &dyn GradientSource,
        origin: EngineStart,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
        mut probe: Option<RoundProbe<'_>>,
        policy: Option<&SnapshotPolicy>,
    ) -> Result<RunHistory, SnapshotError> {
        let d = env.dim();
        assert!(self.rounds > 0, "need at least one round");
        let m = env.workers();
        let root = self.root_rng();

        // Per-worker compressor instances (the stateful EF/SSDM baselines
        // keep their residual/momentum here). Each worker is visited by
        // exactly one thread per round, so the per-slot mutexes are
        // uncontended; state still evolves per-worker-sequentially across
        // rounds, keeping threaded runs bit-exact.
        let worker_comps = self.build_worker_comps(d, m);
        self.reject_stateful_sampling(&worker_comps);
        let snapshotting = policy.is_some() || matches!(origin, EngineStart::Resume(_));
        if snapshotting {
            self.require_snapshot_support(&worker_comps)?;
        }
        // The environment hash is only consulted by snapshot
        // fingerprints; plain runs skip the O(dataset-sample) pass (and
        // its allocations — `tests/zero_alloc_round.rs`).
        let env_tag = if snapshotting { env.env_fingerprint() } else { 0 };

        // The streaming fast path needs the pool's per-thread
        // accumulators; the serial reference engine stays buffered by
        // definition (it IS the reference the fast path is pinned to).
        // Cohorts beyond the accumulator's exact-count capacity keep the
        // buffered route too, mirroring `aggregate`'s own fast-path gate.
        let n_max = WorkerSampler::new(m, self.participation).per_round();
        let threads = self.engine_threads(env, n_max);
        let streaming = threads > 1 && self.streams_votes(n_max);
        let mut lp = match origin {
            EngineStart::Fresh(init) => {
                assert_eq!(init.len(), d, "init params dim mismatch");
                RoundLoop::new(self, d, m, streaming, env_tag, init)
            }
            EngineStart::Resume(snap) => {
                RoundLoop::resume(self, d, m, streaming, env_tag, snap)?
            }
        };
        let start = lp.start_round();

        if threads <= 1 {
            // Serial reference engine: one scratch, buffered aggregation.
            let mut scratch = WorkerScratch::new(d);
            for t in start..self.rounds {
                let lr = self.schedule.at(t);
                let n = lp.select(t);
                for k in 0..n {
                    let w = lp.server.selected[k];
                    let (msg, loss) = self.worker_round(
                        env,
                        t,
                        w,
                        lr,
                        &lp.params,
                        &root,
                        worker_comps.get(w),
                        &mut scratch,
                    );
                    lp.server.losses[k] = loss;
                    lp.server.msgs[k] = Some(msg);
                }
                lp.finish_round(t, lr, n, eval, &mut probe);
                lp.maybe_snapshot(policy, t)?;
            }
        } else {
            // Persistent pool engine (DESIGN.md §10): `threads` workers
            // spawned once for the whole run, parked on the gate between
            // rounds. Each keeps its WorkerScratch, vote accumulator and
            // message scratch across rounds, so steady-state fast-path
            // rounds allocate nothing and spawn nothing.
            let gate = pool::PoolGate::new();
            let cell = pool::JobCell::new();
            let votes = Mutex::new(VoteAccumulator::new());
            let pool_out: Result<(), SnapshotError> = std::thread::scope(|s| {
                // Wakes parked workers even if a coordinator-side panic
                // (eval, probe, a poisoned gate) unwinds this closure —
                // otherwise the scope would join them forever.
                let _shutdown = pool::ShutdownGuard(&gate);
                for ti in 0..threads {
                    let gate = &gate;
                    let cell = &cell;
                    let votes = &votes;
                    let comps = &worker_comps;
                    let root = &root;
                    s.spawn(move || {
                        let _abort = gate.abort_guard();
                        let mut scratch = WorkerScratch::new(d);
                        let mut local = VoteAccumulator::new();
                        let mut pack = PackedTernary::zeros(0, 1.0);
                        let mut seen = 0u64;
                        while let Some(epoch) = gate.await_round(seen) {
                            seen = epoch;
                            let job = cell.read();
                            let (lo, hi) = pool::chunk_bounds(job.n, threads, ti);
                            let sel = &job.selected()[lo..hi];
                            let params = job.params();
                            // SAFETY: this thread exclusively owns slots
                            // lo..hi for this epoch, and the coordinator
                            // stays parked in `wait_done` until `finish`.
                            let out = unsafe { job.outputs(lo, hi) };
                            if job.streaming {
                                local.reset(d, job.n);
                                for (i, &w) in sel.iter().enumerate() {
                                    let (loss, bits) = self.worker_round_streaming(
                                        env,
                                        job.t,
                                        w,
                                        params,
                                        root,
                                        &comps[w],
                                        &mut scratch,
                                        &mut pack,
                                    );
                                    local.fold(&pack);
                                    out.losses[i] = loss;
                                    out.bits[i] = bits;
                                    out.nnz[i] = pack.nnz();
                                }
                                // Merge order across threads is arbitrary;
                                // integer votes make it irrelevant.
                                if !sel.is_empty() {
                                    votes
                                        .lock()
                                        .expect("vote accumulator lock poisoned")
                                        .merge(&local);
                                }
                            } else {
                                for (i, &w) in sel.iter().enumerate() {
                                    let (msg, loss) = self.worker_round(
                                        env,
                                        job.t,
                                        w,
                                        job.lr,
                                        params,
                                        root,
                                        comps.get(w),
                                        &mut scratch,
                                    );
                                    out.losses[i] = loss;
                                    out.msgs[i] = Some(msg);
                                }
                            }
                            gate.finish();
                        }
                    });
                }
                for t in start..self.rounds {
                    let lr = self.schedule.at(t);
                    let n = lp.select(t);
                    if streaming {
                        votes.lock().expect("vote accumulator lock poisoned").reset(d, n);
                    }
                    {
                        let sv = &mut lp.server;
                        cell.publish(pool::RoundJob::new(
                            t,
                            lr,
                            streaming,
                            &sv.selected,
                            &lp.params,
                            &mut sv.losses[..n],
                            &mut sv.bits[..n],
                            &mut sv.nnz[..n],
                            &mut sv.msgs[..n],
                        ));
                    }
                    gate.open(threads);
                    gate.wait_done();
                    if streaming {
                        votes
                            .lock()
                            .expect("vote accumulator lock poisoned")
                            .counts_into(&mut lp.server.counts);
                    }
                    lp.finish_round(t, lr, n, eval, &mut probe);
                    // An early `?` drops the shutdown guard, which wakes
                    // the parked pool so the scope can join it.
                    lp.maybe_snapshot(policy, t)?;
                }
                Ok(())
            });
            pool_out?;
        }

        Ok(lp.into_history(self.algorithm.label(), d))
    }
}

/// Where [`TrainingRun::run_engine`] starts from.
enum EngineStart {
    /// Fresh run from initial parameters.
    Fresh(Vec<f32>),
    /// Continue from a restored coordinator snapshot.
    Resume(CoordinatorSnapshot),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
    use crate::model::ModelKind;

    fn env() -> ClassifierEnv {
        let task = SyntheticTask::generate(
            SyntheticSpec {
                dim: 10,
                classes: 3,
                modes: 1,
                separation: 1.8,
                noise: 0.25,
                label_noise: 0.0,
                train: 600,
                test: 150,
            },
            21,
        );
        let mut rng = Pcg64::seed_from(22);
        let fed =
            DirichletPartitioner { alpha: 0.5, workers: 10 }.partition(&task.train, &mut rng);
        ClassifierEnv::new(
            ModelKind::Linear { inputs: 10, classes: 3 }.build(),
            task.train,
            task.test,
            fed,
            16,
        )
    }

    fn base_run(alg: Algorithm, rounds: usize) -> TrainingRun {
        TrainingRun {
            algorithm: alg,
            schedule: LrSchedule::Const { lr: 0.05 },
            rounds,
            participation: 1.0,
            eval_every: 10,
            seed: 3,
            attack: None,
            selection: Default::default(),
            allow_stateful_with_sampling: false,
            threads: None,
        }
    }

    #[test]
    fn sparsign_majority_vote_learns() {
        let e = env();
        let mut rng = Pcg64::seed_from(1);
        let init = e.init_params(&mut rng);
        let run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 1.0 },
                aggregation: AggregationRule::MajorityVote,
            },
            120,
        );
        let hist = run.run(&e, init, &|p| e.evaluate(p));
        let (_, acc) = hist.final_eval().unwrap();
        assert!(acc > 0.6, "sparsign failed to learn: acc {acc}");
        assert!(hist.total_uplink() > 0.0);
        // Ledger agrees with the per-round reports and records nnz.
        assert_eq!(hist.ledger.rounds(), 120);
        assert_eq!(hist.ledger.total_uplink(), hist.total_uplink());
        assert!(hist.ledger.total_uplink_nnz() > 0);
    }

    #[test]
    fn ef_sparsign_learns_with_sampling() {
        let e = env();
        let mut rng = Pcg64::seed_from(2);
        let init = e.init_params(&mut rng);
        let mut run = base_run(
            Algorithm::EfSparsign {
                b_local: 10.0,
                b_global: 1.0,
                tau: 3,
                server_lr_scale: None,
                server_ef: true,
            },
            80,
        );
        run.participation = 0.5;
        run.schedule = LrSchedule::Const { lr: 0.02 };
        let hist = run.run(&e, init, &|p| e.evaluate(p));
        let (_, acc) = hist.final_eval().unwrap();
        assert!(acc > 0.6, "EF-sparsign acc {acc}");
    }

    #[test]
    fn fedavg_and_fedcom_learn() {
        let e = env();
        let mut rng = Pcg64::seed_from(3);
        let init = e.init_params(&mut rng);
        for alg in [
            Algorithm::FedAvg { tau: 5 },
            Algorithm::FedCom { tau: 5, levels: 255 },
        ] {
            let mut run = base_run(alg, 40);
            run.schedule = LrSchedule::Const { lr: 0.05 };
            let hist = run.run(&e, init.clone(), &|p| e.evaluate(p));
            let (_, acc) = hist.final_eval().unwrap();
            assert!(acc > 0.7, "{}: acc {acc}", hist.label);
        }
    }

    #[test]
    fn fedcom_uplink_cheaper_than_fedavg() {
        let e = env();
        let mut rng = Pcg64::seed_from(4);
        let init = e.init_params(&mut rng);
        let h_avg = base_run(Algorithm::FedAvg { tau: 2 }, 10).run(&e, init.clone(), &|p| {
            e.evaluate(p)
        });
        let h_com = base_run(Algorithm::FedCom { tau: 2, levels: 255 }, 10).run(
            &e,
            init,
            &|p| e.evaluate(p),
        );
        assert!(h_com.total_uplink() < h_avg.total_uplink());
    }

    #[test]
    fn deterministic_replay() {
        let e = env();
        let mut rng = Pcg64::seed_from(5);
        let init = e.init_params(&mut rng);
        let run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 0.5 },
                aggregation: AggregationRule::MajorityVote,
            },
            20,
        );
        let h1 = run.run(&e, init.clone(), &|p| e.evaluate(p));
        let h2 = run.run(&e, init, &|p| e.evaluate(p));
        assert_eq!(h1.final_params, h2.final_params);
        assert_eq!(h1.total_uplink(), h2.total_uplink());
    }

    #[test]
    fn threaded_engine_matches_serial_reference() {
        let e = env();
        let mut rng = Pcg64::seed_from(9);
        let init = e.init_params(&mut rng);
        let mut serial = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 0.5 },
                aggregation: AggregationRule::MajorityVote,
            },
            25,
        );
        serial.threads = Some(1);
        let mut threaded = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 0.5 },
                aggregation: AggregationRule::MajorityVote,
            },
            25,
        );
        threaded.threads = Some(4);
        let h1 = serial.run(&e, init.clone(), &|p| e.evaluate(p));
        let h2 = threaded.run(&e, init, &|p| e.evaluate(p));
        assert_eq!(h1.final_params, h2.final_params);
        for (a, b) in h1.reports.iter().zip(&h2.reports) {
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.uplink_bits, b.uplink_bits);
        }
    }

    #[test]
    #[should_panic(expected = "worker-side state")]
    fn stateful_compressor_with_sampling_is_rejected() {
        let e = env();
        let mut rng = Pcg64::seed_from(6);
        let init = e.init_params(&mut rng);
        let mut run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
                aggregation: AggregationRule::ScaledSign,
            },
            5,
        );
        run.participation = 0.5;
        run.run(&e, init, &|p| e.evaluate(p));
    }

    #[test]
    fn probe_sees_every_round() {
        let e = env();
        let mut rng = Pcg64::seed_from(7);
        let init = e.init_params(&mut rng);
        let run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sign,
                aggregation: AggregationRule::MajorityVote,
            },
            7,
        );
        let mut seen = Vec::new();
        let mut probe = |t: usize, _p: &[f32], u: &[f32]| {
            assert_eq!(u.len(), e.dim());
            seen.push(t);
        };
        run.run_probed(&e, init, &|p| e.evaluate(p), Some(&mut probe));
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn rounds_and_bits_to_target_extraction() {
        let e = env();
        let mut rng = Pcg64::seed_from(8);
        let init = e.init_params(&mut rng);
        let mut run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Identity,
                aggregation: AggregationRule::Mean,
            },
            60,
        );
        run.eval_every = 5;
        let hist = run.run(&e, init, &|p| e.evaluate(p));
        let (_, final_acc) = hist.final_eval().unwrap();
        assert!(final_acc > 0.7);
        let r = hist.rounds_to_acc(0.5).expect("should reach 50%");
        let b = hist.uplink_to_acc(0.5).unwrap();
        assert!(r <= 60 && b > 0.0);
        assert!(hist.rounds_to_acc(1.1).is_none());
        // Eval series is monotone in rounds and bits.
        let series = hist.eval_series();
        assert!(!series.is_empty());
        for w in series.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].2 <= w[1].2);
        }
    }
}
