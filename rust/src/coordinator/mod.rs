//! The L3 coordinator: the paper's training protocols.
//!
//! * [`Algorithm::CompressedGd`] — Algorithm 1 (one compressed gradient
//!   per selected worker per round, server aggregation `C(·)`, broadcast).
//!   With `compressor = sparsign` and `aggregation = MajorityVote` this is
//!   **SPARSIGNSGD**; with the other compressor kinds it instantiates every
//!   baseline row of Tables 1–2.
//! * [`Algorithm::EfSparsign`] — Algorithm 2 (**EF-SPARSIGNSGD**): τ local
//!   sparsign steps per worker (budget `B_l`), a sparsign-compressed model
//!   update (budget `B_g`), and *server-side* error feedback (eq. 8) around
//!   the scaled-sign α-approximate broadcast compressor.
//! * [`Algorithm::FedAvg`] / [`Algorithm::FedCom`] — the local-update
//!   baselines of Table 3 / Fig. 3 (FedCom = FedAvg + s-level QSGD on the
//!   model delta; Haddadpour et al. 2021).
//!
//! The engine is fully deterministic given the run seed: worker `m` at
//! round `t` draws from a stream derived as `root.derive(t‖m)`, so runs
//! replay bit-exactly **regardless of execution order** — which is what
//! makes the round engine's worker fan-out safe. Each round the selected
//! workers are sharded across `TrainingRun::threads` scoped threads
//! (default: `available_parallelism`); per-worker results land in
//! index-addressed slots and are reduced on the coordinator thread in
//! selection order, so `RunHistory` is bit-identical to a serial
//! (`threads = Some(1)`) run.

pub mod aggregation;
pub mod attacks;
pub mod env;
pub mod ledger;
pub mod sampling;

pub use aggregation::{vote_counts, Aggregate, AggregationRule};
pub use attacks::{Attack, AttackPlan};
pub use env::{ClassifierEnv, GradientSource, RosenbrockEnv};
pub use ledger::{CommLedger, RoundComm};
pub use sampling::WorkerSampler;

use crate::compressors::{
    CompressedGrad, Compressor, CompressorKind, NormKind, QsgdCompressor,
    SparsignCompressor,
};
use crate::optim::{sgd_step, LrSchedule};
use crate::util::rng::Pcg64;
use std::sync::Mutex;

/// Federated training algorithm.
#[derive(Clone, Debug)]
pub enum Algorithm {
    /// Algorithm 1: compressed distributed SGD with worker sampling.
    CompressedGd { compressor: CompressorKind, aggregation: AggregationRule },
    /// Algorithm 2: EF-SPARSIGNSGD with τ local updates; `server_lr_scale`
    /// is the η multiplier (Theorem 3 sets η = τ, the default when None).
    EfSparsign {
        b_local: f32,
        b_global: f32,
        tau: usize,
        server_lr_scale: Option<f64>,
        /// Ablation switch: `false` disables the eq. (8) server residual
        /// (the update becomes plain scaled-sign of the round average).
        server_ef: bool,
    },
    /// FedAvg (McMahan et al. 2017): τ full-precision local steps,
    /// uncompressed model-delta upload.
    FedAvg { tau: usize },
    /// FedCom (Haddadpour et al. 2021): FedAvg + s-level QSGD on the
    /// uploaded delta (the paper uses s=255, i.e. 8-bit).
    FedCom { tau: usize, levels: u32 },
}

impl Algorithm {
    /// Table-row label matching the paper's naming.
    pub fn label(&self) -> String {
        match self {
            Algorithm::CompressedGd { compressor, .. } => compressor.label(),
            Algorithm::EfSparsign { b_local, b_global, tau, .. } => {
                format!("EF-sparsignSGD(Bl={b_local},Bg={b_global},tau={tau})")
            }
            Algorithm::FedAvg { tau } => format!("FedAvg-Local{tau}"),
            Algorithm::FedCom { tau, levels } => {
                let bits = (*levels as f64 + 1.0).log2().ceil() as u32;
                format!("FedCom-Local{tau}({bits}bit)")
            }
        }
    }

    /// Local steps per round.
    pub fn tau(&self) -> usize {
        match self {
            Algorithm::CompressedGd { .. } => 1,
            Algorithm::EfSparsign { tau, .. }
            | Algorithm::FedAvg { tau }
            | Algorithm::FedCom { tau, .. } => *tau,
        }
    }
}

/// Per-round metrics.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: usize,
    pub lr: f64,
    /// Mean mini-batch loss over participating workers (first local step).
    pub train_loss: f64,
    /// `(test_loss, test_accuracy)` when this was an eval round.
    pub eval: Option<(f64, f64)>,
    pub uplink_bits: f64,
    pub downlink_bits: f64,
    /// Cumulative uplink bits through this round.
    pub cum_uplink_bits: f64,
}

/// Full run output.
#[derive(Clone, Debug)]
pub struct RunHistory {
    pub label: String,
    pub dim: usize,
    pub reports: Vec<RoundReport>,
    pub final_params: Vec<f32>,
    /// Per-round communication ledger (bits + non-zero counts, built from
    /// the per-message caches — no payload rescans).
    pub ledger: CommLedger,
}

impl RunHistory {
    /// Last recorded evaluation `(loss, acc)`.
    pub fn final_eval(&self) -> Option<(f64, f64)> {
        self.reports.iter().rev().find_map(|r| r.eval)
    }

    /// First round (1-based, as the paper reports) whose evaluation
    /// accuracy reaches `target`.
    pub fn rounds_to_acc(&self, target: f64) -> Option<usize> {
        self.reports
            .iter()
            .find(|r| r.eval.map(|(_, a)| a >= target).unwrap_or(false))
            .map(|r| r.round + 1)
    }

    /// Cumulative uplink bits when accuracy first reaches `target`.
    pub fn uplink_to_acc(&self, target: f64) -> Option<f64> {
        self.reports
            .iter()
            .find(|r| r.eval.map(|(_, a)| a >= target).unwrap_or(false))
            .map(|r| r.cum_uplink_bits)
    }

    /// Evaluation series `(round, acc, cum_uplink_bits)` for the Fig. 3
    /// style curves.
    pub fn eval_series(&self) -> Vec<(usize, f64, f64)> {
        self.reports
            .iter()
            .filter_map(|r| r.eval.map(|(_, a)| (r.round + 1, a, r.cum_uplink_bits)))
            .collect()
    }

    /// Total uplink bits over the run.
    pub fn total_uplink(&self) -> f64 {
        self.reports.last().map(|r| r.cum_uplink_bits).unwrap_or(0.0)
    }
}

/// Inspection hook invoked once per round *before* the model update:
/// `(round, params, aggregated_update)`. Used by the Fig. 1/2 harness to
/// measure the probability of wrong aggregation.
pub type RoundProbe<'a> = &'a mut dyn FnMut(usize, &[f32], &[f32]);

/// A configured training run (the `FederatedServer` driver).
pub struct TrainingRun {
    pub algorithm: Algorithm,
    pub schedule: LrSchedule,
    pub rounds: usize,
    /// Worker participation fraction `p_s` per round.
    pub participation: f64,
    /// Evaluate every k rounds (and always on the final round). 0 ⇒ only
    /// the final round.
    pub eval_every: usize,
    pub seed: u64,
    pub attack: Option<AttackPlan>,
    /// Permit stateful (worker-EF) compressors under partial
    /// participation — off by default because that is exactly the broken
    /// configuration the paper identifies; enable only to demonstrate it.
    pub allow_stateful_with_sampling: bool,
    /// Worker fan-out threads per round; `None` ⇒ `available_parallelism`.
    /// `Some(1)` forces the serial reference engine. Any value yields a
    /// bit-identical `RunHistory` (see the module docs).
    pub threads: Option<usize>,
}

/// Alias kept for API symmetry with the docs ("the federated server").
pub type FederatedServer = TrainingRun;

/// Per-thread scratch reused across rounds — the seed engine allocated
/// `params.clone()`, `accum` and the gradient buffer per worker per round.
/// `model` extends this to the full worker-side hot path: batch gather,
/// activations, deltas and GEMM packing buffers, so a steady-state
/// `loss_grad` performs zero heap allocations (`tests/zero_alloc.rs`).
struct WorkerScratch {
    grad: Vec<f32>,
    wm: Vec<f32>,
    accum: Vec<f32>,
    model: crate::model::ModelWorkspace,
}

impl WorkerScratch {
    fn new(d: usize) -> Self {
        Self {
            grad: vec![0.0; d],
            wm: vec![0.0; d],
            accum: vec![0.0; d],
            model: crate::model::ModelWorkspace::new(),
        }
    }
}

impl TrainingRun {
    /// Minimal constructor with the common defaults.
    pub fn new(algorithm: Algorithm, schedule: LrSchedule, rounds: usize) -> Self {
        Self {
            algorithm,
            schedule,
            rounds,
            participation: 1.0,
            eval_every: 10,
            seed: 0,
            attack: None,
            allow_stateful_with_sampling: false,
            threads: None,
        }
    }

    /// Execute the run on `env`, starting from `init` parameters,
    /// evaluating with `eval` (return `(loss, acc)`).
    pub fn run(
        &self,
        env: &dyn GradientSource,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
    ) -> RunHistory {
        self.run_probed(env, init, eval, None)
    }

    /// Effective worker fan-out width for this run. Environments that are
    /// single-threaded by contract (PJRT-backed models) force 1 regardless
    /// of the requested width.
    fn engine_threads(&self, env: &dyn GradientSource, workers_per_round: usize) -> usize {
        if env.serial_only() {
            return 1;
        }
        let hw = self
            .threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .max(1);
        hw.min(workers_per_round.max(1))
    }

    /// One worker's round: derive its RNG stream, sample the gradient(s),
    /// apply the attack, compress — returns the uplink message and the
    /// first-local-step loss. Pure in `(t, w, params)` given the run seed,
    /// so it can execute on any thread in any order.
    fn worker_round(
        &self,
        env: &dyn GradientSource,
        t: usize,
        w: usize,
        lr: f64,
        params: &[f32],
        root: &Pcg64,
        comps: &[Mutex<Box<dyn Compressor>>],
        scratch: &mut WorkerScratch,
    ) -> (CompressedGrad, f64) {
        let d = params.len();
        let mut wrng = root.derive(((t as u64) << 24) | w as u64);
        match &self.algorithm {
            Algorithm::CompressedGd { .. } => {
                let loss =
                    env.sample_grad_ws(w, params, &mut wrng, &mut scratch.grad, &mut scratch.model);
                if let Some(plan) = &self.attack {
                    plan.apply(w, &mut scratch.grad, &mut wrng);
                }
                let msg = comps[w]
                    .lock()
                    .expect("worker compressor lock poisoned")
                    .compress(&scratch.grad, &mut wrng);
                (msg, loss as f64)
            }
            Algorithm::EfSparsign { b_local, b_global, tau, .. } => {
                let mut local = SparsignCompressor { budget: *b_local };
                scratch.wm.copy_from_slice(params);
                scratch.accum.fill(0.0);
                let mut first_loss = 0.0f64;
                for c in 0..*tau {
                    let loss = env.sample_grad_ws(
                        w,
                        &scratch.wm,
                        &mut wrng,
                        &mut scratch.grad,
                        &mut scratch.model,
                    );
                    if c == 0 {
                        first_loss = loss as f64;
                    }
                    if let Some(plan) = &self.attack {
                        plan.apply(w, &mut scratch.grad, &mut wrng);
                    }
                    let q = local.compress(&scratch.grad, &mut wrng);
                    // wm ← wm − η_L·q ; accum ← accum + q.
                    if let CompressedGrad::Ternary { pack, .. } = &q {
                        let eta_l = lr as f32;
                        let s = pack.scale();
                        let wm = &mut scratch.wm;
                        let accum = &mut scratch.accum;
                        pack.for_each_nonzero(|i, sgn| {
                            let qf = s * sgn as f32;
                            wm[i] -= eta_l * qf;
                            accum[i] += qf;
                        });
                    }
                }
                let mut global = SparsignCompressor { budget: *b_global };
                let delta = global.compress(&scratch.accum, &mut wrng);
                (delta, first_loss)
            }
            Algorithm::FedAvg { tau } | Algorithm::FedCom { tau, .. } => {
                scratch.wm.copy_from_slice(params);
                let mut first_loss = 0.0f64;
                for c in 0..*tau {
                    let loss = env.sample_grad_ws(
                        w,
                        &scratch.wm,
                        &mut wrng,
                        &mut scratch.grad,
                        &mut scratch.model,
                    );
                    if c == 0 {
                        first_loss = loss as f64;
                    }
                    if let Some(plan) = &self.attack {
                        plan.apply(w, &mut scratch.grad, &mut wrng);
                    }
                    sgd_step(&mut scratch.wm, lr as f32, &scratch.grad);
                }
                // Upload Δ = w − w_m (so the server's mean recovers the
                // FedAvg parameter average). FedAvg's Δ IS the message
                // payload, so it owns a fresh Vec; FedCom's Δ is consumed
                // by the quantizer and reuses the per-thread scratch.
                let msg = match &self.algorithm {
                    Algorithm::FedAvg { .. } => {
                        let delta: Vec<f32> =
                            params.iter().zip(&scratch.wm).map(|(a, b)| a - b).collect();
                        CompressedGrad::dense(delta, 32.0 * d as f64)
                    }
                    Algorithm::FedCom { levels, .. } => {
                        for ((dst, &p), &wi) in
                            scratch.accum.iter_mut().zip(params).zip(&scratch.wm)
                        {
                            *dst = p - wi;
                        }
                        let mut q =
                            QsgdCompressor { levels: *levels, norm: NormKind::L2 };
                        q.compress(&scratch.accum, &mut wrng)
                    }
                    _ => unreachable!(),
                };
                (msg, first_loss)
            }
        }
    }

    /// [`TrainingRun::run`] with an optional per-round probe.
    pub fn run_probed(
        &self,
        env: &dyn GradientSource,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
        mut probe: Option<RoundProbe<'_>>,
    ) -> RunHistory {
        let d = env.dim();
        assert_eq!(init.len(), d, "init params dim mismatch");
        assert!(self.rounds > 0, "need at least one round");
        let m = env.workers();
        let sampler = WorkerSampler::new(m, self.participation);
        let root = Pcg64::new(self.seed, 0xc0_0e_d1);
        let mut select_rng = root.derive(0xfeed);

        // Per-worker compressor instances (the stateful EF/SSDM baselines
        // keep their residual/momentum here). Each worker is visited by
        // exactly one thread per round, so the per-slot mutexes are
        // uncontended; state still evolves per-worker-sequentially across
        // rounds, keeping threaded runs bit-exact.
        let worker_comps: Vec<Mutex<Box<dyn Compressor>>> = match &self.algorithm {
            Algorithm::CompressedGd { compressor, .. } => {
                (0..m).map(|_| Mutex::new(compressor.build(d))).collect()
            }
            _ => Vec::new(),
        };
        if let Some(c) = worker_comps.first() {
            let c = c.lock().expect("compressor lock");
            if c.requires_worker_state()
                && self.participation < 1.0
                && !self.allow_stateful_with_sampling
            {
                panic!(
                    "compressor '{}' keeps worker-side state and participation is {} < 1: \
                     this is the configuration the paper shows to be broken \
                     (stale error feedback); set allow_stateful_with_sampling \
                     to run it anyway",
                    c.name(),
                    self.participation
                );
            }
        }

        let threads = self.engine_threads(env, sampler.per_round());
        let mut scratches: Vec<WorkerScratch> =
            (0..threads).map(|_| WorkerScratch::new(d)).collect();

        // Server error-feedback residual (Algorithm 2 only).
        let mut server_residual = vec![0.0f32; d];
        let mut params = init;
        let mut reports = Vec::with_capacity(self.rounds);
        let mut cum_uplink = 0.0f64;
        let mut comm_ledger = CommLedger::new();

        for t in 0..self.rounds {
            let lr = self.schedule.at(t);
            let selected = sampler.select(&mut select_rng);
            let n = selected.len();
            let mut slots: Vec<Option<(CompressedGrad, f64)>> =
                (0..n).map(|_| None).collect();

            if threads <= 1 || n <= 1 {
                // Serial reference engine.
                let scratch = &mut scratches[0];
                for (slot, &w) in slots.iter_mut().zip(&selected) {
                    *slot = Some(self.worker_round(
                        env,
                        t,
                        w,
                        lr,
                        &params,
                        &root,
                        &worker_comps,
                        scratch,
                    ));
                }
            } else {
                // Shard the selected workers across scoped threads; each
                // thread writes its contiguous slot chunk, so no result
                // ever moves between threads out of order.
                let chunk = n.div_ceil(threads);
                let params_ref: &[f32] = &params;
                let comps_ref: &[Mutex<Box<dyn Compressor>>] = &worker_comps;
                let root_ref = &root;
                std::thread::scope(|s| {
                    for (scratch, (sel_chunk, slot_chunk)) in scratches
                        .iter_mut()
                        .zip(selected.chunks(chunk).zip(slots.chunks_mut(chunk)))
                    {
                        s.spawn(move || {
                            for (slot, &w) in slot_chunk.iter_mut().zip(sel_chunk) {
                                *slot = Some(self.worker_round(
                                    env, t, w, lr, params_ref, root_ref, comps_ref,
                                    scratch,
                                ));
                            }
                        });
                    }
                });
            }

            // Deterministic reduction in selection order (f64 sums are
            // order-sensitive; this keeps them independent of the thread
            // count).
            let mut msgs = Vec::with_capacity(n);
            let mut loss_sum = 0.0f64;
            let mut uplink = 0.0f64;
            for slot in slots {
                let (msg, loss) = slot.expect("worker slot not filled");
                uplink += msg.bits();
                loss_sum += loss;
                msgs.push(msg);
            }

            // ---- Server aggregation + model update -----------------------
            let (update, scale, downlink) = match &self.algorithm {
                Algorithm::CompressedGd { aggregation, .. } => {
                    let agg = aggregation.aggregate(&msgs, None);
                    (agg.update, lr as f32, agg.downlink_bits)
                }
                Algorithm::EfSparsign { tau, server_lr_scale, server_ef, .. } => {
                    let residual = server_ef.then_some(server_residual.as_slice());
                    let agg = AggregationRule::ScaledSign.aggregate(&msgs, residual);
                    if *server_ef {
                        // ẽ^{(t+1)} = raw − g̃  (eq. 8).
                        for ((e, &r), &u) in server_residual
                            .iter_mut()
                            .zip(&agg.raw)
                            .zip(&agg.update)
                        {
                            *e = r - u;
                        }
                    }
                    let eta = server_lr_scale.unwrap_or(*tau as f64);
                    ((agg.update), (eta * lr) as f32, agg.downlink_bits)
                }
                Algorithm::FedAvg { .. } | Algorithm::FedCom { .. } => {
                    let agg = AggregationRule::Mean.aggregate(&msgs, None);
                    // Global step γ = 1: w ← w − mean(Δ) = mean(w_m).
                    (agg.update, 1.0, 32.0 * d as f64)
                }
            };
            comm_ledger.record(RoundComm::from_msgs(&msgs, downlink));
            if let Some(p) = probe.as_mut() {
                p(t, &params, &update);
            }
            sgd_step(&mut params, scale, &update);

            cum_uplink += uplink;
            let do_eval = if self.eval_every == 0 {
                t + 1 == self.rounds
            } else {
                (t + 1) % self.eval_every == 0 || t + 1 == self.rounds
            };
            reports.push(RoundReport {
                round: t,
                lr,
                train_loss: loss_sum / n as f64,
                eval: if do_eval { Some(eval(&params)) } else { None },
                uplink_bits: uplink,
                downlink_bits: downlink,
                cum_uplink_bits: cum_uplink,
            });
        }

        RunHistory {
            label: self.algorithm.label(),
            dim: d,
            reports,
            final_params: params,
            ledger: comm_ledger,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
    use crate::model::ModelKind;

    fn env() -> ClassifierEnv {
        let task = SyntheticTask::generate(
            SyntheticSpec {
                dim: 10,
                classes: 3,
                modes: 1,
                separation: 1.8,
                noise: 0.25,
                label_noise: 0.0,
                train: 600,
                test: 150,
            },
            21,
        );
        let mut rng = Pcg64::seed_from(22);
        let fed =
            DirichletPartitioner { alpha: 0.5, workers: 10 }.partition(&task.train, &mut rng);
        ClassifierEnv::new(
            ModelKind::Linear { inputs: 10, classes: 3 }.build(),
            task.train,
            task.test,
            fed,
            16,
        )
    }

    fn base_run(alg: Algorithm, rounds: usize) -> TrainingRun {
        TrainingRun {
            algorithm: alg,
            schedule: LrSchedule::Const { lr: 0.05 },
            rounds,
            participation: 1.0,
            eval_every: 10,
            seed: 3,
            attack: None,
            allow_stateful_with_sampling: false,
            threads: None,
        }
    }

    #[test]
    fn sparsign_majority_vote_learns() {
        let e = env();
        let mut rng = Pcg64::seed_from(1);
        let init = e.init_params(&mut rng);
        let run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 1.0 },
                aggregation: AggregationRule::MajorityVote,
            },
            120,
        );
        let hist = run.run(&e, init, &|p| e.evaluate(p));
        let (_, acc) = hist.final_eval().unwrap();
        assert!(acc > 0.6, "sparsign failed to learn: acc {acc}");
        assert!(hist.total_uplink() > 0.0);
        // Ledger agrees with the per-round reports and records nnz.
        assert_eq!(hist.ledger.rounds(), 120);
        assert_eq!(hist.ledger.total_uplink(), hist.total_uplink());
        assert!(hist.ledger.total_uplink_nnz() > 0);
    }

    #[test]
    fn ef_sparsign_learns_with_sampling() {
        let e = env();
        let mut rng = Pcg64::seed_from(2);
        let init = e.init_params(&mut rng);
        let mut run = base_run(
            Algorithm::EfSparsign {
                b_local: 10.0,
                b_global: 1.0,
                tau: 3,
                server_lr_scale: None,
                server_ef: true,
            },
            80,
        );
        run.participation = 0.5;
        run.schedule = LrSchedule::Const { lr: 0.02 };
        let hist = run.run(&e, init, &|p| e.evaluate(p));
        let (_, acc) = hist.final_eval().unwrap();
        assert!(acc > 0.6, "EF-sparsign acc {acc}");
    }

    #[test]
    fn fedavg_and_fedcom_learn() {
        let e = env();
        let mut rng = Pcg64::seed_from(3);
        let init = e.init_params(&mut rng);
        for alg in [
            Algorithm::FedAvg { tau: 5 },
            Algorithm::FedCom { tau: 5, levels: 255 },
        ] {
            let mut run = base_run(alg, 40);
            run.schedule = LrSchedule::Const { lr: 0.05 };
            let hist = run.run(&e, init.clone(), &|p| e.evaluate(p));
            let (_, acc) = hist.final_eval().unwrap();
            assert!(acc > 0.7, "{}: acc {acc}", hist.label);
        }
    }

    #[test]
    fn fedcom_uplink_cheaper_than_fedavg() {
        let e = env();
        let mut rng = Pcg64::seed_from(4);
        let init = e.init_params(&mut rng);
        let h_avg = base_run(Algorithm::FedAvg { tau: 2 }, 10).run(&e, init.clone(), &|p| {
            e.evaluate(p)
        });
        let h_com = base_run(Algorithm::FedCom { tau: 2, levels: 255 }, 10).run(
            &e,
            init,
            &|p| e.evaluate(p),
        );
        assert!(h_com.total_uplink() < h_avg.total_uplink());
    }

    #[test]
    fn deterministic_replay() {
        let e = env();
        let mut rng = Pcg64::seed_from(5);
        let init = e.init_params(&mut rng);
        let run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 0.5 },
                aggregation: AggregationRule::MajorityVote,
            },
            20,
        );
        let h1 = run.run(&e, init.clone(), &|p| e.evaluate(p));
        let h2 = run.run(&e, init, &|p| e.evaluate(p));
        assert_eq!(h1.final_params, h2.final_params);
        assert_eq!(h1.total_uplink(), h2.total_uplink());
    }

    #[test]
    fn threaded_engine_matches_serial_reference() {
        let e = env();
        let mut rng = Pcg64::seed_from(9);
        let init = e.init_params(&mut rng);
        let mut serial = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 0.5 },
                aggregation: AggregationRule::MajorityVote,
            },
            25,
        );
        serial.threads = Some(1);
        let mut threaded = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 0.5 },
                aggregation: AggregationRule::MajorityVote,
            },
            25,
        );
        threaded.threads = Some(4);
        let h1 = serial.run(&e, init.clone(), &|p| e.evaluate(p));
        let h2 = threaded.run(&e, init, &|p| e.evaluate(p));
        assert_eq!(h1.final_params, h2.final_params);
        for (a, b) in h1.reports.iter().zip(&h2.reports) {
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.uplink_bits, b.uplink_bits);
        }
    }

    #[test]
    #[should_panic(expected = "worker-side state")]
    fn stateful_compressor_with_sampling_is_rejected() {
        let e = env();
        let mut rng = Pcg64::seed_from(6);
        let init = e.init_params(&mut rng);
        let mut run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
                aggregation: AggregationRule::ScaledSign,
            },
            5,
        );
        run.participation = 0.5;
        run.run(&e, init, &|p| e.evaluate(p));
    }

    #[test]
    fn probe_sees_every_round() {
        let e = env();
        let mut rng = Pcg64::seed_from(7);
        let init = e.init_params(&mut rng);
        let run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sign,
                aggregation: AggregationRule::MajorityVote,
            },
            7,
        );
        let mut seen = Vec::new();
        let mut probe = |t: usize, _p: &[f32], u: &[f32]| {
            assert_eq!(u.len(), e.dim());
            seen.push(t);
        };
        run.run_probed(&e, init, &|p| e.evaluate(p), Some(&mut probe));
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn rounds_and_bits_to_target_extraction() {
        let e = env();
        let mut rng = Pcg64::seed_from(8);
        let init = e.init_params(&mut rng);
        let mut run = base_run(
            Algorithm::CompressedGd {
                compressor: CompressorKind::Identity,
                aggregation: AggregationRule::Mean,
            },
            60,
        );
        run.eval_every = 5;
        let hist = run.run(&e, init, &|p| e.evaluate(p));
        let (_, final_acc) = hist.final_eval().unwrap();
        assert!(final_acc > 0.7);
        let r = hist.rounds_to_acc(0.5).expect("should reach 50%");
        let b = hist.uplink_to_acc(0.5).unwrap();
        assert!(r <= 60 && b > 0.0);
        assert!(hist.rounds_to_acc(1.1).is_none());
        // Eval series is monotone in rounds and bits.
        let series = hist.eval_series();
        assert!(!series.is_empty());
        for w in series.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].2 <= w[1].2);
        }
    }
}
