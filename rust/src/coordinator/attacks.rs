//! Adversarial worker behaviours.
//!
//! Remark 2(4) of the paper argues sparsign is "robust against re-scaling
//! attacks that manipulate the magnitudes" because, unlike TernGrad /
//! QSGD, no norm is exchanged — a malicious worker can blow up its
//! gradient magnitude yet still contributes at most ±1 per coordinate.
//! These attack models let the experiment suite quantify that claim
//! (`examples/attack_robustness.rs`).

/// Attack applied to a malicious worker's gradient before compression.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attack {
    /// Multiply the gradient by `factor` (re-scaling attack; Jin et al.
    /// 2020). Defeats magnitude-sharing compressors whose decoded values
    /// scale with ‖g‖.
    Rescale { factor: f32 },
    /// Flip the gradient sign (Byzantine sign-flip).
    SignFlip,
    /// Replace the gradient with noise of the given magnitude.
    Garbage { magnitude: f32 },
}

/// Which workers are malicious: the first `count` worker ids (the engine
/// shuffles worker identity at partition time, so this is a uniform
/// random subset of the data distribution).
#[derive(Clone, Copy, Debug)]
pub struct AttackPlan {
    pub attack: Attack,
    pub malicious: usize,
}

impl AttackPlan {
    pub fn is_malicious(&self, worker: usize) -> bool {
        worker < self.malicious
    }

    /// Apply the attack in place to a malicious worker's gradient.
    pub fn apply(&self, worker: usize, g: &mut [f32], rng: &mut crate::util::rng::Pcg64) {
        if !self.is_malicious(worker) {
            return;
        }
        match self.attack {
            Attack::Rescale { factor } => {
                for v in g.iter_mut() {
                    *v *= factor;
                }
            }
            Attack::SignFlip => {
                for v in g.iter_mut() {
                    *v = -*v;
                }
            }
            Attack::Garbage { magnitude } => {
                for v in g.iter_mut() {
                    *v = rng.normal_f32(0.0, magnitude);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn rescale_only_hits_malicious() {
        let plan = AttackPlan { attack: Attack::Rescale { factor: 100.0 }, malicious: 2 };
        let mut rng = Pcg64::seed_from(1);
        let mut g = vec![1.0, -2.0];
        plan.apply(1, &mut g, &mut rng);
        assert_eq!(g, vec![100.0, -200.0]);
        let mut g2 = vec![1.0, -2.0];
        plan.apply(2, &mut g2, &mut rng);
        assert_eq!(g2, vec![1.0, -2.0]);
    }

    #[test]
    fn sign_flip() {
        let plan = AttackPlan { attack: Attack::SignFlip, malicious: 1 };
        let mut rng = Pcg64::seed_from(2);
        let mut g = vec![1.0, -2.0, 0.0];
        plan.apply(0, &mut g, &mut rng);
        assert_eq!(g, vec![-1.0, 2.0, 0.0]);
    }

    #[test]
    fn garbage_replaces_gradient() {
        let plan = AttackPlan { attack: Attack::Garbage { magnitude: 5.0 }, malicious: 1 };
        let mut rng = Pcg64::seed_from(3);
        let mut g = vec![1.0; 64];
        plan.apply(0, &mut g, &mut rng);
        assert!(g.iter().any(|&v| v != 1.0));
    }
}
