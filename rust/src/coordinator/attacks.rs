//! Adversarial worker behaviours (DESIGN.md §13).
//!
//! Remark 2(4) of the paper argues sparsign is "robust against re-scaling
//! attacks that manipulate the magnitudes" because, unlike TernGrad /
//! QSGD, no norm is exchanged — a malicious worker can blow up its
//! gradient magnitude yet still contributes at most ±1 per coordinate.
//! The attack model here lets the experiment suite quantify that claim
//! (`sparsignd train --attack …`, `experiments::attack_sweep_configs`)
//! and lets the transport tests exercise the coordinator's protocol
//! defenses under real framing (`tests/byzantine_wire.rs`).
//!
//! ## Composable cohorts
//!
//! An [`AttackPlan`] is a set of [`Cohort`]s, each binding one [`Attack`]
//! to an explicit sorted member list. Membership is either a prefix of
//! worker ids (the historical compat form) or a **seeded random subset**
//! ([`Cohort::sampled`]) so attacked experiments compose with Dirichlet
//! non-IID partitions without always hitting the same data shards.
//! Cohorts must be disjoint; the first matching cohort governs a worker.
//!
//! ## Gradient vs. protocol attacks
//!
//! * Gradient-level attacks ([`Attack::Rescale`], [`Attack::SignFlip`],
//!   [`Attack::Garbage`], [`Attack::CollusiveSignFlip`]) mutate the
//!   worker's gradient before compression. They run identically in the
//!   in-process engines and the `net` client fleet — a wire run of an
//!   attacked configuration stays bit-identical to the engine run.
//! * Protocol-level attacks ([`Attack::Straggle`], [`Attack::Equivocate`])
//!   misbehave at the transport: delaying past the round deadline,
//!   re-sending duplicate frames, replaying stale round indices. They are
//!   enacted by the malicious-agent mode of `net::client` and answered by
//!   the coordinator's typed rejects; in the in-process engines (which
//!   have no frames to abuse) they degenerate to honest behaviour.

use crate::util::rng::Pcg64;

/// Attack behaviour assigned to a cohort of malicious workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attack {
    /// Multiply the gradient by `factor` (re-scaling / scale-inflation
    /// attack; Jin et al. 2020). Defeats magnitude-sharing compressors
    /// whose decoded values scale with ‖g‖.
    Rescale { factor: f32 },
    /// Flip the gradient sign (uncoordinated Byzantine sign-flip).
    SignFlip,
    /// Replace the gradient with noise of the given magnitude.
    Garbage { magnitude: f32 },
    /// Colluding sign-flip: every cohort member replaces its gradient
    /// with the *same* adversarial ±1 direction for the round, drawn from
    /// a shared RNG derived as `(cohort seed, round)` — no communication
    /// needed, so the collusion works identically in-process and across a
    /// distributed fleet. This is the strongest vote-stuffing shape: the
    /// cohort never splits its own votes.
    CollusiveSignFlip,
    /// Adaptive straggler (protocol-level): submits its update
    /// `extra_ms` *after* the round deadline the coordinator announced,
    /// drawing a straggler mark and a typed `Late`/`BadRound` reject
    /// (`Late` if the round index is still current, `BadRound` once the
    /// coordinator has moved on). Honest gradient, hostile timing.
    Straggle { extra_ms: u64 },
    /// Equivocation (protocol-level): sends its honest update, then a
    /// duplicate of it, then a replay against a stale round index — each
    /// answered by a typed reject (`Duplicate`, `BadRound`/`Late`)
    /// without perturbing the accepted round state.
    Equivocate,
}

impl Attack {
    /// True for attacks enacted at the transport rather than on the
    /// gradient. Protocol attacks leave the gradient honest.
    pub fn is_protocol_level(&self) -> bool {
        matches!(self, Attack::Straggle { .. } | Attack::Equivocate)
    }
}

/// One attack bound to an explicit, sorted, deduplicated member set.
#[derive(Clone, Debug, PartialEq)]
pub struct Cohort {
    pub attack: Attack,
    /// Sorted worker ids this cohort controls.
    members: Vec<usize>,
    /// Seed for cohort-coordinated randomness (collusive direction).
    seed: u64,
}

impl Cohort {
    /// Cohort over an explicit member list (sorted + deduplicated).
    pub fn explicit(attack: Attack, mut members: Vec<usize>, seed: u64) -> Self {
        members.sort_unstable();
        members.dedup();
        Self { attack, members, seed }
    }

    /// The historical prefix form: workers `0..count`.
    pub fn prefix(attack: Attack, count: usize) -> Self {
        Self { attack, members: (0..count).collect(), seed: 0 }
    }

    /// Seeded random subset of `count` workers out of a population of
    /// `total` — the form that composes with non-IID partitions without
    /// always attacking the same data shards.
    pub fn sampled(attack: Attack, total: usize, count: usize, seed: u64) -> Self {
        assert!(count <= total, "cohort of {count} from {total} workers");
        let mut rng = Pcg64::new(seed, 0xc0_4072);
        Self { attack, members: rng.sample_indices(total, count), seed }
    }

    pub fn members(&self) -> &[usize] {
        &self.members
    }

    pub fn contains(&self, worker: usize) -> bool {
        self.members.binary_search(&worker).is_ok()
    }
}

/// Which workers are malicious and how: a composable set of disjoint
/// attack cohorts.
#[derive(Clone, Debug, PartialEq)]
pub struct AttackPlan {
    cohorts: Vec<Cohort>,
}

impl AttackPlan {
    /// Compat constructor: one cohort over the worker-id prefix
    /// `0..malicious` — the original `AttackPlan { attack, malicious }`
    /// semantics (the engine shuffles worker identity at partition time,
    /// so a prefix is *a* uniform subset, just always the same one).
    pub fn new(attack: Attack, malicious: usize) -> Self {
        Self { cohorts: vec![Cohort::prefix(attack, malicious)] }
    }

    /// One seeded-random cohort of `count` workers from `total`.
    pub fn sampled(attack: Attack, total: usize, count: usize, seed: u64) -> Self {
        Self { cohorts: vec![Cohort::sampled(attack, total, count, seed)] }
    }

    /// Compose multiple cohorts. Panics if any worker appears in two
    /// cohorts — a worker has one behaviour.
    pub fn composed(cohorts: Vec<Cohort>) -> Self {
        let mut all: Vec<usize> = cohorts.iter().flat_map(|c| c.members.iter().copied()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "attack cohorts must be disjoint");
        Self { cohorts }
    }

    pub fn cohorts(&self) -> &[Cohort] {
        &self.cohorts
    }

    pub fn is_malicious(&self, worker: usize) -> bool {
        self.cohorts.iter().any(|c| c.contains(worker))
    }

    /// The attack governing `worker`, if any.
    pub fn attack_of(&self, worker: usize) -> Option<Attack> {
        self.cohorts.iter().find(|c| c.contains(worker)).map(|c| c.attack)
    }

    /// Total malicious workers across all cohorts.
    pub fn malicious_count(&self) -> usize {
        self.cohorts.iter().map(|c| c.members.len()).sum()
    }

    /// True when any cohort misbehaves at the protocol level (the
    /// transport tests skip bit-identity diffs for these — timing and
    /// rejects are inherently nondeterministic).
    pub fn has_protocol_attacks(&self) -> bool {
        self.cohorts.iter().any(|c| c.attack.is_protocol_level())
    }

    /// Parse a CLI/config attack spec into a plan over `workers` ids.
    ///
    /// Grammar: comma-separated cohorts of `kind:count[:param]`, where
    /// `count` is an absolute worker count or a `P%` fraction of the
    /// population, and `param` is the kind's knob:
    ///
    /// | kind         | param (default)        |
    /// |--------------|------------------------|
    /// | `rescale`    | factor (`100`)         |
    /// | `signflip`   | —                      |
    /// | `garbage`    | magnitude (`1`)        |
    /// | `collusive`  | —                      |
    /// | `straggle`   | extra ms (`250`)       |
    /// | `equivocate` | —                      |
    ///
    /// e.g. `--attack collusive:30%` or `--attack signflip:8,equivocate:4`.
    /// Cohort membership is a seeded shuffle of the population carved into
    /// disjoint consecutive chunks, so composed specs never overlap and
    /// the same `(spec, workers, seed)` always yields the same plan on
    /// both sides of a wire run.
    pub fn parse(spec: &str, workers: usize, seed: u64) -> Result<Self, String> {
        let mut wants: Vec<(Attack, usize)> = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(format!("empty cohort in attack spec '{spec}'"));
            }
            let mut f = part.split(':');
            let kind = f.next().unwrap_or("");
            let count_s = f
                .next()
                .ok_or_else(|| format!("cohort '{part}' needs a count: kind:count[:param]"))?;
            let param = f.next();
            if f.next().is_some() {
                return Err(format!("too many ':' fields in cohort '{part}'"));
            }
            let count = if let Some(pct) = count_s.strip_suffix('%') {
                let p: f64 = pct
                    .parse()
                    .map_err(|_| format!("bad percentage '{count_s}' in cohort '{part}'"))?;
                if !(0.0..=100.0).contains(&p) {
                    return Err(format!("percentage '{count_s}' out of 0..=100"));
                }
                ((workers as f64 * p / 100.0).round() as usize).min(workers)
            } else {
                count_s
                    .parse()
                    .map_err(|_| format!("bad count '{count_s}' in cohort '{part}'"))?
            };
            let parse_param = |default: f64| -> Result<f64, String> {
                match param {
                    None => Ok(default),
                    Some(v) => v
                        .parse()
                        .map_err(|_| format!("bad parameter '{v}' in cohort '{part}'")),
                }
            };
            let attack = match kind {
                "rescale" => Attack::Rescale { factor: parse_param(100.0)? as f32 },
                "signflip" => Attack::SignFlip,
                "garbage" => Attack::Garbage { magnitude: parse_param(1.0)? as f32 },
                "collusive" => Attack::CollusiveSignFlip,
                "straggle" => Attack::Straggle { extra_ms: parse_param(250.0)? as u64 },
                "equivocate" => Attack::Equivocate,
                other => return Err(format!("unknown attack kind '{other}'")),
            };
            if param.is_some()
                && matches!(
                    attack,
                    Attack::SignFlip | Attack::CollusiveSignFlip | Attack::Equivocate
                )
            {
                return Err(format!("'{kind}' takes no parameter"));
            }
            wants.push((attack, count));
        }
        let total: usize = wants.iter().map(|(_, n)| n).sum();
        if total > workers {
            return Err(format!(
                "attack spec claims {total} workers but the population is {workers}"
            ));
        }
        // One seeded shuffle, carved into disjoint consecutive chunks.
        let mut ids: Vec<usize> = (0..workers).collect();
        let mut rng = Pcg64::new(seed ^ 0xbad_c0de, 0x900d);
        for i in (1..ids.len()).rev() {
            ids.swap(i, rng.index(i + 1));
        }
        let mut cohorts = Vec::new();
        let mut at = 0;
        for (i, (attack, n)) in wants.into_iter().enumerate() {
            cohorts.push(Cohort::explicit(
                attack,
                ids[at..at + n].to_vec(),
                seed.wrapping_add(i as u64),
            ));
            at += n;
        }
        Ok(AttackPlan::composed(cohorts))
    }

    /// Apply the gradient-level attack (if any) in place to `worker`'s
    /// round-`t` gradient. Protocol-level attacks leave the gradient
    /// untouched here — their misbehaviour happens at the transport.
    pub fn apply(&self, t: usize, worker: usize, g: &mut [f32], rng: &mut Pcg64) {
        let Some(cohort) = self.cohorts.iter().find(|c| c.contains(worker)) else {
            return;
        };
        match cohort.attack {
            Attack::Rescale { factor } => {
                for v in g.iter_mut() {
                    *v *= factor;
                }
            }
            Attack::SignFlip => {
                for v in g.iter_mut() {
                    *v = -*v;
                }
            }
            Attack::Garbage { magnitude } => {
                for v in g.iter_mut() {
                    *v = rng.normal_f32(0.0, magnitude);
                }
            }
            Attack::CollusiveSignFlip => {
                // Shared direction: every member derives the same stream
                // from (cohort seed, round) — coordination without
                // communication, identical across engines and fleets.
                let mut shared = Pcg64::new(cohort.seed ^ 0xc0_11_0d_e5, t as u64);
                let mut bits = 0u64;
                for (i, v) in g.iter_mut().enumerate() {
                    if i % 64 == 0 {
                        bits = shared.next_u64();
                    }
                    *v = if bits & 1 == 1 { 1.0 } else { -1.0 };
                    bits >>= 1;
                }
            }
            Attack::Straggle { .. } | Attack::Equivocate => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn rescale_only_hits_malicious() {
        let plan = AttackPlan::new(Attack::Rescale { factor: 100.0 }, 2);
        let mut rng = Pcg64::seed_from(1);
        let mut g = vec![1.0, -2.0];
        plan.apply(0, 1, &mut g, &mut rng);
        assert_eq!(g, vec![100.0, -200.0]);
        let mut g2 = vec![1.0, -2.0];
        plan.apply(0, 2, &mut g2, &mut rng);
        assert_eq!(g2, vec![1.0, -2.0]);
    }

    #[test]
    fn sign_flip() {
        let plan = AttackPlan::new(Attack::SignFlip, 1);
        let mut rng = Pcg64::seed_from(2);
        let mut g = vec![1.0, -2.0, 0.0];
        plan.apply(3, 0, &mut g, &mut rng);
        assert_eq!(g, vec![-1.0, 2.0, 0.0]);
    }

    #[test]
    fn garbage_replaces_gradient() {
        let plan = AttackPlan::new(Attack::Garbage { magnitude: 5.0 }, 1);
        let mut rng = Pcg64::seed_from(3);
        let mut g = vec![1.0; 64];
        plan.apply(0, 0, &mut g, &mut rng);
        assert!(g.iter().any(|&v| v != 1.0));
    }

    #[test]
    fn sampled_cohort_is_seeded_subset_not_prefix() {
        let a = Cohort::sampled(Attack::SignFlip, 100, 20, 7);
        let b = Cohort::sampled(Attack::SignFlip, 100, 20, 7);
        let c = Cohort::sampled(Attack::SignFlip, 100, 20, 8);
        assert_eq!(a, b, "seeded cohort must be deterministic");
        assert_ne!(a.members(), c.members(), "different seeds, different cohorts");
        assert_eq!(a.members().len(), 20);
        for w in a.members().windows(2) {
            assert!(w[0] < w[1]);
        }
        // Not the prefix (overwhelmingly likely for any decent sampler).
        assert_ne!(a.members(), (0..20).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn collusive_members_share_the_round_direction() {
        let plan = AttackPlan::sampled(Attack::CollusiveSignFlip, 10, 4, 5);
        let members: Vec<usize> = plan.cohorts()[0].members().to_vec();
        let mut rng = Pcg64::seed_from(4);
        let mut first: Option<Vec<f32>> = None;
        for &w in &members {
            let mut g = vec![0.5; 100];
            plan.apply(3, w, &mut g, &mut rng);
            assert!(g.iter().all(|&v| v == 1.0 || v == -1.0));
            match &first {
                None => first = Some(g),
                Some(f) => assert_eq!(&g, f, "cohort members must agree on the direction"),
            }
        }
        // Different rounds get different directions.
        let w = members[0];
        let mut g3 = vec![0.5; 100];
        let mut g4 = vec![0.5; 100];
        plan.apply(3, w, &mut g3, &mut rng);
        plan.apply(4, w, &mut g4, &mut rng);
        assert_ne!(g3, g4);
    }

    #[test]
    fn protocol_attacks_leave_the_gradient_honest() {
        for attack in [Attack::Straggle { extra_ms: 50 }, Attack::Equivocate] {
            let plan = AttackPlan::new(attack, 2);
            assert!(plan.has_protocol_attacks());
            let mut rng = Pcg64::seed_from(6);
            let mut g = vec![1.0, -2.0, 3.0];
            plan.apply(0, 1, &mut g, &mut rng);
            assert_eq!(g, vec![1.0, -2.0, 3.0]);
        }
        assert!(!AttackPlan::new(Attack::SignFlip, 2).has_protocol_attacks());
    }

    #[test]
    fn composed_cohorts_dispatch_by_membership() {
        let plan = AttackPlan::composed(vec![
            Cohort::explicit(Attack::SignFlip, vec![0, 2], 1),
            Cohort::explicit(Attack::Rescale { factor: 10.0 }, vec![5], 1),
        ]);
        assert_eq!(plan.attack_of(2), Some(Attack::SignFlip));
        assert_eq!(plan.attack_of(5), Some(Attack::Rescale { factor: 10.0 }));
        assert_eq!(plan.attack_of(1), None);
        assert_eq!(plan.malicious_count(), 3);
        let mut rng = Pcg64::seed_from(7);
        let mut g = vec![1.0];
        plan.apply(0, 5, &mut g, &mut rng);
        assert_eq!(g, vec![10.0]);
    }

    #[test]
    fn parse_builds_disjoint_seeded_cohorts() {
        let plan = AttackPlan::parse("collusive:30%,equivocate:4", 100, 7).expect("parse");
        assert_eq!(plan.cohorts().len(), 2);
        assert_eq!(plan.cohorts()[0].attack, Attack::CollusiveSignFlip);
        assert_eq!(plan.cohorts()[0].members().len(), 30);
        assert_eq!(plan.cohorts()[1].attack, Attack::Equivocate);
        assert_eq!(plan.cohorts()[1].members().len(), 4);
        assert_eq!(plan.malicious_count(), 34);
        // Deterministic in (spec, workers, seed); seed moves the cohorts.
        assert_eq!(plan, AttackPlan::parse("collusive:30%,equivocate:4", 100, 7).unwrap());
        assert_ne!(plan, AttackPlan::parse("collusive:30%,equivocate:4", 100, 8).unwrap());
        // Not the id prefix: membership comes from a shuffle.
        assert_ne!(plan.cohorts()[0].members(), (0..30).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn parse_reads_parameters_and_defaults() {
        let plan = AttackPlan::parse("rescale:2:1e4", 10, 0).unwrap();
        assert_eq!(plan.cohorts()[0].attack, Attack::Rescale { factor: 1e4 });
        let plan = AttackPlan::parse("straggle:1", 10, 0).unwrap();
        assert_eq!(plan.cohorts()[0].attack, Attack::Straggle { extra_ms: 250 });
        let plan = AttackPlan::parse("garbage:1:5", 10, 0).unwrap();
        assert_eq!(plan.cohorts()[0].attack, Attack::Garbage { magnitude: 5.0 });
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "signflip",
            "signflip:2:9",
            "collusive:30%:1",
            "equivocate:1:1",
            "warp:3",
            "signflip:200%",
            "signflip:7,rescale:5:10", // 12 > 10 workers
            "rescale:1:abc",
            "signflip:x",
        ] {
            assert!(AttackPlan::parse(bad, 10, 0).is_err(), "spec '{bad}' should be refused");
        }
    }

    #[test]
    #[should_panic(expected = "disjoint")]
    fn overlapping_cohorts_are_refused() {
        AttackPlan::composed(vec![
            Cohort::explicit(Attack::SignFlip, vec![0, 1], 1),
            Cohort::explicit(Attack::Equivocate, vec![1, 2], 1),
        ]);
    }
}
