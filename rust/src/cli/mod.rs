//! Minimal dependency-free CLI argument parser (the launcher's substrate;
//! no `clap` in the offline registry).
//!
//! Grammar: `binary <subcommand> [positional…] [--flag value | --switch]`.
//! A `--flag` followed by another `--…` token (or end of argv) is treated
//! as a boolean switch.
//!
//! [`ArgMap`] is the untyped substrate; the per-subcommand option
//! structs in [`opts`] are the real surface — they validate every flag
//! in one place and reject unknown ones with a typed [`opts::CliError`].

pub mod opts;

use std::collections::HashMap;

/// Parsed argument bag.
#[derive(Clone, Debug, Default)]
pub struct ArgMap {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl ArgMap {
    /// Parse from an argv iterator (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = ArgMap::default();
        let argv: Vec<String> = args.into_iter().collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let has_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if has_value {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                if out.subcommand.is_none() && out.positional.is_empty() {
                    out.subcommand = Some(a.clone());
                } else {
                    out.positional.push(a.clone());
                }
                i += 1;
            }
        }
        out
    }

    /// Parse the real process argv.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Typed flag lookup with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Required typed flag.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T, String> {
        let v = self
            .flags
            .get(name)
            .ok_or_else(|| format!("missing required flag --{name}"))?;
        v.parse()
            .map_err(|_| format!("flag --{name}: invalid value '{v}'"))
    }

    /// Raw string flag.
    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Raw string flag with a default (the `serve`/`fleet` launchers'
    /// endpoint and transport flags are string-typed).
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get_str(name).unwrap_or(default)
    }

    /// Boolean switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    /// All `--key value` pairs (for config override forwarding).
    pub fn flag_pairs(&self) -> impl Iterator<Item = (&str, &str)> {
        self.flags.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Every flag/switch name the caller passed (for unknown-flag
    /// rejection in [`opts`]).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.flags.keys().map(|k| k.as_str()).chain(self.switches.iter().map(|s| s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> ArgMap {
        ArgMap::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --rounds 100 --fast --alpha 0.1 extra");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get::<usize>("rounds", 0), 100);
        assert_eq!(a.get::<f64>("alpha", 1.0), 0.1);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("bench");
        assert_eq!(a.get::<usize>("rounds", 7), 7);
        assert!(a.require::<usize>("rounds").is_err());
        let b = parse("bench --rounds nope");
        assert!(b.require::<usize>("rounds").is_err());
    }

    #[test]
    fn str_or_defaults() {
        let a = parse("fleet --transport uds");
        assert_eq!(a.str_or("transport", "tcp"), "uds");
        assert_eq!(a.str_or("addr", "127.0.0.1:0"), "127.0.0.1:0");
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("run --verbose --lr 0.5");
        assert!(a.has("verbose"));
        assert_eq!(a.get::<f64>("lr", 0.0), 0.5);
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert!(a.positional.is_empty());
    }
}
