//! Typed per-subcommand option structs — the launcher's real CLI
//! surface.
//!
//! [`ArgMap`] is a string bag; every subcommand used to fish its flags
//! out of it ad hoc, which meant three copies of the compressor
//! grammar, silent fallback to defaults on unparseable values, and no
//! notion of an *unknown* flag (a typo like `--round 5` just vanished).
//! The structs here parse and validate in one place:
//!
//! * every subcommand rejects flags outside its declared set with a
//!   typed [`CliError::UnknownFlag`];
//! * an unparseable value is a typed [`CliError::Invalid`], never a
//!   silent default;
//! * the rules both sides of a distributed run must agree on — the
//!   `--data`-vs-shape-flag conflict, the compressor/aggregation
//!   grammar, `--attack`/`--selection`/`--faults` parsing — live once,
//!   in [`NetRunOpts`], and `serve`/`fleet`/`shard` all embed it.
//!
//! The launcher maps a `CliError` to `eprintln!` + exit 2, exactly the
//! contract the ad-hoc code had; embedders get the typed value.

use crate::cli::ArgMap;
use crate::compressors::{CompressorKind, NormKind};
use crate::config::parse_selection;
use crate::coordinator::{AggregationRule, SelectionMode};
use crate::net::{Endpoint, FaultPlan};
use std::time::Duration;

/// Why a command line was refused. `Display` renders the operator-facing
/// message (no prefix — the launcher adds none either).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CliError {
    /// A flag the subcommand does not declare (typos included).
    UnknownFlag { subcommand: String, flag: String },
    /// A declared flag with an unparseable or out-of-range value.
    Invalid(String),
    /// Two flags that cannot be combined.
    Conflict(String),
    /// A required flag or companion flag is absent.
    Missing(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag { subcommand, flag } => {
                write!(f, "{subcommand}: unknown flag --{flag} (run `sparsignd` for the flag list)")
            }
            CliError::Invalid(s) | CliError::Conflict(s) | CliError::Missing(s) => {
                write!(f, "{s}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// The flags every net-facing subcommand (`serve`/`fleet`/`shard`, and
/// `soak`'s forwarded set) shares via [`NetRunOpts`].
pub const NET_RUN_FLAGS: &[&str] = &[
    "clients",
    "rounds",
    "dim",
    "classes",
    "batch",
    "alpha",
    "seed",
    "lr",
    "participation",
    "eval-every",
    "compressor",
    "budget",
    "b",
    "levels",
    "aggregation",
    "data",
    "hidden",
    "attack",
    "selection",
    "faults",
    "fault-seed",
];

/// Reject any flag outside the union of `lists`.
fn reject_unknown(args: &ArgMap, subcommand: &str, lists: &[&[&str]]) -> Result<(), CliError> {
    for name in args.names() {
        if !lists.iter().any(|l| l.contains(&name)) {
            return Err(CliError::UnknownFlag {
                subcommand: subcommand.to_string(),
                flag: name.to_string(),
            });
        }
    }
    Ok(())
}

/// Unknown-flag check for subcommands simple enough to keep reading
/// `ArgMap` directly (`tables`, `fig1`, `theory`, …).
pub fn check_known(args: &ArgMap, subcommand: &str, allowed: &[&str]) -> Result<(), CliError> {
    reject_unknown(args, subcommand, &[allowed])
}

/// Typed flag with default; an unparseable value is an error, not the
/// default (the one behavioral difference from `ArgMap::get`).
fn parsed<T: std::str::FromStr>(args: &ArgMap, name: &str, default: T) -> Result<T, CliError> {
    match args.get_str(name) {
        None => Ok(default),
        Some(v) => {
            v.parse().map_err(|_| CliError::Invalid(format!("flag --{name}: invalid value '{v}'")))
        }
    }
}

/// Optional typed flag (no default).
fn parsed_opt<T: std::str::FromStr>(args: &ArgMap, name: &str) -> Result<Option<T>, CliError> {
    match args.get_str(name) {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| CliError::Invalid(format!("flag --{name}: invalid value '{v}'"))),
    }
}

fn parse_endpoint(args: &ArgMap, name: &str, default: &str) -> Result<Endpoint, CliError> {
    Endpoint::parse(args.str_or(name, default)).map_err(|e| CliError::Invalid(e.to_string()))
}

/// Parse `--hidden h1,h2,…` into MLP layer widths.
pub fn parse_hidden(spec: &str) -> Result<Vec<usize>, CliError> {
    spec.split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<usize>().map_err(|_| CliError::Invalid(format!("--hidden: bad width '{t}'")))
        })
        .collect()
}

/// The run shape both sides of a distributed run must agree on: the
/// dataset/partition knobs (or the `--data` store that pins them), the
/// compression and aggregation grammar, and the Byzantine/fault specs.
#[derive(Clone, Debug)]
pub struct NetRunOpts {
    pub clients: usize,
    pub rounds: usize,
    pub dim: usize,
    pub classes: usize,
    pub batch: usize,
    pub alpha: f64,
    pub seed: u64,
    pub lr: f64,
    pub participation: f64,
    pub eval_every: usize,
    pub compressor: CompressorKind,
    pub aggregation: AggregationRule,
    /// `--data F.sgds` — the store pins dataset, partition, and client
    /// count; shape flags conflict with it (checked here, once).
    pub data: Option<String>,
    pub hidden: Vec<usize>,
    /// Raw `--attack SPEC`; parsed into an `AttackPlan` only after the
    /// environment fixes the cohort size.
    pub attack: Option<String>,
    pub selection: SelectionMode,
    /// Whether `--clients` was passed explicitly (a store-backed run
    /// cross-checks it against the store's shard count).
    pub explicit_clients: bool,
    pub faults: Option<FaultPlan>,
}

impl NetRunOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        let clients = parsed(args, "clients", 64usize)?;
        let rounds = parsed(args, "rounds", 3usize)?;
        let dim = parsed(args, "dim", 16usize)?;
        let classes = parsed(args, "classes", 3usize)?;
        let batch = parsed(args, "batch", 16usize)?;
        let alpha = parsed(args, "alpha", 0.5f64)?;
        let seed = parsed(args, "seed", 7u64)?;
        let lr = parsed(args, "lr", 0.05f64)?;
        let participation = parsed(args, "participation", 1.0f64)?;
        let eval_every = parsed(args, "eval-every", 0usize)?;
        if clients == 0 || rounds == 0 {
            return Err(CliError::Invalid("--clients and --rounds must be positive".into()));
        }

        let compressor = match args.str_or("compressor", "sign") {
            "sign" => CompressorKind::Sign,
            "scaledsign" => CompressorKind::ScaledSign,
            "sparsign" => CompressorKind::Sparsign { budget: parsed(args, "budget", 1.0f32)? },
            "stosign" => CompressorKind::StoSign { b: parsed(args, "b", 2.0f32)? },
            "terngrad" => CompressorKind::TernGrad,
            "qsgd" => {
                CompressorKind::Qsgd { levels: parsed(args, "levels", 255u32)?, norm: NormKind::L2 }
            }
            "identity" => CompressorKind::Identity,
            other => return Err(CliError::Invalid(format!("unknown --compressor '{other}'"))),
        };
        let aggregation = match args.str_or("aggregation", "vote") {
            "vote" => AggregationRule::MajorityVote,
            "scaledsign" => AggregationRule::ScaledSign,
            "mean" => AggregationRule::Mean,
            other => return Err(CliError::Invalid(format!("unknown --aggregation '{other}'"))),
        };

        let data = args.get_str("data").map(String::from);
        if data.is_some() {
            // The store pins the dataset and partition; a shape flag
            // would silently disagree with what every other process in
            // the run streams.
            for k in ["dim", "classes", "alpha"] {
                if args.has(k) {
                    return Err(CliError::Conflict(format!(
                        "--{k} conflicts with --data (the store pins the dataset and partition)"
                    )));
                }
            }
        }
        let hidden = args.get_str("hidden").map(parse_hidden).transpose()?.unwrap_or_default();
        let attack = args.get_str("attack").map(String::from);
        let selection =
            parse_selection(args.str_or("selection", "legacy")).map_err(CliError::Invalid)?;
        let faults = match args.get_str("faults") {
            None => None,
            Some(spec) => Some(
                FaultPlan::parse(spec, parsed(args, "fault-seed", 7u64)?)
                    .map_err(|e| CliError::Invalid(format!("--faults: {e}")))?,
            ),
        };
        Ok(NetRunOpts {
            clients,
            rounds,
            dim,
            classes,
            batch,
            alpha,
            seed,
            lr,
            participation,
            eval_every,
            compressor,
            aggregation,
            data,
            hidden,
            attack,
            selection,
            explicit_clients: args.has("clients"),
            faults,
        })
    }
}

/// `train` — launcher-level flags plus the free-form config overrides
/// (`--rounds 100 --alpha 0.1 …`), which `ExperimentConfig` validates
/// key-by-key (its own typed unknown-key rejection).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub config: Option<String>,
    pub data: Option<String>,
    pub hidden: Vec<usize>,
    /// Every remaining `--key value` pair, forwarded to
    /// `ExperimentConfig::apply_override`.
    pub overrides: Vec<(String, String)>,
}

impl TrainOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        let hidden = args.get_str("hidden").map(parse_hidden).transpose()?.unwrap_or_default();
        let overrides = args
            .flag_pairs()
            .filter(|(k, _)| {
                !matches!(*k, "preset" | "only" | "csv" | "trials" | "config" | "data" | "hidden")
            })
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        Ok(TrainOpts {
            config: args.get_str("config").map(String::from),
            data: args.get_str("data").map(String::from),
            hidden,
            overrides,
        })
    }
}

const SERVE_FLAGS: &[&str] = &[
    "addr",
    "deadline-ms",
    "rendezvous-secs",
    "drain-after",
    "snapshot",
    "snapshot-every",
    "event-log",
    "heal-attempts",
    "resume",
    "shards",
    "endpoint-file",
    "history-json",
    "metrics-addr",
    "metrics-linger-ms",
];

/// `serve` — the root coordinator launcher.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub run: NetRunOpts,
    pub addr: Endpoint,
    pub round_deadline: Option<Duration>,
    pub rendezvous_timeout: Duration,
    pub drain_after: Option<usize>,
    /// `(path, every)`; `every == 0` means write-on-drain only, which
    /// requires `drain_after` (validated here).
    pub snapshot: Option<(String, usize)>,
    pub event_log: Option<String>,
    pub heal_attempts: Option<usize>,
    pub resume: Option<String>,
    pub shards: usize,
    pub endpoint_file: Option<String>,
    pub history_json: Option<String>,
    /// `--metrics-addr EP`: serve `GET /metrics` + `GET /healthz` here
    /// (and give each in-process shard its own derived scrape port).
    pub metrics_addr: Option<Endpoint>,
    /// `--metrics-linger-ms D`: keep answering scrapes for `D` after
    /// the final round so an end-of-run scrape can observe the totals.
    pub metrics_linger: Option<Duration>,
}

impl ServeOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        reject_unknown(args, "serve", &[NET_RUN_FLAGS, SERVE_FLAGS])?;
        let run = NetRunOpts::from_args(args)?;
        let addr = parse_endpoint(args, "addr", "tcp://127.0.0.1:7070")?;
        let deadline_ms = parsed(args, "deadline-ms", 0u64)?;
        let drain_after = match parsed(args, "drain-after", 0usize)? {
            0 => None,
            n => Some(n),
        };
        let snapshot = match args.get_str("snapshot") {
            None => None,
            Some(path) => {
                let every = parsed(args, "snapshot-every", 0usize)?;
                // every = 0 means "write on drain only"; without a
                // drain trigger such a policy can never fire — refuse
                // rather than hand the operator crash protection that
                // silently does nothing.
                if every == 0 && drain_after.is_none() {
                    return Err(CliError::Missing(
                        "--snapshot needs a trigger: add --snapshot-every K (periodic) \
                         and/or --drain-after N (write on drain)"
                            .into(),
                    ));
                }
                Some((path.to_string(), every))
            }
        };
        let metrics_linger = match parsed(args, "metrics-linger-ms", 0u64)? {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        Ok(ServeOpts {
            run,
            addr,
            round_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
            rendezvous_timeout: Duration::from_secs(parsed(args, "rendezvous-secs", 120u64)?),
            drain_after,
            snapshot,
            event_log: args.get_str("event-log").map(String::from),
            heal_attempts: match parsed(args, "heal-attempts", 0usize)? {
                0 => None,
                n => Some(n),
            },
            resume: args.get_str("resume").map(String::from),
            shards: parsed(args, "shards", 0usize)?,
            endpoint_file: args.get_str("endpoint-file").map(String::from),
            history_json: args.get_str("history-json").map(String::from),
            metrics_addr: match args.get_str("metrics-addr") {
                None => None,
                Some(_) => Some(parse_endpoint(args, "metrics-addr", "")?),
            },
            metrics_linger,
        })
    }
}

const FLEET_FLAGS: &[&str] = &[
    "agents",
    "shard-line",
    "shard-count",
    "connect",
    "connect-file",
    "via-shards",
    "reconnect-secs",
    "transport",
    "shards",
    "deadline-ms",
];

/// How a `fleet` invocation finds its coordinator(s).
#[derive(Clone, Debug)]
pub enum FleetMode {
    /// `--shard-line I --shard-count K --connect-file F`: serve worker
    /// slice I of a K-shard tree, dialing line `1 + I` of the file.
    ShardLine { file: String, index: usize, count: usize },
    /// `--via-shards --connect-file F`: split the fleet over every
    /// shard line of the endpoint file.
    ViaShards { file: String },
    /// `--connect-file F`: dial line 0, re-reading on every reconnect.
    ConnectFile { file: String },
    /// `--connect EP`: dial a fixed endpoint.
    Connect { addr: Endpoint },
    /// Default: self-contained loopback run diffed against the
    /// in-process engine.
    Loopback { uds: bool, shards: usize, deadline_ms: u64 },
}

/// `fleet` — the client-fleet launcher.
#[derive(Clone, Debug)]
pub struct FleetOpts {
    pub run: NetRunOpts,
    pub agents: Option<usize>,
    pub reconnect_secs: u64,
    pub mode: FleetMode,
}

impl FleetOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        reject_unknown(args, "fleet", &[NET_RUN_FLAGS, FLEET_FLAGS])?;
        let run = NetRunOpts::from_args(args)?;
        let mode = if args.has("shard-line") {
            let Some(file) = args.get_str("connect-file") else {
                return Err(CliError::Missing(
                    "--shard-line needs --connect-file (line 0 root, line 1 + i shard i)".into(),
                ));
            };
            let index = parsed(args, "shard-line", 0usize)?;
            let count = parsed(args, "shard-count", 0usize)?;
            if count == 0 || index >= count {
                return Err(CliError::Invalid(format!(
                    "--shard-line {index} needs --shard-count K with I < K"
                )));
            }
            FleetMode::ShardLine { file: file.to_string(), index, count }
        } else if args.has("via-shards") {
            let Some(file) = args.get_str("connect-file") else {
                return Err(CliError::Missing(
                    "--via-shards needs --connect-file (the endpoint layout \
                     written by `serve --shards N --endpoint-file F`)"
                        .into(),
                ));
            };
            FleetMode::ViaShards { file: file.to_string() }
        } else if let Some(file) = args.get_str("connect-file") {
            FleetMode::ConnectFile { file: file.to_string() }
        } else if args.get_str("connect").is_some() {
            FleetMode::Connect { addr: parse_endpoint(args, "connect", "")? }
        } else {
            FleetMode::Loopback {
                uds: args.str_or("transport", "tcp") == "uds",
                shards: parsed(args, "shards", 0usize)?,
                deadline_ms: parsed(args, "deadline-ms", 2_000u64)?,
            }
        };
        Ok(FleetOpts {
            run,
            agents: parsed_opt::<usize>(args, "agents")?.map(|a| a.max(1)),
            reconnect_secs: parsed(args, "reconnect-secs", 60u64)?,
            mode,
        })
    }
}

const SHARD_FLAGS: &[&str] = &[
    "index",
    "shard-count",
    "listen",
    "connect",
    "connect-file",
    "reconnect-secs",
    "rendezvous-secs",
    "deadline-ms",
    "publish-file",
    "metrics-addr",
];

/// Where a standalone shard finds its root.
#[derive(Clone, Debug)]
pub enum ShardUpstream {
    /// `--connect-file F`: line 0, re-read on every (re)connect.
    File { file: String },
    /// `--connect EP`: a fixed address.
    Addr { addr: Endpoint },
}

/// `shard` — one aggregator shard as its own OS process.
#[derive(Clone, Debug)]
pub struct ShardOpts {
    pub run: NetRunOpts,
    pub index: usize,
    pub shard_count: usize,
    pub listen: Endpoint,
    pub upstream: ShardUpstream,
    pub reconnect_secs: u64,
    pub rendezvous_secs: u64,
    pub deadline_ms: u64,
    pub publish_file: Option<String>,
    pub metrics_addr: Option<Endpoint>,
}

impl ShardOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        reject_unknown(args, "shard", &[NET_RUN_FLAGS, SHARD_FLAGS])?;
        let run = NetRunOpts::from_args(args)?;
        let index = parsed(args, "index", 0usize)?;
        let shard_count = parsed(args, "shard-count", 0usize)?;
        if shard_count == 0 || index >= shard_count {
            return Err(CliError::Invalid(
                "shard needs --index I --shard-count K with I < K".into(),
            ));
        }
        let upstream = if let Some(file) = args.get_str("connect-file") {
            ShardUpstream::File { file: file.to_string() }
        } else if args.get_str("connect").is_some() {
            ShardUpstream::Addr { addr: parse_endpoint(args, "connect", "")? }
        } else {
            return Err(CliError::Missing("shard needs --connect EP or --connect-file F".into()));
        };
        Ok(ShardOpts {
            run,
            index,
            shard_count,
            listen: parse_endpoint(args, "listen", "tcp://127.0.0.1:0")?,
            upstream,
            reconnect_secs: parsed(args, "reconnect-secs", 60u64)?,
            rendezvous_secs: parsed(args, "rendezvous-secs", 120u64)?,
            deadline_ms: parsed(args, "deadline-ms", 0u64)?,
            publish_file: args.get_str("publish-file").map(String::from),
            metrics_addr: match args.get_str("metrics-addr") {
                None => None,
                Some(_) => Some(parse_endpoint(args, "metrics-addr", "")?),
            },
        })
    }
}

const SOAK_FLAGS: &[&str] = &[
    "dir",
    "rounds",
    "clients",
    "shards",
    "faults",
    "fault-seed",
    "transport",
    "heal-attempts",
    "reconnect-secs",
    "timeout-secs",
];

/// Flags `soak` forwards verbatim to every child process (the children
/// rebuild the same environment from the same flags, exactly as a
/// distributed serve/fleet pair does).
pub const SOAK_PASS_KEYS: &[&str] = &[
    "dim",
    "classes",
    "batch",
    "alpha",
    "seed",
    "lr",
    "participation",
    "eval-every",
    "selection",
    "compressor",
    "aggregation",
    "data",
    "hidden",
];

/// `soak` — the churn-soak supervisor. `None` fields keep the
/// `net::SoakOptions` defaults.
#[derive(Clone, Debug)]
pub struct SoakOpts {
    pub dir: String,
    pub rounds: Option<usize>,
    pub clients: Option<usize>,
    pub shards: Option<usize>,
    pub faults: Option<String>,
    pub fault_seed: Option<u64>,
    pub uds: bool,
    pub heal_attempts: Option<usize>,
    pub reconnect_secs: Option<u64>,
    pub timeout_secs: u64,
    pub pass: Vec<(String, String)>,
}

impl SoakOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        reject_unknown(args, "soak", &[SOAK_FLAGS, SOAK_PASS_KEYS])?;
        let mut pass = Vec::new();
        for &key in SOAK_PASS_KEYS {
            if let Some(v) = args.get_str(key) {
                pass.push((key.to_string(), v.to_string()));
            }
        }
        Ok(SoakOpts {
            dir: args.str_or("dir", "target/soak").to_string(),
            rounds: parsed_opt(args, "rounds")?,
            clients: parsed_opt(args, "clients")?,
            shards: parsed_opt::<usize>(args, "shards")?.map(|s| s.max(1)),
            faults: args.get_str("faults").map(String::from),
            fault_seed: parsed_opt(args, "fault-seed")?,
            uds: args.str_or("transport", "tcp") == "uds",
            heal_attempts: parsed_opt(args, "heal-attempts")?,
            reconnect_secs: parsed_opt(args, "reconnect-secs")?,
            timeout_secs: parsed(args, "timeout-secs", 600u64)?,
            pass,
        })
    }
}

const PARITY_FLAGS: &[&str] = &[
    "data",
    "dataset",
    "algs",
    "rounds",
    "batch",
    "eval-every",
    "trials",
    "hidden",
    "csv",
    "min-acc",
];

/// `parity` — the paper-parity sweep over a streamed `.sgds` store.
#[derive(Clone, Debug)]
pub struct ParityOpts {
    pub data: String,
    pub dataset: String,
    pub algs: Option<Vec<String>>,
    pub rounds: Option<usize>,
    pub batch: Option<usize>,
    pub eval_every: Option<usize>,
    pub trials: Option<usize>,
    pub hidden: Vec<usize>,
    pub csv: Option<String>,
    pub min_acc: f64,
}

impl ParityOpts {
    pub fn from_args(args: &ArgMap) -> Result<Self, CliError> {
        reject_unknown(args, "parity", &[PARITY_FLAGS])?;
        let Some(data) = args.get_str("data") else {
            return Err(CliError::Missing(
                "parity needs --data F.sgds (build one with `dataset convert`)".into(),
            ));
        };
        let algs = args.get_str("algs").map(|spec| {
            spec.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).map(String::from).collect()
        });
        Ok(ParityOpts {
            data: data.to_string(),
            dataset: args.str_or("dataset", "fmnist").to_string(),
            algs,
            rounds: parsed_opt(args, "rounds")?,
            batch: parsed_opt(args, "batch")?,
            eval_every: parsed_opt(args, "eval-every")?,
            trials: parsed_opt::<usize>(args, "trials")?.map(|t| t.max(1)),
            hidden: args.get_str("hidden").map(parse_hidden).transpose()?.unwrap_or_default(),
            csv: args.get_str("csv").map(String::from),
            min_acc: parsed(args, "min-acc", 0.0f64)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn am(s: &str) -> ArgMap {
        ArgMap::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn unknown_flags_are_typed_errors() {
        let err = ServeOpts::from_args(&am("serve --adres tcp://h:1")).unwrap_err();
        assert_eq!(
            err,
            CliError::UnknownFlag { subcommand: "serve".into(), flag: "adres".into() }
        );
        assert!(err.to_string().contains("--adres"));
        // Switch-shaped typos are caught too (`--via-shard` would have
        // vanished silently under the old ArgMap lookups).
        let err = FleetOpts::from_args(&am("fleet --via-shard")).unwrap_err();
        assert!(matches!(err, CliError::UnknownFlag { ref flag, .. } if flag == "via-shard"));
    }

    #[test]
    fn unparseable_values_are_errors_not_defaults() {
        let err = FleetOpts::from_args(&am("fleet --rounds nope")).unwrap_err();
        assert!(matches!(err, CliError::Invalid(ref s) if s.contains("--rounds")), "{err}");
        let err = ServeOpts::from_args(&am("serve --deadline-ms -5")).unwrap_err();
        assert!(matches!(err, CliError::Invalid(_)), "{err}");
    }

    #[test]
    fn data_conflicts_with_shape_flags() {
        let err = FleetOpts::from_args(&am("fleet --data t.sgds --alpha 0.1")).unwrap_err();
        assert_eq!(
            err,
            CliError::Conflict(
                "--alpha conflicts with --data (the store pins the dataset and partition)".into()
            )
        );
        // --clients is allowed alongside --data (cross-checked against
        // the store later), and the shape flags parse fine without it.
        assert!(FleetOpts::from_args(&am("fleet --data t.sgds --clients 64")).is_ok());
        assert!(FleetOpts::from_args(&am("fleet --dim 32 --alpha 0.1")).is_ok());
    }

    #[test]
    fn compressor_and_aggregation_grammar() {
        let o = NetRunOpts::from_args(&am("fleet --compressor sparsign --budget 0.5")).unwrap();
        assert_eq!(o.compressor, CompressorKind::Sparsign { budget: 0.5 });
        let o = NetRunOpts::from_args(&am("fleet --compressor qsgd --levels 15")).unwrap();
        assert!(matches!(o.compressor, CompressorKind::Qsgd { levels: 15, .. }));
        let o = NetRunOpts::from_args(&am("fleet --aggregation mean")).unwrap();
        assert_eq!(o.aggregation, AggregationRule::Mean);
        let err = NetRunOpts::from_args(&am("fleet --compressor zip")).unwrap_err();
        assert_eq!(err, CliError::Invalid("unknown --compressor 'zip'".into()));
    }

    #[test]
    fn fleet_mode_precedence_matches_the_launcher() {
        let o = FleetOpts::from_args(&am(
            "fleet --shard-line 1 --shard-count 2 --connect-file ep.txt --via-shards",
        ))
        .unwrap();
        assert!(matches!(o.mode, FleetMode::ShardLine { index: 1, count: 2, .. }));
        let o = FleetOpts::from_args(&am("fleet --via-shards --connect-file ep.txt")).unwrap();
        assert!(matches!(o.mode, FleetMode::ViaShards { .. }));
        let o = FleetOpts::from_args(&am("fleet --connect tcp://h:1")).unwrap();
        assert!(matches!(o.mode, FleetMode::Connect { .. }));
        let o = FleetOpts::from_args(&am("fleet --transport uds --shards 2")).unwrap();
        assert!(matches!(o.mode, FleetMode::Loopback { uds: true, shards: 2, .. }));
        // Companion-flag validation.
        let err = FleetOpts::from_args(&am("fleet --via-shards")).unwrap_err();
        assert!(matches!(err, CliError::Missing(_)));
        let err =
            FleetOpts::from_args(&am("fleet --shard-line 2 --shard-count 2 --connect-file f"))
                .unwrap_err();
        assert!(matches!(err, CliError::Invalid(_)));
    }

    #[test]
    fn serve_snapshot_needs_a_trigger() {
        let err = ServeOpts::from_args(&am("serve --snapshot snap.bin")).unwrap_err();
        assert!(matches!(err, CliError::Missing(ref s) if s.contains("--snapshot-every")));
        assert!(ServeOpts::from_args(&am("serve --snapshot snap.bin --snapshot-every 3")).is_ok());
        assert!(ServeOpts::from_args(&am("serve --snapshot snap.bin --drain-after 5")).is_ok());
    }

    #[test]
    fn serve_parses_metrics_flags() {
        let o = ServeOpts::from_args(&am(
            "serve --metrics-addr tcp://127.0.0.1:9464 --metrics-linger-ms 1500",
        ))
        .unwrap();
        assert_eq!(o.metrics_addr, Some(Endpoint::Tcp("127.0.0.1:9464".into())));
        assert_eq!(o.metrics_linger, Some(Duration::from_millis(1500)));
        let o = ServeOpts::from_args(&am("serve")).unwrap();
        assert!(o.metrics_addr.is_none() && o.metrics_linger.is_none());
    }

    #[test]
    fn shard_and_soak_validate() {
        let err = ShardOpts::from_args(&am("shard --index 0 --shard-count 2")).unwrap_err();
        assert!(matches!(err, CliError::Missing(ref s) if s.contains("--connect")));
        let o = ShardOpts::from_args(&am(
            "shard --index 1 --shard-count 2 --connect-file ep.txt --metrics-addr tcp://h:0",
        ))
        .unwrap();
        assert!(matches!(o.upstream, ShardUpstream::File { .. }));
        assert!(o.metrics_addr.is_some());
        let o = SoakOpts::from_args(&am("soak --rounds 40 --seed 7 --transport uds")).unwrap();
        assert_eq!(o.rounds, Some(40));
        assert!(o.uds);
        assert_eq!(o.pass, vec![("seed".to_string(), "7".to_string())]);
    }

    #[test]
    fn parity_requires_data() {
        let err = ParityOpts::from_args(&am("parity --dataset fmnist")).unwrap_err();
        assert!(matches!(err, CliError::Missing(_)));
        let o = ParityOpts::from_args(&am("parity --data f.sgds --algs a,b --trials 0")).unwrap();
        assert_eq!(o.algs, Some(vec!["a".to_string(), "b".to_string()]));
        assert_eq!(o.trials, Some(1), "--trials floors at one seed");
    }
}
