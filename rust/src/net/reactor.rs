//! Readiness-driven socket multiplexing (DESIGN.md §14.3): the reactor
//! that lets one coordinator (or shard) thread hold tens of thousands
//! of connections without a reader thread per socket.
//!
//! Three layers, bottom-up:
//!
//! * [`Reactor`] — a minimal epoll (Linux) / `poll(2)` (other unix)
//!   shim over hand-written `extern "C"` declarations: no new
//!   dependencies, raw syscalls only. Non-unix builds get a degenerate
//!   timer-tick fallback (every registered socket reported ready each
//!   wait) so the crate still compiles and limps along there.
//! * [`OutQueue`] — a per-connection queue of reference-counted frame
//!   segments flushed with `write_vectored`. A round's model broadcast
//!   is encoded **once** into a single `Arc<[u8]>` and the same
//!   allocation is queued to every connection: no per-client frame
//!   copy, and scatter-gather writes when several frames are pending.
//! * [`Mux`] — the connection table: accepts via the reactor (no
//!   sleep-poll), reads nonblocking sockets into per-connection
//!   buffers, extracts complete wire frames with
//!   [`wire::frame_len`], and drains the out-queues on writability.
//!
//! The reactor is level-triggered on every backend, so the `Mux` may
//! stop reading/writing at any point and rediscover the remaining work
//! on the next `wait`.

use std::collections::VecDeque;
use std::io::{IoSlice, Write};
use std::sync::Arc;
use std::time::Duration;

use super::wire;
use super::{Endpoint, Listener, NetError, Stream};
use crate::metrics::registry::MetricsRegistry;

/// Readiness report for one registered token.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

#[cfg(unix)]
type SourceFd = std::os::fd::RawFd;
#[cfg(not(unix))]
type SourceFd = ();

struct Reg {
    token: u64,
    #[cfg_attr(not(unix), allow(dead_code))]
    fd: SourceFd,
    want_write: bool,
}

// ---------------------------------------------------------------------
// Platform shims. Constants and struct layouts are the kernel ABI; no
// libc crate, by the crate's dependency-free policy.
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLRDHUP: u32 = 0x2000;

    // The kernel packs this struct on x86-64 (and only there).
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    pub struct epoll_event {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut epoll_event) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut epoll_event, maxevents: i32, timeout: i32)
            -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    pub const POLLIN: i16 = 0x1;
    pub const POLLOUT: i16 = 0x4;
    pub const POLLERR: i16 = 0x8;
    pub const POLLHUP: i16 = 0x10;
    pub const POLLNVAL: i16 = 0x20;

    #[derive(Clone, Copy)]
    #[repr(C)]
    pub struct pollfd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    // macOS and the BSDs agree: `typedef unsigned int nfds_t`.
    pub type nfds_t = u32;

    extern "C" {
        pub fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: i32) -> i32;
    }
}

/// Retry a readiness syscall across EINTR: signal delivery (a soak
/// supervisor's SIGCHLD, a profiler tick, a debugger attach) must never
/// surface as a wait error. Shared by the epoll and `poll(2)` wait
/// paths; unit-tested with an injected syscall so the retry contract
/// holds on every backend, not just the one CI happens to run.
#[cfg_attr(not(unix), allow(dead_code))]
fn retry_eintr(
    mut op: impl FnMut() -> Result<usize, std::io::Error>,
) -> Result<usize, std::io::Error> {
    loop {
        match op() {
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            other => return other,
        }
    }
}

fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis().min(i32::MAX as u128) as i32;
            // Round sub-millisecond waits up so a 100µs deadline check
            // does not degenerate into a busy spin.
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms
            }
        }
    }
}

/// The readiness shim. Register sockets under a caller-chosen token,
/// toggle write interest as out-queues fill and drain, and `wait` for
/// the next batch of ready tokens. Read interest is permanent: every
/// registered socket is a frame source until deregistered.
pub(crate) struct Reactor {
    regs: Vec<Reg>,
    #[cfg(target_os = "linux")]
    epfd: i32,
    #[cfg(all(unix, not(target_os = "linux")))]
    pollfds: Vec<sys::pollfd>,
    #[cfg(target_os = "linux")]
    scratch: Vec<sys::epoll_event>,
}

impl Reactor {
    pub fn new() -> Result<Reactor, NetError> {
        #[cfg(target_os = "linux")]
        {
            let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(NetError::Io(std::io::Error::last_os_error()));
            }
            Ok(Reactor { regs: Vec::new(), epfd, scratch: Vec::with_capacity(256) })
        }
        #[cfg(all(unix, not(target_os = "linux")))]
        {
            Ok(Reactor { regs: Vec::new(), pollfds: Vec::new() })
        }
        #[cfg(not(unix))]
        {
            Ok(Reactor { regs: Vec::new() })
        }
    }

    fn slot(&self, token: u64) -> Option<usize> {
        self.regs.iter().position(|r| r.token == token)
    }

    pub fn register(&mut self, fd: SourceFd, token: u64, want_write: bool) -> Result<(), NetError> {
        debug_assert!(self.slot(token).is_none(), "token {token} registered twice");
        #[cfg(target_os = "linux")]
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, want_write)?;
        self.regs.push(Reg { token, fd, want_write });
        Ok(())
    }

    /// Flip write interest for `token`. No-op when already set.
    pub fn set_write(&mut self, token: u64, want_write: bool) -> Result<(), NetError> {
        let Some(i) = self.slot(token) else { return Ok(()) };
        if self.regs[i].want_write == want_write {
            return Ok(());
        }
        self.regs[i].want_write = want_write;
        #[cfg(target_os = "linux")]
        {
            let fd = self.regs[i].fd;
            self.ctl(sys::EPOLL_CTL_MOD, fd, token, want_write)?;
        }
        Ok(())
    }

    pub fn deregister(&mut self, token: u64) -> Result<(), NetError> {
        let Some(i) = self.slot(token) else { return Ok(()) };
        let reg = self.regs.swap_remove(i);
        #[cfg(target_os = "linux")]
        {
            // Kernels before 2.6.9 demanded a non-null event for DEL;
            // passing one is harmless everywhere.
            let mut ev = sys::epoll_event { events: 0, data: 0 };
            let rc = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, reg.fd, &mut ev) };
            // The fd may already be closed (shutdown path); EBADF/ENOENT
            // here is not an error worth surfacing.
            let _ = rc;
        }
        let _ = reg;
        Ok(())
    }

    #[cfg(target_os = "linux")]
    fn ctl(&self, op: i32, fd: i32, token: u64, want_write: bool) -> Result<(), NetError> {
        let mut events = sys::EPOLLIN | sys::EPOLLRDHUP;
        if want_write {
            events |= sys::EPOLLOUT;
        }
        let mut ev = sys::epoll_event { events, data: token };
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(NetError::Io(std::io::Error::last_os_error()));
        }
        Ok(())
    }

    /// Block until at least one registered socket is ready or the
    /// timeout elapses (`None` = forever), appending readiness reports
    /// to `out`. Error/hangup conditions surface as `readable` so the
    /// subsequent read observes the actual EOF or errno.
    pub fn wait(
        &mut self,
        timeout: Option<Duration>,
        out: &mut Vec<Event>,
    ) -> Result<(), NetError> {
        #[cfg(target_os = "linux")]
        {
            let cap = self.regs.len().clamp(16, 1024);
            self.scratch.clear();
            self.scratch.resize(cap, sys::epoll_event { events: 0, data: 0 });
            let n = retry_eintr(|| {
                let rc = unsafe {
                    sys::epoll_wait(
                        self.epfd,
                        self.scratch.as_mut_ptr(),
                        cap as i32,
                        timeout_ms(timeout),
                    )
                };
                if rc >= 0 {
                    Ok(rc as usize)
                } else {
                    Err(std::io::Error::last_os_error())
                }
            })
            .map_err(NetError::Io)?;
            for i in 0..n {
                let ev = self.scratch[i];
                let bits = { ev.events };
                let token = { ev.data };
                out.push(Event {
                    token,
                    readable: bits
                        & (sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP)
                        != 0,
                    writable: bits & sys::EPOLLOUT != 0,
                });
            }
            Ok(())
        }
        #[cfg(all(unix, not(target_os = "linux")))]
        {
            self.pollfds.clear();
            for r in &self.regs {
                let mut events = sys::POLLIN;
                if r.want_write {
                    events |= sys::POLLOUT;
                }
                self.pollfds.push(sys::pollfd { fd: r.fd, events, revents: 0 });
            }
            let n = retry_eintr(|| {
                let rc = unsafe {
                    sys::poll(
                        self.pollfds.as_mut_ptr(),
                        self.pollfds.len() as sys::nfds_t,
                        timeout_ms(timeout),
                    )
                };
                if rc >= 0 {
                    Ok(rc as usize)
                } else {
                    Err(std::io::Error::last_os_error())
                }
            })
            .map_err(NetError::Io)?;
            if n > 0 {
                for (pfd, reg) in self.pollfds.iter().zip(&self.regs) {
                    let bits = pfd.revents;
                    if bits == 0 {
                        continue;
                    }
                    out.push(Event {
                        token: reg.token,
                        readable: bits
                            & (sys::POLLIN | sys::POLLERR | sys::POLLHUP | sys::POLLNVAL)
                            != 0,
                        writable: bits & sys::POLLOUT != 0,
                    });
                }
            }
            Ok(())
        }
        #[cfg(not(unix))]
        {
            // Degenerate fallback for platforms without a readiness
            // syscall shim: tick and report everything ready. The
            // nonblocking reads/writes above it turn spurious readiness
            // into cheap `WouldBlock`s. Functional, not efficient.
            std::thread::sleep(timeout.unwrap_or(Duration::from_millis(5)).min(
                Duration::from_millis(5),
            ));
            for r in &self.regs {
                out.push(Event { token: r.token, readable: true, writable: r.want_write });
            }
            Ok(())
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for Reactor {
    fn drop(&mut self) {
        unsafe { sys::close(self.epfd) };
    }
}

// ---------------------------------------------------------------------
// OutQueue: shared-frame scatter-gather writes.
// ---------------------------------------------------------------------

/// Pending outbound frames for one connection. Frames are queued as
/// `Arc<[u8]>` so a broadcast frame is one allocation shared by every
/// connection's queue; `flush` drains with `write_vectored`, resuming
/// mid-frame after short writes.
#[derive(Default)]
pub(crate) struct OutQueue {
    q: VecDeque<(Arc<[u8]>, usize)>,
    queued: usize,
}

impl OutQueue {
    pub fn push(&mut self, frame: Arc<[u8]>) {
        self.queued += frame.len();
        self.q.push_back((frame, 0));
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Bytes not yet handed to the kernel.
    pub fn pending(&self) -> usize {
        self.queued
    }

    /// Write as much as the socket accepts. `Ok(true)` = fully drained,
    /// `Ok(false)` = the socket would block (re-arm write interest).
    pub fn flush(&mut self, w: &mut impl Write) -> std::io::Result<bool> {
        const BATCH: usize = 64;
        loop {
            if self.q.is_empty() {
                return Ok(true);
            }
            let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(self.q.len().min(BATCH));
            for (frame, off) in self.q.iter().take(BATCH) {
                slices.push(IoSlice::new(&frame[*off..]));
            }
            match w.write_vectored(&slices) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(mut n) => {
                    self.queued -= n;
                    while n > 0 {
                        let (frame, off) = self.q.front_mut().expect("wrote beyond queue");
                        let left = frame.len() - *off;
                        if n >= left {
                            n -= left;
                            self.q.pop_front();
                        } else {
                            *off += n;
                            n = 0;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Mux: the connection table over the reactor.
// ---------------------------------------------------------------------

/// What the protocol layer sees from one `pump`.
#[derive(Debug)]
pub(crate) enum MuxEvent {
    /// New downstream connection accepted; its id is the next free slot.
    Accepted { conn: usize },
    /// One complete, length-delimited frame (header through CRC). The
    /// buffer should be handed back via [`Mux::recycle`] after decoding.
    Frame { conn: usize, bytes: Vec<u8> },
    /// The connection is gone (EOF, socket error, or malformed stream);
    /// emitted at most once per connection, and never after
    /// [`Mux::close`] was called on it explicitly.
    Closed { conn: usize },
}

struct ConnIo {
    stream: Stream,
    rbuf: Vec<u8>,
    rpos: usize,
    out: OutQueue,
}

const LISTENER_TOKEN: u64 = u64::MAX;
/// The optional second listener: the `/metrics` scrape port.
const METRICS_LISTENER_TOKEN: u64 = u64::MAX - 1;
/// Scraper connections live under this token base, in a table separate
/// from protocol connections — they never surface as [`MuxEvent`]s, so
/// the driver's arrival-ordered conn-id contract is untouched.
const HTTP_TOKEN_BASE: u64 = 1 << 62;
/// Hostile-input cap: a scrape request larger than this is not a
/// scrape. (A real `GET /metrics HTTP/1.1` with ordinary headers is a
/// few hundred bytes.)
const MAX_HTTP_REQUEST: usize = 1024;
/// At most this many concurrent scraper connections; accepts beyond it
/// are dropped on the spot so a connection flood cannot grow the table.
const MAX_HTTP_CONNS: usize = 32;
/// Keep at most this many spare frame buffers for reuse.
const SPARE_BUFS: usize = 1024;
/// Compact a read buffer once its consumed prefix exceeds this.
const COMPACT_AT: usize = 64 * 1024;

/// One scraper connection: request bytes in, one response out, close.
struct HttpConn {
    stream: Stream,
    rbuf: Vec<u8>,
    out: OutQueue,
    /// The response is queued; once `out` drains the conn closes.
    responded: bool,
}

/// Byte offset just past the request head's blank line, if complete.
fn request_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Nonblocking connection multiplexer. Connection ids are assigned in
/// arrival order and never reused — the protocol layer (roster, round
/// table) indexes by them.
pub(crate) struct Mux {
    reactor: Reactor,
    listener: Option<Listener>,
    conns: Vec<Option<ConnIo>>,
    /// Scrape port + scraper table (see [`Mux::listen_metrics`]). All
    /// scraper I/O is nonblocking and bounded, so a slow or hostile
    /// scraper can never stall the protocol pump.
    metrics_listener: Option<Listener>,
    metrics: Option<Arc<MetricsRegistry>>,
    http: Vec<Option<HttpConn>>,
    max_payload: usize,
    events: Vec<Event>,
    spare: Vec<Vec<u8>>,
    /// Injected link delay (DESIGN.md §15 `delay:<role>:<N>ms` faults):
    /// applied before every [`Mux::send`] flush, simulating a slow
    /// egress link at the named frame-flush phase. `None` in production.
    send_delay: Option<Duration>,
}

impl Mux {
    pub fn new(max_payload: usize) -> Result<Mux, NetError> {
        Ok(Mux {
            reactor: Reactor::new()?,
            listener: None,
            conns: Vec::new(),
            metrics_listener: None,
            metrics: None,
            http: Vec::new(),
            max_payload,
            events: Vec::new(),
            spare: Vec::new(),
            send_delay: None,
        })
    }

    /// Arm (or clear) the injected per-send link delay.
    pub fn set_send_delay(&mut self, delay: Option<Duration>) {
        self.send_delay = delay;
    }

    /// Adopt a bound listener; new connections surface as
    /// [`MuxEvent::Accepted`] from `pump` — no accept thread, no
    /// sleep-poll.
    pub fn listen(&mut self, listener: Listener) -> Result<(), NetError> {
        assert!(self.listener.is_none(), "one listener per mux");
        listener.set_nonblocking(true)?;
        #[cfg(unix)]
        self.reactor.register(listener.raw_fd(), LISTENER_TOKEN, false)?;
        #[cfg(not(unix))]
        self.reactor.register((), LISTENER_TOKEN, false)?;
        self.listener = Some(listener);
        Ok(())
    }

    /// Adopt a bound scrape listener: connections accepted here are
    /// answered by the built-in `GET /metrics` / `GET /healthz`
    /// HTTP/1.0 responder (rendering `registry`) and never surface as
    /// [`MuxEvent`]s. Same hostile-input discipline as the wire path:
    /// request size capped at [`MAX_HTTP_REQUEST`], connection count at
    /// [`MAX_HTTP_CONNS`], anything that is not a known `GET` drops the
    /// connection without a response.
    pub fn listen_metrics(
        &mut self,
        listener: Listener,
        registry: Arc<MetricsRegistry>,
    ) -> Result<(), NetError> {
        assert!(self.metrics_listener.is_none(), "one metrics listener per mux");
        listener.set_nonblocking(true)?;
        #[cfg(unix)]
        self.reactor.register(listener.raw_fd(), METRICS_LISTENER_TOKEN, false)?;
        #[cfg(not(unix))]
        self.reactor.register((), METRICS_LISTENER_TOKEN, false)?;
        self.metrics_listener = Some(listener);
        self.metrics = Some(registry);
        Ok(())
    }

    /// Dial `ep` (blocking connect) and register the connection.
    pub fn connect(&mut self, ep: &Endpoint) -> Result<usize, NetError> {
        self.adopt(Stream::connect(ep)?)
    }

    /// Register an already-connected stream (e.g. after a blocking
    /// handshake); it is switched to nonblocking mode here.
    pub fn adopt(&mut self, stream: Stream) -> Result<usize, NetError> {
        stream.set_nonblocking(true)?;
        let conn = self.conns.len();
        #[cfg(unix)]
        self.reactor.register(stream.raw_fd(), conn as u64, false)?;
        #[cfg(not(unix))]
        self.reactor.register((), conn as u64, false)?;
        self.conns.push(Some(ConnIo {
            stream,
            rbuf: Vec::new(),
            rpos: 0,
            out: OutQueue::default(),
        }));
        Ok(conn)
    }

    pub fn is_open(&self, conn: usize) -> bool {
        self.conns.get(conn).is_some_and(|c| c.is_some())
    }

    /// Live connection count (open slots).
    pub fn open_conns(&self) -> usize {
        self.conns.iter().filter(|c| c.is_some()).count()
    }

    /// Hand a drained [`MuxEvent::Frame`] buffer back for reuse.
    pub fn recycle(&mut self, mut buf: Vec<u8>) {
        if self.spare.len() < SPARE_BUFS {
            buf.clear();
            self.spare.push(buf);
        }
    }

    fn take_buf(&mut self) -> Vec<u8> {
        self.spare.pop().unwrap_or_default()
    }

    /// Queue `frame` on `conn` and flush opportunistically. Returns
    /// `false` (after tearing the connection down) if the socket is
    /// already closed or errors on the spot; the caller decides what a
    /// dead peer means for the protocol.
    pub fn send(&mut self, conn: usize, frame: Arc<[u8]>) -> bool {
        if let Some(d) = self.send_delay {
            std::thread::sleep(d);
        }
        let Some(Some(io)) = self.conns.get_mut(conn) else { return false };
        io.out.push(frame);
        match io.out.flush(&mut io.stream) {
            Ok(drained) => {
                let _ = self.reactor.set_write(conn as u64, !drained);
                true
            }
            Err(_) => {
                self.close(conn);
                false
            }
        }
    }

    /// Total bytes queued but not yet written on `conn`.
    pub fn backlog(&self, conn: usize) -> usize {
        match self.conns.get(conn) {
            Some(Some(io)) => io.out.pending(),
            _ => 0,
        }
    }

    /// Shut a connection down and forget it. Idempotent; no
    /// [`MuxEvent::Closed`] is emitted for explicit closes.
    pub fn close(&mut self, conn: usize) {
        if let Some(slot) = self.conns.get_mut(conn) {
            if let Some(io) = slot.take() {
                let _ = self.reactor.deregister(conn as u64);
                io.stream.shutdown();
            }
        }
    }

    /// Wait up to `timeout` for readiness and translate it into
    /// protocol-level events. Always makes exactly one reactor wait.
    pub fn pump(
        &mut self,
        timeout: Option<Duration>,
        out: &mut Vec<MuxEvent>,
    ) -> Result<(), NetError> {
        let mut events = std::mem::take(&mut self.events);
        events.clear();
        self.reactor.wait(timeout, &mut events)?;
        for i in 0..events.len() {
            let ev = events[i];
            if ev.token == LISTENER_TOKEN {
                self.accept_ready(out)?;
            } else if ev.token == METRICS_LISTENER_TOKEN {
                self.accept_scrapers();
            } else if ev.token >= HTTP_TOKEN_BASE {
                let slot = (ev.token - HTTP_TOKEN_BASE) as usize;
                if ev.writable {
                    self.http_flush(slot);
                }
                if ev.readable {
                    self.http_read(slot);
                }
            } else {
                let conn = ev.token as usize;
                if ev.writable {
                    self.flush_ready(conn, out);
                }
                if ev.readable {
                    self.read_ready(conn, out);
                }
            }
        }
        self.events = events;
        Ok(())
    }

    fn accept_ready(&mut self, out: &mut Vec<MuxEvent>) -> Result<(), NetError> {
        loop {
            let Some(listener) = self.listener.as_ref() else { return Ok(()) };
            match listener.accept_nonblocking() {
                Ok(Some(stream)) => {
                    let conn = self.adopt(stream)?;
                    out.push(MuxEvent::Accepted { conn });
                }
                Ok(None) => return Ok(()),
                // Transient per-connection accept failures (peer reset
                // while queued, fd pressure) should not kill the serve
                // loop; the reactor will re-report readiness if more
                // connections are pending.
                Err(_) => return Ok(()),
            }
        }
    }

    // -- scrape responder (never visible to the protocol layer) -------

    fn accept_scrapers(&mut self) {
        loop {
            let Some(listener) = self.metrics_listener.as_ref() else { return };
            match listener.accept_nonblocking() {
                Ok(Some(stream)) => {
                    if self.http.iter().filter(|c| c.is_some()).count() >= MAX_HTTP_CONNS {
                        // Connection flood: refuse on the spot. The
                        // stream drops here, sending RST/FIN.
                        if let Some(m) = &self.metrics {
                            m.inc_scraper_dropped();
                        }
                        continue;
                    }
                    if self.adopt_scraper(stream).is_err() {
                        if let Some(m) = &self.metrics {
                            m.inc_scraper_dropped();
                        }
                    }
                }
                Ok(None) => return,
                Err(_) => return,
            }
        }
    }

    fn adopt_scraper(&mut self, stream: Stream) -> Result<(), NetError> {
        stream.set_nonblocking(true)?;
        let slot = self.http.iter().position(|c| c.is_none()).unwrap_or(self.http.len());
        #[cfg(unix)]
        self.reactor.register(stream.raw_fd(), HTTP_TOKEN_BASE + slot as u64, false)?;
        #[cfg(not(unix))]
        self.reactor.register((), HTTP_TOKEN_BASE + slot as u64, false)?;
        let conn =
            HttpConn { stream, rbuf: Vec::new(), out: OutQueue::default(), responded: false };
        if slot == self.http.len() {
            self.http.push(Some(conn));
        } else {
            self.http[slot] = Some(conn);
        }
        Ok(())
    }

    fn close_http(&mut self, slot: usize) {
        if let Some(hc) = self.http.get_mut(slot) {
            if let Some(conn) = hc.take() {
                let _ = self.reactor.deregister(HTTP_TOKEN_BASE + slot as u64);
                conn.stream.shutdown();
            }
        }
    }

    /// Drop a scraper for hostile input and count it.
    fn drop_scraper(&mut self, slot: usize) {
        if let Some(m) = &self.metrics {
            m.inc_scraper_dropped();
        }
        self.close_http(slot);
    }

    fn http_read(&mut self, slot: usize) {
        let mut chunk = [0u8; 1024];
        loop {
            let Some(Some(hc)) = self.http.get_mut(slot) else { return };
            match std::io::Read::read(&mut hc.stream, &mut chunk) {
                Ok(0) => {
                    self.close_http(slot);
                    return;
                }
                Ok(n) => {
                    if hc.responded {
                        // Pipelined extras after the request: ignored;
                        // HTTP/1.0 closes after one response.
                        continue;
                    }
                    hc.rbuf.extend_from_slice(&chunk[..n]);
                    if let Some(end) = request_end(&hc.rbuf) {
                        self.http_respond(slot, end);
                        // `responded` or closed either way; keep
                        // draining the socket until WouldBlock.
                        continue;
                    }
                    if hc.rbuf.len() > MAX_HTTP_REQUEST {
                        self.drop_scraper(slot);
                        return;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_http(slot);
                    return;
                }
            }
        }
    }

    /// Answer one complete request (`rbuf[..end]` is the head through
    /// the blank line). Unknown method/path/version: drop, no response
    /// — a scrape port does not negotiate with strangers.
    fn http_respond(&mut self, slot: usize, end: usize) {
        let Some(Some(hc)) = self.http.get_mut(slot) else { return };
        let head = &hc.rbuf[..end];
        let line = head.split(|&b| b == b'\r').next().unwrap_or(head);
        let Ok(line) = std::str::from_utf8(line) else { return self.drop_scraper(slot) };
        let mut parts = line.split(' ').filter(|p| !p.is_empty());
        let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v)) => (m, p, v),
            _ => return self.drop_scraper(slot),
        };
        if method != "GET" || !version.starts_with("HTTP/1.") || parts.next().is_some() {
            return self.drop_scraper(slot);
        }
        let (ctype, body) = match path {
            "/metrics" => {
                let Some(reg) = self.metrics.clone() else { return self.drop_scraper(slot) };
                reg.inc_scrape();
                ("text/plain; version=0.0.4; charset=utf-8", reg.render())
            }
            "/healthz" => ("text/plain; charset=utf-8", "ok\n".to_string()),
            _ => return self.drop_scraper(slot),
        };
        let response = format!(
            "HTTP/1.0 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let Some(Some(hc)) = self.http.get_mut(slot) else { return };
        hc.responded = true;
        hc.out.push(Arc::from(response.into_bytes().as_slice()));
        self.http_flush(slot);
    }

    fn http_flush(&mut self, slot: usize) {
        let Some(Some(hc)) = self.http.get_mut(slot) else { return };
        match hc.out.flush(&mut hc.stream) {
            Ok(true) => {
                if hc.responded {
                    self.close_http(slot);
                } else {
                    let _ = self.reactor.set_write(HTTP_TOKEN_BASE + slot as u64, false);
                }
            }
            // The scraper is slow: leave the remainder queued and let
            // writability drive the rest. The pump never waits on it.
            Ok(false) => {
                let _ = self.reactor.set_write(HTTP_TOKEN_BASE + slot as u64, true);
            }
            Err(_) => self.close_http(slot),
        }
    }

    fn flush_ready(&mut self, conn: usize, out: &mut Vec<MuxEvent>) {
        let Some(Some(io)) = self.conns.get_mut(conn) else { return };
        match io.out.flush(&mut io.stream) {
            Ok(drained) => {
                let _ = self.reactor.set_write(conn as u64, !drained);
            }
            Err(_) => {
                self.close(conn);
                out.push(MuxEvent::Closed { conn });
            }
        }
    }

    fn read_ready(&mut self, conn: usize, out: &mut Vec<MuxEvent>) {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            let Some(Some(io)) = self.conns.get_mut(conn) else { return };
            match std::io::Read::read(&mut io.stream, &mut chunk) {
                Ok(0) => {
                    self.close(conn);
                    out.push(MuxEvent::Closed { conn });
                    return;
                }
                Ok(n) => {
                    io.rbuf.extend_from_slice(&chunk[..n]);
                    if let Err(()) = self.extract_frames(conn, out) {
                        self.close(conn);
                        out.push(MuxEvent::Closed { conn });
                        return;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close(conn);
                    out.push(MuxEvent::Closed { conn });
                    return;
                }
            }
        }
    }

    /// Slice complete frames out of `conn`'s read buffer. `Err(())`
    /// means the byte stream is unframeable (bad magic/version or an
    /// oversized declaration) and the connection must die.
    fn extract_frames(&mut self, conn: usize, out: &mut Vec<MuxEvent>) -> Result<(), ()> {
        loop {
            let Some(Some(io)) = self.conns.get_mut(conn) else { return Ok(()) };
            let pending = &io.rbuf[io.rpos..];
            match wire::frame_len(pending, self.max_payload) {
                Err(_) => return Err(()),
                Ok(None) => break,
                Ok(Some(total)) => {
                    let start = io.rpos;
                    io.rpos += total;
                    let mut bytes = self.take_buf();
                    let io = self.conns[conn].as_mut().expect("conn vanished mid-extract");
                    bytes.extend_from_slice(&io.rbuf[start..start + total]);
                    out.push(MuxEvent::Frame { conn, bytes });
                }
            }
        }
        let Some(Some(io)) = self.conns.get_mut(conn) else { return Ok(()) };
        if io.rpos == io.rbuf.len() {
            io.rbuf.clear();
            io.rpos = 0;
        } else if io.rpos > COMPACT_AT {
            io.rbuf.drain(..io.rpos);
            io.rpos = 0;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{decode_msg, parse_frame, Msg, WireBuf, MAX_PAYLOAD};
    use crate::net::read_frame_bytes;

    /// A writer that accepts at most `cap` bytes per call and injects
    /// `WouldBlock` on a fixed cadence — the pathological short-write
    /// socket.
    struct ChokedWriter {
        bytes: Vec<u8>,
        cap: usize,
        calls: usize,
        block_every: usize,
    }

    impl Write for ChokedWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.write_vectored(&[IoSlice::new(buf)])
        }
        fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
            self.calls += 1;
            if self.block_every > 0 && self.calls % self.block_every == 0 {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            let mut wrote = 0;
            for b in bufs {
                if wrote == self.cap {
                    break;
                }
                let take = b.len().min(self.cap - wrote);
                self.bytes.extend_from_slice(&b[..take]);
                wrote += take;
                if take < b.len() {
                    break;
                }
            }
            Ok(wrote)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn frames() -> Vec<Arc<[u8]>> {
        let mut wbuf = WireBuf::new();
        let mut out = Vec::new();
        let msgs = [
            Msg::Hello { lo: 0, hi: 9, cfg: 1, env: 2 },
            Msg::Heartbeat { client_id: 4 },
            Msg::Fin { rounds: 77 },
        ];
        msgs.iter()
            .map(|m| {
                out.clear();
                wbuf.encode(m, &mut out);
                Arc::from(out.as_slice())
            })
            .collect()
    }

    #[test]
    fn outqueue_matches_sequential_write_all_bytes() {
        let frames = frames();
        // Reference: plain write_all of each frame in order.
        let mut reference = Vec::new();
        for f in &frames {
            reference.extend_from_slice(f);
        }
        // OutQueue through a 7-byte-per-call writer with periodic
        // WouldBlock: same bytes, same order.
        let mut q = OutQueue::default();
        for f in &frames {
            q.push(Arc::clone(f));
        }
        let mut w = ChokedWriter { bytes: Vec::new(), cap: 7, calls: 0, block_every: 3 };
        let mut spins = 0;
        while !q.flush(&mut w).unwrap() {
            spins += 1;
            assert!(spins < 1000, "flush never drained");
        }
        assert!(q.is_empty());
        assert_eq!(q.pending(), 0);
        assert_eq!(w.bytes, reference, "vectored short-write path reordered or corrupted bytes");
        assert!(spins > 0, "test writer never exercised the WouldBlock resume path");
    }

    #[test]
    fn outqueue_broadcast_shares_one_allocation() {
        let frames = frames();
        let shared = Arc::clone(&frames[2]);
        let mut queues: Vec<OutQueue> = (0..3).map(|_| OutQueue::default()).collect();
        for q in queues.iter_mut() {
            q.push(Arc::clone(&shared));
        }
        // 3 queue entries + `shared` + the original in `frames`.
        assert_eq!(Arc::strong_count(&shared), 5);
        let mut outs = Vec::new();
        for q in queues.iter_mut() {
            let mut w = ChokedWriter { bytes: Vec::new(), cap: 5, calls: 0, block_every: 0 };
            while !q.flush(&mut w).unwrap() {}
            outs.push(w.bytes);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
        assert_eq!(outs[0].as_slice(), &shared[..], "broadcast frame must be byte-identical");
    }

    #[test]
    fn mux_accepts_frames_and_echoes() {
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let listener = Listener::bind(&ep).unwrap();
        let addr = listener.local_endpoint(&ep);
        let mut mux = Mux::new(MAX_PAYLOAD).unwrap();
        mux.listen(listener).unwrap();

        let client = std::thread::spawn(move || {
            let mut s = Stream::connect(&addr).unwrap();
            let mut wbuf = WireBuf::new();
            let mut bytes = Vec::new();
            wbuf.encode(&Msg::Heartbeat { client_id: 3 }, &mut bytes);
            s.write_all(&bytes).unwrap();
            let mut frame = Vec::new();
            let n = read_frame_bytes(&mut s, MAX_PAYLOAD, &mut frame).unwrap();
            let (f, _) = parse_frame(&frame[..n], MAX_PAYLOAD).unwrap();
            decode_msg(f).unwrap()
        });

        let mut events = Vec::new();
        let mut accepted = None;
        let mut got = None;
        for _ in 0..500 {
            events.clear();
            mux.pump(Some(Duration::from_millis(20)), &mut events).unwrap();
            for ev in events.drain(..) {
                match ev {
                    MuxEvent::Accepted { conn } => accepted = Some(conn),
                    MuxEvent::Frame { conn, bytes } => {
                        let (f, used) = parse_frame(&bytes, MAX_PAYLOAD).unwrap();
                        assert_eq!(used, bytes.len());
                        assert_eq!(decode_msg(f).unwrap(), Msg::Heartbeat { client_id: 3 });
                        got = Some(conn);
                        mux.recycle(bytes);
                    }
                    MuxEvent::Closed { .. } => {}
                }
            }
            if let Some(conn) = got {
                assert_eq!(accepted, Some(conn));
                let mut wbuf = WireBuf::new();
                let mut bytes = Vec::new();
                wbuf.encode(&Msg::Ack { t: 3, worker: 0 }, &mut bytes);
                assert!(mux.send(conn, Arc::from(bytes.as_slice())));
                break;
            }
        }
        assert!(got.is_some(), "mux never surfaced the client frame");
        assert_eq!(client.join().unwrap(), Msg::Ack { t: 3, worker: 0 });
    }

    #[test]
    fn mux_kills_conn_on_garbage() {
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let listener = Listener::bind(&ep).unwrap();
        let addr = listener.local_endpoint(&ep);
        let mut mux = Mux::new(MAX_PAYLOAD).unwrap();
        mux.listen(listener).unwrap();
        let client = std::thread::spawn(move || {
            let mut s = Stream::connect(&addr).unwrap();
            s.write_all(b"this is not a SGND frame, not even close....").unwrap();
            // Server should hang up on us; drain until EOF.
            let mut sink = Vec::new();
            let _ = std::io::Read::read_to_end(&mut s, &mut sink);
        });
        let mut events = Vec::new();
        let (mut opened, mut closed) = (false, false);
        for _ in 0..500 {
            events.clear();
            mux.pump(Some(Duration::from_millis(20)), &mut events).unwrap();
            for ev in events.drain(..) {
                match ev {
                    MuxEvent::Accepted { conn } => {
                        opened = true;
                        assert!(mux.is_open(conn));
                    }
                    MuxEvent::Frame { .. } => panic!("garbage must not frame"),
                    MuxEvent::Closed { conn } => {
                        closed = true;
                        assert!(!mux.is_open(conn));
                    }
                }
            }
            if closed {
                break;
            }
        }
        assert!(opened && closed);
        client.join().unwrap();
    }

    #[test]
    fn retry_eintr_retries_interrupts_and_passes_everything_else() {
        // A syscall that is interrupted three times before succeeding —
        // the shape a soak supervisor's SIGCHLD storm produces in the
        // poll(2)/epoll wait.
        let mut calls = 0;
        let n = retry_eintr(|| {
            calls += 1;
            if calls <= 3 {
                Err(std::io::ErrorKind::Interrupted.into())
            } else {
                Ok(7)
            }
        })
        .unwrap();
        assert_eq!(n, 7);
        assert_eq!(calls, 4, "exactly one retry per EINTR");
        // Success on the first call does not retry.
        let mut calls = 0;
        assert_eq!(
            retry_eintr(|| {
                calls += 1;
                Ok(0)
            })
            .unwrap(),
            0
        );
        assert_eq!(calls, 1);
        // Any other error surfaces immediately.
        let mut calls = 0;
        let err = retry_eintr(|| {
            calls += 1;
            Err::<usize, _>(std::io::ErrorKind::BrokenPipe.into())
        })
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
        assert_eq!(calls, 1, "non-EINTR errors must not retry");
    }

    #[test]
    fn mux_half_open_peer_closes_once_despite_queued_output() {
        // A peer that half-closes (shutdown(Write)) while the mux still
        // holds queued output for it: the read-0 must tear the
        // connection down exactly once, dropping the backlog with it —
        // not wedge waiting for writability, not double-report Closed.
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let listener = Listener::bind(&ep).unwrap();
        let Endpoint::Tcp(addr) = listener.local_endpoint(&ep) else { unreachable!() };
        let mut mux = Mux::new(MAX_PAYLOAD).unwrap();
        mux.listen(listener).unwrap();

        let peer = std::net::TcpStream::connect(&addr).unwrap();
        let mut events = Vec::new();
        let mut conn = None;
        for _ in 0..500 {
            events.clear();
            mux.pump(Some(Duration::from_millis(20)), &mut events).unwrap();
            for ev in events.drain(..) {
                if let MuxEvent::Accepted { conn: c } = ev {
                    conn = Some(c);
                }
            }
            if conn.is_some() {
                break;
            }
        }
        let conn = conn.expect("peer never accepted");

        // Queue output until the kernel send buffer chokes and frames
        // sit in the OutQueue (the peer is not reading).
        let frame: Arc<[u8]> = {
            let mut wbuf = WireBuf::new();
            let mut bytes = Vec::new();
            wbuf.encode(&Msg::Fin { rounds: 1 }, &mut bytes);
            Arc::from(bytes.as_slice())
        };
        let mut sends = 0usize;
        while mux.backlog(conn) == 0 {
            assert!(mux.send(conn, Arc::clone(&frame)), "send failed before any backlog");
            sends += 1;
            assert!(sends < 2_000_000, "kernel buffer never filled");
        }
        assert!(mux.backlog(conn) > 0);

        // Half-close: our read side sees EOF while the backlog stands.
        peer.shutdown(std::net::Shutdown::Write).unwrap();
        let mut closes = 0;
        for _ in 0..500 {
            events.clear();
            mux.pump(Some(Duration::from_millis(20)), &mut events).unwrap();
            for ev in events.drain(..) {
                if let MuxEvent::Closed { conn: c } = ev {
                    assert_eq!(c, conn);
                    closes += 1;
                }
            }
            if closes > 0 {
                break;
            }
        }
        assert_eq!(closes, 1, "read-0 with queued output must close exactly once");
        assert!(!mux.is_open(conn));
        assert_eq!(mux.backlog(conn), 0, "a dead conn holds no backlog");
        // Subsequent pumps stay silent about the dead connection.
        for _ in 0..3 {
            events.clear();
            mux.pump(Some(Duration::from_millis(5)), &mut events).unwrap();
            assert!(
                !events.iter().any(|e| matches!(e, MuxEvent::Closed { conn: c } if *c == conn)),
                "Closed must be emitted at most once"
            );
        }
        drop(peer);
    }

    #[test]
    fn mux_backpressure_drains_exactly_once_the_peer_resumes_reading() {
        // A peer that stops reading mid-broadcast: sends keep
        // succeeding (frames queue), write interest re-arms, and once
        // the peer resumes, the queue drains to exactly the broadcast
        // bytes in order — nothing lost, duplicated or reordered.
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let listener = Listener::bind(&ep).unwrap();
        let Endpoint::Tcp(addr) = listener.local_endpoint(&ep) else { unreachable!() };
        let mut mux = Mux::new(MAX_PAYLOAD).unwrap();
        mux.listen(listener).unwrap();

        let frame: Arc<[u8]> = {
            let mut wbuf = WireBuf::new();
            let mut bytes = Vec::new();
            wbuf.encode(&Msg::Fin { rounds: 42 }, &mut bytes);
            Arc::from(bytes.as_slice())
        };
        let flen = frame.len();

        let (tx_total, rx_total) = std::sync::mpsc::channel::<usize>();
        let peer = std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(&addr).unwrap();
            // Stop reading until the broadcaster says how much is coming.
            let total = rx_total.recv().unwrap();
            let mut got = vec![0u8; total];
            std::io::Read::read_exact(&mut s, &mut got).unwrap();
            got
        });

        let mut events = Vec::new();
        let mut conn = None;
        for _ in 0..500 {
            events.clear();
            mux.pump(Some(Duration::from_millis(20)), &mut events).unwrap();
            for ev in events.drain(..) {
                if let MuxEvent::Accepted { conn: c } = ev {
                    conn = Some(c);
                }
            }
            if conn.is_some() {
                break;
            }
        }
        let conn = conn.expect("peer never accepted");

        // Broadcast into the stalled peer until real backpressure shows,
        // then a fixed tail beyond it.
        let mut sends = 0usize;
        while mux.backlog(conn) == 0 {
            assert!(mux.send(conn, Arc::clone(&frame)));
            sends += 1;
            assert!(sends < 2_000_000, "kernel buffer never filled");
        }
        for _ in 0..100 {
            assert!(mux.send(conn, Arc::clone(&frame)), "send must queue under backpressure");
            sends += 1;
        }
        assert!(mux.backlog(conn) > 0);

        // Unblock the reader and pump until the queue drains.
        tx_total.send(sends * flen).unwrap();
        let mut spins = 0;
        while mux.backlog(conn) > 0 {
            events.clear();
            mux.pump(Some(Duration::from_millis(20)), &mut events).unwrap();
            for ev in events.drain(..) {
                assert!(
                    !matches!(ev, MuxEvent::Closed { .. }),
                    "draining a backlog must not kill the conn"
                );
            }
            spins += 1;
            assert!(spins < 5_000, "backlog never drained");
        }
        let got = peer.join().unwrap();
        assert_eq!(got.len(), sends * flen);
        let reference: Vec<u8> = std::iter::repeat(frame.as_ref())
            .take(sends)
            .flat_map(|f| f.iter().copied())
            .collect();
        assert_eq!(got, reference, "backpressured broadcast corrupted the byte stream");
        assert!(mux.is_open(conn));
    }

    /// Blocking scraper client: connect, send `req`, collect whatever
    /// the responder returns until it closes (errors tolerated — a
    /// dropped hostile conn may RST mid-write).
    fn spawn_scraper(addr: &Endpoint, req: &[u8]) -> std::thread::JoinHandle<Vec<u8>> {
        let addr = addr.clone();
        let req = req.to_vec();
        std::thread::spawn(move || {
            let mut s = Stream::connect(&addr).unwrap();
            let _ = s.write_all(&req);
            let mut out = Vec::new();
            let _ = std::io::Read::read_to_end(&mut s, &mut out);
            out
        })
    }

    /// Pump the mux until the scraper thread finishes, asserting the
    /// scrape traffic never surfaces as protocol events.
    fn pump_scrape(mux: &mut Mux, h: std::thread::JoinHandle<Vec<u8>>) -> Vec<u8> {
        let mut events = Vec::new();
        let mut spins = 0;
        while !h.is_finished() {
            events.clear();
            mux.pump(Some(Duration::from_millis(10)), &mut events).unwrap();
            assert!(events.is_empty(), "scraper traffic must not surface as MuxEvents");
            spins += 1;
            assert!(spins < 2_000, "scrape never completed");
        }
        h.join().unwrap()
    }

    #[test]
    fn mux_answers_metrics_and_healthz_scrapes() {
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let listener = Listener::bind(&ep).unwrap();
        let addr = listener.local_endpoint(&ep);
        let mut mux = Mux::new(MAX_PAYLOAD).unwrap();
        let reg = crate::metrics::registry::MetricsRegistry::root();
        reg.observe_round_close(11, 22, 0, 0, 1);
        mux.listen_metrics(listener, Arc::clone(&reg)).unwrap();

        let got = pump_scrape(
            &mut mux,
            spawn_scraper(&addr, b"GET /metrics HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n"),
        );
        let text = String::from_utf8(got).unwrap();
        assert!(text.starts_with("HTTP/1.0 200 OK\r\n"), "bad status line: {text:?}");
        assert!(text.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n"));
        assert!(text.contains("sparsignd_uplink_wire_bytes_total{role=\"root\"} 11\n"));
        assert!(text.contains("sparsignd_rounds_closed_total{role=\"root\"} 1\n"));

        let got = pump_scrape(&mut mux, spawn_scraper(&addr, b"GET /healthz HTTP/1.0\r\n\r\n"));
        let text = String::from_utf8(got).unwrap();
        assert!(text.ends_with("\r\n\r\nok\n"), "healthz body: {text:?}");
    }

    #[test]
    fn mux_drops_hostile_scrapers_and_keeps_serving() {
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let listener = Listener::bind(&ep).unwrap();
        let addr = listener.local_endpoint(&ep);
        let mut mux = Mux::new(MAX_PAYLOAD).unwrap();
        let reg = crate::metrics::registry::MetricsRegistry::root();
        mux.listen_metrics(listener, Arc::clone(&reg)).unwrap();

        // Wrong method, unknown path, and an oversized headerless
        // request: all dropped without a byte of response.
        for req in [
            b"POST /metrics HTTP/1.1\r\n\r\n".to_vec(),
            b"GET /admin HTTP/1.0\r\n\r\n".to_vec(),
            vec![b'A'; 4096],
        ] {
            let got = pump_scrape(&mut mux, spawn_scraper(&addr, &req));
            assert!(got.is_empty(), "hostile request got a response: {got:?}");
        }

        // The responder still answers well-formed scrapes afterwards,
        // and the drops were counted.
        let got = pump_scrape(&mut mux, spawn_scraper(&addr, b"GET /metrics HTTP/1.0\r\n\r\n"));
        let text = String::from_utf8(got).unwrap();
        let body = text.split("\r\n\r\n").nth(1).expect("response has a body");
        let samples = crate::metrics::registry::parse_exposition(body).unwrap();
        assert_eq!(
            crate::metrics::registry::sample_value(
                &samples,
                "sparsignd_scrapers_dropped_total",
                &[("role", "root")],
            ),
            Some(3)
        );
    }
}
