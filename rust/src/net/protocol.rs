//! The coordinator's protocol state machine, transport-free (DESIGN.md
//! §11): phase transitions, the rendezvous roster, and the per-round
//! submission table. `net/server.rs` drives these under its locks; the
//! unit tests below exercise every transition and rejection without a
//! socket in sight.
//!
//! ```text
//!            rendezvous complete            broadcast sent
//!  Standby ────────────────────▶ RoundOpen ───────────────▶ Aggregating
//!     ▲                              ▲                           │
//!     │ final round                  │ next round                │ all live slots filled
//!     │                              │                           │ or deadline expired
//!  Finished ◀──────────────────── Broadcast ◀───────────────────┘
//! ```
//!
//! (The paper's Algorithm 1 loop: the server opens a round, workers
//! submit compressed gradients, aggregation closes the round, and the
//! model broadcast opens the next. xaynet's coordinator uses the same
//! explicit-phase shape for its PET rounds.)

use super::wire::RejectReason;
use crate::coordinator::REJECT_KINDS;

/// Coordinator lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Accepting rendezvous claims; no round open.
    Standby,
    /// Round `t` announced: broadcast in flight.
    RoundOpen(usize),
    /// Round `t` collecting submissions.
    Aggregating(usize),
    /// Round `t` aggregated; result applied / being broadcast.
    Broadcast(usize),
    /// Run complete; `Fin` sent.
    Finished,
}

/// Phase tracker with checked transitions — a wrong transition is a
/// coordinator bug, so it panics rather than limping on.
#[derive(Clone, Debug)]
pub struct PhaseTracker {
    phase: Phase,
}

impl Default for PhaseTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseTracker {
    pub fn new() -> Self {
        Self { phase: Phase::Standby }
    }

    /// Tracker for a coordinator resuming at `next_round` (DESIGN.md
    /// §12): snapshots are taken at round boundaries, so the restored
    /// phase is `Broadcast(next_round - 1)` — exactly where an
    /// uninterrupted coordinator would stand — or `Standby` for a
    /// fresh run.
    pub fn resumed_at(next_round: usize) -> Self {
        Self {
            phase: if next_round == 0 {
                Phase::Standby
            } else {
                Phase::Broadcast(next_round - 1)
            },
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// Standby/Broadcast → RoundOpen(t).
    pub fn open_round(&mut self, t: usize) {
        match self.phase {
            Phase::Standby => assert_eq!(t, 0, "first round must be 0"),
            Phase::Broadcast(prev) => {
                assert_eq!(t, prev + 1, "round {t} after broadcast of {prev}")
            }
            p => panic!("open_round({t}) from {p:?}"),
        }
        self.phase = Phase::RoundOpen(t);
    }

    /// RoundOpen(t) → Aggregating(t).
    pub fn aggregate(&mut self, t: usize) {
        assert_eq!(self.phase, Phase::RoundOpen(t), "aggregate({t})");
        self.phase = Phase::Aggregating(t);
    }

    /// Aggregating(t) → RoundOpen(t): the round closed with zero live
    /// submissions (every host of its cohort died) and is being
    /// re-broadcast after the fleet re-covers the population — the
    /// elastic churn path (DESIGN.md §12). Selection is NOT redrawn;
    /// the same round re-opens.
    pub fn reopen_round(&mut self, t: usize) {
        assert_eq!(self.phase, Phase::Aggregating(t), "reopen_round({t})");
        self.phase = Phase::RoundOpen(t);
    }

    /// Aggregating(t) → Broadcast(t).
    pub fn broadcast(&mut self, t: usize) {
        assert_eq!(self.phase, Phase::Aggregating(t), "broadcast({t})");
        self.phase = Phase::Broadcast(t);
    }

    /// Broadcast(_) → Finished.
    pub fn finish(&mut self) {
        assert!(matches!(self.phase, Phase::Broadcast(_)), "finish from {:?}", self.phase);
        self.phase = Phase::Finished;
    }
}

/// Why a rendezvous claim was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClaimError {
    /// `lo >= hi` — an empty range claims nothing.
    EmptyRange,
    /// Range extends past the announced worker population.
    OutOfRange,
    /// Range intersects one already claimed.
    Overlap,
    /// This connection already holds a claim.
    AlreadyClaimed,
}

/// Rendezvous roster: which connection hosts which worker range. The
/// fleet partitions `base..total` among its agents; the coordinator
/// starts the run once the union of claims covers the population
/// exactly. The root coordinator rosters `0..m` (claimants are clients
/// or aggregator shards); a shard rosters its own slice `lo..hi` of the
/// population (DESIGN.md §14.2), so worker ids stay *global* at every
/// tier — no re-indexing anywhere.
#[derive(Clone, Debug)]
pub struct Roster {
    base: usize,
    total: usize,
    /// `(lo, hi, conn)` claims, disjoint by construction.
    claims: Vec<(usize, usize, usize)>,
}

impl Roster {
    pub fn new(total: usize) -> Self {
        Self::ranged(0, total)
    }

    /// Roster over the global worker slice `[base, total)` — the shard
    /// tier's rendezvous, with claims still in global worker ids.
    pub fn ranged(base: usize, total: usize) -> Self {
        assert!(base < total, "roster needs at least one worker");
        Self { base, total, claims: Vec::new() }
    }

    /// Register `conn` as host of workers `[lo, hi)`.
    pub fn claim(&mut self, conn: usize, lo: usize, hi: usize) -> Result<(), ClaimError> {
        if lo >= hi {
            return Err(ClaimError::EmptyRange);
        }
        if lo < self.base || hi > self.total {
            return Err(ClaimError::OutOfRange);
        }
        for &(clo, chi, cconn) in &self.claims {
            if cconn == conn {
                return Err(ClaimError::AlreadyClaimed);
            }
            if lo < chi && clo < hi {
                return Err(ClaimError::Overlap);
            }
        }
        self.claims.push((lo, hi, conn));
        Ok(())
    }

    /// True once the claims cover `base..total` exactly.
    pub fn covered(&self) -> bool {
        let mut spans: Vec<(usize, usize)> = self.claims.iter().map(|&(l, h, _)| (l, h)).collect();
        spans.sort_unstable();
        let mut at = self.base;
        for (lo, hi) in spans {
            if lo != at {
                return false;
            }
            at = hi;
        }
        at == self.total
    }

    /// Connection hosting worker `w`, if claimed.
    pub fn owner_of(&self, w: usize) -> Option<usize> {
        self.claims.iter().find(|&&(lo, hi, _)| lo <= w && w < hi).map(|&(_, _, c)| c)
    }

    /// Worker range claimed by `conn`, if any.
    pub fn range_of(&self, conn: usize) -> Option<(usize, usize)> {
        self.claims.iter().find(|&&(_, _, c)| c == conn).map(|&(lo, hi, _)| (lo, hi))
    }

    /// Drop `conn`'s claim (it died), returning the freed range. The
    /// dead-conn bookkeeping calls this so a reconnecting agent can
    /// re-claim the range instead of bouncing off `Overlap` — the churn
    /// path elastic federation needs. Coverage regresses until someone
    /// re-claims, which is exactly right: a "covered" roster must mean
    /// *live* connections host every worker.
    pub fn release(&mut self, conn: usize) -> Option<(usize, usize)> {
        let at = self.claims.iter().position(|&(_, _, c)| c == conn)?;
        let (lo, hi, _) = self.claims.swap_remove(at);
        Some((lo, hi))
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

/// Per-round submission table: slot assignment in selection order,
/// idempotent-duplicate and deadline rejection, and partial-participation
/// bookkeeping. The payload side (losses/bits/messages/votes) lives with
/// the server; this table is the pure validation core.
#[derive(Clone, Debug, Default)]
pub struct RoundTable {
    t: usize,
    open: bool,
    /// Worker id → slot (`u32::MAX` = not selected). Length = population.
    slot_of: Vec<u32>,
    /// Slot → owning connection.
    owners: Vec<usize>,
    /// Slot → submission landed.
    filled: Vec<bool>,
    /// Slot → the round is still waiting on it. Set at [`Self::open`]
    /// for live-owned slots only, cleared exactly once when the slot is
    /// released (by [`Self::drop_conn`]/[`Self::settle_conn`]), so the
    /// settle-then-die sequence — a shard delivers its merged frame
    /// (settled) and is then marked dead in the same open round
    /// (dropped) — cannot decrement `expected` twice for one slot.
    awaited: Vec<bool>,
    received: usize,
    /// Live slots the round still waits for (dead-connection slots are
    /// excluded up front and when a connection drops mid-round).
    expected: usize,
    /// Typed rejects issued since the last [`RoundTable::take_rejects`],
    /// per connection (`rejects[conn][RejectReason::index()]`; grows on
    /// demand — an equivocating client is identified by *its* counters,
    /// not just the round total).
    rejects: Vec<[u64; REJECT_KINDS]>,
    /// Same rejects summed over connections.
    rejects_total: [u64; REJECT_KINDS],
}

impl RoundTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open round `t` over `selected` (slot order = selection order).
    /// `owners[k]` is the connection hosting slot `k`'s worker —
    /// `usize::MAX` marks a worker whose range has no live claimant
    /// (its host died and nobody re-claimed yet) — and `alive[conn]`
    /// its liveness. Unowned and dead-connection slots are excluded
    /// from `expected` *up front*, so a round never waits (and a
    /// deadline never has to expire) for a submission that cannot
    /// arrive.
    pub fn open(
        &mut self,
        t: usize,
        m: usize,
        selected: &[usize],
        owners: &[usize],
        alive: &[bool],
    ) {
        assert_eq!(selected.len(), owners.len(), "one owner per slot");
        self.t = t;
        self.open = true;
        self.slot_of.clear();
        self.slot_of.resize(m, u32::MAX);
        for (k, &w) in selected.iter().enumerate() {
            assert!(w < m, "selected worker {w} out of population {m}");
            assert_eq!(self.slot_of[w], u32::MAX, "worker {w} selected twice");
            self.slot_of[w] = k as u32;
        }
        self.owners.clear();
        self.owners.extend_from_slice(owners);
        self.filled.clear();
        self.filled.resize(selected.len(), false);
        self.awaited.clear();
        self.awaited.extend(
            owners.iter().map(|&c| c != usize::MAX && alive.get(c).copied().unwrap_or(false)),
        );
        self.received = 0;
        self.expected = self.awaited.iter().filter(|&&a| a).count();
    }

    /// Validate a submission for `(t, worker)` from `conn`; on success
    /// marks the slot filled and returns its index. Every rejection is
    /// tallied per connection and per kind before it is returned.
    pub fn submit(&mut self, t: usize, worker: usize, conn: usize) -> Result<usize, RejectReason> {
        match self.validate(t, worker, conn) {
            Ok(slot) => Ok(slot),
            Err(reason) => {
                self.note_reject(conn, reason);
                Err(reason)
            }
        }
    }

    fn validate(&mut self, t: usize, worker: usize, conn: usize) -> Result<usize, RejectReason> {
        let slot = self.peek(t, worker, conn)?;
        self.filled[slot] = true;
        self.received += 1;
        Ok(slot)
    }

    /// What [`Self::submit`] would answer for `(t, worker)` from `conn`
    /// — without claiming the slot or tallying a reject. The root uses
    /// this to vet every record of a shard's merged frame *before*
    /// applying any of them: a shard frame is all-or-nothing, so the
    /// vote accumulator and the filled slots can never diverge.
    pub fn peek(&self, t: usize, worker: usize, conn: usize) -> Result<usize, RejectReason> {
        if !self.open || t != self.t {
            // A stale round index on a closed table is the classic
            // straggler shape: the round it aimed for is gone.
            return Err(if t == self.t { RejectReason::Late } else { RejectReason::BadRound });
        }
        if worker >= self.slot_of.len() {
            return Err(RejectReason::UnknownWorker);
        }
        let slot = self.slot_of[worker];
        if slot == u32::MAX {
            return Err(RejectReason::NotSelected);
        }
        let slot = slot as usize;
        if self.owners[slot] != conn {
            return Err(RejectReason::WrongClient);
        }
        if self.filled[slot] {
            return Err(RejectReason::Duplicate);
        }
        Ok(slot)
    }

    fn note_reject(&mut self, conn: usize, reason: RejectReason) {
        if conn >= self.rejects.len() {
            self.rejects.resize(conn + 1, [0; REJECT_KINDS]);
        }
        self.rejects[conn][reason.index()] += 1;
        self.rejects_total[reason.index()] += 1;
    }

    /// Typed rejects issued to `conn` since the last [`Self::take_rejects`].
    pub fn rejects_of(&self, conn: usize) -> [u64; REJECT_KINDS] {
        self.rejects.get(conn).copied().unwrap_or([0; REJECT_KINDS])
    }

    /// Drain the accumulated per-kind reject totals (the server folds
    /// these into the [`crate::coordinator::CommLedger`] after each round
    /// closes; draining rather than reading keeps late post-close rejects
    /// counted exactly once, in the next fold).
    pub fn take_rejects(&mut self) -> [u64; REJECT_KINDS] {
        let out = self.rejects_total;
        self.rejects_total = [0; REJECT_KINDS];
        for per_conn in &mut self.rejects {
            *per_conn = [0; REJECT_KINDS];
        }
        out
    }

    /// A connection died mid-round: stop waiting for its unfilled slots.
    /// Idempotent, and safe after [`Self::settle_conn`] — each slot is
    /// released at most once (see `awaited`), so a shard that dies right
    /// after its frame settled cannot drive `expected` below zero.
    pub fn drop_conn(&mut self, conn: usize) {
        if !self.open {
            return;
        }
        for (k, &owner) in self.owners.iter().enumerate() {
            if owner == conn && self.awaited[k] && !self.filled[k] {
                self.awaited[k] = false;
                self.expected -= 1;
            }
        }
    }

    /// A live shard delivered its merged frame for this round: its
    /// unfilled slots are the workers that sat out (partial
    /// participation downstream), and exactly one frame arrives per
    /// shard per round — stop waiting for them so the root can close
    /// without running out the deadline. Same arithmetic as
    /// [`Self::drop_conn`], but the connection stays alive.
    pub fn settle_conn(&mut self, conn: usize) {
        self.drop_conn(conn);
    }

    /// Close the round (subsequent submissions are `Late`).
    pub fn close(&mut self) {
        self.open = false;
    }

    pub fn is_open(&self) -> bool {
        self.open
    }

    pub fn round(&self) -> usize {
        self.t
    }

    pub fn received(&self) -> usize {
        self.received
    }

    /// True once every live slot has its submission.
    pub fn complete(&self) -> bool {
        self.received >= self.expected
    }

    /// Slot-filled flags (ascending slot order) for compaction.
    pub fn filled(&self) -> &[bool] {
        &self.filled
    }

    /// Selected slots (live or dead) this round.
    pub fn slots(&self) -> usize {
        self.filled.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_walk_the_machine() {
        let mut p = PhaseTracker::new();
        assert_eq!(p.phase(), Phase::Standby);
        p.open_round(0);
        p.aggregate(0);
        p.broadcast(0);
        p.open_round(1);
        p.aggregate(1);
        p.broadcast(1);
        p.finish();
        assert_eq!(p.phase(), Phase::Finished);
    }

    #[test]
    #[should_panic(expected = "open_round")]
    fn cannot_open_round_while_aggregating() {
        let mut p = PhaseTracker::new();
        p.open_round(0);
        p.aggregate(0);
        p.open_round(1);
    }

    #[test]
    #[should_panic(expected = "first round must be 0")]
    fn first_round_must_be_zero() {
        let mut p = PhaseTracker::new();
        p.open_round(3);
    }

    #[test]
    fn roster_coverage_and_rejections() {
        let mut r = Roster::new(10);
        assert!(!r.covered());
        r.claim(0, 0, 4).unwrap();
        assert_eq!(r.claim(1, 3, 6), Err(ClaimError::Overlap));
        assert_eq!(r.claim(1, 5, 5), Err(ClaimError::EmptyRange));
        assert_eq!(r.claim(1, 8, 11), Err(ClaimError::OutOfRange));
        assert_eq!(r.claim(0, 4, 6), Err(ClaimError::AlreadyClaimed));
        r.claim(1, 4, 10).unwrap();
        assert!(r.covered());
        assert_eq!(r.owner_of(3), Some(0));
        assert_eq!(r.owner_of(4), Some(1));
        assert_eq!(r.owner_of(10), None);
        assert_eq!(r.range_of(1), Some((4, 10)));
        assert_eq!(r.range_of(9), None);
    }

    #[test]
    fn roster_gap_is_not_covered() {
        let mut r = Roster::new(6);
        r.claim(0, 0, 2).unwrap();
        r.claim(1, 3, 6).unwrap();
        assert!(!r.covered(), "gap at worker 2");
    }

    #[test]
    fn released_ranges_can_be_reclaimed() {
        let mut r = Roster::new(6);
        r.claim(0, 0, 3).unwrap();
        r.claim(1, 3, 6).unwrap();
        assert!(r.covered());
        // Conn 1 dies: its range frees up and coverage regresses.
        assert_eq!(r.release(1), Some((3, 6)));
        assert_eq!(r.release(1), None, "release is idempotent");
        assert!(!r.covered());
        assert_eq!(r.owner_of(4), None);
        // A reconnecting agent (fresh conn id) re-claims the same range.
        r.claim(2, 3, 6).unwrap();
        assert!(r.covered());
        assert_eq!(r.owner_of(4), Some(2));
    }

    #[test]
    fn ranged_roster_covers_its_slice_in_global_ids() {
        // A shard hosting workers 4..10 rosters that slice directly;
        // claims stay in global worker ids.
        let mut r = Roster::ranged(4, 10);
        assert_eq!(r.claim(0, 0, 4), Err(ClaimError::OutOfRange));
        assert_eq!(r.claim(0, 3, 5), Err(ClaimError::OutOfRange));
        r.claim(0, 4, 7).unwrap();
        assert!(!r.covered());
        r.claim(1, 7, 10).unwrap();
        assert!(r.covered());
        assert_eq!(r.owner_of(3), None);
        assert_eq!(r.owner_of(4), Some(0));
        assert_eq!(r.range_of(1), Some((7, 10)));
        // Ranged from zero is exactly the classic roster.
        let mut flat = Roster::ranged(0, 2);
        flat.claim(0, 0, 2).unwrap();
        assert!(flat.covered());
    }

    #[test]
    fn peek_matches_submit_without_claiming() {
        let mut tb = RoundTable::new();
        let alive = vec![true, true];
        tb.open(1, 4, &[2, 0], &[0, 1], &alive);
        // Peek agrees with submit on every outcome but mutates nothing.
        assert_eq!(tb.peek(0, 2, 0), Err(RejectReason::BadRound));
        assert_eq!(tb.peek(1, 3, 0), Err(RejectReason::NotSelected));
        assert_eq!(tb.peek(1, 2, 1), Err(RejectReason::WrongClient));
        assert_eq!(tb.peek(1, 2, 0), Ok(0));
        assert_eq!(tb.peek(1, 2, 0), Ok(0), "peek never claims the slot");
        assert_eq!(tb.received(), 0);
        assert_eq!(tb.take_rejects(), [0; REJECT_KINDS], "peek never tallies");
        assert_eq!(tb.submit(1, 2, 0), Ok(0));
        assert_eq!(tb.peek(1, 2, 0), Err(RejectReason::Duplicate));
    }

    #[test]
    fn settled_conn_stops_blocking_completion() {
        let mut tb = RoundTable::new();
        // Two shards, three selected workers each side of the cut.
        let alive = vec![true, true];
        tb.open(0, 6, &[0, 1, 3, 4], &[0, 0, 1, 1], &alive);
        assert_eq!(tb.submit(0, 0, 0), Ok(0));
        assert_eq!(tb.submit(0, 3, 1), Ok(2));
        assert_eq!(tb.submit(0, 4, 1), Ok(3));
        assert!(!tb.complete(), "worker 1 still owed");
        // Shard 0's merged frame arrived without worker 1 (it sat out):
        // settling the shard releases the slot, the shard stays usable.
        tb.settle_conn(0);
        assert!(tb.complete());
    }

    #[test]
    fn reopen_after_empty_aggregation_is_legal() {
        let mut p = PhaseTracker::new();
        p.open_round(0);
        p.aggregate(0);
        // Zero live submissions: re-broadcast the same round.
        p.reopen_round(0);
        p.aggregate(0);
        p.broadcast(0);
        p.open_round(1);
    }

    #[test]
    #[should_panic(expected = "reopen_round")]
    fn reopen_requires_an_aggregating_round() {
        let mut p = PhaseTracker::new();
        p.open_round(0);
        p.reopen_round(0);
    }

    #[test]
    fn resumed_tracker_continues_the_machine() {
        // Fresh resume = Standby; mid-run resume lands on Broadcast of
        // the last completed round, so the next open_round is legal.
        assert_eq!(PhaseTracker::resumed_at(0).phase(), Phase::Standby);
        let mut p = PhaseTracker::resumed_at(3);
        assert_eq!(p.phase(), Phase::Broadcast(2));
        p.open_round(3);
        p.aggregate(3);
        p.broadcast(3);
        p.finish();
    }

    #[test]
    fn unowned_slots_are_never_awaited() {
        let mut tb = RoundTable::new();
        let alive = vec![true];
        // Worker 1's host died and released its range before the round
        // opened: its slot carries the usize::MAX owner sentinel.
        tb.open(0, 3, &[0, 1, 2], &[0, usize::MAX, 0], &alive);
        assert_eq!(tb.submit(0, 0, 0), Ok(0));
        assert!(!tb.complete());
        assert_eq!(tb.submit(0, 2, 0), Ok(2));
        assert!(tb.complete(), "the orphaned slot must not stall the round");
        // The orphan slot still rejects impostors with a typed reason.
        assert_eq!(tb.submit(0, 1, 0), Err(RejectReason::WrongClient));
    }

    #[test]
    fn round_table_validates_submissions() {
        let mut tb = RoundTable::new();
        // Population 6, selection [4, 1, 5], conns: 0 hosts 0..3, 1 hosts 3..6.
        let alive = vec![true, true];
        tb.open(2, 6, &[4, 1, 5], &[1, 0, 1], &alive);
        assert!(tb.is_open() && !tb.complete());
        assert_eq!(tb.submit(1, 4, 1), Err(RejectReason::BadRound));
        assert_eq!(tb.submit(2, 0, 0), Err(RejectReason::NotSelected));
        assert_eq!(tb.submit(2, 9, 0), Err(RejectReason::UnknownWorker));
        assert_eq!(tb.submit(2, 4, 0), Err(RejectReason::WrongClient));
        assert_eq!(tb.submit(2, 4, 1), Ok(0));
        assert_eq!(tb.submit(2, 4, 1), Err(RejectReason::Duplicate));
        assert_eq!(tb.submit(2, 1, 0), Ok(1));
        assert_eq!(tb.submit(2, 5, 1), Ok(2));
        assert!(tb.complete());
        assert_eq!(tb.received(), 3);
        tb.close();
        assert_eq!(tb.submit(2, 5, 1), Err(RejectReason::Late));
        assert_eq!(tb.filled(), &[true, true, true]);
    }

    #[test]
    fn rejects_are_tallied_per_connection_and_kind() {
        let mut tb = RoundTable::new();
        let alive = vec![true, true];
        tb.open(2, 6, &[4, 1, 5], &[1, 0, 1], &alive);
        // Conn 0 probes another client's worker and an unknown id; conn 1
        // double-submits.
        assert!(tb.submit(2, 4, 0).is_err()); // WrongClient
        assert!(tb.submit(2, 9, 0).is_err()); // UnknownWorker
        assert!(tb.submit(2, 4, 1).is_ok());
        assert!(tb.submit(2, 4, 1).is_err()); // Duplicate
        assert!(tb.submit(1, 1, 0).is_err()); // BadRound
        let c0 = tb.rejects_of(0);
        assert_eq!(c0[RejectReason::WrongClient.index()], 1);
        assert_eq!(c0[RejectReason::UnknownWorker.index()], 1);
        assert_eq!(c0[RejectReason::BadRound.index()], 1);
        let c1 = tb.rejects_of(1);
        assert_eq!(c1[RejectReason::Duplicate.index()], 1);
        assert_eq!(c1.iter().sum::<u64>(), 1);
        // Unseen connections read as zero.
        assert_eq!(tb.rejects_of(9), [0; REJECT_KINDS]);

        // Draining returns the totals once and resets both layers.
        let total = tb.take_rejects();
        assert_eq!(total.iter().sum::<u64>(), 4);
        assert_eq!(total[RejectReason::Duplicate.index()], 1);
        assert_eq!(tb.take_rejects(), [0; REJECT_KINDS]);
        assert_eq!(tb.rejects_of(0), [0; REJECT_KINDS]);

        // A post-close straggler lands in the next drain, not nowhere.
        tb.close();
        assert_eq!(tb.submit(2, 5, 1), Err(RejectReason::Late));
        assert_eq!(tb.take_rejects()[RejectReason::Late.index()], 1);
    }

    #[test]
    fn dead_connections_shrink_expectations() {
        let mut tb = RoundTable::new();
        let alive = vec![true, false];
        tb.open(0, 4, &[0, 1, 3], &[0, 0, 1], &alive);
        // Conn 1 was dead at open: only 2 live slots expected.
        assert_eq!(tb.submit(0, 0, 0), Ok(0));
        assert!(!tb.complete());
        assert_eq!(tb.submit(0, 1, 0), Ok(1));
        assert!(tb.complete());
        assert_eq!(tb.received(), 2);

        // Mid-round drop: conn 0 dies after filling one of its two slots.
        let alive = vec![true, true];
        tb.open(1, 4, &[0, 1, 3], &[0, 0, 1], &alive);
        assert_eq!(tb.submit(1, 0, 0), Ok(0));
        tb.drop_conn(0);
        assert!(!tb.complete());
        assert_eq!(tb.submit(1, 3, 1), Ok(2));
        assert!(tb.complete(), "slot 1 no longer awaited");
    }

    #[test]
    fn settle_then_drop_releases_each_slot_once() {
        // The settle-then-die sequence: shard 0's merged frame arrives
        // without one of its workers (settled), then the shard dies in
        // the same open round (dropped). Before the `awaited` flags this
        // decremented `expected` twice for the unfilled slot —
        // underflowing the counter and wedging the round.
        let mut tb = RoundTable::new();
        let alive = vec![true, true];
        tb.open(0, 6, &[0, 1, 3, 4], &[0, 0, 1, 1], &alive);
        assert_eq!(tb.submit(0, 0, 0), Ok(0));
        tb.settle_conn(0); // frame applied; worker 1 sat out
        tb.drop_conn(0); // the shard dies before the round closes
        assert!(!tb.complete(), "shard 1 still owes two slots");
        assert_eq!(tb.submit(0, 3, 1), Ok(2));
        assert_eq!(tb.submit(0, 4, 1), Ok(3));
        assert!(tb.complete());
        // Repeated drops of either connection stay no-ops.
        tb.drop_conn(0);
        tb.drop_conn(1);
        assert!(tb.complete());
        assert_eq!(tb.received(), 3);
    }
}
