//! Structured per-round event log (DESIGN.md §15): one flat JSON object
//! per line, flushed per event, written by the coordinator as protocol
//! events happen. The `soak` supervisor tails it to key process kills
//! to *round boundaries* (never wall-clock offsets), and operators get
//! the same observable record a harness does.
//!
//! The vocabulary is the flat `{"key": number-or-string}` JSON that
//! [`crate::metrics::parse_flat_json`] already reads — every line
//! carries `"event"` plus numeric fields. Events:
//!
//! ```text
//! serve_start  resumed, round            coordinator up (resumed=1 after --resume)
//! round_open   t, attempt                cohort broadcast (attempt>0 = re-broadcast)
//! round_close  t, senders, stragglers,   round finished and applied
//!              up_bytes, down_bytes,
//!              shard_up, shard_down,
//!              rejects, snap_age
//! recoverage   t, attempt                waiting for the fleet to re-cover the population
//! conn_dead    conn, shard, lo, hi       a connection died (lo/hi if it held a claim)
//! reclaim      conn, shard, lo, hi       a claim was accepted (rendezvous or respawn)
//! snapshot     t                         snapshot written after round t closed
//! drain        rounds                    graceful drain exit (no Fin)
//! fin          rounds                    run complete, Fin broadcast
//! ```
//!
//! A SIGKILL can tear the final line; [`EventLog::append`] therefore
//! starts with a newline so the successor's first event never fuses
//! with a torn tail, and readers skip lines that fail to parse.
//!
//! The same drivers that emit here also feed the live scrape counters
//! in [`crate::metrics::registry::MetricsRegistry`] — the event log is
//! the durable record, the registry is the instantaneous one; both
//! observe the same protocol facts at the same call sites.

use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

/// Append-only JSONL event sink; cheap no-op when disabled (`None` in
/// the options structs). Interior mutability so the single-threaded
/// drivers can emit from `&self` contexts.
pub struct EventLog {
    inner: Mutex<BufWriter<File>>,
}

impl fmt::Debug for EventLog {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("EventLog").finish_non_exhaustive()
    }
}

impl EventLog {
    /// Create/truncate the log at `path` (a fresh run).
    pub fn create(path: &Path) -> std::io::Result<Self> {
        let f = File::create(path)?;
        Ok(Self { inner: Mutex::new(BufWriter::new(f)) })
    }

    /// Open `path` for append (a resumed coordinator keeps the
    /// predecessor's record). Leads with a newline to neutralize a torn
    /// final line from a SIGKILLed predecessor.
    pub fn append(path: &Path) -> std::io::Result<Self> {
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        let log = Self { inner: Mutex::new(BufWriter::new(f)) };
        {
            let mut w = log.inner.lock().unwrap_or_else(|e| e.into_inner());
            let _ = w.write_all(b"\n");
            let _ = w.flush();
        }
        Ok(log)
    }

    /// Emit one event line and flush it (a supervisor keyed to the log
    /// must see events as they happen, and a kill must lose at most the
    /// line being written). I/O errors are swallowed: observability
    /// must never fail the run it observes.
    pub fn emit(&self, event: &str, fields: &[(&str, u64)]) {
        let mut line = String::with_capacity(64);
        line.push_str("{\"event\": \"");
        line.push_str(event);
        line.push('"');
        for (k, v) in fields {
            line.push_str(", \"");
            line.push_str(k);
            line.push_str("\": ");
            line.push_str(&v.to_string());
        }
        line.push_str("}\n");
        let mut w = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let _ = w.write_all(line.as_bytes());
        let _ = w.flush();
    }
}

/// Parse an event-log body into `(event, fields)` records, skipping
/// blank and torn lines — the reader half of the contract, shared by
/// the soak supervisor and the tests.
pub fn parse_events(body: &str) -> Vec<(String, Vec<(String, f64)>)> {
    let mut out = Vec::new();
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(kvs) = crate::metrics::parse_flat_json(line) else { continue };
        let mut event = String::new();
        let mut fields = Vec::new();
        for (k, v) in kvs {
            match v {
                crate::metrics::FlatVal::Str(s) if k == "event" => event = s,
                crate::metrics::FlatVal::Num(n) => fields.push((k, n)),
                crate::metrics::FlatVal::Str(_) => {}
            }
        }
        if !event.is_empty() {
            out.push((event, fields));
        }
    }
    out
}

/// Convenience: the value of `field` in an `(event, fields)` record.
pub fn event_field(fields: &[(String, f64)], name: &str) -> Option<f64> {
    fields.iter().find(|(k, _)| k == name).map(|&(_, v)| v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_parseable_flat_json_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("sparsignd-ev-{}.jsonl", std::process::id()));
        let log = EventLog::create(&path).unwrap();
        log.emit("serve_start", &[("resumed", 0), ("round", 0)]);
        log.emit("round_close", &[("t", 3), ("senders", 9), ("stragglers", 1)]);
        drop(log);
        let body = std::fs::read_to_string(&path).unwrap();
        let evs = parse_events(&body);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].0, "serve_start");
        assert_eq!(event_field(&evs[0].1, "resumed"), Some(0.0));
        assert_eq!(evs[1].0, "round_close");
        assert_eq!(event_field(&evs[1].1, "senders"), Some(9.0));
        assert_eq!(event_field(&evs[1].1, "missing"), None);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn append_survives_a_torn_tail() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("sparsignd-ev-torn-{}.jsonl", std::process::id()));
        // A predecessor died mid-write: the file ends in half a line.
        std::fs::write(&path, "{\"event\": \"round_close\", \"t\": 0}\n{\"event\": \"rou").unwrap();
        let log = EventLog::append(&path).unwrap();
        log.emit("serve_start", &[("resumed", 1), ("round", 1)]);
        drop(log);
        let body = std::fs::read_to_string(&path).unwrap();
        let evs = parse_events(&body);
        assert_eq!(evs.len(), 2, "torn line skipped, successor line intact");
        assert_eq!(evs[0].0, "round_close");
        assert_eq!(evs[1].0, "serve_start");
        assert_eq!(event_field(&evs[1].1, "resumed"), Some(1.0));
        std::fs::remove_file(&path).unwrap();
    }
}
