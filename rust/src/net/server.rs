//! The coordinator service (DESIGN.md §11): Algorithm 1's parameter
//! server behind a real socket.
//!
//! An accept loop (TCP or UDS) hands each connection to a reader thread.
//! Readers decode update frames **directly into the streaming
//! aggregation path**: the ternary bitplanes land in a per-reader
//! scratch [`PackedTernary`] and fold into the shared
//! [`VoteAccumulator`] under the round gate's mutex — the server never
//! buffers the round's `n` messages on the unit-scale fast path, exactly
//! like the PR 3 pool engine. Per-slot scalars (loss, bit cost, nnz) are
//! recorded in selection-slot order, so the shared
//! `RoundLoop::finish_round` tail reduces them in the same order as
//! the in-process engine and the resulting `RunHistory` is
//! bit-identical on the same seed (`tests/net_loopback.rs`).
//!
//! Fault handling: duplicate submissions are rejected idempotently,
//! frames for a closed round are rejected as `Late`, a dead connection's
//! pending slots stop being awaited, and a round closes at its deadline
//! with partial participation — stragglers are counted in the ledger
//! (`CommLedger::annotate_wire`), alongside the actual framed byte
//! traffic.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compressors::{CompressedGrad, PackedTernary};
use crate::coordinator::{RoundLoop, RunHistory, TrainingRun, VoteAccumulator, WorkerSampler};
use crate::snapshot::{CoordinatorSnapshot, SnapshotPolicy};

use super::protocol::{PhaseTracker, Roster, RoundTable};
use super::wire::{self, Msg, MsgType, RejectReason, WireBuf};
use super::{read_frame_bytes, Endpoint, Listener, NetError, Stream};

/// Coordinator service configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address.
    pub endpoint: Endpoint,
    /// Per-round submission deadline; `None` waits for every live
    /// selected worker (the loopback-equivalence configuration).
    pub round_deadline: Option<Duration>,
    /// How long to wait for the fleet to cover the worker population.
    pub rendezvous_timeout: Duration,
    /// Frame payload cap handed to the decoder.
    pub max_payload: usize,
    /// Coordinator snapshot policy (DESIGN.md §12): periodic every-k
    /// writes and/or the drain-time write. `None` disables snapshots.
    pub snapshot: Option<SnapshotPolicy>,
    /// Graceful drain: finish round `drain_after - 1` (i.e. complete
    /// `drain_after` rounds), write a snapshot if a policy is set, close
    /// every connection *without* `Fin`, and return
    /// [`NetError::Drained`]. The SIGTERM-shaped exit a supervisor uses
    /// before handing the endpoint to a `--resume` successor.
    pub drain_after: Option<usize>,
    /// Resume from a restored snapshot instead of `init` (which then
    /// only supplies the expected dimension). The snapshot is
    /// revalidated against the run's config fingerprint.
    pub resume: Option<CoordinatorSnapshot>,
    /// Environment fingerprint mixed into snapshot fingerprints
    /// ([`crate::coordinator::GradientSource::env_fingerprint`] of the
    /// dataset both sides were built from). The coordinator itself never
    /// sees the data, so the caller supplies it — the `serve` CLI sets
    /// it from the env it constructs; 0 (the default) disables the
    /// environment check but keeps every other fingerprint guard.
    pub env_fingerprint: u64,
}

impl ServeOptions {
    pub fn new(endpoint: Endpoint) -> Self {
        Self {
            endpoint,
            round_deadline: None,
            rendezvous_timeout: Duration::from_secs(30),
            max_payload: wire::MAX_PAYLOAD,
            snapshot: None,
            drain_after: None,
            resume: None,
            env_fingerprint: 0,
        }
    }
}

/// One registered connection: the writer half plus its identity. The
/// reader half lives in the connection's reader thread.
struct ConnHandle {
    id: usize,
    writer: Mutex<Stream>,
}

/// Shared round state behind one mutex: the pure submission table plus
/// the payload slots and the streaming vote accumulator. Readers mutate
/// it frame-by-frame; the coordinator opens/closes rounds and extracts.
struct Gate {
    d: usize,
    streaming: bool,
    table: RoundTable,
    losses: Vec<f64>,
    bits: Vec<f64>,
    nnz: Vec<usize>,
    msgs: Vec<Option<CompressedGrad>>,
    votes: VoteAccumulator,
    up_bytes: u64,
}

/// Reader/accept → coordinator notifications.
enum Ev {
    /// A connection was accepted and its reader thread started.
    Conn(Arc<ConnHandle>),
    /// Rendezvous claim for workers `[lo, hi)` with the claimant's
    /// run-config and environment fingerprints.
    Hello { conn: usize, lo: u64, hi: u64, cfg: u64, env: u64 },
    /// Liveness ping.
    Beat { conn: usize },
    /// A submission was accepted into the gate.
    Progress,
    /// Connection closed (EOF, IO error, or protocol violation).
    Gone { conn: usize },
}

/// A bound-but-not-yet-serving coordinator; binding first lets callers
/// learn the resolved endpoint (`:0` TCP picks a free port) before the
/// fleet dials in.
pub struct NetCoordinator {
    listener: Listener,
    local: Endpoint,
    opts: ServeOptions,
}

impl NetCoordinator {
    /// Bind the accept socket.
    pub fn bind(opts: ServeOptions) -> Result<Self, NetError> {
        let listener = Listener::bind(&opts.endpoint)?;
        let local = listener.local_endpoint(&opts.endpoint);
        Ok(Self { listener, local, opts })
    }

    /// The resolved bind address (dial this).
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.local
    }

    /// Run `run.rounds` federated rounds over the socket and return the
    /// run history. `workers` is the population M the fleet must cover;
    /// `eval` is the server-side test evaluation (exactly as
    /// `TrainingRun::run` takes it).
    pub fn serve(
        self,
        run: &TrainingRun,
        workers: usize,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
    ) -> Result<RunHistory, NetError> {
        let NetCoordinator { listener, local, mut opts } = self;
        let d = init.len();
        let n_max = WorkerSampler::new(workers, run.participation).per_round();
        let streaming = run.streams_votes(n_max);
        if opts.snapshot.is_some() || opts.resume.is_some() {
            // The snapshot covers server-side state only; stateful
            // worker compressors live in the clients and cannot ride it.
            run.require_snapshot_support(&run.build_worker_comps(d, 1))
                .map_err(NetError::Snapshot)?;
        }
        let env_tag = opts.env_fingerprint;
        let lp = match opts.resume.take() {
            Some(snap) => RoundLoop::resume(run, d, workers, streaming, env_tag, snap)
                .map_err(NetError::Snapshot)?,
            None => RoundLoop::new(run, d, workers, streaming, env_tag, init),
        };
        let opts = &opts;
        let listener = &listener;
        listener.set_nonblocking(true)?;
        let gate = Mutex::new(Gate {
            d,
            streaming,
            table: RoundTable::new(),
            losses: Vec::new(),
            bits: Vec::new(),
            nnz: Vec::new(),
            msgs: Vec::new(),
            votes: VoteAccumulator::new(),
            up_bytes: 0,
        });
        let accepting = AtomicBool::new(true);
        let (tx, rx) = mpsc::channel::<Ev>();
        let max_payload = opts.max_payload;

        let result = std::thread::scope(|s| {
            // Accept loop: registers the writer half, spawns the reader
            // thread (the scope handle is Sync, so nested spawns are
            // fine), and tells the coordinator.
            let gate_ref = &gate;
            let accepting_ref = &accepting;
            let acc_tx = tx.clone();
            let acc_handle = s.spawn(move || {
                let mut next_id = 0usize;
                while accepting_ref.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok(Some(stream)) => {
                            let Ok(reader) = stream.try_clone() else { continue };
                            let writer = Mutex::new(stream);
                            let h = Arc::new(ConnHandle { id: next_id, writer });
                            next_id += 1;
                            if acc_tx.send(Ev::Conn(h.clone())).is_err() {
                                return;
                            }
                            let rd_tx = acc_tx.clone();
                            s.spawn(move || {
                                let shape = (d, streaming);
                                reader_loop(&h, reader, gate_ref, &rd_tx, max_payload, shape);
                            });
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(2)),
                        Err(_) => return,
                    }
                }
            });

            let phase = PhaseTracker::resumed_at(lp.start_round());
            let drv = Driver {
                run,
                m: workers,
                lp,
                opts,
                gate: &gate,
                rx: &rx,
                phase,
                roster: Roster::new(workers),
                conns: Vec::new(),
                alive: Vec::new(),
                wbuf: WireBuf::new(),
                frame: Vec::new(),
            };
            let (out, conns) = drv.drive(eval);
            // Stop accepting and unblock every reader regardless of how
            // the run ended, or the scope would join forever. Connections
            // the accept loop registered but the driver never processed
            // (they sit in the channel) get shut down too — join the
            // accept thread first so no further ones appear.
            accepting.store(false, Ordering::SeqCst);
            let _ = acc_handle.join();
            while let Ok(ev) = rx.try_recv() {
                if let Ev::Conn(h) = ev {
                    h.writer.lock().unwrap_or_else(|e| e.into_inner()).shutdown();
                }
            }
            for c in &conns {
                c.writer.lock().unwrap_or_else(|e| e.into_inner()).shutdown();
            }
            out
        });

        // A UDS socket file outlives its listener; clean up.
        #[cfg(unix)]
        if let Endpoint::Uds(path) = &local {
            let _ = std::fs::remove_file(path);
        }
        #[cfg(not(unix))]
        let _ = &local;
        result
    }
}

/// The coordinator proper: rendezvous, then the round loop over the
/// shared [`RoundLoop`] tail.
struct Driver<'a> {
    run: &'a TrainingRun,
    m: usize,
    lp: RoundLoop<'a>,
    opts: &'a ServeOptions,
    gate: &'a Mutex<Gate>,
    rx: &'a mpsc::Receiver<Ev>,
    phase: PhaseTracker,
    roster: Roster,
    conns: Vec<Arc<ConnHandle>>,
    alive: Vec<bool>,
    wbuf: WireBuf,
    frame: Vec<u8>,
}

type DriveOutcome = (Result<RunHistory, NetError>, Vec<Arc<ConnHandle>>);

impl<'a> Driver<'a> {
    /// Run the whole protocol; consumes the driver so the finished
    /// `RoundLoop` moves out without a placeholder. Returns the
    /// connection handles alongside so the caller can shut them down.
    fn drive(mut self, eval: &dyn Fn(&[f32]) -> (f64, f64)) -> DriveOutcome {
        let res = self.run_protocol(eval);
        let out = match res {
            Ok(()) => {
                let label = self.run.algorithm.label();
                let d = self.lp.params.len();
                Ok(self.lp.into_history(label, d))
            }
            Err(e) => Err(e),
        };
        (out, self.conns)
    }

    fn run_protocol(&mut self, eval: &dyn Fn(&[f32]) -> (f64, f64)) -> Result<(), NetError> {
        self.rendezvous()?;
        // A resumed coordinator starts at the snapshot's next round; the
        // reconnected fleet recomputes that round from the same
        // (seed, round, worker) RNG streams, so nothing is lost even if
        // the dead coordinator had already opened it.
        let start = self.lp.start_round();
        for t in start..self.run.rounds {
            self.round(t, eval)?;
            let done = t + 1;
            // `>=` rather than `==`: a resumed coordinator whose start
            // round is already past the drain mark drains after its
            // first completed round instead of silently never draining.
            let draining =
                self.opts.drain_after.map_or(false, |n| done >= n) && done < self.run.rounds;
            if let Some(policy) = &self.opts.snapshot {
                if policy.due(done, self.run.rounds) || draining {
                    self.lp.to_snapshot().save(&policy.path).map_err(NetError::Snapshot)?;
                }
            }
            if draining {
                // Graceful SIGTERM-style drain: the round is complete and
                // snapshotted; exit without Fin so the fleet reconnects
                // to the successor coordinator.
                return Err(NetError::Drained { rounds_done: done });
            }
        }
        // Rejects issued after the final round closed (a straggler's
        // stale replay, say) would otherwise be dropped on the floor.
        self.fold_rejects();
        // Fin + state machine epilogue.
        let fin = Msg::Fin { rounds: self.run.rounds as u64 };
        for id in 0..self.conns.len() {
            if self.alive[id] {
                let _ = self.send(id, &fin);
            }
        }
        self.phase.finish();
        Ok(())
    }

    /// Wait until the fleet covers the worker population.
    fn rendezvous(&mut self) -> Result<(), NetError> {
        let deadline = Instant::now() + self.opts.rendezvous_timeout;
        while !self.roster.covered() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(NetError::Protocol("rendezvous timeout".into()));
            }
            match self.rx.recv_timeout(left.min(Duration::from_millis(200))) {
                Ok(ev) => self.on_event(ev, None)?,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(NetError::Protocol("accept loop died".into()));
                }
            }
        }
        Ok(())
    }

    /// One federated round over the wire.
    fn round(&mut self, t: usize, eval: &dyn Fn(&[f32]) -> (f64, f64)) -> Result<(), NetError> {
        // Drain queued notifications first: a connection that died (or an
        // agent that re-claimed a freed range) between rounds must be
        // reflected in the expectations *before* they are set, not
        // discovered while the deadline runs down.
        while let Ok(ev) = self.rx.try_recv() {
            self.on_event(ev, Some(t))?;
        }
        let run = self.run;
        let lr = run.schedule.at(t);
        // Selection is drawn exactly once per round (the RNG stream is
        // part of the determinism contract); a re-broadcast after an
        // all-hosts-dead attempt reuses the same cohort.
        let n = self.lp.select(t);
        self.phase.open_round(t);
        let mut down_bytes = 0u64;
        let mut sel_ids: Vec<u64> = Vec::new();
        let mut attempts = 0usize;

        loop {
            // Slot owners come from the rendezvous roster. A worker whose
            // host died (its claim was released) and has no replacement
            // yet gets the unowned sentinel — a straggler from the start,
            // never awaited.
            let owners: Vec<usize> = self.lp.server.selected[..n]
                .iter()
                .map(|&w| self.roster.owner_of(w).unwrap_or(usize::MAX))
                .collect();
            {
                let mut g = self.gate.lock().unwrap_or_else(|e| e.into_inner());
                g.table.open(t, self.m, &self.lp.server.selected[..n], &owners, &self.alive);
                if g.streaming {
                    g.votes.reset(g.d, n);
                }
                g.losses.clear();
                g.losses.resize(n, 0.0);
                g.bits.clear();
                g.bits.resize(n, 0.0);
                g.nnz.clear();
                g.nnz.resize(n, 0);
                g.msgs.clear();
                g.msgs.resize(n, None);
                g.up_bytes = 0;
            }

            // Broadcast: per-connection selection subset + the model.
            let deadline_ms =
                self.opts.round_deadline.map(|d| d.as_millis() as u64).unwrap_or(0);
            for id in 0..self.conns.len() {
                if !self.alive[id] {
                    continue;
                }
                let Some((lo, hi)) = self.roster.range_of(id) else { continue };
                sel_ids.clear();
                for &w in &self.lp.server.selected[..n] {
                    if lo <= w && w < hi {
                        sel_ids.push(w as u64);
                    }
                }
                self.frame.clear();
                let len = self.wbuf.encode_round_open(
                    t as u64,
                    lr,
                    deadline_ms,
                    &sel_ids,
                    &self.lp.params,
                    &mut self.frame,
                );
                let ok = {
                    let mut w =
                        self.conns[id].writer.lock().unwrap_or_else(|e| e.into_inner());
                    std::io::Write::write_all(&mut *w, &self.frame).is_ok()
                };
                if ok {
                    down_bytes += len as u64;
                } else {
                    self.mark_dead(id);
                }
            }
            self.phase.aggregate(t);

            // Collect until every live slot filled or the deadline expires.
            let hard_deadline = self.opts.round_deadline.map(|d| Instant::now() + d);
            loop {
                {
                    let g = self.gate.lock().unwrap_or_else(|e| e.into_inner());
                    if g.table.complete() {
                        break;
                    }
                }
                let wait = match hard_deadline {
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        left.min(Duration::from_millis(200))
                    }
                    None => Duration::from_millis(200),
                };
                match self.rx.recv_timeout(wait) {
                    Ok(ev) => self.on_event(ev, Some(t))?,
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(NetError::Protocol("accept loop died".into()));
                    }
                }
            }

            // Close the round and compact filled slots into the shared
            // RoundLoop buffers (ascending slot order = selection order,
            // the same deterministic reduction order the in-process
            // engine uses).
            let (n_eff, stragglers, up_bytes) = {
                let mut g = self.gate.lock().unwrap_or_else(|e| e.into_inner());
                let g = &mut *g;
                g.table.close();
                let mut k_new = 0usize;
                for k in 0..n {
                    if g.table.filled()[k] {
                        self.lp.server.losses[k_new] = g.losses[k];
                        self.lp.server.bits[k_new] = g.bits[k];
                        self.lp.server.nnz[k_new] = g.nnz[k];
                        self.lp.server.msgs[k_new] = g.msgs[k].take();
                        k_new += 1;
                    }
                }
                if g.streaming && k_new > 0 {
                    g.votes.counts_into(&mut self.lp.server.counts);
                }
                (k_new, n - k_new, g.up_bytes)
            };
            if n_eff == 0 {
                // Zero live submissions. A covered roster means the
                // cohort's hosts are alive yet silent — fatal, exactly as
                // before. An uncovered one means every host died: give
                // the fleet's reconnect-with-backoff one bounded
                // re-rendezvous window to re-claim, then re-broadcast
                // the same round (worker rounds are pure, so recomputing
                // is harmless). Capped so a pathologically flapping
                // fleet cannot spin a round forever.
                attempts += 1;
                if self.roster.covered() || attempts >= 3 {
                    return Err(NetError::Protocol(format!(
                        "round {t}: no submissions arrived"
                    )));
                }
                self.phase.reopen_round(t);
                self.await_recoverage(t)?;
                continue;
            }
            self.lp.finish_round(t, lr, n_eff, eval, &mut None);
            self.lp.ledger.annotate_wire(t, up_bytes, down_bytes, stragglers);
            self.fold_rejects();
            self.phase.broadcast(t);
            return Ok(());
        }
    }

    /// After an all-hosts-dead round attempt: wait (bounded by the
    /// rendezvous timeout) for reconnecting agents to re-claim until the
    /// roster covers the population again.
    fn await_recoverage(&mut self, t: usize) -> Result<(), NetError> {
        let deadline = Instant::now() + self.opts.rendezvous_timeout;
        while !self.roster.covered() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(NetError::Protocol(format!(
                    "round {t}: no submissions arrived and the fleet did not re-cover \
                     the population"
                )));
            }
            match self.rx.recv_timeout(left.min(Duration::from_millis(200))) {
                Ok(ev) => self.on_event(ev, Some(t))?,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(NetError::Protocol("accept loop died".into()));
                }
            }
        }
        Ok(())
    }

    /// Handle one notification. `round` is the currently-aggregating
    /// round (heartbeat acks echo it), `None` during rendezvous.
    fn on_event(&mut self, ev: Ev, round: Option<usize>) -> Result<(), NetError> {
        match ev {
            Ev::Conn(h) => {
                debug_assert_eq!(h.id, self.conns.len(), "conn ids are arrival-ordered");
                self.conns.push(h);
                self.alive.push(true);
            }
            Ev::Hello { conn, lo, hi, cfg, env } => {
                // A fleet built from drifted flags (different seed,
                // schedule, compressor, dataset α/batch, …) must be
                // refused at rendezvous: the coordinator cannot see the
                // clients' data, so the fingerprints carry the proof.
                // The env check only arms when the caller supplied its
                // own environment hash (the CLI always does).
                let want_cfg = self.run.config_fingerprint(self.lp.params.len(), self.m, 0);
                let env_ok =
                    self.opts.env_fingerprint == 0 || env == self.opts.env_fingerprint;
                if cfg != want_cfg || !env_ok {
                    self.hangup(conn);
                    return Ok(());
                }
                let claim = usize::try_from(lo)
                    .ok()
                    .zip(usize::try_from(hi).ok())
                    .map(|(l, h)| self.roster.claim(conn, l, h));
                match claim {
                    // A valid claim is welcomed during rendezvous AND
                    // mid-run: a dead connection's range is released by
                    // the dead-conn bookkeeping, so a reconnecting agent
                    // re-claims it and rejoins from the next round — the
                    // churn path elastic federation (and a restarted
                    // coordinator's re-rostering) depends on.
                    Some(Ok(())) => {
                        let msg = Msg::Welcome {
                            client_id: conn as u64,
                            workers: self.m as u64,
                            dim: self.lp.params.len() as u64,
                            rounds: self.run.rounds as u64,
                            // Committed-seed selection broadcasts its
                            // root-key commitment at rendezvous (all
                            // zeros in legacy mode) so clients can later
                            // audit the selection stream (DESIGN.md §13).
                            commit: self.lp.selection_commitment(),
                        };
                        if self.send(conn, &msg).is_err() {
                            self.mark_dead(conn);
                        }
                    }
                    // Bad claims (overlap with a live host, bad range)
                    // are hung up on; the reader thread turns the
                    // shutdown into `Gone`.
                    _ => self.hangup(conn),
                }
            }
            Ev::Beat { conn } => {
                let t = round.unwrap_or(0) as u64;
                let _ = self.send(conn, &Msg::Ack { t, worker: conn as u64 });
            }
            Ev::Progress => {}
            Ev::Gone { conn } => self.mark_dead(conn),
        }
        Ok(())
    }

    /// Drain the round table's typed-reject tallies into the ledger's
    /// cumulative per-kind counters (surfaced by `history_json` and the
    /// adversarial tests).
    fn fold_rejects(&mut self) {
        let rejects = {
            let mut g = self.gate.lock().unwrap_or_else(|e| e.into_inner());
            g.table.take_rejects()
        };
        self.lp.ledger.add_rejects(&rejects);
    }

    fn send(&mut self, conn: usize, msg: &Msg) -> Result<usize, NetError> {
        self.frame.clear();
        let len = self.wbuf.encode(msg, &mut self.frame);
        let mut w = self.conns[conn].writer.lock().unwrap_or_else(|e| e.into_inner());
        std::io::Write::write_all(&mut *w, &self.frame)?;
        Ok(len)
    }

    fn hangup(&mut self, conn: usize) {
        if let Some(h) = self.conns.get(conn) {
            h.writer.lock().unwrap_or_else(|e| e.into_inner()).shutdown();
        }
    }

    fn mark_dead(&mut self, conn: usize) {
        if conn < self.alive.len() && self.alive[conn] {
            self.alive[conn] = false;
            self.hangup(conn);
            // Free the range so a reconnecting agent can re-claim it,
            // and stop awaiting the open round's unfilled slots — both
            // immediately, not at the deadline.
            self.roster.release(conn);
            let mut g = self.gate.lock().unwrap_or_else(|e| e.into_inner());
            g.table.drop_conn(conn);
        }
    }
}

/// Per-connection reader: frames → validated protocol events. Update
/// payloads are decoded into the per-reader scratch *before* the gate
/// lock (readers parallelize the O(d) unpack work); the slot claim and
/// the vote fold then happen under the lock, so a round that closes
/// never loses a submission it already counted. `shape` is the run's
/// `(d, streaming)` pair, immutable for the whole serve.
fn reader_loop(
    h: &Arc<ConnHandle>,
    mut reader: Stream,
    gate: &Mutex<Gate>,
    tx: &mpsc::Sender<Ev>,
    max_payload: usize,
    shape: (usize, bool),
) {
    let mut buf = Vec::new();
    let mut pack = PackedTernary::zeros(0, 1.0);
    let mut wbuf = WireBuf::new();
    let mut out = Vec::new();
    loop {
        let Ok(len) = read_frame_bytes(&mut reader, max_payload, &mut buf) else { break };
        let Ok((frame, _)) = wire::parse_frame(&buf[..len], max_payload) else { break };
        match frame.msg_type {
            MsgType::Hello => {
                let Ok(Msg::Hello { lo, hi, cfg, env }) = wire::decode_msg(frame) else { break };
                if tx.send(Ev::Hello { conn: h.id, lo, hi, cfg, env }).is_err() {
                    break;
                }
            }
            MsgType::Heartbeat => {
                if tx.send(Ev::Beat { conn: h.id }).is_err() {
                    break;
                }
            }
            MsgType::Update => {
                let Ok(uv) = wire::decode_update(frame.payload) else { break };
                match submit_update(h.id, &uv, len as u64, shape, gate, &mut pack) {
                    Ok(()) => {
                        if tx.send(Ev::Progress).is_err() {
                            break;
                        }
                    }
                    Err(Some(reason)) => {
                        out.clear();
                        let reject = Msg::Reject { t: uv.t, worker: uv.worker, reason };
                        wbuf.encode(&reject, &mut out);
                        let mut w = h.writer.lock().unwrap_or_else(|e| e.into_inner());
                        let _ = std::io::Write::write_all(&mut *w, &out);
                    }
                    // Payload broke the streaming contract: corrupt or
                    // hostile peer — hang up.
                    Err(None) => break,
                }
            }
            // Client-bound message types on a server-bound stream are a
            // protocol violation.
            _ => break,
        }
    }
    let _ = tx.send(Ev::Gone { conn: h.id });
}

/// Validate + record one update submission. `Err(Some(reason))` asks the
/// reader to send a typed reject; `Err(None)` is a payload-level
/// violation that drops the connection.
fn submit_update(
    conn: usize,
    uv: &wire::UpdateView<'_>,
    wire_len: u64,
    (d, streaming): (usize, bool),
    gate: &Mutex<Gate>,
    pack: &mut PackedTernary,
) -> Result<(), Option<RejectReason>> {
    if uv.grad.dim() != d {
        return Err(None);
    }
    let t = usize::try_from(uv.t).unwrap_or(usize::MAX);
    let worker = usize::try_from(uv.worker).unwrap_or(usize::MAX);
    // Decode the payload into the per-reader scratch OUTSIDE the gate
    // lock — the O(d) unpack runs concurrently across readers — and
    // before claiming the slot: a slot marked filled must always hold a
    // recorded submission.
    let msg = if streaming {
        match uv.grad.unpack_ternary_into(pack) {
            Ok(Some(())) if pack.scale() == 1.0 => None,
            // Dense, mis-scaled or invariant-violating payloads cannot
            // enter the vote accumulator.
            _ => return Err(None),
        }
    } else {
        match uv.grad.to_msg() {
            Ok(m) => Some(m),
            Err(_) => return Err(None),
        }
    };
    let mut g = gate.lock().unwrap_or_else(|e| e.into_inner());
    let g = &mut *g;
    let slot = g.table.submit(t, worker, conn).map_err(Some)?;
    g.losses[slot] = uv.loss;
    g.bits[slot] = uv.grad.bits();
    match msg {
        None => {
            g.nnz[slot] = pack.nnz();
            g.votes.fold(pack);
        }
        Some(m) => {
            g.nnz[slot] = m.nnz();
            g.msgs[slot] = Some(m);
        }
    }
    g.up_bytes += wire_len;
    Ok(())
}
