//! The coordinator service (DESIGN.md §11, §14): Algorithm 1's
//! parameter server behind a real socket.
//!
//! One thread, one readiness loop. Every connection — direct client or
//! aggregator shard — lives in the [`Mux`], and the driver consumes
//! protocol events frame by frame: no accept thread, no per-connection
//! reader threads, no sleep-polling, no round gate mutex. Update frames
//! decode **directly into the streaming aggregation path**: the ternary
//! bitplanes land in a scratch [`PackedTernary`] and fold into the
//! [`VoteAccumulator`]; a shard's merged frame lands its carry-save
//! counter planes with the same word-parallel merge. Per-slot scalars
//! (loss, bit cost, nnz) are recorded in selection-slot order, so the
//! shared `RoundLoop::finish_round` tail reduces them in the same order
//! as the in-process engine and the resulting `RunHistory` is
//! bit-identical on the same seed (`tests/net_loopback.rs`,
//! `tests/shard_tree.rs`) — flat or sharded, the votes commute.
//!
//! The per-round model broadcast is encoded **once** into a refcounted
//! frame shared by every connection's output queue (clients filter the
//! full cohort to their hosted range; shards relay the bytes verbatim),
//! so the O(d) payload is never copied per peer.
//!
//! Fault handling: duplicate submissions are rejected idempotently,
//! frames for a closed round are rejected as `Late`, a dead connection's
//! pending slots stop being awaited, and a round closes at its deadline
//! with partial participation — stragglers are counted in the ledger,
//! alongside the actual framed byte traffic, split by tier
//! (client-facing vs shard-facing wire bytes).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compressors::{CompressedGrad, PackedTernary};
use crate::coordinator::{RoundLoop, RunHistory, TrainingRun, VoteAccumulator, WorkerSampler};
use crate::metrics::registry::{phase as mphase, MetricsRegistry};
use crate::snapshot::{CoordinatorSnapshot, SnapshotPolicy};

use super::events::EventLog;
use super::faults::FaultInjector;
use super::protocol::{PhaseTracker, Roster, RoundTable};
use super::reactor::{Mux, MuxEvent};
use super::wire::{self, Msg, MsgType, RejectReason, WireBuf};
use super::{Endpoint, Listener, NetError};

/// Coordinator service configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address.
    pub endpoint: Endpoint,
    /// Per-round submission deadline; `None` waits for every live
    /// selected worker (the loopback-equivalence configuration).
    pub round_deadline: Option<Duration>,
    /// How long to wait for the fleet to cover the worker population.
    pub rendezvous_timeout: Duration,
    /// Frame payload cap handed to the decoder.
    pub max_payload: usize,
    /// Coordinator snapshot policy (DESIGN.md §12): periodic every-k
    /// writes and/or the drain-time write. `None` disables snapshots.
    pub snapshot: Option<SnapshotPolicy>,
    /// Graceful drain: finish round `drain_after - 1` (i.e. complete
    /// `drain_after` rounds), write a snapshot if a policy is set, close
    /// every connection *without* `Fin`, and return
    /// [`NetError::Drained`]. The SIGTERM-shaped exit a supervisor uses
    /// before handing the endpoint to a `--resume` successor.
    pub drain_after: Option<usize>,
    /// Resume from a restored snapshot instead of `init` (which then
    /// only supplies the expected dimension). The snapshot is
    /// revalidated against the run's config fingerprint.
    pub resume: Option<CoordinatorSnapshot>,
    /// Environment fingerprint mixed into snapshot fingerprints
    /// ([`crate::coordinator::GradientSource::env_fingerprint`] of the
    /// dataset both sides were built from). The coordinator itself never
    /// sees the data, so the caller supplies it — the `serve` CLI sets
    /// it from the env it constructs; 0 (the default) disables the
    /// environment check but keeps every other fingerprint guard.
    pub env_fingerprint: u64,
    /// Structured per-round event log (DESIGN.md §15); `None` disables.
    pub event_log: Option<Arc<EventLog>>,
    /// Strict self-healing (the soak contract): `Some(k)` re-opens any
    /// round that closed with unfilled slots — an owner died, or a
    /// respawn re-rostered mid-round and left stale slot owners — and
    /// re-broadcasts it (same cohort, fresh owners, one bounded
    /// re-coverage wait per attempt), up to `k` attempts per round,
    /// failing the run loudly if the round still cannot fill. Every
    /// round then closes with its *full* cohort, which is what makes a
    /// churned RunHistory bit-identical to an uninterrupted one.
    /// `None` keeps the legacy elastic behaviour: partial rounds close
    /// as partial participation, only the all-hosts-dead case re-opens
    /// (capped at 3 attempts).
    pub heal_attempts: Option<usize>,
    /// In-process fault injection for this role (DESIGN.md §15);
    /// `None` runs clean.
    pub faults: Option<FaultInjector>,
    /// Scrape port: serve `GET /metrics` / `GET /healthz` here
    /// (DESIGN.md §17). `None` disables the observability plane.
    pub metrics_addr: Option<Endpoint>,
    /// The registry the scrape port renders. Usually left `None` —
    /// [`NetCoordinator::bind`] creates a root registry when
    /// `metrics_addr` is set — but injectable so a test (or an
    /// embedding) can read the same counters the scraper sees.
    pub metrics: Option<Arc<MetricsRegistry>>,
    /// Keep answering scrapes for this long after `Fin` before the
    /// serve call returns, so an external scraper can deterministically
    /// observe the *final* counter totals. Skipped on drain/error.
    pub metrics_linger: Option<Duration>,
}

impl ServeOptions {
    /// Coordinator options with every knob at its default: no deadline
    /// (wait for the full cohort), 30 s rendezvous, no snapshots, no
    /// event log, no fault injection, no scrape port.
    ///
    /// Configure with the `with_*` builders:
    ///
    /// ```
    /// use std::time::Duration;
    /// use sparsignd::net::{Endpoint, ServeOptions};
    /// use sparsignd::snapshot::SnapshotPolicy;
    ///
    /// let opts = ServeOptions::new(Endpoint::Tcp("127.0.0.1:0".into()))
    ///     .with_round_deadline(Some(Duration::from_secs(2)))
    ///     .with_rendezvous_timeout(Duration::from_secs(10))
    ///     .with_snapshot(Some(SnapshotPolicy::every("snap.bin", 5)))
    ///     .with_heal_attempts(Some(10))
    ///     .with_metrics_addr(Some(Endpoint::Tcp("127.0.0.1:9464".into())));
    /// assert_eq!(opts.heal_attempts, Some(10));
    /// assert!(opts.metrics_addr.is_some());
    /// ```
    pub fn new(endpoint: Endpoint) -> Self {
        Self {
            endpoint,
            round_deadline: None,
            rendezvous_timeout: Duration::from_secs(30),
            max_payload: wire::MAX_PAYLOAD,
            snapshot: None,
            drain_after: None,
            resume: None,
            env_fingerprint: 0,
            event_log: None,
            heal_attempts: None,
            faults: None,
            metrics_addr: None,
            metrics: None,
            metrics_linger: None,
        }
    }

    /// Per-round submission deadline (`None` waits for the full cohort).
    pub fn with_round_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.round_deadline = deadline;
        self
    }

    /// Rendezvous / re-coverage wait budget.
    pub fn with_rendezvous_timeout(mut self, timeout: Duration) -> Self {
        self.rendezvous_timeout = timeout;
        self
    }

    /// Coordinator snapshot policy (DESIGN.md §12).
    pub fn with_snapshot(mut self, policy: Option<SnapshotPolicy>) -> Self {
        self.snapshot = policy;
        self
    }

    /// Graceful drain after `n` completed rounds.
    pub fn with_drain_after(mut self, n: Option<usize>) -> Self {
        self.drain_after = n;
        self
    }

    /// Strict self-healing attempt cap (the soak contract).
    pub fn with_heal_attempts(mut self, attempts: Option<usize>) -> Self {
        self.heal_attempts = attempts;
        self
    }

    /// Structured per-round event log (DESIGN.md §15).
    pub fn with_event_log(mut self, log: Option<Arc<EventLog>>) -> Self {
        self.event_log = log;
        self
    }

    /// In-process fault injection for the coordinator role.
    pub fn with_faults(mut self, faults: Option<FaultInjector>) -> Self {
        self.faults = faults;
        self
    }

    /// Scrape port for `GET /metrics` / `GET /healthz` (DESIGN.md §17).
    pub fn with_metrics_addr(mut self, addr: Option<Endpoint>) -> Self {
        self.metrics_addr = addr;
        self
    }

    /// Inject the registry the scrape port renders (tests/embeddings).
    pub fn with_metrics(mut self, registry: Option<Arc<MetricsRegistry>>) -> Self {
        self.metrics = registry;
        self
    }

    /// Post-`Fin` scrape window (final counters stay observable).
    pub fn with_metrics_linger(mut self, linger: Option<Duration>) -> Self {
        self.metrics_linger = linger;
        self
    }
}

/// A bound-but-not-yet-serving coordinator; binding first lets callers
/// learn the resolved endpoint (`:0` TCP picks a free port) before the
/// fleet dials in.
pub struct NetCoordinator {
    listener: Listener,
    local: Endpoint,
    metrics_listener: Option<Listener>,
    metrics_local: Option<Endpoint>,
    opts: ServeOptions,
}

impl NetCoordinator {
    /// Bind the accept socket — and the scrape socket, when
    /// `opts.metrics_addr` asks for one (creating a root registry
    /// unless the caller injected their own via `opts.metrics`).
    pub fn bind(mut opts: ServeOptions) -> Result<Self, NetError> {
        let listener = Listener::bind(&opts.endpoint)?;
        let local = listener.local_endpoint(&opts.endpoint);
        let (metrics_listener, metrics_local) = match &opts.metrics_addr {
            Some(addr) => {
                let l = Listener::bind(addr)?;
                let resolved = l.local_endpoint(addr);
                if opts.metrics.is_none() {
                    opts.metrics = Some(MetricsRegistry::root());
                }
                (Some(l), Some(resolved))
            }
            None => (None, None),
        };
        Ok(Self { listener, local, metrics_listener, metrics_local, opts })
    }

    /// The resolved bind address (dial this).
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.local
    }

    /// The resolved scrape address (`GET /metrics` here), when bound.
    pub fn metrics_endpoint(&self) -> Option<&Endpoint> {
        self.metrics_local.as_ref()
    }

    /// The registry the scrape port renders, when one exists.
    pub fn metrics_registry(&self) -> Option<&Arc<MetricsRegistry>> {
        self.opts.metrics.as_ref()
    }

    /// Run `run.rounds` federated rounds over the socket and return the
    /// run history. `workers` is the population M the fleet must cover;
    /// `eval` is the server-side test evaluation (exactly as
    /// `TrainingRun::run` takes it).
    pub fn serve(
        self,
        run: &TrainingRun,
        workers: usize,
        init: Vec<f32>,
        eval: &dyn Fn(&[f32]) -> (f64, f64),
    ) -> Result<RunHistory, NetError> {
        let NetCoordinator { listener, local, metrics_listener, metrics_local, mut opts } = self;
        let d = init.len();
        let n_max = WorkerSampler::new(workers, run.participation).per_round();
        let streaming = run.streams_votes(n_max);
        if opts.snapshot.is_some() || opts.resume.is_some() {
            // The snapshot covers server-side state only; stateful
            // worker compressors live in the clients and cannot ride it.
            run.require_snapshot_support(&run.build_worker_comps(d, 1))
                .map_err(NetError::Snapshot)?;
        }
        let env_tag = opts.env_fingerprint;
        let resumed = opts.resume.is_some();
        let lp = match opts.resume.take() {
            Some(snap) => RoundLoop::resume(run, d, workers, streaming, env_tag, snap)
                .map_err(NetError::Snapshot)?,
            None => RoundLoop::new(run, d, workers, streaming, env_tag, init),
        };
        let mut mux = Mux::new(opts.max_payload)?;
        mux.listen(listener)?;
        if let Some(l) = metrics_listener {
            let reg = opts.metrics.clone().unwrap_or_else(MetricsRegistry::root);
            mux.listen_metrics(l, reg)?;
        }
        if let Some(fi) = &opts.faults {
            mux.set_send_delay(fi.send_delay());
        }
        if let Some(log) = &opts.event_log {
            log.emit(
                "serve_start",
                &[("resumed", resumed as u64), ("round", lp.start_round() as u64)],
            );
        }

        let phase = PhaseTracker::resumed_at(lp.start_round());
        let metrics = opts.metrics.clone();
        let drv = Driver {
            run,
            metrics,
            m: workers,
            lp,
            opts: &opts,
            mux,
            phase,
            roster: Roster::new(workers),
            alive: Vec::new(),
            is_shard: Vec::new(),
            d,
            streaming,
            table: RoundTable::new(),
            losses: Vec::new(),
            bits: Vec::new(),
            nnz: Vec::new(),
            msgs: Vec::new(),
            votes: VoteAccumulator::new(),
            seen: Vec::new(),
            up_bytes: 0,
            down_extra: 0,
            shard_up: 0,
            pack: PackedTernary::zeros(0, 1.0),
            wbuf: WireBuf::new(),
            frame: Vec::new(),
            evs: Vec::new(),
            rounds_since_snap: 0,
        };
        let result = drv.drive(eval);

        // A UDS socket file outlives its listener; clean up (the scrape
        // socket too, when it was UDS-bound).
        #[cfg(unix)]
        {
            if let Endpoint::Uds(path) = &local {
                let _ = std::fs::remove_file(path);
            }
            if let Some(Endpoint::Uds(path)) = &metrics_local {
                let _ = std::fs::remove_file(path);
            }
        }
        #[cfg(not(unix))]
        let _ = (&local, &metrics_local);
        result
    }
}

/// The coordinator proper: rendezvous, then the round loop over the
/// shared [`RoundLoop`] tail. Single-threaded — every field is plain
/// state mutated between [`Mux::pump`] calls.
struct Driver<'a> {
    run: &'a TrainingRun,
    /// Observability registry (DESIGN.md §17); `None` when no scrape
    /// port was asked for. Every feed is a relaxed atomic op at a site
    /// where the fact is already in hand — never a reason to block.
    metrics: Option<Arc<MetricsRegistry>>,
    m: usize,
    lp: RoundLoop<'a>,
    opts: &'a ServeOptions,
    mux: Mux,
    phase: PhaseTracker,
    roster: Roster,
    alive: Vec<bool>,
    /// Connections that rendezvoused with `ShardHello` — they submit
    /// merged accumulator frames, never individual updates.
    is_shard: Vec<bool>,
    d: usize,
    streaming: bool,
    table: RoundTable,
    /// Per-slot payload state for the aggregating round (what the PR 3
    /// gate held behind its mutex, now plain driver fields).
    losses: Vec<f64>,
    bits: Vec<f64>,
    nnz: Vec<usize>,
    msgs: Vec<Option<CompressedGrad>>,
    votes: VoteAccumulator,
    /// Scratch slot-dedup bitmap for vetting a shard frame's records.
    seen: Vec<bool>,
    /// Client-tier uplink bytes this attempt (direct updates + bytes
    /// the shards report having accepted downstream).
    up_bytes: u64,
    /// Client-tier downlink bytes the shards report having broadcast.
    down_extra: u64,
    /// Shard-tier uplink bytes (the merged frames themselves).
    shard_up: u64,
    pack: PackedTernary,
    wbuf: WireBuf,
    frame: Vec<u8>,
    evs: Vec<MuxEvent>,
    /// Completed rounds since the last snapshot write (the event log's
    /// `snap_age`; 0 right after a resume — a snapshot was just read).
    rounds_since_snap: u64,
}

impl<'a> Driver<'a> {
    /// Run the whole protocol; consumes the driver so the finished
    /// `RoundLoop` moves out without a placeholder.
    fn drive(mut self, eval: &dyn Fn(&[f32]) -> (f64, f64)) -> Result<RunHistory, NetError> {
        let res = self.run_protocol(eval);
        // Tear every connection down regardless of how the run ended —
        // a drain exits without Fin by design, an error as a side effect.
        for conn in 0..self.alive.len() {
            self.mux.close(conn);
        }
        match res {
            Ok(()) => {
                let label = self.run.algorithm.label();
                let d = self.lp.params.len();
                Ok(self.lp.into_history(label, d))
            }
            Err(e) => Err(e),
        }
    }

    fn run_protocol(&mut self, eval: &dyn Fn(&[f32]) -> (f64, f64)) -> Result<(), NetError> {
        self.rendezvous()?;
        // A resumed coordinator starts at the snapshot's next round; the
        // reconnected fleet recomputes that round from the same
        // (seed, round, worker) RNG streams, so nothing is lost even if
        // the dead coordinator had already opened it.
        let start = self.lp.start_round();
        for t in start..self.run.rounds {
            self.round(t, eval)?;
            let done = t + 1;
            self.rounds_since_snap += 1;
            if let Some(m) = self.met() {
                m.set_snapshot_age(self.rounds_since_snap);
            }
            // `>=` rather than `==`: a resumed coordinator whose start
            // round is already past the drain mark drains after its
            // first completed round instead of silently never draining.
            let draining =
                self.opts.drain_after.map_or(false, |n| done >= n) && done < self.run.rounds;
            if let Some(policy) = &self.opts.snapshot {
                if policy.due(done, self.run.rounds) || draining {
                    self.lp.to_snapshot().save(&policy.path).map_err(NetError::Snapshot)?;
                    self.rounds_since_snap = 0;
                    if let Some(m) = self.met() {
                        m.set_snapshot_age(0);
                    }
                    self.emit("snapshot", &[("t", t as u64)]);
                }
            }
            if draining {
                // Graceful SIGTERM-style drain: the round is complete and
                // snapshotted; exit without Fin so the fleet reconnects
                // to the successor coordinator.
                self.emit("drain", &[("rounds", done as u64)]);
                return Err(NetError::Drained { rounds_done: done });
            }
        }
        // Rejects issued after the final round closed (a straggler's
        // stale replay, say) would otherwise be dropped on the floor.
        self.fold_rejects();
        // Fin + state machine epilogue.
        let fin = Msg::Fin { rounds: self.run.rounds as u64 };
        for conn in 0..self.alive.len() {
            if self.alive[conn] && !self.send(conn, &fin) {
                self.mark_dead(conn);
            }
        }
        // Nonblocking sockets may still hold queued Fin bytes; give the
        // reactor a bounded window to flush before the teardown.
        self.drain_outgoing();
        self.phase.finish();
        if let Some(m) = self.met() {
            m.set_phase(mphase::FINISHED);
        }
        self.emit("fin", &[("rounds", self.run.rounds as u64)]);
        self.linger_for_scrapes();
        Ok(())
    }

    /// Post-`Fin` scrape window: keep the reactor pumping (the scrape
    /// responder included) so an external scraper can observe the final
    /// counter totals before the sockets vanish. Protocol conns are
    /// already finished; any events that still arrive are handled
    /// normally and change nothing.
    fn linger_for_scrapes(&mut self) {
        let Some(window) = self.opts.metrics_linger else { return };
        if self.metrics.is_none() {
            return;
        }
        let deadline = Instant::now() + window;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return;
            }
            if self.pump_step(left.min(Duration::from_millis(100)), None).is_err() {
                return;
            }
        }
    }

    /// Wait until the fleet covers the worker population.
    fn rendezvous(&mut self) -> Result<(), NetError> {
        let deadline = Instant::now() + self.opts.rendezvous_timeout;
        while !self.roster.covered() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(NetError::Protocol("rendezvous timeout".into()));
            }
            self.pump_step(left.min(Duration::from_millis(200)), None)?;
        }
        Ok(())
    }

    /// One federated round over the wire.
    fn round(&mut self, t: usize, eval: &dyn Fn(&[f32]) -> (f64, f64)) -> Result<(), NetError> {
        // Drain pending readiness first: a connection that died (or an
        // agent that re-claimed a freed range) between rounds must be
        // reflected in the expectations *before* they are set, not
        // discovered while the deadline runs down.
        self.pump_step(Duration::ZERO, Some(t))?;
        let run = self.run;
        let lr = run.schedule.at(t);
        // Selection is drawn exactly once per round (the RNG stream is
        // part of the determinism contract); a re-broadcast after an
        // all-hosts-dead attempt reuses the same cohort.
        let n = self.lp.select(t);
        self.phase.open_round(t);
        if let Some(m) = self.met() {
            m.set_round(t as u64);
            m.set_cohort(n as u64);
            m.set_phase(mphase::OPEN);
        }
        let mut sel_ids: Vec<u64> = Vec::with_capacity(n);
        let mut attempts = 0usize;

        loop {
            // Wire accounting is per attempt: only the attempt that
            // actually closes the round is annotated into the ledger,
            // so a healed (re-broadcast) round reports exactly the
            // bytes an uninterrupted round would — re-broadcasts are
            // operational noise, not training traffic.
            let mut down_client = 0u64;
            let mut down_shard = 0u64;
            // Slot owners come from the rendezvous roster. A worker whose
            // host died (its claim was released) and has no replacement
            // yet gets the unowned sentinel — a straggler from the start,
            // never awaited.
            let owners: Vec<usize> = self.lp.server.selected[..n]
                .iter()
                .map(|&w| self.roster.owner_of(w).unwrap_or(usize::MAX))
                .collect();
            self.table.open(t, self.m, &self.lp.server.selected[..n], &owners, &self.alive);
            if self.streaming {
                self.votes.reset(self.d, n);
            }
            self.losses.clear();
            self.losses.resize(n, 0.0);
            self.bits.clear();
            self.bits.resize(n, 0.0);
            self.nnz.clear();
            self.nnz.resize(n, 0);
            self.msgs.clear();
            self.msgs.resize(n, None);
            self.up_bytes = 0;
            self.down_extra = 0;
            self.shard_up = 0;

            // Broadcast: the full cohort + the model, encoded exactly
            // once and queued as one shared refcounted frame on every
            // live claimant — clients filter to their hosted range,
            // shards relay the identical bytes downstream.
            let deadline_ms =
                self.opts.round_deadline.map(|d| d.as_millis() as u64).unwrap_or(0);
            sel_ids.clear();
            sel_ids.extend(self.lp.server.selected[..n].iter().map(|&w| w as u64));
            self.frame.clear();
            let len = self.wbuf.encode_round_open(
                t as u64,
                lr,
                deadline_ms,
                &sel_ids,
                &self.lp.params,
                &mut self.frame,
            );
            let shared: Arc<[u8]> = Arc::from(self.frame.as_slice());
            for conn in 0..self.alive.len() {
                if !self.alive[conn] || self.roster.range_of(conn).is_none() {
                    continue;
                }
                if self.mux.send(conn, shared.clone()) {
                    if self.is_shard[conn] {
                        down_shard += len as u64;
                    } else {
                        down_client += len as u64;
                    }
                } else {
                    self.mark_dead(conn);
                }
            }
            self.emit("round_open", &[("t", t as u64), ("attempt", attempts as u64)]);
            self.phase.aggregate(t);
            if let Some(m) = self.met() {
                m.set_phase(mphase::AGGREGATE);
            }

            // Collect until every live slot filled or the deadline expires.
            let hard_deadline = self.opts.round_deadline.map(|d| Instant::now() + d);
            loop {
                if self.table.complete() {
                    break;
                }
                let wait = match hard_deadline {
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        left.min(Duration::from_millis(200))
                    }
                    None => Duration::from_millis(200),
                };
                self.pump_step(wait, Some(t))?;
            }

            // Close the round and compact filled slots into the shared
            // RoundLoop buffers (ascending slot order = selection order,
            // the same deterministic reduction order the in-process
            // engine uses).
            self.table.close();
            let mut n_eff = 0usize;
            for k in 0..n {
                if self.table.filled()[k] {
                    self.lp.server.losses[n_eff] = self.losses[k];
                    self.lp.server.bits[n_eff] = self.bits[k];
                    self.lp.server.nnz[n_eff] = self.nnz[k];
                    self.lp.server.msgs[n_eff] = self.msgs[k].take();
                    n_eff += 1;
                }
            }
            if self.streaming && n_eff > 0 {
                self.votes.counts_into(&mut self.lp.server.counts);
            }
            let stragglers = n - n_eff;
            let strict = self.opts.heal_attempts;
            if n_eff < n && (strict.is_some() || n_eff == 0) {
                // Legacy (`strict == None`): only the all-hosts-dead
                // case re-opens. Zero live submissions with a covered
                // roster means the cohort's hosts are alive yet silent —
                // fatal, exactly as before. An uncovered one means every
                // host died: give the fleet's reconnect-with-backoff one
                // bounded re-rendezvous window to re-claim, then
                // re-broadcast the same round (worker rounds are pure,
                // so recomputing is harmless).
                //
                // Strict (`strict == Some(cap)`, the soak contract):
                // ANY shortfall heals — with no deadline a round can
                // only close short because an owner died, or because a
                // respawn re-rostered mid-round and left the table's
                // slot owners stale (the respawn-races-the-round case:
                // the roster is covered again but the new connection
                // cannot fill the old owner's slots). Both re-open with
                // fresh owners. Capped so a pathologically flapping
                // fleet cannot spin a round forever.
                attempts += 1;
                let fatal = match strict {
                    None => self.roster.covered() || attempts >= 3,
                    Some(cap) => attempts >= cap.max(1),
                };
                if fatal {
                    return Err(NetError::Protocol(if n_eff == 0 {
                        format!("round {t}: no submissions arrived")
                    } else {
                        format!(
                            "round {t}: {n_eff} of {n} submissions after {attempts} attempts"
                        )
                    }));
                }
                self.emit(
                    "recoverage",
                    &[
                        ("t", t as u64),
                        ("missing", stragglers as u64),
                        ("attempt", attempts as u64),
                    ],
                );
                self.phase.reopen_round(t);
                if let Some(m) = self.met() {
                    m.inc_heal_attempt();
                    m.set_phase(mphase::OPEN);
                }
                if !self.roster.covered() {
                    self.await_recoverage(t)?;
                }
                continue;
            }
            self.lp.finish_round(t, lr, n_eff, eval, &mut None);
            self.lp.ledger.annotate_wire_tiered(
                t,
                self.up_bytes,
                down_client + self.down_extra,
                stragglers,
                self.shard_up,
                down_shard,
            );
            let rejects = self.table.take_rejects();
            self.lp.ledger.add_rejects(&rejects);
            // Same values, same site, as the ledger annotation above —
            // the scrape counters bit-match `history_json` by
            // construction.
            if let Some(m) = self.met() {
                m.observe_round_close(
                    self.up_bytes,
                    down_client + self.down_extra,
                    self.shard_up,
                    down_shard,
                    stragglers as u64,
                );
                m.add_rejects(&rejects);
            }
            self.emit(
                "round_close",
                &[
                    ("t", t as u64),
                    ("senders", n_eff as u64),
                    ("stragglers", stragglers as u64),
                    ("up_bytes", self.up_bytes),
                    ("down_bytes", down_client + self.down_extra),
                    ("shard_up", self.shard_up),
                    ("shard_down", down_shard),
                    ("rejects", rejects.iter().sum()),
                    ("snap_age", self.rounds_since_snap),
                ],
            );
            self.phase.broadcast(t);
            if let Some(m) = self.met() {
                m.set_phase(mphase::BROADCAST);
            }
            return Ok(());
        }
    }

    /// After an all-hosts-dead round attempt: wait (bounded by the
    /// rendezvous timeout) for reconnecting agents to re-claim until the
    /// roster covers the population again.
    fn await_recoverage(&mut self, t: usize) -> Result<(), NetError> {
        let deadline = Instant::now() + self.opts.rendezvous_timeout;
        while !self.roster.covered() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(NetError::Protocol(format!(
                    "round {t}: no submissions arrived and the fleet did not re-cover \
                     the population"
                )));
            }
            self.pump_step(left.min(Duration::from_millis(200)), Some(t))?;
        }
        Ok(())
    }

    /// One reactor turn: wait up to `wait` for readiness, then handle
    /// every event it produced. `round` is the currently-aggregating
    /// round (heartbeat acks and stale-frame rejects echo it), `None`
    /// during rendezvous.
    fn pump_step(&mut self, wait: Duration, round: Option<usize>) -> Result<(), NetError> {
        let mut evs = std::mem::take(&mut self.evs);
        evs.clear();
        let res = self.mux.pump(Some(wait), &mut evs);
        for ev in evs.drain(..) {
            self.on_mux_event(ev, round);
        }
        self.evs = evs;
        res
    }

    fn on_mux_event(&mut self, ev: MuxEvent, round: Option<usize>) {
        match ev {
            MuxEvent::Accepted { conn } => {
                debug_assert_eq!(conn, self.alive.len(), "conn ids are arrival-ordered");
                self.alive.push(true);
                self.is_shard.push(false);
            }
            MuxEvent::Closed { conn } => self.mark_dead(conn),
            MuxEvent::Frame { conn, bytes } => {
                self.on_frame(conn, &bytes, round);
                self.mux.recycle(bytes);
            }
        }
    }

    /// Dispatch one complete frame from `conn`.
    fn on_frame(&mut self, conn: usize, bytes: &[u8], round: Option<usize>) {
        if conn >= self.alive.len() || !self.alive[conn] {
            return;
        }
        let Ok((frame, _)) = wire::parse_frame(bytes, self.opts.max_payload) else {
            self.hangup(conn);
            return;
        };
        match frame.msg_type {
            MsgType::Hello => match wire::decode_msg(frame) {
                Ok(Msg::Hello { lo, hi, cfg, env }) => self.on_hello(conn, lo, hi, cfg, env, false),
                _ => self.hangup(conn),
            },
            MsgType::ShardHello => match wire::decode_msg(frame) {
                Ok(Msg::ShardHello { lo, hi, cfg, env }) => {
                    self.on_hello(conn, lo, hi, cfg, env, true)
                }
                _ => self.hangup(conn),
            },
            MsgType::Heartbeat => {
                let t = round.unwrap_or(0) as u64;
                if !self.send(conn, &Msg::Ack { t, worker: conn as u64 }) {
                    self.mark_dead(conn);
                }
            }
            MsgType::Update => {
                if self.is_shard[conn] {
                    // Shards submit merged frames, never raw updates.
                    self.hangup(conn);
                    return;
                }
                let Ok(uv) = wire::decode_update(frame.payload) else {
                    self.hangup(conn);
                    return;
                };
                match self.submit_update(conn, &uv, bytes.len() as u64) {
                    Ok(()) => {}
                    Err(Some(reason)) => {
                        let reject = Msg::Reject { t: uv.t, worker: uv.worker, reason };
                        if !self.send(conn, &reject) {
                            self.mark_dead(conn);
                        }
                    }
                    // Payload broke the streaming contract: corrupt or
                    // hostile peer — hang up.
                    Err(None) => self.hangup(conn),
                }
            }
            MsgType::ShardAgg => {
                if !self.is_shard[conn] {
                    self.hangup(conn);
                    return;
                }
                self.on_shard_agg(conn, frame.payload, bytes.len() as u64);
            }
            // Client-bound message types on a server-bound stream are a
            // protocol violation.
            _ => self.hangup(conn),
        }
    }

    /// Rendezvous claim — `Hello` from a client, `ShardHello` from an
    /// aggregator shard. Identical fingerprint and roster vetting; the
    /// only difference is which submission grammar the connection is
    /// then allowed to speak.
    fn on_hello(&mut self, conn: usize, lo: u64, hi: u64, cfg: u64, env: u64, shard: bool) {
        // A fleet built from drifted flags (different seed, schedule,
        // compressor, dataset α/batch, …) must be refused at rendezvous:
        // the coordinator cannot see the clients' data, so the
        // fingerprints carry the proof. The env check only arms when the
        // caller supplied its own environment hash (the CLI always does).
        let want_cfg = self.run.config_fingerprint(self.lp.params.len(), self.m, 0);
        let env_ok = self.opts.env_fingerprint == 0 || env == self.opts.env_fingerprint;
        if cfg != want_cfg || !env_ok {
            self.hangup(conn);
            return;
        }
        // A shard's merged frame carries vote-counter planes; without
        // the streaming vote path there is nothing to merge them into.
        if shard && !self.streaming {
            self.hangup(conn);
            return;
        }
        let claim = usize::try_from(lo)
            .ok()
            .zip(usize::try_from(hi).ok())
            .map(|(l, h)| self.roster.claim(conn, l, h));
        match claim {
            // A valid claim is welcomed during rendezvous AND mid-run: a
            // dead connection's range is released by the dead-conn
            // bookkeeping, so a reconnecting agent (or respawned shard)
            // re-claims it and rejoins from the next round — the churn
            // path elastic federation depends on.
            Some(Ok(())) => {
                self.is_shard[conn] = shard;
                if let Some(m) = self.met() {
                    m.roster_add(hi.saturating_sub(lo));
                }
                self.emit(
                    "reclaim",
                    &[("conn", conn as u64), ("shard", shard as u64), ("lo", lo), ("hi", hi)],
                );
                let msg = Msg::Welcome {
                    client_id: conn as u64,
                    workers: self.m as u64,
                    dim: self.lp.params.len() as u64,
                    rounds: self.run.rounds as u64,
                    // Committed-seed selection broadcasts its root-key
                    // commitment at rendezvous (all zeros in legacy mode)
                    // so clients can later audit the selection stream
                    // (DESIGN.md §13).
                    commit: self.lp.selection_commitment(),
                };
                if !self.send(conn, &msg) {
                    self.mark_dead(conn);
                }
            }
            // Bad claims (overlap with a live host, bad range) are hung
            // up on.
            _ => self.hangup(conn),
        }
    }

    /// Validate + record one direct-client update. `Err(Some(reason))`
    /// asks for a typed reject; `Err(None)` is a payload-level violation
    /// that drops the connection.
    fn submit_update(
        &mut self,
        conn: usize,
        uv: &wire::UpdateView<'_>,
        wire_len: u64,
    ) -> Result<(), Option<RejectReason>> {
        if uv.grad.dim() != self.d {
            return Err(None);
        }
        let t = usize::try_from(uv.t).unwrap_or(usize::MAX);
        let worker = usize::try_from(uv.worker).unwrap_or(usize::MAX);
        // Decode the payload into the scratch pack *before* claiming the
        // slot: a slot marked filled must always hold a recorded
        // submission.
        let msg = if self.streaming {
            match uv.grad.unpack_ternary_into(&mut self.pack) {
                Ok(Some(())) if self.pack.scale() == 1.0 => None,
                // Dense, mis-scaled or invariant-violating payloads
                // cannot enter the vote accumulator.
                _ => return Err(None),
            }
        } else {
            match uv.grad.to_msg() {
                Ok(m) => Some(m),
                Err(_) => return Err(None),
            }
        };
        let slot = self.table.submit(t, worker, conn).map_err(Some)?;
        self.losses[slot] = uv.loss;
        self.bits[slot] = uv.grad.bits();
        match msg {
            None => {
                self.nnz[slot] = self.pack.nnz();
                self.votes.fold(&self.pack);
            }
            Some(m) => {
                self.nnz[slot] = m.nnz();
                self.msgs[slot] = Some(m);
            }
        }
        self.up_bytes += wire_len;
        Ok(())
    }

    /// A shard's merged round submission: one frame speaking for every
    /// downstream worker that participated. All-or-nothing — every
    /// record is vetted *before* anything is applied, so the vote
    /// accumulator and the filled slots can never diverge. Shards are
    /// trusted infrastructure (DESIGN.md §14.5): a structural violation
    /// here means a broken or impostor shard, and the whole connection
    /// is dropped rather than salvaging partial state.
    fn on_shard_agg(&mut self, conn: usize, payload: &[u8], wire_len: u64) {
        let Ok(v) = wire::decode_shard_agg(payload) else {
            self.hangup(conn);
            return;
        };
        let lo = usize::try_from(v.lo).unwrap_or(usize::MAX);
        let hi = usize::try_from(v.hi).unwrap_or(usize::MAX);
        // The frame must speak for exactly the range this shard rostered.
        if self.roster.range_of(conn) != Some((lo, hi)) {
            self.hangup(conn);
            return;
        }
        let t = usize::try_from(v.t).unwrap_or(usize::MAX);
        if !self.table.is_open() || t != self.table.round() {
            // The shard missed the close — the merged-frame analogue of
            // a straggling client: tally a typed reject per carried
            // record and tell the shard once.
            let mut reason = if t == self.table.round() {
                RejectReason::Late
            } else {
                RejectReason::BadRound
            };
            for rec in &v.recs {
                let worker = usize::try_from(rec.worker).unwrap_or(usize::MAX);
                if let Err(r) = self.table.submit(t, worker, conn) {
                    reason = r;
                }
            }
            let reject = Msg::Reject { t: v.t, worker: v.lo, reason };
            if !self.send(conn, &reject) {
                self.mark_dead(conn);
            }
            return;
        }
        if v.dim != self.d {
            self.hangup(conn);
            return;
        }
        // Phase 1: vet every record read-only (slot validity, no
        // duplicates within the frame, unit scale — the streaming
        // contract the shard enforced downstream).
        self.seen.clear();
        self.seen.resize(self.table.filled().len(), false);
        let mut slots: Vec<usize> = Vec::with_capacity(v.recs.len());
        for rec in &v.recs {
            if rec.scale != 1.0 || rec.nnz > v.dim as u64 {
                self.hangup(conn);
                return;
            }
            let worker = usize::try_from(rec.worker).unwrap_or(usize::MAX);
            let slot = match self.table.peek(t, worker, conn) {
                Ok(slot) if !self.seen[slot] => slot,
                _ => {
                    self.hangup(conn);
                    return;
                }
            };
            self.seen[slot] = true;
            slots.push(slot);
        }
        // Phase 2: merge the counter planes first — it validates its
        // preconditions (plane depth, message budget, byte lengths)
        // before mutating — then claim the slots, which can no longer
        // fail.
        if self.votes.merge_wire_planes(v.msgs as usize, v.planes, v.pos, v.neg).is_err() {
            self.hangup(conn);
            return;
        }
        for (rec, &slot) in v.recs.iter().zip(&slots) {
            let worker = usize::try_from(rec.worker).unwrap_or(usize::MAX);
            let claimed = self.table.submit(t, worker, conn);
            debug_assert_eq!(claimed, Ok(slot), "vetted record must claim its slot");
            self.losses[slot] = rec.loss;
            self.bits[slot] = rec.bits;
            self.nnz[slot] = rec.nnz as usize;
        }
        // Tiered byte accounting: the frame itself is shard-tier uplink;
        // the bytes it reports are the client tier the shard fronted.
        self.up_bytes += v.up_bytes;
        self.down_extra += v.down_bytes;
        self.shard_up += wire_len;
        // Shard-local typed rejects (its own stragglers/equivocators)
        // fold into the same cumulative ledger counters.
        self.lp.ledger.add_rejects(&v.rejects);
        if let Some(m) = self.met() {
            m.add_rejects(&v.rejects);
        }
        // The shard has spoken for its whole range this round: anything
        // unfilled sat out downstream (partial participation), and
        // exactly one merged frame arrives per shard per round — stop
        // awaiting those slots so the round can close without running
        // out the deadline.
        self.table.settle_conn(conn);
    }

    /// Drain the round table's typed-reject tallies into the ledger's
    /// cumulative per-kind counters (surfaced by `history_json` and the
    /// adversarial tests).
    fn fold_rejects(&mut self) {
        let rejects = self.table.take_rejects();
        self.lp.ledger.add_rejects(&rejects);
        if let Some(m) = self.met() {
            m.add_rejects(&rejects);
        }
    }

    /// The observability registry, if a scrape port is armed.
    fn met(&self) -> Option<&MetricsRegistry> {
        self.metrics.as_deref()
    }

    /// Bounded post-Fin flush: pump until every live connection's output
    /// queue is empty (or the window closes). Peers hanging up while we
    /// flush is normal — they got their Fin.
    fn drain_outgoing(&mut self) {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let pending: usize =
                (0..self.alive.len()).filter(|&c| self.alive[c]).map(|c| self.mux.backlog(c)).sum();
            if pending == 0 {
                return;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return;
            }
            if self.pump_step(left.min(Duration::from_millis(50)), None).is_err() {
                return;
            }
        }
    }

    /// Emit one event-log line if a log is configured.
    fn emit(&self, event: &str, fields: &[(&str, u64)]) {
        if let Some(log) = &self.opts.event_log {
            log.emit(event, fields);
        }
    }

    fn send(&mut self, conn: usize, msg: &Msg) -> bool {
        self.frame.clear();
        self.wbuf.encode(msg, &mut self.frame);
        self.mux.send(conn, Arc::from(self.frame.as_slice()))
    }

    /// Protocol violation or refused rendezvous: same teardown as a
    /// death we observed — with the reactor there is no reader thread
    /// to notice a shutdown, so the bookkeeping runs here directly.
    fn hangup(&mut self, conn: usize) {
        self.mark_dead(conn);
    }

    fn mark_dead(&mut self, conn: usize) {
        self.mux.close(conn);
        if conn < self.alive.len() && self.alive[conn] {
            self.alive[conn] = false;
            // Free the range so a reconnecting agent can re-claim it,
            // and stop awaiting the open round's unfilled slots — both
            // immediately, not at the deadline.
            let freed = self.roster.release(conn);
            self.table.drop_conn(conn);
            let (lo, hi) = freed.unwrap_or((0, 0));
            if let Some(m) = self.met() {
                m.roster_sub((hi as u64).saturating_sub(lo as u64));
            }
            self.emit(
                "conn_dead",
                &[
                    ("conn", conn as u64),
                    ("shard", self.is_shard.get(conn).copied().unwrap_or(false) as u64),
                    ("claimed", freed.is_some() as u64),
                    ("lo", lo as u64),
                    ("hi", hi as u64),
                ],
            );
        }
    }
}
