//! Churn soak harness: run a full sharded federation as **child
//! processes**, kill and respawn them on a seeded [`FaultPlan`]
//! schedule, and prove the final run history is **bit-identical** to an
//! uninterrupted reference run of the same configuration.
//!
//! # Process model
//!
//! One supervisor (this module, in-process) forks `1 + K + K` children
//! of the sparsignd binary itself:
//!
//! * the **root** (`serve --shards 0 --snapshot … --snapshot-every 1
//!   --event-log …`), which publishes its bound endpoint to a
//!   single-line `root.ep`;
//! * `K` **shard relays** (`shard --index i …`), each publishing its
//!   bound endpoint to a single-line `shard{i}.ep` and resolving its
//!   upstream from line 0 of the composed `endpoints.txt` on every
//!   (re)connect;
//! * `K` **fleet processes** (`fleet --shard-line i …`), each hosting
//!   the worker slice `chunk_bounds(m, K, i)` and dialing line `1 + i`
//!   of `endpoints.txt`.
//!
//! Every endpoint file has exactly **one writer**: children own their
//! own `*.ep` line, and only the supervisor composes the multi-line
//! `endpoints.txt` (atomically, via tmp + rename). This removes the
//! read-modify-write race a shared multi-line file would have when a
//! respawned child re-publishes concurrently with another's startup.
//!
//! # Deterministic fault injection
//!
//! Kills are keyed to the root's structured event log, not wall-clock
//! sleeps: the root snapshots **every** round, and each `snapshot{t}`
//! event marks a durable boundary (`done = t + 1` rounds are fully
//! committed). The supervisor replays `done = 1, 2, 3, …` through
//! [`FaultSchedule::actions_after`] as boundaries appear and executes
//! the resulting kills with SIGKILL — no cooperative shutdown, by
//! design. A killed root is respawned with `--resume`; killed shards
//! and fleets are respawned fresh (they are stateless between rounds).
//!
//! Bit-identity then follows from four properties proved elsewhere in
//! the tree: snapshots resume bit-exactly (snapshot v3), strict
//! self-healing re-opens any round that closed short so every round
//! settles with full coverage, per-attempt accounting resets mean a
//! healed round ledgers exactly the bytes of its closing attempt, and
//! worker rounds are pure functions of `(seed, round, worker, params)`.

use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::metrics::registry::{parse_exposition, sample_value};

use super::events::{event_field, parse_events};
use super::faults::{FaultAction, FaultPlan};
use super::{Endpoint, NetError, Stream};

/// Configuration for [`run_soak`].
#[derive(Debug, Clone)]
pub struct SoakOptions {
    /// Rounds per run (both reference and faulted).
    pub rounds: usize,
    /// Total worker count (split across shards by `chunk_bounds`).
    pub clients: usize,
    /// Shard-relay count (`K`); one fleet process per shard.
    pub shards: usize,
    /// Fault plan spec (the `FaultPlan` grammar); empty = no faults,
    /// which still runs both pipelines and must compare equal.
    pub faults: String,
    /// Seed for the fault schedule and injectors.
    pub fault_seed: u64,
    /// Use Unix-domain sockets instead of loopback TCP.
    pub uds: bool,
    /// Scratch directory; `reference/` and `faulted/` subtrees are
    /// created (and clobbered) inside it.
    pub dir: PathBuf,
    /// Path of the sparsignd binary to fork (normally
    /// `std::env::current_exe()`; explicit for testability).
    pub binary: PathBuf,
    /// Extra CLI flags forwarded verbatim to every child (training
    /// configuration: `--dim`, `--alpha`, `--seed`, …).
    pub pass: Vec<(String, String)>,
    /// Watchdog: a pipeline that has not finished within this budget is
    /// killed and the soak fails.
    pub timeout: Duration,
    /// `--heal-attempts` forwarded to the root (strict self-healing cap).
    pub heal_attempts: usize,
    /// `--reconnect-secs` forwarded to shards and fleets.
    pub reconnect_secs: u64,
}

impl SoakOptions {
    /// Defaults matching the CI soak-smoke job; callers override
    /// `dir`/`binary` at minimum.
    pub fn new(dir: PathBuf, binary: PathBuf) -> Self {
        SoakOptions {
            rounds: 40,
            clients: 8,
            shards: 2,
            faults: String::new(),
            fault_seed: 7,
            uds: false,
            dir,
            binary,
            pass: Vec::new(),
            timeout: Duration::from_secs(600),
            heal_attempts: 10,
            reconnect_secs: 60,
        }
    }
}

/// Outcome of a soak: the byte-comparison verdict plus restart and
/// round counters recovered from the faulted run's event log.
#[derive(Debug, Clone)]
pub struct SoakReport {
    /// `true` iff the faulted run's `--history-json` is byte-identical
    /// to the uninterrupted reference run's.
    pub identical: bool,
    /// Coordinator (root) kills executed by the schedule.
    pub coordinator_restarts: usize,
    /// Shard-relay kills executed by the schedule.
    pub shard_restarts: usize,
    /// Fleet-process kills executed by the schedule.
    pub agent_restarts: usize,
    /// Distinct rounds that closed in the faulted run (re-runs of a
    /// round after a resume count once).
    pub rounds_closed: usize,
    /// Rounds the faulted root re-opened under strict healing.
    pub recoverages: usize,
    /// Path of the reference run's history JSON.
    pub reference_json: PathBuf,
    /// Path of the faulted run's history JSON.
    pub faulted_json: PathBuf,
    /// Path of the faulted run's event log.
    pub event_log: PathBuf,
    /// Successful `/metrics` scrapes of the faulted root while it ran.
    pub metrics_scrapes: usize,
    /// Distinct coordinator generations those scrapes reached — > 1
    /// proves the scrape port came back after a kill+respawn.
    pub metrics_generations: usize,
    /// `true` iff the scraped `sparsignd_round` gauge never went
    /// backwards across coordinator generations (per-process counters
    /// reset on respawn by design; the round gauge tracks resumed
    /// protocol state and must be monotone).
    pub round_gauge_monotonic: bool,
}

/// Run the reference pipeline (no faults) and the faulted pipeline
/// (under `opts.faults`), compare their history JSON byte-for-byte,
/// and report restart counters. Both pipelines use the same child
/// supervisor; the reference simply has an empty schedule.
pub fn run_soak(opts: &SoakOptions) -> Result<SoakReport, NetError> {
    if opts.shards == 0 {
        return Err(NetError::Config("soak needs --shards >= 1".into()));
    }
    if opts.rounds == 0 {
        return Err(NetError::Config("soak needs --rounds >= 1".into()));
    }
    if opts.clients < opts.shards {
        return Err(NetError::Config(format!(
            "soak needs --clients >= --shards ({} < {})",
            opts.clients, opts.shards
        )));
    }
    // Parse eagerly so a bad spec fails before any process is forked.
    let plan = FaultPlan::parse(&opts.faults, opts.fault_seed).map_err(NetError::Config)?;

    let reference = run_pipeline(opts, "reference", None)?;
    let faulted = run_pipeline(opts, "faulted", Some(&plan))?;

    let ref_body = std::fs::read(&reference.history)?;
    let faulted_body = std::fs::read(&faulted.history)?;
    let events_body = std::fs::read_to_string(&faulted.events).unwrap_or_default();
    let (rounds_closed, recoverages) = count_progress(&events_body);

    Ok(SoakReport {
        identical: ref_body == faulted_body,
        coordinator_restarts: faulted.coordinator_restarts,
        shard_restarts: faulted.shard_restarts,
        agent_restarts: faulted.agent_restarts,
        rounds_closed,
        recoverages,
        reference_json: reference.history,
        faulted_json: faulted.history,
        event_log: faulted.events,
        metrics_scrapes: faulted.metrics_scrapes,
        metrics_generations: faulted.metrics_generations,
        round_gauge_monotonic: faulted.round_gauge_monotonic,
    })
}

/// Distinct `round_close` rounds and total `recoverage` events in an
/// event-log body. Distinct because a round re-run after a resume
/// appears twice in the log but settles once in the history.
fn count_progress(events_body: &str) -> (usize, usize) {
    let mut closed: Vec<u64> = Vec::new();
    let mut recoverages = 0usize;
    for (event, fields) in parse_events(events_body) {
        match event.as_str() {
            "round_close" => {
                if let Some(t) = event_field(&fields, "t") {
                    let t = t as u64;
                    if !closed.contains(&t) {
                        closed.push(t);
                    }
                }
            }
            "recoverage" => recoverages += 1,
            _ => {}
        }
    }
    (closed.len(), recoverages)
}

/// Per-pipeline result handed back to [`run_soak`].
struct PipelineOutcome {
    history: PathBuf,
    events: PathBuf,
    coordinator_restarts: usize,
    shard_restarts: usize,
    agent_restarts: usize,
    metrics_scrapes: usize,
    metrics_generations: usize,
    round_gauge_monotonic: bool,
}

/// Live scrape sidecar: polls the root's `/metrics` (discovered through
/// the `# metrics root …` comment line of `root.ep`) while the pipeline
/// runs. A failed connect or a torn body is a missed sample, never an
/// error — the root may be mid-respawn, and the scrape plane must not
/// perturb the run it observes.
struct MetricsWatch {
    scrapes: usize,
    generations: Vec<usize>,
    last_round: u64,
    regressed: bool,
    last_poll: Option<Instant>,
}

impl MetricsWatch {
    fn new() -> Self {
        MetricsWatch {
            scrapes: 0,
            generations: Vec::new(),
            last_round: 0,
            regressed: false,
            last_poll: None,
        }
    }

    /// Scrape at most every 100ms (the supervisor loop spins at 20ms).
    /// `gen` is the currently supervised root generation; scrapes landed
    /// against it prove the scrape port survives (or returns after) a
    /// kill. The `sparsignd_round` gauge must be globally nondecreasing:
    /// a respawned root resumes from its snapshot, so an observed
    /// regression means the resume lost protocol state.
    fn poll(&mut self, root_ep: &Path, gen: Option<usize>) {
        let Some(gen) = gen else { return };
        if self.last_poll.map(|t| t.elapsed() < Duration::from_millis(100)).unwrap_or(false) {
            return;
        }
        self.last_poll = Some(Instant::now());
        let Some(ep) = metrics_endpoint_of(root_ep) else { return };
        let Some(body) = scrape_metrics(&ep) else { return };
        let Ok(samples) = parse_exposition(&body) else { return };
        let Some(round) = sample_value(&samples, "sparsignd_round", &[("role", "root")]) else {
            return;
        };
        self.scrapes += 1;
        if !self.generations.contains(&gen) {
            self.generations.push(gen);
        }
        if round < self.last_round {
            self.regressed = true;
        }
        self.last_round = self.last_round.max(round);
    }
}

/// The scrape endpoint a serving root appends to its endpoint file as a
/// `# metrics root <ep>` comment line (after the endpoint lines, so
/// line-indexed readers never see it).
fn metrics_endpoint_of(ep_file: &Path) -> Option<Endpoint> {
    let body = std::fs::read_to_string(ep_file).ok()?;
    body.lines()
        .filter_map(|l| l.trim().strip_prefix("# metrics root "))
        .find_map(|rest| Endpoint::parse(rest.trim()).ok())
}

/// One blocking HTTP/1.0 `GET /metrics`. Returns the body on a 200,
/// `None` on any connection, timeout, or protocol failure.
fn scrape_metrics(ep: &Endpoint) -> Option<String> {
    let mut stream = Stream::connect(ep).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(2))).ok()?;
    stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").ok()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).ok()?;
    let text = String::from_utf8(raw).ok()?;
    let (head, body) = text.split_once("\r\n\r\n")?;
    head.starts_with("HTTP/1.0 200").then(|| body.to_string())
}

/// Paths shared by all children of one pipeline.
struct Paths {
    dir: PathBuf,
    logs: PathBuf,
    root_ep: PathBuf,
    shard_eps: Vec<PathBuf>,
    endpoints: PathBuf,
    snapshot: PathBuf,
    events: PathBuf,
    history: PathBuf,
}

impl Paths {
    fn new(base: &Path, tag: &str, shards: usize) -> std::io::Result<Paths> {
        let dir = base.join(tag);
        // Clobber any previous run of this tag so stale endpoint files
        // or a stale snapshot cannot leak into a fresh pipeline.
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        let logs = dir.join("logs");
        std::fs::create_dir_all(&logs)?;
        Ok(Paths {
            shard_eps: (0..shards).map(|i| dir.join(format!("shard{i}.ep"))).collect(),
            root_ep: dir.join("root.ep"),
            endpoints: dir.join("endpoints.txt"),
            snapshot: dir.join("snap.bin"),
            events: dir.join("events.jsonl"),
            history: dir.join("history.json"),
            logs,
            dir,
        })
    }
}

/// One supervised child. `gen` bumps on every respawn so UDS socket
/// paths and log files never collide with a dead generation's.
struct Slot {
    child: Child,
    gen: usize,
}

/// Kills every still-running child on drop so a supervisor error (or
/// watchdog fire) cannot leak orphan processes.
struct Fleet {
    root: Option<Slot>,
    shards: Vec<Option<Slot>>,
    fleets: Vec<Option<Slot>>,
}

impl Fleet {
    fn kill_all(&mut self) {
        let slots = self
            .root
            .iter_mut()
            .chain(self.shards.iter_mut().flatten())
            .chain(self.fleets.iter_mut().flatten());
        for slot in slots {
            let _ = slot.child.kill();
            let _ = slot.child.wait();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.kill_all();
    }
}

fn kill_slot(slot: &mut Slot) {
    let _ = slot.child.kill();
    let _ = slot.child.wait();
}

/// Fork the full topology, babysit it (endpoint composition, fault
/// execution, watchdog), and return once the root exits cleanly.
fn run_pipeline(
    opts: &SoakOptions,
    tag: &str,
    plan: Option<&FaultPlan>,
) -> Result<PipelineOutcome, NetError> {
    let paths = Paths::new(&opts.dir, tag, opts.shards)?;
    let mut schedule = plan.map(|p| p.schedule(opts.shards, opts.shards));
    let fault_spec = plan.filter(|p| !p.is_empty()).map(|_| opts.faults.as_str());

    let mut fleet = Fleet {
        root: Some(spawn_root(opts, &paths, 0, false, fault_spec)?),
        shards: (0..opts.shards)
            .map(|i| spawn_shard(opts, &paths, i, 0, fault_spec).map(Some))
            .collect::<Result<_, _>>()?,
        fleets: (0..opts.shards)
            .map(|i| spawn_fleet(opts, &paths, i, 0, fault_spec).map(Some))
            .collect::<Result<_, _>>()?,
    };

    let deadline = Instant::now() + opts.timeout;
    let mut composed = String::new();
    let mut done = 0usize; // boundaries replayed through the schedule
    let mut coordinator_restarts = 0usize;
    let mut shard_restarts = 0usize;
    let mut agent_restarts = 0usize;
    let mut watch = MetricsWatch::new();

    loop {
        if Instant::now() > deadline {
            fleet.kill_all();
            return Err(NetError::Protocol(format!(
                "soak {tag}: watchdog fired after {:?} (see {})",
                opts.timeout,
                paths.logs.display()
            )));
        }

        compose_endpoints(&paths, &mut composed)?;
        watch.poll(&paths.root_ep, fleet.root.as_ref().map(|s| s.gen));

        // Root exit ends the pipeline: clean exit means Fin went out
        // and the history JSON is on disk; anything else is fatal.
        let root_status = match fleet.root.as_mut() {
            Some(slot) => slot.child.try_wait()?,
            None => None,
        };
        if let Some(status) = root_status {
            fleet.root = None;
            if !status.success() {
                fleet.kill_all();
                return Err(NetError::Protocol(format!(
                    "soak {tag}: coordinator exited with {status} (see {})",
                    paths.logs.display()
                )));
            }
            break;
        }

        // A shard or fleet child must only exit after Fin (success) —
        // kills never race this check because the supervisor reaps a
        // kill synchronously below. Nonzero means a real crash.
        let mut crashed: Option<(&'static str, usize, std::process::ExitStatus)> = None;
        for (kind, slots) in [("shard", &mut fleet.shards), ("fleet", &mut fleet.fleets)] {
            for (i, entry) in slots.iter_mut().enumerate() {
                let Some(slot) = entry.as_mut() else { continue };
                if let Some(status) = slot.child.try_wait()? {
                    if status.success() {
                        *entry = None;
                    } else {
                        crashed = Some((kind, i, status));
                    }
                }
            }
        }
        if let Some((kind, i, status)) = crashed {
            fleet.kill_all();
            return Err(NetError::Protocol(format!(
                "soak {tag}: {kind} {i} exited with {status} (see {})",
                paths.logs.display()
            )));
        }

        // Replay newly durable boundaries through the fault schedule.
        // `snapshot{t}` is emitted after the save returns, so a kill
        // issued for boundary `done = t + 1` can always resume.
        if let Some(sched) = schedule.as_mut() {
            let durable = latest_boundary(&paths.events);
            while done < durable {
                done += 1;
                for action in sched.actions_after(done) {
                    match action {
                        FaultAction::KillCoordinator => {
                            if let Some(slot) = fleet.root.as_mut() {
                                kill_slot(slot);
                                let gen = slot.gen + 1;
                                *slot = spawn_root(opts, &paths, gen, true, fault_spec)?;
                                coordinator_restarts += 1;
                            }
                        }
                        FaultAction::KillShard(i) => {
                            if let Some(slot) = fleet.shards[i].as_mut() {
                                kill_slot(slot);
                                let gen = slot.gen + 1;
                                *slot = spawn_shard(opts, &paths, i, gen, fault_spec)?;
                                shard_restarts += 1;
                            }
                        }
                        FaultAction::KillAgent(i) => {
                            if let Some(slot) = fleet.fleets[i].as_mut() {
                                kill_slot(slot);
                                let gen = slot.gen + 1;
                                *slot = spawn_fleet(opts, &paths, i, gen, fault_spec)?;
                                agent_restarts += 1;
                            }
                        }
                    }
                }
            }
        }

        std::thread::sleep(Duration::from_millis(20));
    }

    // Grace period: shards and fleets exit on their own after relaying
    // Fin, but a child respawned at the last boundary may never have
    // seen it — reap what finishes, then kill the rest without error.
    let grace = Instant::now() + Duration::from_secs(10);
    while Instant::now() < grace {
        let mut live = false;
        for entry in fleet.shards.iter_mut().chain(fleet.fleets.iter_mut()) {
            if let Some(slot) = entry.as_mut() {
                if slot.child.try_wait()?.is_some() {
                    *entry = None;
                } else {
                    live = true;
                }
            }
        }
        if !live {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    fleet.kill_all();

    if !paths.history.exists() {
        return Err(NetError::Protocol(format!(
            "soak {tag}: coordinator exited without writing {}",
            paths.history.display()
        )));
    }
    Ok(PipelineOutcome {
        history: paths.history,
        events: paths.events,
        coordinator_restarts,
        shard_restarts,
        agent_restarts,
        metrics_scrapes: watch.scrapes,
        metrics_generations: watch.generations.len(),
        round_gauge_monotonic: !watch.regressed,
    })
}

/// Highest `done` count made durable so far: `snapshot{t}` means rounds
/// `0..=t` are committed, i.e. `done = t + 1`. Reads the whole log each
/// poll; at soak scale (hundreds of rounds, one line each) that is
/// cheaper than being clever.
fn latest_boundary(events: &Path) -> usize {
    let Ok(body) = std::fs::read_to_string(events) else { return 0 };
    let mut done = 0usize;
    for (event, fields) in parse_events(&body) {
        if event == "snapshot" {
            if let Some(t) = event_field(&fields, "t") {
                done = done.max(t as usize + 1);
            }
        }
    }
    done
}

/// Compose `endpoints.txt` (line 0 = root, line `1 + i` = shard `i`)
/// from the single-writer per-child files. Missing or still-empty
/// children yield a blank line, which readers treat as retriable.
/// Written atomically, and only when the body actually changed.
fn compose_endpoints(paths: &Paths, last: &mut String) -> std::io::Result<()> {
    let mut body = String::new();
    body.push_str(&first_line(&paths.root_ep));
    body.push('\n');
    for ep in &paths.shard_eps {
        body.push_str(&first_line(ep));
        body.push('\n');
    }
    if body != *last {
        let tmp = paths.dir.join("endpoints.txt.tmp");
        std::fs::write(&tmp, &body)?;
        std::fs::rename(&tmp, &paths.endpoints)?;
        *last = body;
    }
    Ok(())
}

fn first_line(path: &Path) -> String {
    std::fs::read_to_string(path)
        .ok()
        .and_then(|b| b.lines().next().map(|l| l.trim().to_string()))
        .unwrap_or_default()
}

/// Listen endpoint for generation `gen` of a child. TCP binds an
/// ephemeral port; UDS gets a generation-suffixed path so a respawn
/// never fights the dead generation's stale socket file.
fn listen_endpoint(opts: &SoakOptions, paths: &Paths, name: &str, gen: usize) -> String {
    if opts.uds {
        format!("uds://{}", paths.dir.join(format!("{name}-g{gen}.sock")).display())
    } else {
        "tcp://127.0.0.1:0".to_string()
    }
}

/// Shared `Command` scaffolding: the subcommand first (it is
/// positional), then the common training flags, the fault spec, a
/// per-generation log file, and no inherited stdin.
fn child_command(
    opts: &SoakOptions,
    paths: &Paths,
    subcommand: &str,
    name: &str,
    gen: usize,
    fault_spec: Option<&str>,
) -> std::io::Result<Command> {
    let log = std::fs::File::create(paths.logs.join(format!("{name}-g{gen}.log")))?;
    let err = log.try_clone()?;
    let mut cmd = Command::new(&opts.binary);
    cmd.stdin(Stdio::null()).stdout(Stdio::from(log)).stderr(Stdio::from(err));
    cmd.arg(subcommand);
    cmd.arg("--clients").arg(opts.clients.to_string());
    cmd.arg("--rounds").arg(opts.rounds.to_string());
    for (flag, value) in &opts.pass {
        cmd.arg(format!("--{flag}")).arg(value);
    }
    if let Some(spec) = fault_spec {
        cmd.arg("--faults").arg(spec);
        cmd.arg("--fault-seed").arg(opts.fault_seed.to_string());
    }
    Ok(cmd)
}

fn spawn(mut cmd: Command, gen: usize) -> Result<Slot, NetError> {
    let child = cmd.spawn()?;
    Ok(Slot { child, gen })
}

fn spawn_root(
    opts: &SoakOptions,
    paths: &Paths,
    gen: usize,
    resume: bool,
    fault_spec: Option<&str>,
) -> Result<Slot, NetError> {
    let mut cmd = child_command(opts, paths, "serve", "root", gen, fault_spec)?;
    cmd.arg("--addr").arg(listen_endpoint(opts, paths, "root", gen));
    // Every generation gets its own scrape port (ephemeral TCP or a
    // generation-suffixed socket) published via the endpoint file's
    // `# metrics root …` line; the supervisor's MetricsWatch follows it
    // across respawns.
    cmd.arg("--metrics-addr").arg(listen_endpoint(opts, paths, "root-metrics", gen));
    cmd.arg("--endpoint-file").arg(&paths.root_ep);
    cmd.arg("--snapshot").arg(&paths.snapshot);
    cmd.arg("--snapshot-every").arg("1");
    cmd.arg("--event-log").arg(&paths.events);
    cmd.arg("--heal-attempts").arg(opts.heal_attempts.to_string());
    cmd.arg("--history-json").arg(&paths.history);
    cmd.arg("--rendezvous-secs").arg("120");
    if resume {
        cmd.arg("--resume").arg(&paths.snapshot);
    }
    spawn(cmd, gen)
}

fn spawn_shard(
    opts: &SoakOptions,
    paths: &Paths,
    i: usize,
    gen: usize,
    fault_spec: Option<&str>,
) -> Result<Slot, NetError> {
    let mut cmd = child_command(opts, paths, "shard", &format!("shard{i}"), gen, fault_spec)?;
    cmd.arg("--index").arg(i.to_string());
    cmd.arg("--shard-count").arg(opts.shards.to_string());
    cmd.arg("--listen").arg(listen_endpoint(opts, paths, &format!("shard{i}"), gen));
    cmd.arg("--connect-file").arg(&paths.endpoints);
    cmd.arg("--publish-file").arg(&paths.shard_eps[i]);
    cmd.arg("--reconnect-secs").arg(opts.reconnect_secs.to_string());
    spawn(cmd, gen)
}

fn spawn_fleet(
    opts: &SoakOptions,
    paths: &Paths,
    i: usize,
    gen: usize,
    fault_spec: Option<&str>,
) -> Result<Slot, NetError> {
    let mut cmd = child_command(opts, paths, "fleet", &format!("fleet{i}"), gen, fault_spec)?;
    cmd.arg("--connect-file").arg(&paths.endpoints);
    cmd.arg("--shard-line").arg(i.to_string());
    cmd.arg("--shard-count").arg(opts.shards.to_string());
    cmd.arg("--reconnect-secs").arg(opts.reconnect_secs.to_string());
    spawn(cmd, gen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_progress_dedups_rerun_rounds_and_counts_recoverages() {
        let body = "\
{\"event\":\"round_close\",\"t\":0}\n\
{\"event\":\"snapshot\",\"t\":0}\n\
{\"event\":\"round_close\",\"t\":1}\n\
{\"event\":\"recoverage\",\"t\":2,\"missing\":3}\n\
{\"event\":\"round_close\",\"t\":2}\n\
{\"event\":\"round_close\",\"t\":1}\n";
        let (closed, recoverages) = count_progress(body);
        assert_eq!(closed, 3, "re-run of round 1 after a resume counts once");
        assert_eq!(recoverages, 1);
    }

    #[test]
    fn latest_boundary_is_monotone_over_the_log() {
        let dir = std::env::temp_dir().join(format!("soak-boundary-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        assert_eq!(latest_boundary(&path), 0, "missing log means no boundary");
        std::fs::write(
            &path,
            "{\"event\":\"snapshot\",\"t\":4}\n{\"event\":\"snapshot\",\"t\":2}\n",
        )
        .unwrap();
        assert_eq!(latest_boundary(&path), 5, "max wins even out of order");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn metrics_endpoint_is_read_from_the_comment_line() {
        let dir = std::env::temp_dir().join(format!("soak-mep-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("root.ep");
        std::fs::write(
            &path,
            "tcp://127.0.0.1:9001\n# metrics root tcp://127.0.0.1:9464\n",
        )
        .unwrap();
        assert_eq!(
            metrics_endpoint_of(&path),
            Some(Endpoint::Tcp("127.0.0.1:9464".into()))
        );
        // No comment line (metrics disabled) → no endpoint, no error.
        std::fs::write(&path, "tcp://127.0.0.1:9001\n").unwrap();
        assert_eq!(metrics_endpoint_of(&path), None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compose_endpoints_blanks_missing_children_and_writes_atomically() {
        let dir = std::env::temp_dir().join(format!("soak-compose-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let paths = Paths::new(&dir, "t", 2).unwrap();
        std::fs::write(&paths.root_ep, "tcp://127.0.0.1:9001\n").unwrap();
        std::fs::write(&paths.shard_eps[1], "tcp://127.0.0.1:9003\n").unwrap();
        let mut last = String::new();
        compose_endpoints(&paths, &mut last).unwrap();
        let body = std::fs::read_to_string(&paths.endpoints).unwrap();
        assert_eq!(body, "tcp://127.0.0.1:9001\n\ntcp://127.0.0.1:9003\n");
        // Unchanged inputs must not rewrite the file (mtime-stable).
        let before = std::fs::metadata(&paths.endpoints).unwrap().modified().unwrap();
        std::thread::sleep(Duration::from_millis(20));
        compose_endpoints(&paths, &mut last).unwrap();
        let after = std::fs::metadata(&paths.endpoints).unwrap().modified().unwrap();
        assert_eq!(before, after);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
