//! The aggregator-shard tier (DESIGN.md §14): a mid-tree coordinator
//! that fronts a disjoint slice `[lo, hi)` of the worker population.
//!
//! Downstream it speaks the ordinary coordinator protocol — clients
//! rendezvous with `Hello`, receive `Welcome`/`RoundOpen`/`Ack`/
//! `Reject`/`Fin`, and submit `Update` frames — so a fleet agent cannot
//! tell a shard from the root. Upstream it rendezvouses with
//! `ShardHello` over the same wire grammar and, once per round, folds
//! everything it accepted into its local
//! [`VoteAccumulator`] and streams **one** merged `ShardAgg` frame to
//! the root: the raw carry-save counter planes, the per-worker scalar
//! records in slot order, the client-tier byte totals it fronted, and
//! its drained typed-reject tallies. Vote counts are integer sums, so
//! the root's word-parallel merge of shard planes commutes with folding
//! the same updates directly — a sharded run's `RunHistory` is
//! bit-identical to the flat run on the same seed
//! (`tests/shard_tree.rs`).
//!
//! The shard never holds model state and never sees the data: it
//! relays the root's `RoundOpen` broadcast downstream *verbatim* (one
//! refcounted frame shared across every client's output queue) and
//! validates submissions with the same [`RoundTable`] the root uses.
//! Like the root it is single-threaded: one [`Mux`] readiness loop
//! carries the upstream connection and every downstream client.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compressors::PackedTernary;
use crate::coordinator::{TrainingRun, VoteAccumulator, WorkerSampler};
use crate::metrics::registry::{phase as mphase, MetricsRegistry};

use super::client::retriable;
use super::faults::FaultInjector;
use super::protocol::{Phase, PhaseTracker, Roster, RoundTable};
use super::reactor::{Mux, MuxEvent};
use super::wire::{self, Msg, MsgType, ShardRec, WireBuf};
use super::{read_frame_bytes, Endpoint, Listener, NetError, Stream};

/// Aggregator-shard configuration.
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// The root coordinator (or parent shard) to report to.
    pub upstream: Endpoint,
    /// Bind address for the downstream fleet.
    pub listen: Endpoint,
    /// Global worker range this shard fronts (`lo..hi`).
    pub lo: usize,
    pub hi: usize,
    /// Local submission deadline per round; `None` waits for every live
    /// downstream slot (the loopback-equivalence configuration). When
    /// the root runs a deadline, set this *shorter* so the merged frame
    /// lands before the root closes the round.
    pub round_deadline: Option<Duration>,
    /// How long the shard waits for its downstream fleet to cover
    /// `[lo, hi)` once a round is pending relay.
    pub rendezvous_timeout: Duration,
    /// Frame payload cap, both directions.
    pub max_payload: usize,
    /// Read timeout for the blocking upstream handshake.
    pub handshake_timeout: Duration,
    /// Environment fingerprint downstream claims must match (0 disables
    /// the check, exactly as on the root).
    pub env_fingerprint: u64,
    /// Upstream self-healing window: on losing the root connection
    /// (root crash, drain/restart, injected partition) keep redialing
    /// with exponential backoff for this long instead of failing the
    /// shard. `None` is the legacy fail-fast behaviour. The abandoned
    /// round is void — the respawned root re-broadcasts it after this
    /// shard re-claims its range, so the run stays bit-identical.
    pub reconnect: Option<Duration>,
    /// Re-resolve the upstream endpoint from this `(file, line)` on
    /// every dial instead of using the static `upstream` address — a
    /// respawned root binds a fresh port and republishes it, and a
    /// shard that cached the dead address would redial into the void.
    pub upstream_file: Option<(PathBuf, usize)>,
    /// Deterministic fault injection for soak runs (`None` in
    /// production): outbound send delay plus scheduled upstream
    /// partitions, scoped to the shard role by [`FaultPlan::injector`].
    ///
    /// [`FaultPlan::injector`]: super::faults::FaultPlan::injector
    pub faults: Option<FaultInjector>,
    /// Scrape port: every shard exposes its own `GET /metrics` /
    /// `GET /healthz`, so a whole aggregation tree is scrape-able
    /// (DESIGN.md §17). `None` disables it.
    pub metrics_addr: Option<Endpoint>,
    /// The registry the scrape port renders. Callers that know the
    /// shard's index should inject [`MetricsRegistry::shard`] so the
    /// `shard="<index>"` label is right; when left `None`,
    /// [`ShardCoordinator::bind`] falls back to labelling by `lo`.
    pub metrics: Option<Arc<MetricsRegistry>>,
}

impl ShardOptions {
    pub fn new(upstream: Endpoint, listen: Endpoint, lo: usize, hi: usize) -> Self {
        Self {
            upstream,
            listen,
            lo,
            hi,
            round_deadline: None,
            rendezvous_timeout: Duration::from_secs(30),
            max_payload: wire::MAX_PAYLOAD,
            handshake_timeout: Duration::from_secs(30),
            env_fingerprint: 0,
            reconnect: None,
            upstream_file: None,
            faults: None,
            metrics_addr: None,
            metrics: None,
        }
    }

    /// Scrape port for this shard (DESIGN.md §17).
    pub fn with_metrics_addr(mut self, addr: Option<Endpoint>) -> Self {
        self.metrics_addr = addr;
        self
    }

    /// Inject the registry the scrape port renders.
    pub fn with_metrics(mut self, registry: Option<Arc<MetricsRegistry>>) -> Self {
        self.metrics = registry;
        self
    }
}

/// What one shard observed over a full run, split by tier.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStats {
    /// Rounds relayed downstream (re-broadcasts of the same round count
    /// again, exactly as the root re-sends them).
    pub rounds_relayed: u64,
    /// Client updates accepted and folded into merged frames.
    pub updates_folded: u64,
    /// Client-tier wire bytes accepted (update frames).
    pub client_up_bytes: u64,
    /// Client-tier wire bytes broadcast (relayed `RoundOpen` frames).
    pub client_down_bytes: u64,
    /// Shard-tier wire bytes sent upstream (`ShardHello` + merged
    /// `ShardAgg` frames).
    pub root_up_bytes: u64,
    /// Shard-tier wire bytes received from upstream.
    pub root_down_bytes: u64,
    /// Typed rejects the root issued against this shard's merged frames
    /// (a late shard is a straggler like any other).
    pub rejects_from_root: u64,
    /// Times the upstream link was lost and re-rendezvoused (0 unless
    /// [`ShardOptions::reconnect`] is set).
    pub upstream_reconnects: u64,
}

/// A bound-but-not-yet-serving shard; binding first lets callers learn
/// the resolved downstream endpoint before the fleet dials in.
pub struct ShardCoordinator {
    listener: Listener,
    local: Endpoint,
    metrics_listener: Option<Listener>,
    metrics_local: Option<Endpoint>,
    opts: ShardOptions,
}

impl ShardCoordinator {
    /// Bind the downstream accept socket — and the scrape socket when
    /// `opts.metrics_addr` asks for one.
    pub fn bind(mut opts: ShardOptions) -> Result<Self, NetError> {
        if opts.lo >= opts.hi {
            return Err(NetError::Config(format!(
                "shard range {}..{} is empty",
                opts.lo, opts.hi
            )));
        }
        let listener = Listener::bind(&opts.listen)?;
        let local = listener.local_endpoint(&opts.listen);
        let (metrics_listener, metrics_local) = match &opts.metrics_addr {
            Some(addr) => {
                let l = Listener::bind(addr)?;
                let resolved = l.local_endpoint(addr);
                if opts.metrics.is_none() {
                    opts.metrics = Some(MetricsRegistry::shard(opts.lo));
                }
                (Some(l), Some(resolved))
            }
            None => (None, None),
        };
        Ok(Self { listener, local, metrics_listener, metrics_local, opts })
    }

    /// The resolved downstream bind address (clients dial this).
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.local
    }

    /// The resolved scrape address (`GET /metrics` here), when bound.
    pub fn metrics_endpoint(&self) -> Option<&Endpoint> {
        self.metrics_local.as_ref()
    }

    /// The registry the scrape port renders, when one exists.
    pub fn metrics_registry(&self) -> Option<&Arc<MetricsRegistry>> {
        self.opts.metrics.as_ref()
    }

    /// Rendezvous upstream, serve the downstream fleet until the root
    /// sends `Fin` (relayed before returning), and report the byte
    /// traffic. `workers`/`dim` are the global population M and model
    /// dimension d the run was built for — the shard needs them before
    /// the upstream `Welcome` to compute the config fingerprint its
    /// `ShardHello` must carry.
    pub fn run(
        self,
        run: &TrainingRun,
        workers: usize,
        dim: usize,
    ) -> Result<ShardStats, NetError> {
        let ShardCoordinator { listener, local, metrics_listener, metrics_local, opts } = self;
        if opts.hi > workers {
            return Err(NetError::Config(format!(
                "shard range {}..{} exceeds population {workers}",
                opts.lo, opts.hi
            )));
        }
        // The merged frame carries vote-counter planes; without the
        // streaming vote path there is nothing to fold them into —
        // same gate the root applies to `ShardHello` claims.
        let n_max = WorkerSampler::new(workers, run.participation).per_round();
        if !run.streams_votes(n_max) {
            return Err(NetError::Config(
                "sharded aggregation requires the streaming unit-ternary vote path \
                 (majority-vote aggregation with a stateless sign compressor)"
                    .into(),
            ));
        }

        let mut stats = ShardStats::default();
        let cfg = run.config_fingerprint(dim, workers, 0);
        let (upstream, commit) =
            handshake_with_retry(&opts, run, workers, dim, cfg, &mut stats)?;

        let mut mux = Mux::new(opts.max_payload)?;
        if let Some(fi) = &opts.faults {
            mux.set_send_delay(fi.send_delay());
        }
        let up = mux.adopt(upstream)?;
        mux.listen(listener)?;
        if let Some(l) = metrics_listener {
            let reg = opts.metrics.clone().unwrap_or_else(|| MetricsRegistry::shard(opts.lo));
            mux.listen_metrics(l, reg)?;
        }

        let metrics = opts.metrics.clone();
        let drv = ShardDriver {
            run,
            metrics,
            m: workers,
            d: dim,
            cfg,
            commit,
            faults: opts.faults.clone(),
            opts: &opts,
            mux,
            up,
            phase: PhaseTracker::new(),
            roster: Roster::ranged(opts.lo, opts.hi),
            alive: vec![true], // conn 0 = upstream
            table: RoundTable::new(),
            round: None,
            pending: None,
            votes: VoteAccumulator::new(),
            losses: Vec::new(),
            bits: Vec::new(),
            nnz: Vec::new(),
            slot_worker: Vec::new(),
            pack: PackedTernary::zeros(0, 1.0),
            wbuf: WireBuf::new(),
            frame: Vec::new(),
            evs: Vec::new(),
            stats,
            fin: false,
        };
        let result = drv.drive();

        #[cfg(unix)]
        {
            if let Endpoint::Uds(path) = &local {
                let _ = std::fs::remove_file(path);
            }
            if let Some(Endpoint::Uds(path)) = &metrics_local {
                let _ = std::fs::remove_file(path);
            }
        }
        #[cfg(not(unix))]
        let _ = (&local, &metrics_local);
        result
    }
}

/// The upstream address for the next dial: the static option, or —
/// when [`ShardOptions::upstream_file`] is set — re-read from the
/// endpoint file so a respawned root's fresh port is picked up. A
/// missing or still-blank line is a *retriable* I/O miss (the root may
/// not have republished yet), not a config error.
fn resolve_upstream(opts: &ShardOptions) -> Result<Endpoint, NetError> {
    let Some((path, line)) = &opts.upstream_file else {
        return Ok(opts.upstream.clone());
    };
    let body = std::fs::read_to_string(path)?;
    let text = body.lines().nth(*line).map(str::trim).unwrap_or("");
    if text.is_empty() {
        return Err(NetError::Io(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            format!("endpoint file {} has no line {} yet", path.display(), line),
        )));
    }
    Endpoint::parse(text)
}

/// [`handshake_upstream`] with the fleet agents' backoff discipline
/// (25 ms doubling, capped at 1 s) inside the
/// [`ShardOptions::reconnect`] window, re-resolving the endpoint before
/// every dial. Without a window it is a single attempt, as before.
fn handshake_with_retry(
    opts: &ShardOptions,
    run: &TrainingRun,
    workers: usize,
    dim: usize,
    cfg: u64,
    stats: &mut ShardStats,
) -> Result<(Stream, [u64; 4]), NetError> {
    let deadline = opts.reconnect.map(|w| Instant::now() + w);
    let mut backoff = Duration::from_millis(25);
    loop {
        let attempt = resolve_upstream(opts)
            .and_then(|ep| handshake_upstream(&ep, opts, run, workers, dim, cfg, stats));
        match attempt {
            Ok(ok) => return Ok(ok),
            Err(e) if retriable(&e) => {
                let Some(dl) = deadline else { return Err(e) };
                if Instant::now() + backoff >= dl {
                    return Err(e);
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Blocking upstream rendezvous: `ShardHello` → `Welcome` (whose shape
/// must match the run this shard was built for). Returns the connected
/// stream plus the root's selection commitment, which the shard relays
/// verbatim in its own downstream `Welcome`s.
fn handshake_upstream(
    upstream: &Endpoint,
    opts: &ShardOptions,
    run: &TrainingRun,
    workers: usize,
    dim: usize,
    cfg: u64,
    stats: &mut ShardStats,
) -> Result<(Stream, [u64; 4]), NetError> {
    let mut conn = Stream::connect(upstream)?;
    conn.set_read_timeout(Some(opts.handshake_timeout))?;
    let mut wbuf = WireBuf::new();
    let mut out = Vec::new();
    let hello = Msg::ShardHello {
        lo: opts.lo as u64,
        hi: opts.hi as u64,
        cfg,
        env: opts.env_fingerprint,
    };
    stats.root_up_bytes += wbuf.encode(&hello, &mut out) as u64;
    std::io::Write::write_all(&mut conn, &out)?;

    let mut buf = Vec::new();
    let len = read_frame_bytes(&mut conn, opts.max_payload, &mut buf)?;
    stats.root_down_bytes += len as u64;
    let (frame, _) = wire::parse_frame(&buf[..len], opts.max_payload)?;
    match wire::decode_msg(frame)? {
        Msg::Welcome { workers: w, dim: d, rounds, commit, .. } => {
            if w != workers as u64 || d != dim as u64 || rounds != run.rounds as u64 {
                return Err(NetError::Protocol(format!(
                    "upstream welcome shape mismatch: root says {w}w/{d}d/{rounds}r, \
                     shard built for {workers}w/{dim}d/{}r",
                    run.rounds
                )));
            }
            Ok((conn, commit))
        }
        other => Err(NetError::Protocol(format!(
            "expected Welcome from upstream, got {:?}",
            other.msg_type()
        ))),
    }
}

/// A `RoundOpen` received from upstream but not yet relayed — the
/// downstream fleet has not covered `[lo, hi)` yet (it dials
/// concurrently with the shard's own upstream claim, so the root's
/// first broadcast can outrun it). Held until coverage, bounded by the
/// rendezvous timeout.
struct PendingRound {
    t: usize,
    raw: Arc<[u8]>,
    selected_local: Vec<usize>,
    since: Instant,
}

/// The round currently collecting downstream submissions.
struct OpenRound {
    t: usize,
    deadline: Option<Instant>,
    /// Client-tier uplink bytes accepted this round.
    up_bytes: u64,
    /// Client-tier downlink bytes relayed this round.
    down_bytes: u64,
}

/// The shard proper. Single-threaded: every field is plain state
/// mutated between [`Mux::pump`] calls, exactly like the root's driver.
struct ShardDriver<'a> {
    run: &'a TrainingRun,
    /// Observability registry (DESIGN.md §17); `None` without a scrape
    /// port. Fed at the same points the [`ShardStats`] fields move.
    metrics: Option<Arc<MetricsRegistry>>,
    /// Global population / model dimension (the shard validates against
    /// the same shapes the root announces).
    m: usize,
    d: usize,
    cfg: u64,
    /// Root's selection commitment, relayed in downstream `Welcome`s
    /// (refreshed on upstream reconnect — a respawned root resumed from
    /// a snapshot carries the same commitment forward).
    commit: [u64; 4],
    /// Shard-scoped fault injector (owned: `partition_now` keeps
    /// fired-round state).
    faults: Option<FaultInjector>,
    opts: &'a ShardOptions,
    mux: Mux,
    /// Upstream connection id inside the mux (adopted first, so 0).
    up: usize,
    phase: PhaseTracker,
    roster: Roster,
    alive: Vec<bool>,
    table: RoundTable,
    round: Option<OpenRound>,
    pending: Option<PendingRound>,
    votes: VoteAccumulator,
    /// Per-local-slot scalars, compacted into `ShardRec`s at round close.
    losses: Vec<f64>,
    bits: Vec<f64>,
    nnz: Vec<usize>,
    /// Local slot → global worker id (slot order = the global selection
    /// order filtered to `[lo, hi)`).
    slot_worker: Vec<usize>,
    pack: PackedTernary,
    wbuf: WireBuf,
    frame: Vec<u8>,
    evs: Vec<MuxEvent>,
    stats: ShardStats,
    fin: bool,
}

impl<'a> ShardDriver<'a> {
    fn drive(mut self) -> Result<ShardStats, NetError> {
        let res = self.serve();
        for conn in 0..self.alive.len() {
            self.mux.close(conn);
        }
        res.map(|()| self.stats)
    }

    fn serve(&mut self) -> Result<(), NetError> {
        loop {
            if self.fin {
                // Fin relayed; flush the queues and exit.
                self.drain_outgoing();
                if matches!(self.phase.phase(), Phase::Broadcast(_)) {
                    self.phase.finish();
                }
                if let Some(m) = self.met() {
                    m.set_phase(mphase::FINISHED);
                }
                return Ok(());
            }
            if !self.mux.is_open(self.up) {
                self.reconnect_upstream()?;
            }
            // A deferred round starts the moment the fleet covers the
            // range — and fails the shard if it never does.
            if let Some(p) = &self.pending {
                if self.roster.covered() {
                    let p = self.pending.take().expect("pending checked");
                    self.start_round(p);
                } else if p.since.elapsed() > self.opts.rendezvous_timeout {
                    return Err(NetError::Protocol(format!(
                        "shard {}..{}: round {} pending but the downstream fleet \
                         never covered the range",
                        self.opts.lo, self.opts.hi, p.t
                    )));
                }
            }
            // Finalize on deadline or completion.
            let mut wait = Duration::from_millis(200);
            if let Some(or) = &self.round {
                let expired = match or.deadline {
                    Some(dl) => {
                        let left = dl.saturating_duration_since(Instant::now());
                        wait = wait.min(left);
                        left.is_zero()
                    }
                    None => false,
                };
                if expired || self.table.complete() {
                    self.finalize_round();
                    continue;
                }
            }
            self.pump_step(wait)?;
        }
    }

    /// One reactor turn, reusing the event buffer across calls.
    fn pump_step(&mut self, wait: Duration) -> Result<(), NetError> {
        let mut evs = std::mem::take(&mut self.evs);
        evs.clear();
        let res = self.mux.pump(Some(wait), &mut evs);
        for ev in evs.drain(..) {
            self.on_mux_event(ev);
        }
        self.evs = evs;
        res
    }

    fn on_mux_event(&mut self, ev: MuxEvent) {
        match ev {
            MuxEvent::Accepted { conn } => {
                debug_assert_eq!(conn, self.alive.len(), "conn ids are arrival-ordered");
                self.alive.push(true);
            }
            MuxEvent::Closed { conn } => self.mark_dead(conn),
            MuxEvent::Frame { conn, bytes } => {
                self.on_frame(conn, &bytes);
                self.mux.recycle(bytes);
            }
        }
    }

    fn on_frame(&mut self, conn: usize, bytes: &[u8]) {
        if conn >= self.alive.len() || !self.alive[conn] {
            return;
        }
        if conn == self.up {
            self.stats.root_down_bytes += bytes.len() as u64;
            if let Some(m) = self.met() {
                m.add_shard_downlink_wire_bytes(bytes.len() as u64);
            }
            self.on_upstream_frame(bytes);
        } else {
            self.on_downstream_frame(conn, bytes);
        }
    }

    /// Control frames from the root: round broadcasts to relay, typed
    /// rejects against our merged frames, heartbeat acks, and `Fin`.
    /// Anything else — or an undecodable frame — is a root-side
    /// protocol violation the shard cannot continue past.
    fn on_upstream_frame(&mut self, bytes: &[u8]) {
        let Ok((frame, _)) = wire::parse_frame(bytes, self.opts.max_payload) else {
            self.mux.close(self.up);
            return;
        };
        match frame.msg_type {
            MsgType::RoundOpen => match wire::decode_msg(frame) {
                Ok(Msg::RoundOpen { t, selected, params, .. }) => {
                    if params.len() != self.d {
                        self.mux.close(self.up);
                        return;
                    }
                    self.on_round_open(t, &selected, bytes);
                }
                _ => self.mux.close(self.up),
            },
            MsgType::Fin => {
                // Discard any still-open round (the root has moved on)
                // and relay the run's end to every downstream client.
                self.abandon_round();
                let shared: Arc<[u8]> = Arc::from(bytes);
                for conn in 0..self.alive.len() {
                    if conn == self.up || !self.alive[conn] {
                        continue;
                    }
                    if self.mux.send(conn, shared.clone()) {
                        self.stats.client_down_bytes += bytes.len() as u64;
                    } else {
                        self.mark_dead(conn);
                    }
                }
                self.fin = true;
            }
            MsgType::Reject => {
                self.stats.rejects_from_root += 1;
            }
            MsgType::Ack => {}
            _ => self.mux.close(self.up),
        }
    }

    /// An upstream `RoundOpen`: supersedes whatever round is open (a
    /// re-broadcast of the same round after a zero-submission attempt,
    /// or a newer round the root opened after closing ours without us)
    /// and is relayed as soon as the downstream roster covers the range.
    fn on_round_open(&mut self, t: u64, selected: &[u64], raw: &[u8]) {
        let Ok(t) = usize::try_from(t) else {
            self.mux.close(self.up);
            return;
        };
        // Scheduled partition: sever our own upstream link at this
        // round boundary instead of relaying it. The serve loop's
        // reconnect path re-rendezvouses; the root reclaims the range
        // and (under strict healing) re-broadcasts the round.
        if let Some(fi) = &mut self.faults {
            if fi.partition_now(t) {
                self.mux.close(self.up);
                return;
            }
        }
        self.abandon_round();
        // The global cohort, filtered to this shard's slice — in the
        // global selection order, which every tier preserves.
        let selected_local: Vec<usize> = selected
            .iter()
            .filter_map(|&w| usize::try_from(w).ok())
            .filter(|&w| w >= self.opts.lo && w < self.opts.hi)
            .collect();
        self.pending = Some(PendingRound {
            t,
            raw: Arc::from(raw),
            selected_local,
            since: Instant::now(),
        });
    }

    /// Relay the round downstream and open the local table.
    fn start_round(&mut self, p: PendingRound) {
        let t = p.t;
        self.note_round_open(t);
        let n_local = p.selected_local.len();
        let owners: Vec<usize> = p
            .selected_local
            .iter()
            .map(|&w| self.roster.owner_of(w).unwrap_or(usize::MAX))
            .collect();
        self.table.open(t, self.m, &p.selected_local, &owners, &self.alive);
        self.votes.reset(self.d, n_local.max(1));
        self.losses.clear();
        self.losses.resize(n_local, 0.0);
        self.bits.clear();
        self.bits.resize(n_local, 0.0);
        self.nnz.clear();
        self.nnz.resize(n_local, 0);
        self.slot_worker.clear();
        self.slot_worker.extend_from_slice(&p.selected_local);

        let mut down_bytes = 0u64;
        let len = p.raw.len() as u64;
        for conn in 0..self.alive.len() {
            if conn == self.up || !self.alive[conn] || self.roster.range_of(conn).is_none() {
                continue;
            }
            if self.mux.send(conn, p.raw.clone()) {
                down_bytes += len;
            } else {
                self.mark_dead(conn);
            }
        }
        self.phase.aggregate(t);
        self.stats.rounds_relayed += 1;
        if let Some(m) = self.met() {
            m.set_round(t as u64);
            m.set_cohort(n_local as u64);
            m.set_phase(mphase::AGGREGATE);
        }
        let deadline = self.opts.round_deadline.map(|d| Instant::now() + d);
        self.round = Some(OpenRound { t, deadline, up_bytes: 0, down_bytes });
    }

    /// Phase bookkeeping for an upstream round announcement. The shard
    /// does not drive the round sequence — the root does — so beyond
    /// the two in-sequence transitions it re-anchors the tracker at the
    /// announced round (first round of a resumed run, a re-broadcast of
    /// the same round, or a round the root opened after closing ours
    /// without us).
    fn note_round_open(&mut self, t: usize) {
        match self.phase.phase() {
            Phase::Standby if t == 0 => self.phase.open_round(0),
            Phase::Broadcast(prev) if t == prev + 1 => self.phase.open_round(t),
            _ => {
                self.phase = PhaseTracker::resumed_at(t);
                self.phase.open_round(t);
            }
        }
    }

    /// The upstream link is gone (root crash, drain/restart, injected
    /// partition, or a root-side protocol violation that made us hang
    /// up). With a [`ShardOptions::reconnect`] window: void the open
    /// round — the root has already released this shard's claim and,
    /// under strict healing, will re-broadcast after we re-claim — and
    /// block on the backoff redial, re-resolving the endpoint so a
    /// respawned root's fresh port is found. Downstream sessions are
    /// fenced (dropped) with the epoch; reconnecting clients re-claim
    /// and see the round again via the relayed re-broadcast. Without a
    /// reconnect window this is the legacy fail-fast.
    fn reconnect_upstream(&mut self) -> Result<(), NetError> {
        if self.opts.reconnect.is_none() {
            return Err(NetError::Disconnected);
        }
        self.abandon_round();
        if self.alive.get(self.up).copied().unwrap_or(false) {
            self.alive[self.up] = false;
        }
        // Epoch fence: drop every downstream session before redialing.
        // A client update still in flight for the voided round dies
        // with its socket instead of landing after the new epoch opens
        // as a Late/Duplicate typed reject — reject tallies ride merged
        // frames into the root's ledger, so a healed run must produce
        // none that the uninterrupted run would not. The fleet's
        // reconnect-with-backoff re-claims on a fresh socket and
        // recomputes from the re-broadcast (worker rounds are pure).
        for conn in 0..self.alive.len() {
            if conn != self.up && self.alive[conn] {
                self.mark_dead(conn);
            }
        }
        let (stream, commit) = handshake_with_retry(
            self.opts,
            self.run,
            self.m,
            self.d,
            self.cfg,
            &mut self.stats,
        )?;
        let conn = self.mux.adopt(stream)?;
        // No pump ran during the blocking redial, so no Accepted event
        // raced the id: adopt order == arrival order still holds.
        debug_assert_eq!(conn, self.alive.len(), "conn ids are arrival-ordered");
        self.alive.push(true);
        self.up = conn;
        self.commit = commit;
        self.stats.upstream_reconnects += 1;
        if let Some(m) = self.met() {
            m.inc_upstream_reconnect();
        }
        Ok(())
    }

    /// Close the local round and stream the merged frame upstream.
    fn finalize_round(&mut self) {
        let Some(or) = self.round.take() else { return };
        self.table.close();
        let mut recs = Vec::with_capacity(self.slot_worker.len());
        for (k, &w) in self.slot_worker.iter().enumerate() {
            if self.table.filled()[k] {
                recs.push(ShardRec {
                    worker: w as u64,
                    loss: self.losses[k],
                    bits: self.bits[k],
                    nnz: self.nnz[k] as u64,
                    scale: 1.0,
                });
            }
        }
        debug_assert_eq!(self.votes.msgs(), recs.len(), "one fold per filled slot");
        // `(planes == 0) != (k == 0)` is malformed on the wire, so an
        // empty round ships empty planes.
        let (planes, pos, neg) = if recs.is_empty() {
            (0, &[][..], &[][..])
        } else {
            (self.votes.planes(), self.votes.pos_planes(), self.votes.neg_planes())
        };
        let rejects = self.table.take_rejects();
        self.frame.clear();
        let mut out = std::mem::take(&mut self.frame);
        let len = self.wbuf.encode_shard_agg(
            or.t as u64,
            self.opts.lo as u64,
            self.opts.hi as u64,
            &recs,
            or.up_bytes,
            or.down_bytes,
            &rejects,
            self.d,
            planes,
            pos,
            neg,
            &mut out,
        );
        let shared: Arc<[u8]> = Arc::from(out.as_slice());
        self.frame = out;
        let mut merged_len = 0u64;
        if self.mux.send(self.up, shared) {
            self.stats.root_up_bytes += len as u64;
            merged_len = len as u64;
        }
        let stragglers = (self.slot_worker.len() - recs.len()) as u64;
        self.stats.updates_folded += recs.len() as u64;
        self.stats.client_up_bytes += or.up_bytes;
        self.stats.client_down_bytes += or.down_bytes;
        // Same movements as the ShardStats fields above: client-tier
        // bytes this round, the merged frame as shard-tier uplink
        // (downlink is counted per upstream frame), local stragglers,
        // and the locally-tallied typed rejects riding the frame.
        if let Some(m) = self.met() {
            m.observe_round_close(or.up_bytes, or.down_bytes, merged_len, 0, stragglers);
            m.add_rejects(&rejects);
            m.set_phase(mphase::BROADCAST);
        }
        self.phase.broadcast(or.t);
    }

    /// Drop a superseded round without reporting it upstream (the root
    /// has already closed it and counted our slots as stragglers).
    /// Locally-tallied typed rejects survive in the table and ride the
    /// next merged frame.
    fn abandon_round(&mut self) {
        if self.round.take().is_some() {
            self.table.close();
        }
        self.pending = None;
    }

    /// Downstream frames: the ordinary client-facing protocol.
    fn on_downstream_frame(&mut self, conn: usize, bytes: &[u8]) {
        let Ok((frame, _)) = wire::parse_frame(bytes, self.opts.max_payload) else {
            self.hangup(conn);
            return;
        };
        match frame.msg_type {
            MsgType::Hello => match wire::decode_msg(frame) {
                Ok(Msg::Hello { lo, hi, cfg, env }) => self.on_hello(conn, lo, hi, cfg, env),
                _ => self.hangup(conn),
            },
            MsgType::Heartbeat => {
                let t = self.round.as_ref().map(|r| r.t).unwrap_or(0) as u64;
                if !self.send(conn, &Msg::Ack { t, worker: conn as u64 }) {
                    self.mark_dead(conn);
                }
            }
            MsgType::Update => {
                let Ok(uv) = wire::decode_update(frame.payload) else {
                    self.hangup(conn);
                    return;
                };
                match self.submit_update(conn, &uv, bytes.len() as u64) {
                    Ok(()) => {}
                    Err(Some(reason)) => {
                        let reject = Msg::Reject { t: uv.t, worker: uv.worker, reason };
                        if !self.send(conn, &reject) {
                            self.mark_dead(conn);
                        }
                    }
                    Err(None) => self.hangup(conn),
                }
            }
            // Nested shard tiers are not supported: a `ShardHello` (or
            // any server-bound oddity) downstream is a protocol error.
            _ => self.hangup(conn),
        }
    }

    /// Downstream rendezvous claim — the same fingerprint vetting the
    /// root applies, against this shard's `[lo, hi)` roster (claims
    /// stay in global worker ids; `Roster::ranged` bounds them).
    fn on_hello(&mut self, conn: usize, lo: u64, hi: u64, cfg: u64, env: u64) {
        let env_ok = self.opts.env_fingerprint == 0 || env == self.opts.env_fingerprint;
        if cfg != self.cfg || !env_ok {
            self.hangup(conn);
            return;
        }
        let claim = usize::try_from(lo)
            .ok()
            .zip(usize::try_from(hi).ok())
            .map(|(l, h)| self.roster.claim(conn, l, h));
        match claim {
            Some(Ok(())) => {
                if let Some(m) = self.met() {
                    m.roster_add(hi.saturating_sub(lo));
                }
                let msg = Msg::Welcome {
                    client_id: conn as u64,
                    workers: self.m as u64,
                    dim: self.d as u64,
                    rounds: self.run.rounds as u64,
                    commit: self.commit,
                };
                if !self.send(conn, &msg) {
                    self.mark_dead(conn);
                }
            }
            _ => self.hangup(conn),
        }
    }

    /// Validate + fold one downstream update — the same split contract
    /// as the root: `Err(Some(reason))` asks for a typed reject,
    /// `Err(None)` is a payload violation that drops the connection.
    fn submit_update(
        &mut self,
        conn: usize,
        uv: &wire::UpdateView<'_>,
        wire_len: u64,
    ) -> Result<(), Option<wire::RejectReason>> {
        if uv.grad.dim() != self.d {
            return Err(None);
        }
        let t = usize::try_from(uv.t).unwrap_or(usize::MAX);
        let worker = usize::try_from(uv.worker).unwrap_or(usize::MAX);
        // The shard only exists on the streaming vote path: every
        // accepted payload must be unit-scale packed ternary, decoded
        // *before* the slot is claimed.
        match uv.grad.unpack_ternary_into(&mut self.pack) {
            Ok(Some(())) if self.pack.scale() == 1.0 => {}
            _ => return Err(None),
        }
        let slot = self.table.submit(t, worker, conn).map_err(Some)?;
        self.losses[slot] = uv.loss;
        self.bits[slot] = uv.grad.bits();
        self.nnz[slot] = self.pack.nnz();
        self.votes.fold(&self.pack);
        if let Some(or) = &mut self.round {
            or.up_bytes += wire_len;
        }
        Ok(())
    }

    /// Bounded post-Fin flush, mirroring the root's.
    fn drain_outgoing(&mut self) {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let pending: usize =
                (0..self.alive.len()).filter(|&c| self.alive[c]).map(|c| self.mux.backlog(c)).sum();
            if pending == 0 {
                return;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return;
            }
            if self.pump_step(left.min(Duration::from_millis(50))).is_err() {
                return;
            }
        }
    }

    fn send(&mut self, conn: usize, msg: &Msg) -> bool {
        self.frame.clear();
        self.wbuf.encode(msg, &mut self.frame);
        self.mux.send(conn, Arc::from(self.frame.as_slice()))
    }

    fn hangup(&mut self, conn: usize) {
        self.mark_dead(conn);
    }

    /// The observability registry, if a scrape port is armed.
    fn met(&self) -> Option<&MetricsRegistry> {
        self.metrics.as_deref()
    }

    fn mark_dead(&mut self, conn: usize) {
        self.mux.close(conn);
        if conn < self.alive.len() && self.alive[conn] {
            self.alive[conn] = false;
            if conn != self.up {
                let freed = self.roster.release(conn);
                self.table.drop_conn(conn);
                if let (Some(m), Some((lo, hi))) = (self.met(), freed) {
                    m.roster_sub((hi - lo) as u64);
                }
            }
        }
    }
}
