//! The federation transport layer (DESIGN.md §11): a real byte boundary
//! for the compressed messages the rest of the crate only accounted for.
//!
//! * [`wire`] — versioned, length-prefixed, CRC-checked binary frames;
//!   `PackedTernary` bitplanes cross the wire as raw `u64` words and
//!   round-trip bit-identically.
//! * [`protocol`] — the coordinator state machine (Standby → RoundOpen →
//!   Aggregating → Broadcast), rendezvous roster and per-round
//!   submission table, transport-free and unit-tested.
//! * `reactor` (crate-private) — the readiness-driven connection multiplexer
//!   (DESIGN.md §14.3): one thread, nonblocking sockets behind an
//!   epoll/poll shim, vectored broadcast writes of shared refcounted
//!   frames — no per-connection threads, no sleep-polling accept loop.
//! * [`server`] — the root coordinator service over TCP or Unix-domain
//!   sockets, single-threaded on the reactor: update frames decode
//!   straight into the PR 3 [`crate::coordinator::VoteAccumulator`]
//!   streaming path (no n-message buffering), with per-round deadlines,
//!   duplicate/straggler rejection, heartbeat liveness, and merged
//!   shard-aggregate frames from the tier below.
//! * [`shard`] — the aggregator-shard tier (DESIGN.md §14): each shard
//!   owns a disjoint client range, folds its slice's updates into a
//!   local accumulator, and streams exactly one merged frame per round
//!   upstream; the root merges shard accumulators word-parallel.
//! * [`client`] — the fleet driver: N agent threads multiplexing M
//!   virtual clients each through the full protocol, plus the loopback
//!   harnesses (flat and sharded) the equivalence tests and benches use.
//!
//! Every node in the tree — the root coordinator and each shard — can
//! additionally expose a Prometheus scrape port (`--metrics-addr`): a
//! minimal HTTP/1.0 `GET /metrics` + `GET /healthz` responder served by
//! the *same* reactor thread as the protocol, fed from a wait-free
//! [`MetricsRegistry`](crate::metrics::registry::MetricsRegistry).
//! Scrapes never block a round (DESIGN.md §17).
//!
//! An end-to-end loopback run — compress, frame, send, decode, vote,
//! broadcast — produces a `RunHistory` **bit-identical** to the
//! in-process engine on the same seed (`tests/net_loopback.rs`), because
//! both drive the same `RoundLoop` tail and the same per-worker RNG
//! streams; the wire merely moves the bytes.

pub mod client;
pub mod events;
pub mod faults;
pub mod protocol;
pub(crate) mod reactor;
pub mod server;
pub mod shard;
pub mod soak;
pub mod wire;

pub use client::{
    run_fleet, run_fleet_range, run_fleet_src, run_loopback, run_loopback_sharded, EndpointFile,
    EndpointFileLine, EndpointSource, FleetOptions, FleetStats,
};
pub use crate::metrics::registry::MetricsRegistry;
pub use events::EventLog;
pub use faults::{FaultInjector, FaultPlan, FaultRole, FaultSchedule};
pub use server::{NetCoordinator, ServeOptions};
pub use shard::{ShardCoordinator, ShardOptions, ShardStats};
pub use soak::{run_soak, SoakOptions, SoakReport};
pub use wire::{Msg, MsgType, RejectReason, WireError};

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::Duration;

/// Transport-layer failure.
#[derive(Debug)]
pub enum NetError {
    /// Frame-level decode failure (see [`WireError`]).
    Wire(WireError),
    /// Socket-level failure.
    Io(std::io::Error),
    /// Peer closed the connection.
    Disconnected,
    /// Protocol violation or run-level failure (message text says what).
    Protocol(String),
    /// Invalid configuration (bad endpoint, unsupported platform, …).
    Config(String),
    /// Snapshot write/load failure (see [`crate::snapshot::SnapshotError`]).
    Snapshot(crate::snapshot::SnapshotError),
    /// Not a failure: the coordinator drained gracefully after
    /// `rounds_done` rounds (finished the open round, snapshotted, and
    /// exited so a successor can `--resume`). Connections are closed
    /// without `Fin`, which is the fleet's cue to reconnect.
    Drained { rounds_done: usize },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Wire(e) => write!(f, "wire: {e}"),
            NetError::Io(e) => write!(f, "io: {e}"),
            NetError::Disconnected => write!(f, "peer disconnected"),
            NetError::Protocol(s) => write!(f, "protocol: {s}"),
            NetError::Config(s) => write!(f, "config: {s}"),
            NetError::Snapshot(e) => write!(f, "snapshot: {e}"),
            NetError::Drained { rounds_done } => {
                write!(f, "coordinator drained after {rounds_done} rounds")
            }
        }
    }
}

impl std::error::Error for NetError {}

impl From<WireError> for NetError {
    fn from(e: WireError) -> Self {
        NetError::Wire(e)
    }
}

impl From<std::io::Error> for NetError {
    fn from(e: std::io::Error) -> Self {
        NetError::Io(e)
    }
}

impl From<crate::snapshot::SnapshotError> for NetError {
    fn from(e: crate::snapshot::SnapshotError) -> Self {
        NetError::Snapshot(e)
    }
}

/// A serve/connect address: TCP (`tcp://host:port` or bare `host:port`)
/// or a Unix-domain socket path (`uds:///path/to.sock`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Tcp(String),
    #[cfg(unix)]
    Uds(std::path::PathBuf),
}

impl Endpoint {
    /// Parse the endpoint grammar above.
    pub fn parse(s: &str) -> Result<Endpoint, NetError> {
        if let Some(rest) = s.strip_prefix("tcp://") {
            return Ok(Endpoint::Tcp(rest.to_string()));
        }
        if let Some(rest) = s.strip_prefix("uds://") {
            #[cfg(unix)]
            return Ok(Endpoint::Uds(std::path::PathBuf::from(rest)));
            #[cfg(not(unix))]
            {
                let _ = rest;
                return Err(NetError::Config("uds:// endpoints need a unix platform".into()));
            }
        }
        if s.contains(':') {
            return Ok(Endpoint::Tcp(s.to_string()));
        }
        Err(NetError::Config(format!("unparseable endpoint '{s}'")))
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "tcp://{a}"),
            #[cfg(unix)]
            Endpoint::Uds(p) => write!(f, "uds://{}", p.display()),
        }
    }
}

/// One accepted / dialed connection (TCP with `NODELAY`, or UDS).
#[derive(Debug)]
pub(crate) enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(UnixStream),
}

impl Stream {
    pub(crate) fn connect(ep: &Endpoint) -> Result<Stream, NetError> {
        match ep {
            Endpoint::Tcp(addr) => {
                let s = TcpStream::connect(addr)?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
            #[cfg(unix)]
            Endpoint::Uds(path) => Ok(Stream::Uds(UnixStream::connect(path)?)),
        }
    }

    pub(crate) fn try_clone(&self) -> Result<Stream, NetError> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Uds(s) => Stream::Uds(s.try_clone()?),
        })
    }

    /// Unblock any reader/writer parked on this socket.
    pub(crate) fn shutdown(&self) {
        let how = std::net::Shutdown::Both;
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(how),
            #[cfg(unix)]
            Stream::Uds(s) => s.shutdown(how),
        };
    }

    pub(crate) fn set_read_timeout(&self, d: Option<Duration>) -> Result<(), NetError> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d)?,
            #[cfg(unix)]
            Stream::Uds(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }

    pub(crate) fn set_nonblocking(&self, nb: bool) -> Result<(), NetError> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb)?,
            #[cfg(unix)]
            Stream::Uds(s) => s.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Raw descriptor for reactor registration (unix only).
    #[cfg(unix)]
    pub(crate) fn raw_fd(&self) -> std::os::fd::RawFd {
        use std::os::fd::AsRawFd;
        match self {
            Stream::Tcp(s) => s.as_raw_fd(),
            Stream::Uds(s) => s.as_raw_fd(),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Uds(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[std::io::IoSlice<'_>]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write_vectored(bufs),
            #[cfg(unix)]
            Stream::Uds(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Uds(s) => s.flush(),
        }
    }
}

/// Bound accept socket.
#[derive(Debug)]
pub(crate) enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(UnixListener),
}

impl Listener {
    pub(crate) fn bind(ep: &Endpoint) -> Result<Listener, NetError> {
        match ep {
            Endpoint::Tcp(addr) => Ok(Listener::Tcp(TcpListener::bind(addr)?)),
            #[cfg(unix)]
            Endpoint::Uds(path) => {
                // A stale socket file from a dead server blocks rebinds.
                let _ = std::fs::remove_file(path);
                Ok(Listener::Uds(UnixListener::bind(path)?))
            }
        }
    }

    /// The resolved local endpoint (a `:0` TCP bind reports its port).
    pub(crate) fn local_endpoint(&self, requested: &Endpoint) -> Endpoint {
        match self {
            Listener::Tcp(l) => match l.local_addr() {
                Ok(a) => Endpoint::Tcp(a.to_string()),
                Err(_) => requested.clone(),
            },
            #[cfg(unix)]
            Listener::Uds(_) => requested.clone(),
        }
    }

    pub(crate) fn set_nonblocking(&self, nb: bool) -> Result<(), NetError> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
            #[cfg(unix)]
            Listener::Uds(l) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// Accept one connection; `Ok(None)` on `WouldBlock`.
    pub(crate) fn accept(&self) -> Result<Option<Stream>, NetError> {
        let res = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                Stream::Tcp(s)
            }),
            #[cfg(unix)]
            Listener::Uds(l) => l.accept().map(|(s, _)| Stream::Uds(s)),
        };
        match res {
            Ok(s) => {
                // The stream must not inherit the listener's
                // non-blocking mode: readers block on whole frames.
                match &s {
                    Stream::Tcp(t) => t.set_nonblocking(false)?,
                    #[cfg(unix)]
                    Stream::Uds(u) => u.set_nonblocking(false)?,
                }
                Ok(Some(s))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(NetError::Io(e)),
        }
    }

    /// Accept one connection for the reactor path: the accepted stream
    /// *stays nonblocking* (unlike [`Listener::accept`], which restores
    /// blocking mode for thread-per-connection readers).
    pub(crate) fn accept_nonblocking(&self) -> Result<Option<Stream>, NetError> {
        let res = match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                Stream::Tcp(s)
            }),
            #[cfg(unix)]
            Listener::Uds(l) => l.accept().map(|(s, _)| Stream::Uds(s)),
        };
        match res {
            Ok(s) => {
                s.set_nonblocking(true)?;
                Ok(Some(s))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(NetError::Io(e)),
        }
    }

    /// Raw descriptor for reactor registration (unix only).
    #[cfg(unix)]
    pub(crate) fn raw_fd(&self) -> std::os::fd::RawFd {
        use std::os::fd::AsRawFd;
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            #[cfg(unix)]
            Listener::Uds(l) => l.as_raw_fd(),
        }
    }
}

/// Read exactly one frame's bytes into `buf` (cleared first), returning
/// its total length. Framing only — the caller validates with
/// [`wire::parse_frame`] (one CRC pass). The declared payload length is
/// capped by `max_payload` *before* any buffer growth, so a hostile
/// peer cannot force an allocation. Public so out-of-crate clients (and
/// the fault-injection tests) can speak the protocol over any `Read`.
pub fn read_frame_bytes(
    r: &mut impl Read,
    max_payload: usize,
    buf: &mut Vec<u8>,
) -> Result<usize, NetError> {
    buf.clear();
    buf.resize(wire::HEADER_FIXED, 0);
    read_exact_or_eof(r, &mut buf[..])?;
    // Length varint, one byte at a time (≤ 10).
    let mut len = 0u64;
    let mut byte = [0u8; 1];
    for i in 0..10 {
        read_exact_or_eof(r, &mut byte)?;
        buf.push(byte[0]);
        let low = (byte[0] & 0x7f) as u64;
        if i == 9 && low > 1 {
            return Err(WireError::Malformed("varint overflows u64").into());
        }
        len |= low << (7 * i);
        if byte[0] & 0x80 == 0 {
            break;
        }
        if i == 9 {
            return Err(WireError::Malformed("varint longer than 10 bytes").into());
        }
    }
    if len > max_payload as u64 {
        return Err(WireError::Oversized { len, max: max_payload }.into());
    }
    let at = buf.len();
    buf.resize(at + len as usize + wire::CRC_LEN, 0);
    read_exact_or_eof(r, &mut buf[at..])?;
    Ok(buf.len())
}

fn read_exact_or_eof(r: &mut impl Read, out: &mut [u8]) -> Result<(), NetError> {
    match r.read_exact(out) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(NetError::Disconnected),
        Err(e) => Err(NetError::Io(e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_grammar() {
        assert_eq!(
            Endpoint::parse("tcp://127.0.0.1:7070").unwrap(),
            Endpoint::Tcp("127.0.0.1:7070".into())
        );
        assert_eq!(Endpoint::parse("127.0.0.1:0").unwrap(), Endpoint::Tcp("127.0.0.1:0".into()));
        #[cfg(unix)]
        assert_eq!(
            Endpoint::parse("uds:///tmp/x.sock").unwrap(),
            Endpoint::Uds(std::path::PathBuf::from("/tmp/x.sock"))
        );
        assert!(Endpoint::parse("garbage").is_err());
        assert_eq!(Endpoint::parse("tcp://h:1").unwrap().to_string(), "tcp://h:1");
    }

    #[test]
    fn frame_reader_round_trips_over_a_pipe() {
        // An in-memory "socket": encode two frames, stream-read them back.
        let hello = Msg::Hello { lo: 0, hi: 5, cfg: 7, env: 0 };
        let mut wbuf = wire::WireBuf::new();
        let mut bytes = Vec::new();
        wbuf.encode(&hello, &mut bytes);
        wbuf.encode(&Msg::Fin { rounds: 9 }, &mut bytes);
        let mut cursor = std::io::Cursor::new(bytes);
        let mut frame = Vec::new();
        let n1 = read_frame_bytes(&mut cursor, wire::MAX_PAYLOAD, &mut frame).unwrap();
        let (f1, used) = wire::parse_frame(&frame[..n1], wire::MAX_PAYLOAD).unwrap();
        assert_eq!(used, n1);
        assert_eq!(wire::decode_msg(f1).unwrap(), hello);
        let n2 = read_frame_bytes(&mut cursor, wire::MAX_PAYLOAD, &mut frame).unwrap();
        let (f2, _) = wire::parse_frame(&frame[..n2], wire::MAX_PAYLOAD).unwrap();
        assert_eq!(wire::decode_msg(f2).unwrap(), Msg::Fin { rounds: 9 });
        // Clean EOF at a frame boundary reads as a disconnect.
        let err = read_frame_bytes(&mut cursor, wire::MAX_PAYLOAD, &mut frame).unwrap_err();
        assert!(matches!(err, NetError::Disconnected));
    }

    #[test]
    fn frame_reader_caps_hostile_lengths() {
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&wire::MAGIC.to_be_bytes());
        hostile.push(wire::WIRE_VERSION);
        hostile.push(7); // Fin
        wire::push_varint(&mut hostile, (wire::MAX_PAYLOAD as u64) + 1);
        hostile.extend_from_slice(&[0; 32]);
        let mut cursor = std::io::Cursor::new(hostile);
        let mut frame = Vec::new();
        let err = read_frame_bytes(&mut cursor, wire::MAX_PAYLOAD, &mut frame).unwrap_err();
        assert!(matches!(err, NetError::Wire(WireError::Oversized { .. })), "{err}");
    }
}
