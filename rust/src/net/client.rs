//! The client fleet driver: N agent threads, each multiplexing a
//! contiguous range of virtual clients (workers) through the full
//! protocol — rendezvous, round-open, compute, compress, frame, submit,
//! repeat until `Fin`.
//!
//! Each virtual worker's round is computed by the **same**
//! `TrainingRun::worker_round` the in-process engines run, from the same
//! seed-derived RNG stream, so the update frames a fleet sends are
//! bit-identical to the messages the pool engine folds locally — the
//! transport moves bytes, it does not perturb the math
//! (`tests/net_loopback.rs` pins this end to end).
//!
//! ## Elastic reconnect (DESIGN.md §12)
//!
//! With [`FleetOptions::reconnect`] set, an agent that loses its
//! connection (coordinator killed, drained, or restarted) re-dials with
//! exponential backoff, re-resolves the endpoint through its
//! [`EndpointSource`] on every attempt, re-claims the same worker range
//! and keeps serving. Because worker rounds are pure in
//! `(seed, round, worker, params)`, re-computing a round the dead
//! coordinator had already opened is harmless — the resumed
//! coordinator's `RunHistory` stays bit-identical to an uninterrupted
//! run (`tests/snapshot_resume.rs`, the `resume-equivalence` CI job).
//!
//! ## Malicious-agent mode (DESIGN.md §13)
//!
//! When the fleet's `TrainingRun` carries an [`AttackPlan`] with
//! protocol-level cohorts, agents enact those behaviours against the
//! real framing: [`Attack::Straggle`] holds a hosted worker's update
//! past the announced round deadline (drawing a straggler mark and a
//! typed `Late`/`BadRound` reject),
//! [`Attack::Equivocate`] follows the honest update with a byte-identical
//! duplicate and a stale-round replay (drawing `Duplicate` and
//! `BadRound`/`Late`). Gradient-level attacks need no transport support:
//! they are applied inside `TrainingRun::worker_round`, exactly as the
//! in-process engines apply them, so attacked wire runs stay
//! bit-identical to attacked engine runs. Honest workers hosted by the
//! same agent are always served *before* the misbehaving ones so an
//! attacker cannot starve its co-hosted honest peers of the round
//! window.
//!
//! [`AttackPlan`]: crate::coordinator::AttackPlan

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::{pool, Attack, GradientSource, RunHistory, TrainingRun, WorkerScratch};

use super::faults::FaultInjector;
use super::server::{NetCoordinator, ServeOptions};
use super::shard::{ShardCoordinator, ShardOptions, ShardStats};
use super::wire::{self, Msg, WireBuf};
use super::{read_frame_bytes, Endpoint, NetError, Stream};

/// Where an agent finds the coordinator. Re-resolved on every dial, so
/// a restarted coordinator can come back on a different address (the
/// `serve --endpoint-file` hand-off).
pub trait EndpointSource: Sync {
    fn endpoint(&self) -> Result<Endpoint, NetError>;
}

impl EndpointSource for Endpoint {
    fn endpoint(&self) -> Result<Endpoint, NetError> {
        Ok(self.clone())
    }
}

/// Endpoint published through a file (one trimmed line, the
/// `Endpoint::parse` grammar). Reads fail with a retriable `Io` error
/// while the coordinator has not written it yet.
#[derive(Clone, Debug)]
pub struct EndpointFile(pub PathBuf);

impl EndpointSource for EndpointFile {
    fn endpoint(&self) -> Result<Endpoint, NetError> {
        let body = std::fs::read_to_string(&self.0)?;
        // Tolerate the multi-line shard layout: line 0 is the root (or
        // only) endpoint either way.
        Endpoint::parse(body.lines().next().unwrap_or("").trim())
    }
}

/// One line of a multi-line endpoint file — `serve --shards N` writes
/// the root endpoint on line 0 and one shard endpoint per following
/// line, so `fleet --via-shards` points each sub-fleet at its shard.
/// Re-read on every dial, like [`EndpointFile`]. A missing or blank
/// line is a *retriable* `Io` error, not a config error: a respawned
/// shard publishes its fresh port by rewriting its line, and during
/// that window the line is legitimately absent — a reconnecting
/// sub-fleet must keep backing off until it reappears, exactly as it
/// does while the whole file has not been written yet.
#[derive(Clone, Debug)]
pub struct EndpointFileLine(pub PathBuf, pub usize);

impl EndpointSource for EndpointFileLine {
    fn endpoint(&self) -> Result<Endpoint, NetError> {
        let body = std::fs::read_to_string(&self.0)?;
        let line = body.lines().nth(self.1).map(str::trim).unwrap_or("");
        if line.is_empty() {
            return Err(NetError::Io(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("endpoint file {} has no line {} yet", self.0.display(), self.1),
            )));
        }
        Endpoint::parse(line)
    }
}

/// Shared mutable endpoint for in-process coordinator hand-offs (the
/// kill+resume integration tests).
impl EndpointSource for Mutex<Endpoint> {
    fn endpoint(&self) -> Result<Endpoint, NetError> {
        Ok(self.lock().unwrap_or_else(|e| e.into_inner()).clone())
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Agent threads; each hosts a contiguous share of the workers.
    pub agents: usize,
    /// Frame payload cap.
    pub max_payload: usize,
    /// Socket read timeout (a dead coordinator should not hang the
    /// fleet forever).
    pub read_timeout: Duration,
    /// Total per-outage window for reconnect-with-backoff; `None`
    /// fails fast on the first connection loss (the loopback-harness
    /// configuration).
    pub reconnect: Option<Duration>,
    /// Deterministic fault injection for soak runs (`None` in
    /// production): per-update send delay plus scheduled partitions
    /// (an agent drops its session at the scheduled round boundary and
    /// recovers through the ordinary reconnect path), scoped to the
    /// client role by [`FaultPlan::injector`].
    ///
    /// [`FaultPlan::injector`]: super::faults::FaultPlan::injector
    pub faults: Option<FaultInjector>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self {
            agents: hw.min(8),
            max_payload: wire::MAX_PAYLOAD,
            read_timeout: Duration::from_secs(60),
            reconnect: None,
            faults: None,
        }
    }
}

impl FleetOptions {
    /// Builder entry point — identical to [`Default`], reads better in
    /// a chain.
    ///
    /// ```
    /// use sparsignd::net::FleetOptions;
    /// use std::time::Duration;
    ///
    /// let opts = FleetOptions::new()
    ///     .with_agents(4)
    ///     .with_reconnect(Some(Duration::from_secs(30)));
    /// assert_eq!(opts.agents, 4);
    /// assert_eq!(opts.reconnect, Some(Duration::from_secs(30)));
    /// ```
    pub fn new() -> Self {
        Self::default()
    }

    /// Agent thread count (clamped to at least 1).
    pub fn with_agents(mut self, agents: usize) -> Self {
        self.agents = agents.max(1);
        self
    }

    /// Frame payload cap.
    pub fn with_max_payload(mut self, cap: usize) -> Self {
        self.max_payload = cap;
        self
    }

    /// Socket read timeout.
    pub fn with_read_timeout(mut self, timeout: Duration) -> Self {
        self.read_timeout = timeout;
        self
    }

    /// Per-outage reconnect-with-backoff window (`None` fails fast).
    pub fn with_reconnect(mut self, window: Option<Duration>) -> Self {
        self.reconnect = window;
        self
    }

    /// Deterministic fault injection (soak runs).
    pub fn with_faults(mut self, faults: Option<FaultInjector>) -> Self {
        self.faults = faults;
        self
    }
}

/// What the fleet observed, summed over agents.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStats {
    /// Update frames sent (one per selected worker per round).
    pub updates_sent: u64,
    /// Typed rejects received.
    pub rejected: u64,
    /// Round-open frames received (per agent, so `rounds × agents` for a
    /// full run).
    pub rounds_seen: u64,
    /// Bytes written (frames, client → server).
    pub bytes_up: u64,
    /// Bytes read (frames, server → client).
    pub bytes_down: u64,
    /// Sessions re-established after a connection loss.
    pub reconnects: u64,
}

impl FleetStats {
    fn absorb(&mut self, o: FleetStats) {
        self.updates_sent += o.updates_sent;
        self.rejected += o.rejected;
        self.rounds_seen += o.rounds_seen;
        self.bytes_up += o.bytes_up;
        self.bytes_down += o.bytes_down;
        self.reconnects += o.reconnects;
    }
}

/// Drive `env.workers()` virtual clients against the coordinator at
/// `ep`, partitioned over `opts.agents` threads. Returns once the
/// coordinator sends `Fin` (or any agent fails).
pub fn run_fleet(
    ep: &Endpoint,
    run: &TrainingRun,
    env: &dyn GradientSource,
    opts: &FleetOptions,
) -> Result<FleetStats, NetError> {
    run_fleet_src(ep, run, env, opts)
}

/// [`run_fleet`] over any [`EndpointSource`] — the elastic entry point.
pub fn run_fleet_src(
    src: &dyn EndpointSource,
    run: &TrainingRun,
    env: &dyn GradientSource,
    opts: &FleetOptions,
) -> Result<FleetStats, NetError> {
    run_fleet_range(src, run, env, 0, env.workers(), opts)
}

/// [`run_fleet_src`] restricted to the global worker slice `[lo, hi)` —
/// the sub-fleet a shard fronts (`fleet --via-shards`). Worker ids stay
/// global: the agents claim and compute exactly the workers the shard's
/// roster spans, from the same seed-derived RNG streams as everywhere
/// else.
pub fn run_fleet_range(
    src: &dyn EndpointSource,
    run: &TrainingRun,
    env: &dyn GradientSource,
    lo: usize,
    hi: usize,
    opts: &FleetOptions,
) -> Result<FleetStats, NetError> {
    let m = env.workers();
    let d = env.dim();
    if lo >= hi || hi > m {
        return Err(NetError::Config(format!(
            "fleet range {lo}..{hi} invalid for population {m}"
        )));
    }
    // The stateful-compressor × sampling refusal applies to remote
    // workers exactly as it does in-process.
    let probe = run.build_worker_comps(d, 1);
    run.reject_stateful_sampling(&probe);
    // Reconnecting re-computes rounds the dead coordinator had already
    // opened; that is only sound for stateless worker compressors
    // (replaying a round would double-advance worker-side state). Same
    // policy — and same check — as the coordinator's snapshot guard.
    if opts.reconnect.is_some() {
        run.require_snapshot_support(&probe).map_err(|e| {
            NetError::Config(format!(
                "reconnect would replay rounds into stateful worker compressors ({e}); \
                 disable reconnect or use a stateless compressor"
            ))
        })?;
    }
    // Serial-only environments (PJRT-backed models) must not be sampled
    // from concurrent agent threads — same clamp as the round engine.
    let span = hi - lo;
    let agents = if env.serial_only() { 1 } else { opts.agents.clamp(1, span) };
    let results: Mutex<Vec<Result<FleetStats, NetError>>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for a in 0..agents {
            let (alo, ahi) = pool::chunk_bounds(span, agents, a);
            if alo >= ahi {
                continue;
            }
            let results = &results;
            s.spawn(move || {
                let out = agent_loop(src, run, env, lo + alo, lo + ahi, opts);
                results.lock().unwrap_or_else(|e| e.into_inner()).push(out);
            });
        }
    });
    let mut stats = FleetStats::default();
    for r in results.into_inner().unwrap_or_else(|e| e.into_inner()) {
        stats.absorb(r?);
    }
    Ok(stats)
}

/// An error that a reconnecting agent may recover from: the socket went
/// away (killed/drained coordinator). Read timeouts are explicitly NOT
/// retriable — a slow-but-healthy round must fail the fleet loudly, not
/// be silently converted into partial participation by a mid-round
/// reconnect (which would break the bit-identity contract). Protocol,
/// wire and config errors mean a bug or a hostile peer and always fail.
pub(crate) fn retriable(e: &NetError) -> bool {
    match e {
        NetError::Disconnected => true,
        NetError::Io(err) => !matches!(
            err.kind(),
            std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
        ),
        _ => false,
    }
}

/// One agent: hosts workers `[lo, hi)`, reconnecting across coordinator
/// restarts when configured.
fn agent_loop(
    src: &dyn EndpointSource,
    run: &TrainingRun,
    env: &dyn GradientSource,
    lo: usize,
    hi: usize,
    opts: &FleetOptions,
) -> Result<FleetStats, NetError> {
    let d = env.dim();
    // Per-hosted-worker compressor bank (index `w - lo`) + the same
    // worker-side scratch and root RNG stream the in-process engines
    // use. All survive a reconnect: the session is transport state, the
    // worker math is not.
    let comps = run.build_worker_comps(d, hi - lo);
    let mut scratch = WorkerScratch::new(d);
    let root = run.root_rng();
    let mut params = vec![0.0f32; d];
    let mut stats = FleetStats::default();
    let mut wbuf = WireBuf::new();
    let mut out = Vec::new();
    let mut buf = Vec::new();
    let mut first_session = true;
    // Per-agent injector clone: `partition_now` keeps fired-round state,
    // which must survive reconnects (a recovered agent does not re-drop
    // the same round).
    let mut faults = opts.faults.clone();

    loop {
        let mut conn = connect_session(src, run, env, lo, hi, opts, &mut stats)?;
        if !first_session {
            stats.reconnects += 1;
        }
        first_session = false;
        let fin = serve_session(
            &mut conn,
            run,
            env,
            lo,
            hi,
            opts,
            &mut faults,
            &comps,
            &mut scratch,
            &root,
            &mut params,
            &mut wbuf,
            &mut out,
            &mut buf,
            &mut stats,
        );
        match fin {
            Ok(()) => return Ok(stats),
            Err(e) if retriable(&e) && opts.reconnect.is_some() => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Dial the coordinator and complete the rendezvous handshake (Hello →
/// Welcome shape echo → one Heartbeat), retrying retriable failures
/// with exponential backoff inside the configured window.
fn connect_session(
    src: &dyn EndpointSource,
    run: &TrainingRun,
    env: &dyn GradientSource,
    lo: usize,
    hi: usize,
    opts: &FleetOptions,
    stats: &mut FleetStats,
) -> Result<Stream, NetError> {
    let deadline = opts.reconnect.map(|w| Instant::now() + w);
    let mut backoff = Duration::from_millis(25);
    loop {
        match try_handshake(src, run, env, lo, hi, opts, stats) {
            Ok(conn) => return Ok(conn),
            Err(e) if retriable(&e) => {
                let Some(dl) = deadline else { return Err(e) };
                if Instant::now() + backoff >= dl {
                    return Err(e);
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
            Err(e) => return Err(e),
        }
    }
}

fn try_handshake(
    src: &dyn EndpointSource,
    run: &TrainingRun,
    env: &dyn GradientSource,
    lo: usize,
    hi: usize,
    opts: &FleetOptions,
    stats: &mut FleetStats,
) -> Result<Stream, NetError> {
    let d = env.dim();
    let m = env.workers();
    let ep = src.endpoint()?;
    let mut conn = Stream::connect(&ep)?;
    conn.set_read_timeout(Some(opts.read_timeout))?;
    let mut wbuf = WireBuf::new();
    let mut out = Vec::new();
    let mut buf = Vec::new();

    // The claim carries proof of what this fleet was built from: the
    // run-config fingerprint (env component zero — the coordinator
    // recomputes the same value from its own TrainingRun) and the data
    // environment's structural hash. A drifted fleet is hung up on at
    // rendezvous instead of silently diverging the run.
    let hello = Msg::Hello {
        lo: lo as u64,
        hi: hi as u64,
        cfg: run.config_fingerprint(d, m, 0),
        env: env.env_fingerprint(),
    };
    stats.bytes_up += wbuf.encode(&hello, &mut out) as u64;
    conn.write_all(&out)?;

    // Rendezvous reply must echo the run shape this fleet was built for.
    let msg = read_msg(&mut conn, opts.max_payload, &mut buf, stats)?;
    match msg {
        Msg::Welcome { workers, dim, rounds, .. } => {
            if workers != m as u64 || dim != d as u64 || rounds != run.rounds as u64 {
                return Err(NetError::Protocol(format!(
                    "welcome shape mismatch: server says {workers}w/{dim}d/{rounds}r, \
                     fleet built for {m}w/{d}d/{}r",
                    run.rounds
                )));
            }
        }
        other => {
            return Err(NetError::Protocol(format!("expected Welcome, got {:?}", other.msg_type())))
        }
    }

    // Exercise the liveness path once per session (server replies Ack).
    let beat = Msg::Heartbeat { client_id: lo as u64 };
    out.clear();
    stats.bytes_up += wbuf.encode(&beat, &mut out) as u64;
    conn.write_all(&out)?;
    Ok(conn)
}

/// Serve rounds over one established session until `Fin` (Ok) or the
/// connection fails (the caller decides whether to reconnect).
#[allow(clippy::too_many_arguments)]
fn serve_session(
    conn: &mut Stream,
    run: &TrainingRun,
    env: &dyn GradientSource,
    lo: usize,
    hi: usize,
    opts: &FleetOptions,
    faults: &mut Option<FaultInjector>,
    comps: &crate::coordinator::WorkerComps,
    scratch: &mut WorkerScratch,
    root: &crate::util::rng::Pcg64,
    params: &mut [f32],
    wbuf: &mut WireBuf,
    out: &mut Vec<u8>,
    buf: &mut Vec<u8>,
    stats: &mut FleetStats,
) -> Result<(), NetError> {
    let d = env.dim();
    let send_delay = faults.as_ref().and_then(FaultInjector::send_delay);
    loop {
        let msg = read_msg(conn, opts.max_payload, buf, stats)?;
        match msg {
            Msg::RoundOpen { t, lr, deadline_ms, selected, params: bcast } => {
                stats.rounds_seen += 1;
                if bcast.len() != d {
                    return Err(NetError::Protocol("broadcast dim mismatch".into()));
                }
                params.copy_from_slice(&bcast);
                let t_us = usize::try_from(t)
                    .map_err(|_| NetError::Protocol("round index overflow".into()))?;
                // Scheduled partition: drop the session at this round
                // boundary and recover through the reconnect path. The
                // skipped cohort is recomputed from the re-broadcast, so
                // the healed run stays bit-identical.
                if let Some(fi) = faults.as_mut() {
                    if fi.partition_now(t_us) {
                        return Err(NetError::Disconnected);
                    }
                }
                // Protocol-level attackers are deferred until every honest
                // hosted worker has submitted: a misbehaving co-tenant must
                // not eat its neighbours' round window.
                let mut deferred: Vec<(u64, Attack)> = Vec::new();
                for &w64 in &selected {
                    let w = w64 as usize;
                    // The coordinator broadcasts the *full* cohort in one
                    // shared frame (flat and sharded tiers alike); each
                    // agent serves its hosted slice and skips the rest.
                    if w < lo || w >= hi {
                        continue;
                    }
                    let protocol_attack = run
                        .attack
                        .as_ref()
                        .and_then(|p| p.attack_of(w))
                        .filter(Attack::is_protocol_level);
                    if let Some(a) = protocol_attack {
                        deferred.push((w64, a));
                        continue;
                    }
                    let (grad, loss) = run.worker_round(
                        env,
                        t_us,
                        w,
                        lr,
                        params,
                        root,
                        comps.get(w - lo),
                        scratch,
                    );
                    out.clear();
                    stats.bytes_up += wbuf.encode_update(t, w64, loss, &grad, out) as u64;
                    if let Some(d) = send_delay {
                        std::thread::sleep(d);
                    }
                    conn.write_all(out)?;
                    stats.updates_sent += 1;
                }
                for (w64, a) in deferred {
                    let w = w64 as usize;
                    let (grad, loss) = run.worker_round(
                        env,
                        t_us,
                        w,
                        lr,
                        params,
                        root,
                        comps.get(w - lo),
                        scratch,
                    );
                    match a {
                        Attack::Equivocate => {
                            // Honest update, then a byte-identical duplicate,
                            // then a replay against a stale round index. The
                            // connection stays up: equivocation is answered
                            // with typed rejects, not a hangup.
                            out.clear();
                            stats.bytes_up += wbuf.encode_update(t, w64, loss, &grad, out) as u64;
                            conn.write_all(out)?;
                            stats.updates_sent += 1;
                            stats.bytes_up += out.len() as u64;
                            conn.write_all(out)?;
                            let stale = if t > 0 { t - 1 } else { t + 1 };
                            out.clear();
                            stats.bytes_up +=
                                wbuf.encode_update(stale, w64, loss, &grad, out) as u64;
                            conn.write_all(out)?;
                        }
                        Attack::Straggle { extra_ms } => {
                            // Adaptive straggler: hold the (honest) update
                            // until the announced deadline has passed, plus a
                            // margin, so it lands as a typed `Late`/`BadRound`
                            // reject after the round has closed.
                            std::thread::sleep(Duration::from_millis(
                                deadline_ms.saturating_add(extra_ms),
                            ));
                            out.clear();
                            stats.bytes_up += wbuf.encode_update(t, w64, loss, &grad, out) as u64;
                            conn.write_all(out)?;
                            stats.updates_sent += 1;
                        }
                        _ => unreachable!("deferred set holds protocol-level attacks only"),
                    }
                }
            }
            Msg::Ack { .. } => {}
            Msg::Reject { .. } => stats.rejected += 1,
            Msg::Fin { .. } => return Ok(()),
            other => {
                return Err(NetError::Protocol(format!(
                    "unexpected {:?} from coordinator",
                    other.msg_type()
                )))
            }
        }
    }
}

/// Read + fully decode the next frame (agents are control-plane readers;
/// the zero-copy update path is server-side).
fn read_msg(
    conn: &mut Stream,
    max_payload: usize,
    buf: &mut Vec<u8>,
    stats: &mut FleetStats,
) -> Result<Msg, NetError> {
    let len = read_frame_bytes(conn, max_payload, buf)?;
    stats.bytes_down += len as u64;
    let (frame, _) = wire::parse_frame(&buf[..len], max_payload)?;
    Ok(wire::decode_msg(frame)?)
}

/// Bind a coordinator on a loopback endpoint, serve `run` from one
/// thread and drive the full fleet from this one — the end-to-end
/// federated path (compress → frame → send → decode → vote → broadcast)
/// in a single process. Returns the server's `RunHistory` plus the
/// fleet's transport stats.
///
/// `eval` needs `Sync` because the serving thread borrows it across the
/// spawn; `TrainingRun::run`'s plain `&dyn Fn` contract is unchanged.
pub fn run_loopback(
    run: &TrainingRun,
    env: &dyn GradientSource,
    init: Vec<f32>,
    eval: &(dyn Fn(&[f32]) -> (f64, f64) + Sync),
    serve_opts: ServeOptions,
    fleet_opts: &FleetOptions,
) -> Result<(RunHistory, FleetStats), NetError> {
    let coordinator = NetCoordinator::bind(serve_opts)?;
    let ep = coordinator.local_endpoint().clone();
    let m = env.workers();
    let mut server_out: Option<Result<RunHistory, NetError>> = None;
    let fleet_out = std::thread::scope(|s| {
        let handle = s.spawn(|| coordinator.serve(run, m, init, eval));
        let fleet = run_fleet(&ep, run, env, fleet_opts);
        server_out = Some(match handle.join() {
            Ok(r) => r,
            Err(_) => Err(NetError::Protocol("coordinator thread panicked".into())),
        });
        fleet
    });
    let hist = server_out.expect("server result recorded")?;
    Ok((hist, fleet_out?))
}

/// [`run_loopback`] through an aggregation tree (DESIGN.md §14): bind
/// the root coordinator plus `shards` aggregator shards partitioning
/// `0..m` by [`pool::chunk_bounds`], then drive one ranged sub-fleet
/// per shard — all in this process, over real sockets. Returns the
/// root's `RunHistory` (bit-identical to the flat and in-process runs
/// on the same seed — `tests/shard_tree.rs`), the summed fleet stats,
/// and each shard's per-tier traffic stats in shard order.
///
/// When the root runs a `round_deadline`, each shard gets 3/4 of it so
/// its merged frame lands before the root closes the round; stragglers
/// therefore draw their `Late` rejects at the shard tier.
#[allow(clippy::type_complexity)]
pub fn run_loopback_sharded(
    run: &TrainingRun,
    env: &dyn GradientSource,
    init: Vec<f32>,
    eval: &(dyn Fn(&[f32]) -> (f64, f64) + Sync),
    serve_opts: ServeOptions,
    fleet_opts: &FleetOptions,
    shards: usize,
    uds: bool,
) -> Result<(RunHistory, FleetStats, Vec<ShardStats>), NetError> {
    let m = env.workers();
    let d = env.dim();
    let shards = shards.clamp(1, m);
    let shard_deadline = serve_opts.round_deadline.map(|dl| dl * 3 / 4);
    let max_payload = serve_opts.max_payload;
    let env_tag = serve_opts.env_fingerprint;

    let coordinator = NetCoordinator::bind(serve_opts)?;
    let root_ep = coordinator.local_endpoint().clone();
    // Bind every shard before any thread runs so the downstream
    // endpoints are known up front.
    let mut bound: Vec<(usize, usize, ShardCoordinator)> = Vec::with_capacity(shards);
    for i in 0..shards {
        let (lo, hi) = pool::chunk_bounds(m, shards, i);
        let mut so = ShardOptions::new(root_ep.clone(), loopback_endpoint(uds), lo, hi);
        so.round_deadline = shard_deadline;
        so.max_payload = max_payload;
        so.env_fingerprint = env_tag;
        bound.push((lo, hi, ShardCoordinator::bind(so)?));
    }

    let mut server_out: Option<Result<RunHistory, NetError>> = None;
    let shard_out: Mutex<Vec<(usize, Result<ShardStats, NetError>)>> = Mutex::new(Vec::new());
    let fleet_out: Mutex<Vec<Result<FleetStats, NetError>>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        let root = s.spawn(|| coordinator.serve(run, m, init, eval));
        for (i, (lo, hi, shard)) in bound.into_iter().enumerate() {
            let shard_ep = shard.local_endpoint().clone();
            let shard_out = &shard_out;
            let fleet_out = &fleet_out;
            s.spawn(move || {
                let out = shard.run(run, m, d);
                shard_out.lock().unwrap_or_else(|e| e.into_inner()).push((i, out));
            });
            s.spawn(move || {
                let out = run_fleet_range(&shard_ep, run, env, lo, hi, fleet_opts);
                fleet_out.lock().unwrap_or_else(|e| e.into_inner()).push(out);
            });
        }
        server_out = Some(match root.join() {
            Ok(r) => r,
            Err(_) => Err(NetError::Protocol("root coordinator thread panicked".into())),
        });
    });
    let hist = server_out.expect("server result recorded")?;
    let mut stats = FleetStats::default();
    for r in fleet_out.into_inner().unwrap_or_else(|e| e.into_inner()) {
        stats.absorb(r?);
    }
    let mut tagged = shard_out.into_inner().unwrap_or_else(|e| e.into_inner());
    tagged.sort_by_key(|(i, _)| *i);
    let mut shard_stats = Vec::new();
    for (_, r) in tagged {
        shard_stats.push(r?);
    }
    Ok((hist, stats, shard_stats))
}

/// A fresh loopback endpoint for tests/benches: UDS under the temp dir
/// on unix (tagged by pid + a counter), TCP on an ephemeral port
/// elsewhere.
pub fn loopback_endpoint(uds: bool) -> Endpoint {
    #[cfg(unix)]
    if uds {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        return Endpoint::Uds(std::env::temp_dir().join(format!("sparsignd-{pid}-{n}.sock")));
    }
    let _ = uds;
    Endpoint::Tcp("127.0.0.1:0".into())
}
