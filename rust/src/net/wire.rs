//! The versioned wire codec (DESIGN.md §11): length-prefixed binary
//! frames carrying the federation protocol's messages.
//!
//! ## Frame grammar
//!
//! ```text
//! frame   := magic:u32be  version:u8  msg_type:u8  len:varint
//!            payload[len]  crc:u32le
//! varint  := LEB128, ≤ 10 bytes, minimal range checks (u64)
//! crc     := CRC-32 (IEEE 802.3, poly 0xEDB88320) over every frame
//!            byte before the checksum itself (magic included)
//! ```
//!
//! The fixed header is written/read through [`crate::coding::bitio`]
//! (MSB-first, so the magic lands big-endian on the wire); payload
//! scalars are little-endian. Ternary gradients travel as their raw
//! `u64` bitplanes plus `(dim, nnz, scale, bits)` scalars, so a message
//! round-trips **bit-identically** — the cached `nnz` is revalidated by
//! popcount on decode rather than trusted.
//!
//! ## Hardening
//!
//! Decoding never panics and never allocates from an attacker-declared
//! length: the frame length is capped by [`MAX_PAYLOAD`] *before* any
//! allocation, every interior count (`dim`, selection size, plane
//! bytes) is checked against the bytes actually present, and every
//! failure is a typed [`WireError`] (`tests/property_suite.rs` fuzzes
//! truncations and byte mutations against this contract).
//!
//! ## Version policy
//!
//! `version` is a single byte, bumped on any incompatible layout change;
//! decoders reject mismatches with [`WireError::BadVersion`] (no
//! negotiation — the coordinator and fleet ship together). New message
//! types are additive: unknown `msg_type` values are a typed error, so
//! an old peer fails loudly rather than misparsing.

use crate::coding::bitio::{BitReader, BitWriter};
use crate::compressors::{CompressedGrad, PackedTernary};
use crate::coordinator::REJECT_KINDS;

/// Frame magic: `"SGND"` read MSB-first.
pub const MAGIC: u32 = 0x5347_4E44;
/// Current wire-format version. v2: `Hello` carries the run-config and
/// environment fingerprints (DESIGN.md §12), so a coordinator refuses a
/// fleet built from drifted flags at rendezvous instead of silently
/// diverging. v3: `Welcome` carries the selection-commitment words
/// (DESIGN.md §13; all zeros in legacy selection mode).
pub const WIRE_VERSION: u8 = 3;
/// Hard payload cap: decoders refuse to allocate past this, bounding
/// memory even against a hostile length prefix.
pub const MAX_PAYLOAD: usize = 1 << 28;
/// Fixed header bytes before the length varint (magic + version + type).
pub const HEADER_FIXED: usize = 6;
/// Trailing checksum bytes.
pub const CRC_LEN: usize = 4;

/// Typed decode failure. Never panics, never over-allocates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than the frame (or field) requires.
    Truncated { need: usize, have: usize },
    /// First four bytes are not [`MAGIC`].
    BadMagic { got: u32 },
    /// Version byte differs from [`WIRE_VERSION`].
    BadVersion { got: u8 },
    /// Unknown message-type byte.
    BadMsgType { got: u8 },
    /// Checksum mismatch (corrupt frame).
    BadCrc { want: u32, got: u32 },
    /// Declared payload length exceeds the decoder's cap.
    Oversized { len: u64, max: usize },
    /// Structurally invalid payload (bad varint, count/byte mismatch,
    /// violated ternary invariant, trailing garbage, …).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::BadMagic { got } => write!(f, "bad frame magic {got:#010x}"),
            WireError::BadVersion { got } => {
                write!(f, "wire version {got} (this build speaks {WIRE_VERSION})")
            }
            WireError::BadMsgType { got } => write!(f, "unknown message type {got}"),
            WireError::BadCrc { want, got } => {
                write!(f, "crc mismatch: frame says {want:#010x}, computed {got:#010x}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "payload length {len} exceeds cap {max}")
            }
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected).
// ---------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 over `data` (IEEE polynomial, init/xorout `!0`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// Varints + little-endian scalar helpers.
// ---------------------------------------------------------------------

/// Append an LEB128 varint.
pub fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Cursor over a payload slice; every `take_*` bounds-checks first.
/// Crate-visible: the coordinator snapshot codec (`crate::snapshot`)
/// decodes its body with the same hardened primitives.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Bytes consumed so far (the snapshot codec locates its body with
    /// this, exactly as [`parse_frame`] does in-module).
    pub(crate) fn pos(&self) -> usize {
        self.pos
    }

    pub(crate) fn done(&self) -> Result<(), WireError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes after payload"))
        }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { need: self.pos + n, have: self.buf.len() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn varint(&mut self) -> Result<u64, WireError> {
        let mut v = 0u64;
        for i in 0..10 {
            let b = self.u8()?;
            let low = (b & 0x7f) as u64;
            if i == 9 && low > 1 {
                return Err(WireError::Malformed("varint overflows u64"));
            }
            v |= low << (7 * i);
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(WireError::Malformed("varint longer than 10 bytes"))
    }

    /// Varint bounded to `usize` and to a caller cap (count fields).
    pub(crate) fn count(&mut self, cap: usize, what: &'static str) -> Result<usize, WireError> {
        let v = self.varint()?;
        if v > cap as u64 {
            return Err(WireError::Malformed(what));
        }
        Ok(v as usize)
    }

    pub(crate) fn f32(&mut self) -> Result<f32, WireError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, WireError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn u64le(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

// ---------------------------------------------------------------------
// Message vocabulary.
// ---------------------------------------------------------------------

/// Frame type byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgType {
    /// Client → server rendezvous: "I host workers `[lo, hi)`".
    Hello = 1,
    /// Server → client rendezvous accept (run shape echo).
    Welcome = 2,
    /// Server → client round start: lr, deadline, per-connection
    /// selection, model broadcast.
    RoundOpen = 3,
    /// Client → server update submission (one per selected worker).
    Update = 4,
    /// Server → client positive acknowledgement (heartbeat reply).
    Ack = 5,
    /// Server → client typed refusal of a submission.
    Reject = 6,
    /// Server → client end of run.
    Fin = 7,
    /// Client → server liveness signal.
    Heartbeat = 8,
    /// Shard → root rendezvous: "I aggregate workers `[lo, hi)`"
    /// (DESIGN.md §14). Same fields as `Hello`; the distinct type tags
    /// the connection as an aggregator tier, not a client.
    ShardHello = 9,
    /// Shard → root per-round merged submission: the shard's filled
    /// record metadata plus its raw `VoteAccumulator` counter planes,
    /// merged word-parallel at the root. Additive message (the frame
    /// grammar and every v3 message are unchanged), so no version bump.
    ShardAgg = 10,
}

impl MsgType {
    fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            1 => MsgType::Hello,
            2 => MsgType::Welcome,
            3 => MsgType::RoundOpen,
            4 => MsgType::Update,
            5 => MsgType::Ack,
            6 => MsgType::Reject,
            7 => MsgType::Fin,
            8 => MsgType::Heartbeat,
            9 => MsgType::ShardHello,
            10 => MsgType::ShardAgg,
            _ => return None,
        })
    }
}

/// Why the coordinator refused a submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum RejectReason {
    /// Round index is not the currently open round.
    BadRound = 1,
    /// Worker was not selected this round.
    NotSelected = 2,
    /// A submission for this worker already landed (idempotent reject).
    Duplicate = 3,
    /// The round closed (deadline or completion) before this frame.
    Late = 4,
    /// Worker id outside the announced population.
    UnknownWorker = 5,
    /// Submission from a connection that does not own the worker.
    WrongClient = 6,
}

impl RejectReason {
    /// Stable counter index (discriminant − 1): the order the ledger's
    /// [`crate::coordinator::REJECT_KINDS`] array and `history_json`'s
    /// `rejects_by_kind` use.
    pub fn index(self) -> usize {
        self as usize - 1
    }

    fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            1 => RejectReason::BadRound,
            2 => RejectReason::NotSelected,
            3 => RejectReason::Duplicate,
            4 => RejectReason::Late,
            5 => RejectReason::UnknownWorker,
            6 => RejectReason::WrongClient,
            _ => return None,
        })
    }
}

/// Owned, fully-validated protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Rendezvous claim for workers `[lo, hi)`. `cfg` is the claimant's
    /// run-config fingerprint (`TrainingRun::config_fingerprint` with a
    /// zero env component — both sides can compute it from their own
    /// `TrainingRun`) and `env` its data-environment fingerprint
    /// (`GradientSource::env_fingerprint`); the coordinator hangs up on
    /// a mismatched fleet at rendezvous.
    Hello { lo: u64, hi: u64, cfg: u64, env: u64 },
    Welcome { client_id: u64, workers: u64, dim: u64, rounds: u64, commit: [u64; 4] },
    RoundOpen { t: u64, lr: f64, deadline_ms: u64, selected: Vec<u64>, params: Vec<f32> },
    Update { t: u64, worker: u64, loss: f64, grad: CompressedGrad },
    Ack { t: u64, worker: u64 },
    Reject { t: u64, worker: u64, reason: RejectReason },
    Fin { rounds: u64 },
    Heartbeat { client_id: u64 },
    /// Aggregator-shard rendezvous claim (same shape as `Hello`).
    ShardHello { lo: u64, hi: u64, cfg: u64, env: u64 },
}

impl Msg {
    /// This message's frame type byte.
    pub fn msg_type(&self) -> MsgType {
        match self {
            Msg::Hello { .. } => MsgType::Hello,
            Msg::Welcome { .. } => MsgType::Welcome,
            Msg::RoundOpen { .. } => MsgType::RoundOpen,
            Msg::Update { .. } => MsgType::Update,
            Msg::Ack { .. } => MsgType::Ack,
            Msg::Reject { .. } => MsgType::Reject,
            Msg::Fin { .. } => MsgType::Fin,
            Msg::Heartbeat { .. } => MsgType::Heartbeat,
            Msg::ShardHello { .. } => MsgType::ShardHello,
        }
    }
}

// ---------------------------------------------------------------------
// Zero-copy decode views.
// ---------------------------------------------------------------------

/// Borrowed view of a parsed frame: type byte + payload slice (the
/// payload still points into the caller's buffer).
#[derive(Clone, Copy, Debug)]
pub struct Frame<'a> {
    pub msg_type: MsgType,
    pub payload: &'a [u8],
}

/// Borrowed view of an update's gradient payload — the coordinator's
/// hot path decodes ternary bitplanes straight out of the frame buffer
/// into a reusable [`PackedTernary`] (no per-message allocation) and
/// folds it into the vote accumulator.
#[derive(Clone, Copy, Debug)]
pub enum GradView<'a> {
    Ternary { dim: usize, nnz: usize, scale: f32, bits: f64, mask: &'a [u8], sign: &'a [u8] },
    Dense { dim: usize, bits: f64, values: &'a [u8] },
}

impl GradView<'_> {
    /// Gradient dimension.
    pub fn dim(&self) -> usize {
        match self {
            GradView::Ternary { dim, .. } | GradView::Dense { dim, .. } => *dim,
        }
    }

    /// Declared message bit cost.
    pub fn bits(&self) -> f64 {
        match self {
            GradView::Ternary { bits, .. } | GradView::Dense { bits, .. } => *bits,
        }
    }

    /// Decode a ternary payload into a caller-owned pack (revalidating
    /// every invariant); returns `None` for dense payloads.
    pub fn unpack_ternary_into(&self, pack: &mut PackedTernary) -> Result<Option<()>, WireError> {
        let GradView::Ternary { dim, nnz, scale, mask, sign, .. } = *self else {
            return Ok(None);
        };
        let words = mask
            .chunks_exact(8)
            .zip(sign.chunks_exact(8))
            .map(|(m, s)| (le_word(m), le_word(s)));
        pack.load_words(dim, scale, words).map_err(WireError::Malformed)?;
        if pack.nnz() != nnz {
            return Err(WireError::Malformed("declared nnz disagrees with bitplanes"));
        }
        Ok(Some(()))
    }

    /// Materialize an owned [`CompressedGrad`] (bit-identical to the
    /// encoded message; dense non-zero counts are recounted).
    pub fn to_msg(&self) -> Result<CompressedGrad, WireError> {
        match *self {
            GradView::Ternary { bits, .. } => {
                let mut pack = PackedTernary::zeros(0, 1.0);
                self.unpack_ternary_into(&mut pack)?;
                Ok(CompressedGrad::ternary(pack, bits))
            }
            GradView::Dense { bits, values, .. } => {
                let v: Vec<f32> = values
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(CompressedGrad::dense(v, bits))
            }
        }
    }
}

#[inline]
fn le_word(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Borrowed view of an [`MsgType::Update`] payload.
#[derive(Clone, Copy, Debug)]
pub struct UpdateView<'a> {
    pub t: u64,
    pub worker: u64,
    pub loss: f64,
    pub grad: GradView<'a>,
}

/// One accepted submission's metadata inside a [`MsgType::ShardAgg`]
/// frame — everything the root needs to fill its per-slot arrays; the
/// vote content itself travels merged in the counter planes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardRec {
    pub worker: u64,
    pub loss: f64,
    pub bits: f64,
    pub nnz: u64,
    pub scale: f32,
}

/// Borrowed view of a [`MsgType::ShardAgg`] payload. `pos`/`neg` are
/// the little-endian bytes of the shard accumulator's carry-save
/// counter planes (`words(dim) * planes` words each, per-word
/// plane-major — the `VoteAccumulator` memory layout verbatim).
#[derive(Clone, Debug)]
pub struct ShardAggView<'a> {
    pub t: u64,
    pub lo: u64,
    pub hi: u64,
    pub recs: Vec<ShardRec>,
    /// Client-tier wire bytes the shard accepted this round.
    pub up_bytes: u64,
    /// Client-tier wire bytes the shard broadcast this round.
    pub down_bytes: u64,
    /// Shard-local typed rejects issued this round, by
    /// [`RejectReason::index`].
    pub rejects: [u64; REJECT_KINDS],
    pub msgs: u64,
    pub dim: usize,
    pub planes: usize,
    pub pos: &'a [u8],
    pub neg: &'a [u8],
}

/// Decode a shard merged-round submission as a borrowed view.
/// `frame.msg_type` must be [`MsgType::ShardAgg`]. Payload grammar:
///
/// ```text
/// shard_agg := t:varint lo:varint hi:varint
///              k:varint  k × (worker:varint loss:f64le bits:f64le
///                             nnz:varint scale:f32le)
///              up_bytes:varint down_bytes:varint
///              rejects:varint × REJECT_KINDS
///              msgs:varint (= k)  dim:varint  planes:varint
///              pos[words(dim)·planes]:u64le  neg[same]:u64le
/// ```
///
/// Counts are bounded by the bytes present before anything allocates,
/// exactly like the update path.
pub fn decode_shard_agg(payload: &[u8]) -> Result<ShardAggView<'_>, WireError> {
    let mut cur = Cursor::new(payload);
    let t = cur.varint()?;
    let lo = cur.varint()?;
    let hi = cur.varint()?;
    // Each record is ≥ 22 bytes (two f64, one f32, two ≥1-byte varints).
    let k = cur.count(cur.remaining() / 22 + 1, "shard record count exceeds payload")?;
    let mut recs = Vec::with_capacity(k);
    for _ in 0..k {
        let worker = cur.varint()?;
        let loss = cur.f64()?;
        let bits = cur.f64()?;
        let nnz = cur.varint()?;
        let scale = cur.f32()?;
        recs.push(ShardRec { worker, loss, bits, nnz, scale });
    }
    let up_bytes = cur.varint()?;
    let down_bytes = cur.varint()?;
    let mut rejects = [0u64; REJECT_KINDS];
    for r in rejects.iter_mut() {
        *r = cur.varint()?;
    }
    let msgs = cur.varint()?;
    if msgs != k as u64 {
        return Err(WireError::Malformed("shard msgs disagrees with record count"));
    }
    let dim = cur.count(4 * MAX_PAYLOAD, "shard dim out of range")?;
    // ≤ 15 planes cover the protocol's 32767-message streaming cap.
    let planes = cur.count(16, "shard planes out of range")?;
    if (planes == 0) != (k == 0) {
        return Err(WireError::Malformed("shard planes/record count mismatch"));
    }
    let plane_bytes = PackedTernary::words(dim) * 8 * planes;
    let pos = cur.take(plane_bytes)?;
    let neg = cur.take(plane_bytes)?;
    cur.done()?;
    Ok(ShardAggView { t, lo, hi, recs, up_bytes, down_bytes, rejects, msgs, dim, planes, pos, neg })
}

// ---------------------------------------------------------------------
// Encoding.
// ---------------------------------------------------------------------

const GRAD_TERNARY: u8 = 0;
const GRAD_DENSE: u8 = 1;

/// Reusable frame encoder: owns the payload scratch so steady-state
/// encoding reuses one buffer per connection.
#[derive(Default)]
pub struct WireBuf {
    payload: Vec<u8>,
}

impl WireBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Encode `msg` as one complete frame appended to `out`; returns the
    /// frame's byte length.
    pub fn encode(&mut self, msg: &Msg, out: &mut Vec<u8>) -> usize {
        self.payload.clear();
        let p = &mut self.payload;
        match msg {
            Msg::Hello { lo, hi, cfg, env } => {
                push_varint(p, *lo);
                push_varint(p, *hi);
                // Fingerprints are full-entropy u64s: fixed-width beats
                // a (typically 10-byte) varint.
                p.extend_from_slice(&cfg.to_le_bytes());
                p.extend_from_slice(&env.to_le_bytes());
            }
            Msg::Welcome { client_id, workers, dim, rounds, commit } => {
                push_varint(p, *client_id);
                push_varint(p, *workers);
                push_varint(p, *dim);
                push_varint(p, *rounds);
                // Commitment words are full-entropy (or all-zero):
                // fixed-width, like the Hello fingerprints.
                for w in commit {
                    p.extend_from_slice(&w.to_le_bytes());
                }
            }
            Msg::RoundOpen { t, lr, deadline_ms, selected, params } => {
                push_varint(p, *t);
                p.extend_from_slice(&lr.to_le_bytes());
                push_varint(p, *deadline_ms);
                push_varint(p, selected.len() as u64);
                for &w in selected {
                    push_varint(p, w);
                }
                push_varint(p, params.len() as u64);
                for &x in params {
                    p.extend_from_slice(&x.to_le_bytes());
                }
            }
            Msg::Update { t, worker, loss, grad } => {
                push_varint(p, *t);
                push_varint(p, *worker);
                p.extend_from_slice(&loss.to_le_bytes());
                encode_grad(p, grad);
            }
            Msg::Ack { t, worker } => {
                push_varint(p, *t);
                push_varint(p, *worker);
            }
            Msg::Reject { t, worker, reason } => {
                push_varint(p, *t);
                push_varint(p, *worker);
                p.push(*reason as u8);
            }
            Msg::Fin { rounds } => {
                push_varint(p, *rounds);
            }
            Msg::Heartbeat { client_id } => {
                push_varint(p, *client_id);
            }
            Msg::ShardHello { lo, hi, cfg, env } => {
                push_varint(p, *lo);
                push_varint(p, *hi);
                p.extend_from_slice(&cfg.to_le_bytes());
                p.extend_from_slice(&env.to_le_bytes());
            }
        }
        frame(msg.msg_type(), &self.payload, out)
    }

    /// Encode one shard→root merged-round submission (see
    /// [`decode_shard_agg`] for the payload grammar); returns the
    /// frame's byte length. `pos`/`neg` are the shard accumulator's raw
    /// carry-save counter planes (`words(dim) * planes` words each).
    pub fn encode_shard_agg(
        &mut self,
        t: u64,
        lo: u64,
        hi: u64,
        recs: &[ShardRec],
        up_bytes: u64,
        down_bytes: u64,
        rejects: &[u64; REJECT_KINDS],
        dim: usize,
        planes: usize,
        pos: &[u64],
        neg: &[u64],
        out: &mut Vec<u8>,
    ) -> usize {
        debug_assert_eq!(pos.len(), PackedTernary::words(dim) * planes);
        debug_assert_eq!(neg.len(), pos.len());
        self.payload.clear();
        let p = &mut self.payload;
        push_varint(p, t);
        push_varint(p, lo);
        push_varint(p, hi);
        push_varint(p, recs.len() as u64);
        for r in recs {
            push_varint(p, r.worker);
            p.extend_from_slice(&r.loss.to_le_bytes());
            p.extend_from_slice(&r.bits.to_le_bytes());
            push_varint(p, r.nnz);
            p.extend_from_slice(&r.scale.to_le_bytes());
        }
        push_varint(p, up_bytes);
        push_varint(p, down_bytes);
        for &r in rejects {
            push_varint(p, r);
        }
        push_varint(p, recs.len() as u64); // msgs folded into the planes
        push_varint(p, dim as u64);
        push_varint(p, planes as u64);
        for &w in pos {
            p.extend_from_slice(&w.to_le_bytes());
        }
        for &w in neg {
            p.extend_from_slice(&w.to_le_bytes());
        }
        frame(MsgType::ShardAgg, &self.payload, out)
    }

    /// Borrow-friendly round-open encoder (the coordinator's per-round
    /// broadcast: no params clone per connection); returns the frame's
    /// byte length.
    pub fn encode_round_open(
        &mut self,
        t: u64,
        lr: f64,
        deadline_ms: u64,
        selected: &[u64],
        params: &[f32],
        out: &mut Vec<u8>,
    ) -> usize {
        self.payload.clear();
        let p = &mut self.payload;
        push_varint(p, t);
        p.extend_from_slice(&lr.to_le_bytes());
        push_varint(p, deadline_ms);
        push_varint(p, selected.len() as u64);
        for &w in selected {
            push_varint(p, w);
        }
        push_varint(p, params.len() as u64);
        for &x in params {
            p.extend_from_slice(&x.to_le_bytes());
        }
        frame(MsgType::RoundOpen, &self.payload, out)
    }

    /// Borrow-friendly update encoder (the client fleet's hot path: no
    /// intermediate [`Msg`]); returns the frame's byte length.
    pub fn encode_update(
        &mut self,
        t: u64,
        worker: u64,
        loss: f64,
        grad: &CompressedGrad,
        out: &mut Vec<u8>,
    ) -> usize {
        self.payload.clear();
        let p = &mut self.payload;
        push_varint(p, t);
        push_varint(p, worker);
        p.extend_from_slice(&loss.to_le_bytes());
        encode_grad(p, grad);
        frame(MsgType::Update, &self.payload, out)
    }
}

fn encode_grad(p: &mut Vec<u8>, grad: &CompressedGrad) {
    match grad {
        CompressedGrad::Ternary { pack, bits } => {
            p.push(GRAD_TERNARY);
            push_varint(p, pack.dim() as u64);
            push_varint(p, pack.nnz() as u64);
            p.extend_from_slice(&pack.scale().to_le_bytes());
            p.extend_from_slice(&bits.to_le_bytes());
            for &w in pack.mask_words() {
                p.extend_from_slice(&w.to_le_bytes());
            }
            for &w in pack.sign_words() {
                p.extend_from_slice(&w.to_le_bytes());
            }
        }
        CompressedGrad::Dense { v, bits, .. } => {
            p.push(GRAD_DENSE);
            push_varint(p, v.len() as u64);
            p.extend_from_slice(&bits.to_le_bytes());
            for &x in v {
                p.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Assemble one frame around a finished payload. The fixed header goes
/// through [`BitWriter`] (MSB-first), matching the [`BitReader`] parse
/// on the way in. Panics on payloads beyond [`MAX_PAYLOAD`]: every
/// decoder in the protocol rejects such frames, so failing loudly at
/// the encoder (with the actionable size) beats a fleet-wide
/// `Oversized` reject storm at d > 2²⁶-parameter scale.
fn frame(ty: MsgType, payload: &[u8], out: &mut Vec<u8>) -> usize {
    assert!(
        payload.len() <= MAX_PAYLOAD,
        "frame payload {} B exceeds MAX_PAYLOAD {} B (shard the broadcast or raise the cap)",
        payload.len(),
        MAX_PAYLOAD
    );
    let start = out.len();
    let mut hdr = BitWriter::new();
    hdr.push_bits(MAGIC as u64, 32);
    hdr.push_bits(WIRE_VERSION as u64, 8);
    hdr.push_bits(ty as u64, 8);
    out.extend_from_slice(hdr.as_bytes());
    push_varint(out, payload.len() as u64);
    out.extend_from_slice(payload);
    let crc = crc32(&out[start..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out.len() - start
}

// ---------------------------------------------------------------------
// Decoding.
// ---------------------------------------------------------------------

/// Incremental frame delimiter for nonblocking reads: how many bytes
/// does the frame at the front of `buf` span? `Ok(None)` means the
/// buffer holds a valid-so-far prefix — wait for more bytes. Errors are
/// fatal stream corruption (bad magic/version, hostile length): the
/// connection cannot be re-synchronized. Validates framing only; the
/// caller runs [`parse_frame`] on the complete bytes for the CRC and
/// type checks (one CRC pass total, same as the blocking reader).
pub fn frame_len(buf: &[u8], max_payload: usize) -> Result<Option<usize>, WireError> {
    // magic(4) + version(1): fatal checks, byte-at-a-time so a partial
    // prefix is judged as far as it goes.
    for (i, &b) in MAGIC.to_be_bytes().iter().enumerate() {
        match buf.get(i) {
            None => return Ok(None),
            Some(&got) if got != b => {
                let mut four = [0u8; 4];
                for (j, slot) in four.iter_mut().enumerate() {
                    *slot = buf.get(j).copied().unwrap_or(0);
                }
                return Err(WireError::BadMagic { got: u32::from_be_bytes(four) });
            }
            Some(_) => {}
        }
    }
    match buf.get(4) {
        None => return Ok(None),
        Some(&v) if v != WIRE_VERSION => return Err(WireError::BadVersion { got: v }),
        Some(_) => {}
    }
    // Type byte is validated by parse_frame (unknown types are a typed
    // error there, and the frame is still well-delimited here).
    if buf.len() < HEADER_FIXED {
        return Ok(None);
    }
    // Length varint, mirroring the Cursor rules.
    let mut len = 0u64;
    let mut vlen = 0usize;
    for i in 0..10 {
        let Some(&b) = buf.get(HEADER_FIXED + i) else { return Ok(None) };
        let low = (b & 0x7f) as u64;
        if i == 9 && low > 1 {
            return Err(WireError::Malformed("varint overflows u64"));
        }
        len |= low << (7 * i);
        if b & 0x80 == 0 {
            vlen = i + 1;
            break;
        }
        if i == 9 {
            return Err(WireError::Malformed("varint longer than 10 bytes"));
        }
    }
    if vlen == 0 {
        return Ok(None);
    }
    if len > max_payload as u64 {
        return Err(WireError::Oversized { len, max: max_payload });
    }
    let total = HEADER_FIXED + vlen + len as usize + CRC_LEN;
    Ok(if buf.len() < total { None } else { Some(total) })
}

/// Parse and checksum one frame from the front of `buf`; returns the
/// borrowed frame and the total bytes consumed. `max_payload` caps the
/// declared length before anything else happens.
pub fn parse_frame(buf: &[u8], max_payload: usize) -> Result<(Frame<'_>, usize), WireError> {
    if buf.len() < HEADER_FIXED {
        return Err(WireError::Truncated { need: HEADER_FIXED, have: buf.len() });
    }
    let mut hdr = BitReader::new(&buf[..HEADER_FIXED]);
    let magic = hdr.read_bits(32).expect("fixed header") as u32;
    if magic != MAGIC {
        return Err(WireError::BadMagic { got: magic });
    }
    let version = hdr.read_bits(8).expect("fixed header") as u8;
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion { got: version });
    }
    let ty_byte = hdr.read_bits(8).expect("fixed header") as u8;
    let msg_type = MsgType::from_u8(ty_byte).ok_or(WireError::BadMsgType { got: ty_byte })?;

    let mut cur = Cursor::new(&buf[HEADER_FIXED..]);
    let len = cur.varint()?;
    if len > max_payload as u64 {
        return Err(WireError::Oversized { len, max: max_payload });
    }
    let len = len as usize;
    let payload_at = HEADER_FIXED + cur.pos;
    let total = payload_at + len + CRC_LEN;
    if buf.len() < total {
        return Err(WireError::Truncated { need: total, have: buf.len() });
    }
    let mut crc_bytes = [0u8; CRC_LEN];
    crc_bytes.copy_from_slice(&buf[total - CRC_LEN..total]);
    let want = u32::from_le_bytes(crc_bytes);
    let got = crc32(&buf[..total - CRC_LEN]);
    if want != got {
        return Err(WireError::BadCrc { want, got });
    }
    Ok((Frame { msg_type, payload: &buf[payload_at..payload_at + len] }, total))
}

/// Decode an update payload as a borrowed view (the coordinator's hot
/// path). `frame.msg_type` must be [`MsgType::Update`].
pub fn decode_update(payload: &[u8]) -> Result<UpdateView<'_>, WireError> {
    let mut cur = Cursor::new(payload);
    let t = cur.varint()?;
    let worker = cur.varint()?;
    let loss = cur.f64()?;
    let grad = decode_grad(&mut cur)?;
    cur.done()?;
    Ok(UpdateView { t, worker, loss, grad })
}

fn decode_grad<'a>(cur: &mut Cursor<'a>) -> Result<GradView<'a>, WireError> {
    match cur.u8()? {
        GRAD_TERNARY => {
            // Counts are bounded by the bytes that must follow them, so
            // nothing here can demand an allocation the payload cannot
            // back: dim is capped so the plane bytes fit the remainder.
            let dim = cur.count(4 * MAX_PAYLOAD, "ternary dim out of range")?;
            let nnz = cur.count(dim, "nnz exceeds dim")?;
            let scale = cur.f32()?;
            let bits = cur.f64()?;
            let plane_bytes = PackedTernary::words(dim) * 8;
            let mask = cur.take(plane_bytes)?;
            let sign = cur.take(plane_bytes)?;
            Ok(GradView::Ternary { dim, nnz, scale, bits, mask, sign })
        }
        GRAD_DENSE => {
            let bytes_left = cur.remaining();
            let dim = cur.count(bytes_left / 4 + 1, "dense dim exceeds payload")?;
            let bits = cur.f64()?;
            let nbytes = dim.checked_mul(4).ok_or(WireError::Malformed("dense dim overflow"))?;
            let values = cur.take(nbytes)?;
            Ok(GradView::Dense { dim, bits, values })
        }
        _ => Err(WireError::Malformed("unknown gradient payload kind")),
    }
}

/// Fully decode one parsed frame into an owned [`Msg`], validating every
/// field (the control-plane path; the coordinator uses
/// [`decode_update`] + [`GradView::unpack_ternary_into`] for updates).
pub fn decode_msg(frame: Frame<'_>) -> Result<Msg, WireError> {
    let mut cur = Cursor::new(frame.payload);
    let msg = match frame.msg_type {
        MsgType::Hello => {
            let lo = cur.varint()?;
            let hi = cur.varint()?;
            let cfg = cur.u64le()?;
            let env = cur.u64le()?;
            Msg::Hello { lo, hi, cfg, env }
        }
        MsgType::Welcome => {
            let client_id = cur.varint()?;
            let workers = cur.varint()?;
            let dim = cur.varint()?;
            let rounds = cur.varint()?;
            let mut commit = [0u64; 4];
            for w in commit.iter_mut() {
                *w = cur.u64le()?;
            }
            Msg::Welcome { client_id, workers, dim, rounds, commit }
        }
        MsgType::RoundOpen => {
            let t = cur.varint()?;
            let lr = cur.f64()?;
            let deadline_ms = cur.varint()?;
            // Each selected id takes ≥ 1 byte, so the count is bounded by
            // the bytes present. Grow the vec from *parsed* ids rather
            // than reserving off the declared count — a reservation would
            // amplify a hostile count 8× (u64 per payload byte) before a
            // single id was validated.
            let k = cur.count(cur.remaining(), "selection count exceeds payload")?;
            let mut selected = Vec::new();
            for _ in 0..k {
                selected.push(cur.varint()?);
            }
            let d = cur.count(cur.remaining() / 4 + 1, "params dim exceeds payload")?;
            let bytes = cur.take(4 * d)?;
            let params = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            Msg::RoundOpen { t, lr, deadline_ms, selected, params }
        }
        MsgType::Update => {
            let uv = decode_update(frame.payload)?;
            return Ok(Msg::Update {
                t: uv.t,
                worker: uv.worker,
                loss: uv.loss,
                grad: uv.grad.to_msg()?,
            });
        }
        MsgType::Ack => {
            let t = cur.varint()?;
            let worker = cur.varint()?;
            Msg::Ack { t, worker }
        }
        MsgType::Reject => {
            let t = cur.varint()?;
            let worker = cur.varint()?;
            let b = cur.u8()?;
            let bad = WireError::Malformed("unknown reject reason");
            let reason = RejectReason::from_u8(b).ok_or(bad)?;
            Msg::Reject { t, worker, reason }
        }
        MsgType::Fin => Msg::Fin { rounds: cur.varint()? },
        MsgType::Heartbeat => Msg::Heartbeat { client_id: cur.varint()? },
        MsgType::ShardHello => {
            let lo = cur.varint()?;
            let hi = cur.varint()?;
            let cfg = cur.u64le()?;
            let env = cur.u64le()?;
            Msg::ShardHello { lo, hi, cfg, env }
        }
        // Bulk data-plane frame: owned decode would clone the counter
        // planes for no caller. Use the borrowed view.
        MsgType::ShardAgg => {
            return Err(WireError::Malformed("shard-agg frames use decode_shard_agg"));
        }
    };
    cur.done()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn roundtrip(msg: &Msg) -> Msg {
        let mut wbuf = WireBuf::new();
        let mut out = Vec::new();
        let n = wbuf.encode(msg, &mut out);
        assert_eq!(n, out.len());
        let (frame, consumed) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        assert_eq!(consumed, out.len());
        assert_eq!(frame.msg_type, msg.msg_type());
        decode_msg(frame).unwrap()
    }

    fn sample_ternary(d: usize, seed: u64) -> CompressedGrad {
        let mut rng = Pcg64::seed_from(seed);
        let codes: Vec<i8> = (0..d).map(|_| [-1i8, 0, 0, 1][rng.index(4)]).collect();
        let pack = PackedTernary::from_codes(&codes, 1.0);
        let bits = 2.0 * d as f64 + 17.5;
        CompressedGrad::ternary(pack, bits)
    }

    #[test]
    fn every_message_roundtrips_bit_identically() {
        let msgs = vec![
            Msg::Hello { lo: 0, hi: 1000, cfg: 0x1122_3344_5566_7788, env: u64::MAX },
            Msg::Welcome {
                client_id: 3,
                workers: 1000,
                dim: 1 << 20,
                rounds: 500,
                commit: [u64::MAX, 0, 0x0123_4567_89ab_cdef, 7],
            },
            Msg::RoundOpen {
                t: 41,
                lr: 0.012345,
                deadline_ms: 250,
                selected: vec![0, 7, 63, 64, 999],
                params: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE, -0.0],
            },
            Msg::Update { t: 41, worker: 7, loss: 0.693147, grad: sample_ternary(130, 1) },
            Msg::Update {
                t: 2,
                worker: 0,
                loss: -1.0,
                grad: CompressedGrad::dense(vec![0.5, 0.0, -3.25], 96.0),
            },
            Msg::Ack { t: 5, worker: 2 },
            Msg::Reject { t: 5, worker: 2, reason: RejectReason::Duplicate },
            Msg::Fin { rounds: 120 },
            Msg::Heartbeat { client_id: 9 },
            Msg::ShardHello { lo: 512, hi: 1024, cfg: 0xdead_beef, env: 42 },
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn ternary_update_roundtrips_through_scratch_pack() {
        let grad = sample_ternary(777, 3);
        let CompressedGrad::Ternary { pack: src, bits } = &grad else { unreachable!() };
        let mut wbuf = WireBuf::new();
        let mut out = Vec::new();
        wbuf.encode_update(9, 42, 0.25, &grad, &mut out);
        let (frame, _) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        assert_eq!(frame.msg_type, MsgType::Update);
        let uv = decode_update(frame.payload).unwrap();
        assert_eq!((uv.t, uv.worker, uv.loss), (9, 42, 0.25));
        assert_eq!(uv.grad.bits(), *bits);
        let mut scratch = PackedTernary::zeros(0, 1.0);
        uv.grad.unpack_ternary_into(&mut scratch).unwrap().unwrap();
        assert_eq!(&scratch, src);
    }

    #[test]
    fn nnz_lie_is_rejected() {
        let grad = sample_ternary(64, 4);
        let mut wbuf = WireBuf::new();
        let mut out = Vec::new();
        wbuf.encode_update(0, 0, 0.0, &grad, &mut out);
        // The nnz varint sits right after the frame header + t/worker/
        // loss fields; easier to corrupt a mask byte and watch the
        // recount disagree (CRC is recomputed to isolate the check).
        let (frame, total) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        let payload_at = total - CRC_LEN - frame.payload.len();
        let mask_byte = payload_at + frame.payload.len() - 16; // inside planes
        out[mask_byte] ^= 0x01;
        let body_len = out.len() - CRC_LEN;
        let crc = crc32(&out[..body_len]).to_le_bytes();
        out[body_len..].copy_from_slice(&crc);
        let (frame, _) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        let err = decode_msg(frame).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err}");
    }

    #[test]
    fn typed_errors_for_bad_magic_version_type_crc_and_caps() {
        let mut wbuf = WireBuf::new();
        let mut good = Vec::new();
        wbuf.encode(&Msg::Fin { rounds: 3 }, &mut good);

        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(matches!(parse_frame(&bad, MAX_PAYLOAD), Err(WireError::BadMagic { .. })));

        let mut bad = good.clone();
        bad[4] = WIRE_VERSION + 1;
        assert!(matches!(
            parse_frame(&bad, MAX_PAYLOAD),
            Err(WireError::BadVersion { got }) if got == WIRE_VERSION + 1
        ));

        let mut bad = good.clone();
        bad[5] = 0xee;
        assert!(matches!(parse_frame(&bad, MAX_PAYLOAD), Err(WireError::BadMsgType { got: 0xee })));

        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x10;
        assert!(matches!(parse_frame(&bad, MAX_PAYLOAD), Err(WireError::BadCrc { .. })));

        // A hostile length prefix is rejected before any allocation.
        let mut huge = good[..HEADER_FIXED].to_vec();
        push_varint(&mut huge, u64::MAX / 2);
        huge.extend_from_slice(&[0u8; 16]);
        assert!(matches!(parse_frame(&huge, MAX_PAYLOAD), Err(WireError::Oversized { .. })));

        // Every truncation of a valid frame is a typed error.
        for cut in 0..good.len() {
            let err = parse_frame(&good[..cut], MAX_PAYLOAD).unwrap_err();
            assert!(matches!(err, WireError::Truncated { .. }), "cut at {cut}: {err}");
        }
    }

    #[test]
    fn frame_overhead_under_one_percent_at_1e5_coords() {
        // Satellite: wire framing (header + varints + CRC) must cost
        // < 1% of an update frame at d ≥ 10^5 — the PackedTernary
        // payload dominates.
        let d = 100_000;
        let grad = sample_ternary(d, 5);
        let mut wbuf = WireBuf::new();
        let mut out = Vec::new();
        let frame_len = wbuf.encode_update(3, 17, 0.5, &grad, &mut out);
        let (frame, _) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        let overhead = frame_len - frame.payload.len();
        let share = overhead as f64 / frame_len as f64;
        assert!(share < 0.01, "framing overhead {overhead}B / {frame_len}B = {share:.4}");
        // And the plane payload is exactly 2 bits/coordinate plus the
        // fixed scalars, i.e. the 4x-smaller PR 1 representation really
        // is what crosses the wire.
        let plane_bytes = 2 * PackedTernary::words(d) * 8;
        assert!(frame.payload.len() < plane_bytes + 64);
    }

    fn sample_shard_agg(out: &mut Vec<u8>) -> (Vec<ShardRec>, Vec<u64>, Vec<u64>) {
        // dim 100 → 2 words; 3 messages → planes happen to be caller's
        // choice here (the accumulator dictates it in production).
        let dim = 100;
        let planes = 2;
        let words = PackedTernary::words(dim) * planes;
        let pos: Vec<u64> = (0..words as u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect();
        let neg: Vec<u64> = pos.iter().map(|w| !w).collect();
        let recs = vec![
            ShardRec { worker: 3, loss: 0.25, bits: 217.0, nnz: 40, scale: 1.0 },
            ShardRec { worker: 64, loss: -0.5, bits: 217.0, nnz: 17, scale: 1.0 },
            ShardRec { worker: 99, loss: 2.0, bits: 219.5, nnz: 100, scale: 1.0 },
        ];
        let rejects = [0, 1, 0, 2, 0, 0];
        let mut wbuf = WireBuf::new();
        let n =
            wbuf.encode_shard_agg(7, 50, 150, &recs, 4096, 8192, &rejects, dim, planes, &pos, &neg, out);
        assert_eq!(n, out.len());
        (recs, pos, neg)
    }

    #[test]
    fn shard_agg_roundtrips_bit_identically() {
        let mut out = Vec::new();
        let (recs, pos, neg) = sample_shard_agg(&mut out);
        let (frame, consumed) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        assert_eq!(consumed, out.len());
        assert_eq!(frame.msg_type, MsgType::ShardAgg);
        let view = decode_shard_agg(frame.payload).unwrap();
        assert_eq!((view.t, view.lo, view.hi), (7, 50, 150));
        assert_eq!(view.recs, recs);
        assert_eq!((view.up_bytes, view.down_bytes), (4096, 8192));
        assert_eq!(view.rejects, [0, 1, 0, 2, 0, 0]);
        assert_eq!((view.msgs, view.dim, view.planes), (3, 100, 2));
        let got_pos: Vec<u64> = view.pos.chunks_exact(8).map(le_word).collect();
        let got_neg: Vec<u64> = view.neg.chunks_exact(8).map(le_word).collect();
        assert_eq!(got_pos, pos);
        assert_eq!(got_neg, neg);
        // The owned decoder refuses the bulk frame by design.
        assert!(matches!(decode_msg(frame), Err(WireError::Malformed(_))));
    }

    #[test]
    fn shard_agg_hardening_rejects_inconsistent_payloads() {
        let mut out = Vec::new();
        sample_shard_agg(&mut out);
        let (frame, _) = parse_frame(&out, MAX_PAYLOAD).unwrap();
        // Every truncation of the payload is a typed error, never a panic.
        for cut in 0..frame.payload.len() {
            assert!(decode_shard_agg(&frame.payload[..cut]).is_err(), "cut {cut}");
        }
        // k = 0 must come with zero planes and no plane bytes.
        let mut wbuf = WireBuf::new();
        let mut empty = Vec::new();
        wbuf.encode_shard_agg(0, 0, 8, &[], 0, 0, &[0; REJECT_KINDS], 100, 0, &[], &[], &mut empty);
        let (f, _) = parse_frame(&empty, MAX_PAYLOAD).unwrap();
        let view = decode_shard_agg(f.payload).unwrap();
        assert_eq!((view.msgs, view.planes), (0, 0));
        assert!(view.pos.is_empty() && view.neg.is_empty());
    }

    #[test]
    fn frame_len_delimits_partial_and_concatenated_streams() {
        let mut wbuf = WireBuf::new();
        let mut bytes = Vec::new();
        let n1 = wbuf.encode(&Msg::Heartbeat { client_id: 1 }, &mut bytes);
        let n2 = wbuf.encode(&Msg::Fin { rounds: 4 }, &mut bytes);
        // Every strict prefix of frame 1: incomplete, not an error.
        for cut in 0..n1 {
            assert_eq!(frame_len(&bytes[..cut], MAX_PAYLOAD).unwrap(), None, "cut {cut}");
        }
        // The exact frame and any longer buffer delimit frame 1 only.
        assert_eq!(frame_len(&bytes[..n1], MAX_PAYLOAD).unwrap(), Some(n1));
        assert_eq!(frame_len(&bytes, MAX_PAYLOAD).unwrap(), Some(n1));
        // And the tail delimits frame 2.
        assert_eq!(frame_len(&bytes[n1..], MAX_PAYLOAD).unwrap(), Some(n2));
        // Garbage and protocol drift are fatal, immediately.
        assert!(matches!(frame_len(b"XXXXXXXX", MAX_PAYLOAD), Err(WireError::BadMagic { .. })));
        let mut drift = bytes[..n1].to_vec();
        drift[4] = WIRE_VERSION + 9;
        assert!(matches!(frame_len(&drift, MAX_PAYLOAD), Err(WireError::BadVersion { .. })));
        // A hostile declared length dies before any buffering decision.
        let mut huge = bytes[..HEADER_FIXED].to_vec();
        push_varint(&mut huge, u64::MAX / 2);
        assert!(matches!(frame_len(&huge, MAX_PAYLOAD), Err(WireError::Oversized { .. })));
        // frame_len agrees with parse_frame's `used` on real frames.
        let (_, used) = parse_frame(&bytes, MAX_PAYLOAD).unwrap();
        assert_eq!(used, n1);
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC-32 of "123456789" is 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn varint_roundtrip_and_overflow() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            buf.clear();
            push_varint(&mut buf, v);
            let mut cur = Cursor::new(&buf);
            assert_eq!(cur.varint().unwrap(), v);
            assert_eq!(cur.remaining(), 0);
        }
        // 10 bytes with a too-large final digit overflows u64.
        let over = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x02];
        let mut cur = Cursor::new(&over);
        assert!(cur.varint().is_err());
        // 11-byte varints are malformed.
        let long = [0x80u8; 11];
        let mut cur = Cursor::new(&long);
        assert!(cur.varint().is_err());
    }
}
