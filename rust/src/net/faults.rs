//! Deterministic fault injection (DESIGN.md §15): the `FaultPlan`
//! grammar, its supervisor-side kill schedule, and the in-process
//! `FaultInjector` the transport tiers consult at named protocol
//! phases.
//!
//! The plan mirrors the [`AttackPlan`] grammar — comma-separated
//! `kind[:operand[:operand]]` cohorts — but where an attack plan
//! assigns *worker behaviours*, a fault plan schedules *infrastructure
//! abuse*: process kills, link delays, and partitions. Every fault is
//! seeded and lands at a named protocol event (a round boundary read
//! from the event log, a round-open broadcast, a frame flush) — never
//! at a wall-clock offset — so a soak run under a plan is exactly
//! repeatable and `sleep`-flakiness cannot creep into the harness.
//!
//! Process-level kinds (`kill-shard`, `kill-coordinator`,
//! `agent-churn`) are consumed by the `soak` supervisor through
//! [`FaultSchedule`]; in-process kinds (`delay`, `partition`) ride into
//! the serve/shard/fleet options as a [`FaultInjector`] and are applied
//! by the tier itself: a delay slows every outbound frame flush of the
//! named role (the reactor's send path), a partition makes the named
//! role sever its *own* upstream connection at the open of the
//! scheduled round — exercising exactly the reconnect-with-backoff
//! machinery a real network fault would.
//!
//! [`AttackPlan`]: crate::coordinator::AttackPlan

use std::time::Duration;

use crate::util::rng::Pcg64;

/// Which tier an in-process fault names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultRole {
    /// The root coordinator.
    Root,
    /// An aggregator shard (`range` in the grammar is an alias — the
    /// ranged tier).
    Shard,
    /// A fleet agent.
    Client,
}

/// When a scheduled fault fires, in completed-round counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultWhen {
    /// Exactly once, at the boundary after round `t` closes.
    Round(usize),
    /// At every boundary where `done % k == 0` (and `done > 0`).
    Every(usize),
}

impl FaultWhen {
    /// Does the schedule fire at the boundary after `done` completed
    /// rounds?
    pub fn fires_at(&self, done: usize) -> bool {
        match *self {
            FaultWhen::Round(r) => done == r,
            FaultWhen::Every(k) => done > 0 && done % k == 0,
        }
    }
}

/// One parsed fault cohort.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// SIGKILL one shard process per firing (the supervisor rotates
    /// which, deterministically) and respawn it.
    KillShard(FaultWhen),
    /// SIGKILL the root coordinator per firing; the supervisor respawns
    /// it with `--resume` from its latest snapshot.
    KillCoordinator(FaultWhen),
    /// Per-round-boundary seeded chance (percent) of killing one fleet
    /// agent process, which is then respawned.
    AgentChurn(f64),
    /// Delay every outbound frame flush of the named role.
    Delay(FaultRole, Duration),
    /// The named role severs its own upstream connection at the open of
    /// each scheduled round (roots have no upstream, so `Root` is
    /// rejected at parse time).
    Partition(FaultRole, FaultWhen),
}

/// A parsed, seeded fault plan. The seed pins every randomized decision
/// (churn victims, shard rotation origin) so two soak runs under the
/// same plan inject byte-identical abuse.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    seed: u64,
}

fn parse_when(s: &str, part: &str) -> Result<FaultWhen, String> {
    if let Some(r) = s.strip_prefix("round=") {
        let t: usize = r.parse().map_err(|_| format!("bad round '{s}' in fault '{part}'"))?;
        return Ok(FaultWhen::Round(t));
    }
    if let Some(k) = s.strip_prefix("every=") {
        let k: usize = k.parse().map_err(|_| format!("bad period '{s}' in fault '{part}'"))?;
        if k == 0 {
            return Err(format!("period must be >= 1 in fault '{part}'"));
        }
        return Ok(FaultWhen::Every(k));
    }
    Err(format!("fault '{part}' needs round=T or every=K, got '{s}'"))
}

fn parse_role(s: &str, part: &str) -> Result<FaultRole, String> {
    match s {
        "root" | "coordinator" => Ok(FaultRole::Root),
        "shard" | "range" => Ok(FaultRole::Shard),
        "client" | "agent" | "fleet" => Ok(FaultRole::Client),
        _ => Err(format!("unknown role '{s}' in fault '{part}' (root|shard|client)")),
    }
}

impl FaultPlan {
    /// Parse the comma-separated fault grammar:
    ///
    /// ```text
    /// kill-shard:round=7 | kill-shard:every=29
    /// kill-coordinator:round=50 | kill-coordinator:every=50
    /// agent-churn:10%
    /// delay:shard:200ms | delay:root:5ms | delay:client:1ms
    /// partition:shard:round=3 | partition:range | partition:client:every=10
    /// ```
    ///
    /// `partition` defaults to `round=1` when no schedule is given (the
    /// `partition:range` shorthand). An empty spec is an empty plan.
    pub fn parse(spec: &str, seed: u64) -> Result<Self, String> {
        let mut faults = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                if spec.trim().is_empty() {
                    continue;
                }
                return Err(format!("empty fault in spec '{spec}'"));
            }
            let mut f = part.split(':');
            let kind = f.next().unwrap_or("");
            let op1 = f.next();
            let op2 = f.next();
            if f.next().is_some() {
                return Err(format!("too many ':' fields in fault '{part}'"));
            }
            let fault = match kind {
                "kill-shard" | "kill-coordinator" => {
                    let when_s =
                        op1.ok_or_else(|| format!("fault '{part}' needs round=T or every=K"))?;
                    if op2.is_some() {
                        return Err(format!("fault '{part}' takes one operand"));
                    }
                    let when = parse_when(when_s, part)?;
                    if kind == "kill-shard" {
                        Fault::KillShard(when)
                    } else {
                        Fault::KillCoordinator(when)
                    }
                }
                "agent-churn" => {
                    let pct_s = op1
                        .and_then(|s| s.strip_suffix('%'))
                        .ok_or_else(|| format!("fault '{part}' needs a percentage, e.g. 10%"))?;
                    if op2.is_some() {
                        return Err(format!("fault '{part}' takes one operand"));
                    }
                    let p: f64 = pct_s
                        .parse()
                        .map_err(|_| format!("bad percentage in fault '{part}'"))?;
                    if !(0.0..=100.0).contains(&p) {
                        return Err(format!("percentage out of 0..=100 in fault '{part}'"));
                    }
                    Fault::AgentChurn(p)
                }
                "delay" => {
                    let role = parse_role(
                        op1.ok_or_else(|| format!("fault '{part}' needs a role"))?,
                        part,
                    )?;
                    let ms_s = op2
                        .and_then(|s| s.strip_suffix("ms"))
                        .ok_or_else(|| format!("fault '{part}' needs a duration, e.g. 200ms"))?;
                    let ms: u64 =
                        ms_s.parse().map_err(|_| format!("bad duration in fault '{part}'"))?;
                    Fault::Delay(role, Duration::from_millis(ms))
                }
                "partition" => {
                    let role = parse_role(
                        op1.ok_or_else(|| format!("fault '{part}' needs a role"))?,
                        part,
                    )?;
                    if role == FaultRole::Root {
                        return Err(format!(
                            "fault '{part}': the root has no upstream to partition from \
                             (use kill-coordinator)"
                        ));
                    }
                    let when = match op2 {
                        Some(s) => parse_when(s, part)?,
                        None => FaultWhen::Round(1),
                    };
                    Fault::Partition(role, when)
                }
                _ => return Err(format!("unknown fault kind '{kind}' in '{part}'")),
            };
            faults.push(fault);
        }
        Ok(Self { faults, seed })
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// The in-process injector for one process of the topology —
    /// `delay` and `partition` faults addressed to `role` (process
    /// kills are the supervisor's job and never appear here).
    pub fn injector(&self, role: FaultRole) -> FaultInjector {
        let mut send_delay = None;
        let mut partitions = Vec::new();
        for f in &self.faults {
            match *f {
                Fault::Delay(r, d) if r == role => {
                    send_delay = Some(send_delay.map_or(d, |p: Duration| p.max(d)));
                }
                Fault::Partition(r, when) if r == role => partitions.push(when),
                _ => {}
            }
        }
        FaultInjector { send_delay, partitions, fired: Vec::new() }
    }

    /// The supervisor-side kill schedule over a concrete topology.
    pub fn schedule(&self, shards: usize, agents: usize) -> FaultSchedule {
        FaultSchedule {
            faults: self.faults.clone(),
            shards,
            agents,
            rng: Pcg64::new(self.seed ^ 0xfa17_1e55, 0x50a6),
            next_shard: 0,
            next_agent: 0,
        }
    }
}

/// One process kill the supervisor must carry out at a round boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// SIGKILL + respawn shard `i`.
    KillShard(usize),
    /// SIGKILL + respawn (with `--resume`) the root coordinator.
    KillCoordinator,
    /// SIGKILL + respawn fleet agent process `i`.
    KillAgent(usize),
}

/// The process-kill schedule over a concrete topology: feed it each
/// round boundary in order and it answers which processes die there.
/// Fully determined by `(plan seed, topology, boundary order)` — the
/// supervisor drives it from event-log round closes, so the same plan
/// over the same run kills the same processes at the same rounds every
/// time.
#[derive(Clone, Debug)]
pub struct FaultSchedule {
    faults: Vec<Fault>,
    shards: usize,
    agents: usize,
    rng: Pcg64,
    next_shard: usize,
    next_agent: usize,
}

impl FaultSchedule {
    /// Kills to carry out at the boundary after `done` rounds have
    /// completed. Must be called for every boundary in ascending order
    /// (the rotation and churn draws advance per call).
    pub fn actions_after(&mut self, done: usize) -> Vec<FaultAction> {
        let mut out = Vec::new();
        for f in &self.faults {
            match *f {
                Fault::KillShard(when) if when.fires_at(done) && self.shards > 0 => {
                    out.push(FaultAction::KillShard(self.next_shard % self.shards));
                    self.next_shard += 1;
                }
                Fault::KillCoordinator(when) if when.fires_at(done) => {
                    out.push(FaultAction::KillCoordinator);
                }
                Fault::AgentChurn(pct) if self.agents > 0 && done > 0 => {
                    // Seeded Bernoulli draw per boundary; victims rotate
                    // so churn spreads across the fleet.
                    let draw = self.rng.next_u64() as f64 / u64::MAX as f64 * 100.0;
                    if draw < pct {
                        out.push(FaultAction::KillAgent(self.next_agent % self.agents));
                        self.next_agent += 1;
                    }
                }
                _ => {}
            }
        }
        out
    }
}

/// In-process fault state for one transport process: consulted at the
/// named phases where its faults land.
#[derive(Clone, Debug, Default)]
pub struct FaultInjector {
    send_delay: Option<Duration>,
    partitions: Vec<FaultWhen>,
    /// Rounds where a partition already fired (each scheduled round
    /// severs once, however many times the round re-opens).
    fired: Vec<usize>,
}

impl FaultInjector {
    /// Delay to apply before every outbound frame flush (the reactor's
    /// send path), if a `delay` fault names this role.
    pub fn send_delay(&self) -> Option<Duration> {
        self.send_delay
    }

    /// True exactly once per scheduled round: the role must sever its
    /// upstream connection *now* (at the open of round `t`) and take
    /// its normal reconnect path.
    pub fn partition_now(&mut self, t: usize) -> bool {
        if self.fired.contains(&t) {
            return false;
        }
        if self.partitions.iter().any(|w| w.fires_at(t)) {
            self.fired.push(t);
            return true;
        }
        false
    }

    /// Anything to do at all? (Lets callers skip per-frame checks.)
    pub fn is_empty(&self) -> bool {
        self.send_delay.is_none() && self.partitions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_grammar() {
        let p = FaultPlan::parse(
            "kill-shard:every=29,kill-coordinator:round=50,agent-churn:10%",
            7,
        )
        .unwrap();
        assert_eq!(
            p.faults(),
            &[
                Fault::KillShard(FaultWhen::Every(29)),
                Fault::KillCoordinator(FaultWhen::Round(50)),
                Fault::AgentChurn(10.0),
            ]
        );
        let p = FaultPlan::parse("delay:shard:200ms,partition:range", 7).unwrap();
        assert_eq!(
            p.faults(),
            &[
                Fault::Delay(FaultRole::Shard, Duration::from_millis(200)),
                Fault::Partition(FaultRole::Shard, FaultWhen::Round(1)),
            ]
        );
        assert!(FaultPlan::parse("", 7).unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "kill-shard",
            "kill-shard:7",
            "kill-shard:every=0",
            "kill-shard:round=x",
            "agent-churn:10",
            "agent-churn:101%",
            "delay:shard",
            "delay:shard:200",
            "delay:nowhere:200ms",
            "partition:root",
            "frobnicate:round=1",
            "kill-shard:round=1:extra:extra",
        ] {
            assert!(FaultPlan::parse(bad, 7).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn schedule_is_deterministic_and_rotates_shards() {
        let p = FaultPlan::parse("kill-shard:every=2,kill-coordinator:round=4", 42).unwrap();
        let drive = |mut s: FaultSchedule| -> Vec<Vec<FaultAction>> {
            (0..=6).map(|done| s.actions_after(done)).collect()
        };
        let a = drive(p.schedule(2, 2));
        let b = drive(p.schedule(2, 2));
        assert_eq!(a, b, "same seed + topology → same kills");
        assert_eq!(a[2], vec![FaultAction::KillShard(0)]);
        assert_eq!(a[4], vec![FaultAction::KillShard(1), FaultAction::KillCoordinator]);
        assert_eq!(a[6], vec![FaultAction::KillShard(0)], "rotation wraps");
        assert!(a[1].is_empty() && a[3].is_empty() && a[5].is_empty());
        assert!(a[0].is_empty(), "every=K never fires before a round completes");
    }

    #[test]
    fn churn_draws_are_seeded() {
        let p = FaultPlan::parse("agent-churn:50%", 9).unwrap();
        let kills = |seed_plan: &FaultPlan| -> Vec<Vec<FaultAction>> {
            let mut s = seed_plan.schedule(0, 3);
            (0..40).map(|done| s.actions_after(done)).collect()
        };
        let a = kills(&p);
        assert_eq!(a, kills(&p), "replays identically");
        let total: usize = a.iter().map(Vec::len).sum();
        assert!(total > 5 && total < 35, "~50% of 39 boundaries, got {total}");
        // Victims rotate through the fleet.
        let mut seen = std::collections::HashSet::new();
        for acts in &a {
            for act in acts {
                if let FaultAction::KillAgent(i) = act {
                    seen.insert(*i);
                }
            }
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn injector_scopes_faults_to_its_role_and_fires_once() {
        let p =
            FaultPlan::parse("delay:shard:5ms,partition:shard:round=2,delay:client:1ms", 7)
                .unwrap();
        let mut shard = p.injector(FaultRole::Shard);
        assert_eq!(shard.send_delay(), Some(Duration::from_millis(5)));
        assert!(!shard.partition_now(1));
        assert!(shard.partition_now(2));
        assert!(!shard.partition_now(2), "a re-opened round does not re-sever");
        let client = p.injector(FaultRole::Client);
        assert_eq!(client.send_delay(), Some(Duration::from_millis(1)));
        let root = p.injector(FaultRole::Root);
        assert!(root.is_empty());
    }
}
