//! Model substrate: loss/gradient providers for the federated engine.
//!
//! A [`Model`] is a *stateless* description of the architecture; parameters
//! live in one flat `Vec<f32>` owned by the coordinator (the compressors
//! operate on the flat gradient vector, exactly as in the paper where the
//! whole parameter vector `w ∈ ℝᵈ` is compressed coordinate-wise).
//!
//! Implementations:
//! * [`SoftmaxRegression`] — linear classifier (convex sanity substrate).
//! * [`Mlp`] — the paper's §C.2 architecture family (e.g. 784-256-128-10
//!   with ReLU for Fashion-MNIST).
//! * [`rosenbrock`] — the §6.1 deterministic objective with the eq. (11)
//!   scaled-objective heterogeneity.
//! * `runtime::HloModel` — the same trait backed by an AOT-compiled
//!   JAX/Pallas artifact executed via PJRT.

mod linear;
mod mlp;
pub mod rosenbrock;

pub use linear::SoftmaxRegression;
pub use mlp::Mlp;

use crate::data::BatchScratch;
use crate::util::linalg::GemmScratch;
use crate::util::rng::Pcg64;

/// Reusable buffers for a model's forward/backward pass, owned per engine
/// thread (embedded in the coordinator's `WorkerScratch`) so the
/// steady-state training hot path performs **zero heap allocations**: all
/// buffers grow to their high-water mark on the first call and are reused
/// verbatim afterwards (`tests/zero_alloc.rs` pins this with a counting
/// allocator).
///
/// Fields are public so `Model` impls can split-borrow them (activations
/// immutably while the GEMM scratch is borrowed mutably); none of the
/// model methods touch `batch`, which belongs to the environment layer's
/// mini-batch gather (`ClassifierEnv::sample_grad_ws`).
#[derive(Default)]
pub struct ModelWorkspace {
    /// Per-layer forward activations: `acts[l]` is layer `l`'s output
    /// (`batch × widths[l+1]`); the input batch is borrowed, never copied.
    pub acts: Vec<Vec<f32>>,
    /// Backprop delta for the current layer.
    pub delta: Vec<f32>,
    /// Backprop delta for the next-lower layer (swapped with `delta`).
    pub delta2: Vec<f32>,
    /// GEMM packing buffers (see [`crate::util::linalg::gemm_with`]).
    pub gemm: GemmScratch,
    /// Mini-batch sampling/gather scratch for the environment layer.
    pub batch: BatchScratch,
}

impl ModelWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure `acts` holds at least `layers` buffers and return them.
    pub fn acts_for(&mut self, layers: usize) -> &mut Vec<Vec<f32>> {
        while self.acts.len() < layers {
            self.acts.push(Vec::new());
        }
        &mut self.acts
    }
}

/// Resize a workspace buffer to `len` without shrinking capacity (and
/// without the redundant zero-fill when the length already matches — the
/// caller overwrites every element).
#[inline]
pub(crate) fn ensure_len(v: &mut Vec<f32>, len: usize) {
    if v.len() != len {
        v.resize(len, 0.0);
    }
}

/// A differentiable supervised model over flat parameters.
pub trait Model: Send + Sync {
    /// Total number of parameters `d`.
    fn dim(&self) -> usize;

    /// Compute mean loss over the batch and write the gradient into
    /// `grad` (overwritten, not accumulated). `x` is `batch×in_dim`
    /// row-major, `y` the labels. All intermediate buffers come from
    /// `ws`; after warm-up the call performs no heap allocation.
    fn loss_grad_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        grad: &mut [f32],
        ws: &mut ModelWorkspace,
    ) -> f32;

    /// Mean loss + accuracy on a dataset slice (no gradient), using `ws`
    /// for intermediates.
    fn evaluate_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        ws: &mut ModelWorkspace,
    ) -> (f64, f64);

    /// [`Self::loss_grad_ws`] with a throwaway workspace — convenience
    /// wrapper for tests/examples off the hot path.
    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[usize], grad: &mut [f32]) -> f32 {
        self.loss_grad_ws(params, x, y, grad, &mut ModelWorkspace::new())
    }

    /// [`Self::evaluate_ws`] with a throwaway workspace.
    fn evaluate(&self, params: &[f32], x: &[f32], y: &[usize]) -> (f64, f64) {
        self.evaluate_ws(params, x, y, &mut ModelWorkspace::new())
    }

    /// Initialize parameters.
    fn init(&self, rng: &mut Pcg64) -> Vec<f32>;

    /// Human-readable description.
    fn describe(&self) -> String;

    /// True when `loss_grad`/`evaluate` must only ever run on one thread
    /// at a time (the PJRT-backed `HloModel` — its compile cache is
    /// `Rc`/`RefCell`). The round engine consults this through
    /// [`crate::coordinator::GradientSource::serial_only`] and pins its
    /// fan-out to a single thread. Pure-rust models are thread-safe.
    fn serial_only(&self) -> bool {
        false
    }
}

/// Config-level model selection.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelKind {
    /// Linear softmax classifier.
    Linear { inputs: usize, classes: usize },
    /// ReLU MLP with the given hidden widths.
    Mlp { inputs: usize, hidden: Vec<usize>, classes: usize },
    /// AOT-compiled JAX artifact (loaded by `runtime`); the string names
    /// the artifact stem, e.g. `"mlp_fmnist"` → `artifacts/mlp_fmnist.hlo.txt`.
    Hlo { artifact: String, inputs: usize, classes: usize },
}

impl ModelKind {
    /// Paper §C.2 Fashion-MNIST network: 784-256-128-C MLP.
    pub fn paper_fmnist_mlp(classes: usize) -> Self {
        ModelKind::Mlp { inputs: 784, hidden: vec![256, 128], classes }
    }

    /// Build the pure-rust models; `Hlo` is constructed via
    /// [`crate::runtime::HloModel::load`] instead (needs a PJRT client).
    pub fn build(&self) -> Box<dyn Model> {
        match self {
            ModelKind::Linear { inputs, classes } => {
                Box::new(SoftmaxRegression::new(*inputs, *classes))
            }
            ModelKind::Mlp { inputs, hidden, classes } => {
                Box::new(Mlp::new(*inputs, hidden.clone(), *classes))
            }
            ModelKind::Hlo { .. } => {
                panic!("HLO-backed models are built through runtime::HloModel::load")
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            ModelKind::Linear { inputs, classes } => format!("linear({inputs}->{classes})"),
            ModelKind::Mlp { inputs, hidden, classes } => {
                let h: Vec<String> = hidden.iter().map(|x| x.to_string()).collect();
                format!("mlp({inputs}-{}-{classes})", h.join("-"))
            }
            ModelKind::Hlo { artifact, .. } => format!("hlo({artifact})"),
        }
    }
}

/// Softmax cross-entropy forward+backward shared by the classifiers.
///
/// `logits` is `batch×classes` and is replaced in-place by
/// `∂loss/∂logits = (softmax - onehot)/batch`; returns the mean CE loss.
///
/// Fused per row: stabilized max, exp+sum, then a single normalize pass
/// that folds the softmax `1/Σ` and the `1/batch` gradient scale together
/// — three passes over the logits instead of the former five.
pub(crate) fn softmax_xent_backward(logits: &mut [f32], y: &[usize], classes: usize) -> f32 {
    let batch = y.len();
    debug_assert_eq!(logits.len(), batch * classes);
    let mut loss = 0.0f64;
    let inv_b = 1.0 / batch as f32;
    for (i, &yi) in y.iter().enumerate() {
        debug_assert!(yi < classes);
        let row = &mut logits[i * classes..(i + 1) * classes];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        let p = (row[yi] * inv).max(1e-12);
        loss -= (p as f64).ln();
        // dlogits = (softmax - onehot)/batch, normalization fused in.
        let s = inv * inv_b;
        for v in row.iter_mut() {
            *v *= s;
        }
        row[yi] -= inv_b;
    }
    (loss / batch as f64) as f32
}

/// Accuracy + mean loss given logits (used by `evaluate` impls).
///
/// NaN-robust on purpose: a diverged model (e.g. under a re-scaling
/// attack) produces non-finite logits; those rows count as wrong with a
/// capped loss instead of panicking, so the attack experiments can report
/// the collapse.
pub(crate) fn softmax_xent_eval(logits: &mut [f32], y: &[usize], classes: usize) -> (f64, f64) {
    let batch = y.len();
    crate::util::linalg::softmax_rows(logits, batch, classes);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for (i, &yi) in y.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let p = row[yi];
        loss -= if p.is_finite() { (p.max(1e-12) as f64).ln() } else { (1e-12f64).ln() };
        let mut argmax = usize::MAX;
        let mut best = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v.is_finite() && v > best {
                best = v;
                argmax = j;
            }
        }
        if argmax == yi {
            correct += 1;
        }
    }
    (loss / batch as f64, correct as f64 / batch as f64)
}

/// Finite-difference gradient check used by the test suites of every
/// model implementation.
#[cfg(test)]
pub(crate) fn grad_check(model: &dyn Model, x: &[f32], y: &[usize], seed: u64) {
    let mut rng = Pcg64::seed_from(seed);
    let params = model.init(&mut rng);
    let mut grad = vec![0.0; model.dim()];
    model.loss_grad(&params, x, y, &mut grad);
    let eps = 1e-3f32;
    let mut scratch = vec![0.0; model.dim()];
    // Check a deterministic subsample of coordinates.
    let step = (model.dim() / 25).max(1);
    for i in (0..model.dim()).step_by(step) {
        let mut pp = params.clone();
        pp[i] += eps;
        let lp = model.loss_grad(&pp, x, y, &mut scratch);
        pp[i] -= 2.0 * eps;
        let lm = model.loss_grad(&pp, x, y, &mut scratch);
        let fd = (lp - lm) / (2.0 * eps);
        let an = grad[i];
        let denom = fd.abs().max(an.abs()).max(1e-2);
        assert!(
            (fd - an).abs() / denom < 0.08,
            "coord {i}: finite-diff {fd} vs analytic {an}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xent_backward_matches_softmax_identity() {
        // For a single example, dlogit_j = softmax_j - 1[j=y].
        let mut logits = vec![1.0f32, 2.0, 3.0];
        let mut probs = logits.clone();
        crate::util::linalg::softmax_rows(&mut probs, 1, 3);
        let loss = softmax_xent_backward(&mut logits, &[2], 3);
        assert!((loss + probs[2].max(1e-12).ln()).abs() < 1e-6);
        for j in 0..3 {
            let want = probs[j] - if j == 2 { 1.0 } else { 0.0 };
            assert!((logits[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn eval_perfect_prediction() {
        let mut logits = vec![10.0f32, -10.0, -10.0, 10.0]; // 2 examples, 2 classes
        let (loss, acc) = softmax_xent_eval(&mut logits, &[0, 1], 2);
        assert!(acc == 1.0);
        assert!(loss < 1e-6);
    }

    #[test]
    fn model_kind_builds_and_labels() {
        let k = ModelKind::paper_fmnist_mlp(10);
        let m = k.build();
        assert_eq!(m.dim(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
        assert_eq!(k.label(), "mlp(784-256-128-10)");
        let lin = ModelKind::Linear { inputs: 4, classes: 3 }.build();
        assert_eq!(lin.dim(), 4 * 3 + 3);
    }

    #[test]
    #[should_panic(expected = "runtime::HloModel")]
    fn hlo_kind_needs_runtime() {
        ModelKind::Hlo { artifact: "x".into(), inputs: 1, classes: 2 }.build();
    }
}
