//! Linear softmax classifier — the convex substrate used for fast
//! integration tests and the theory-validation experiments.

use super::{softmax_xent_backward, softmax_xent_eval, Model};
use crate::util::linalg::{matmul_a_bt, matmul_at_b};
use crate::util::rng::Pcg64;

/// `logits = x·Wᵀ + b`, cross-entropy loss.
///
/// Parameter layout (flat): `W` stored `classes×inputs` row-major, then
/// `b` (`classes`).
#[derive(Clone, Debug)]
pub struct SoftmaxRegression {
    pub inputs: usize,
    pub classes: usize,
}

impl SoftmaxRegression {
    pub fn new(inputs: usize, classes: usize) -> Self {
        assert!(inputs > 0 && classes > 1);
        Self { inputs, classes }
    }

    fn split<'a>(&self, params: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        let wlen = self.classes * self.inputs;
        (&params[..wlen], &params[wlen..wlen + self.classes])
    }

    fn logits(&self, params: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
        let (w, b) = self.split(params);
        let mut logits = vec![0.0f32; batch * self.classes];
        // x: batch×inputs, w: classes×inputs ⇒ logits = x · wᵀ.
        matmul_a_bt(&mut logits, x, w, batch, self.inputs, self.classes);
        for i in 0..batch {
            for (l, &bi) in logits[i * self.classes..(i + 1) * self.classes]
                .iter_mut()
                .zip(b)
            {
                *l += bi;
            }
        }
        logits
    }
}

impl Model for SoftmaxRegression {
    fn dim(&self) -> usize {
        self.classes * self.inputs + self.classes
    }

    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[usize], grad: &mut [f32]) -> f32 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let batch = y.len();
        assert_eq!(x.len(), batch * self.inputs, "batch feature shape");
        let mut dlogits = self.logits(params, x, batch);
        let loss = softmax_xent_backward(&mut dlogits, y, self.classes);
        // dW = dlogitsᵀ · x  (classes×inputs); dlogits: batch×classes.
        grad.fill(0.0);
        let wlen = self.classes * self.inputs;
        matmul_at_b(&mut grad[..wlen], &dlogits, x, self.classes, batch, self.inputs);
        // db = column sums of dlogits.
        let db = &mut grad[wlen..];
        for i in 0..batch {
            for (dbj, &dl) in db.iter_mut().zip(&dlogits[i * self.classes..(i + 1) * self.classes]) {
                *dbj += dl;
            }
        }
        loss
    }

    fn evaluate(&self, params: &[f32], x: &[f32], y: &[usize]) -> (f64, f64) {
        let batch = y.len();
        let mut logits = self.logits(params, x, batch);
        softmax_xent_eval(&mut logits, y, self.classes)
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut p = vec![0.0f32; self.dim()];
        let std = (1.0 / self.inputs as f32).sqrt();
        let wlen = self.classes * self.inputs;
        rng.fill_normal(&mut p[..wlen], 0.0, std);
        // biases at zero
        p
    }

    fn describe(&self) -> String {
        format!("softmax-regression {}→{}", self.inputs, self.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::grad_check;

    #[test]
    fn gradient_matches_finite_differences() {
        let m = SoftmaxRegression::new(6, 4);
        let mut rng = Pcg64::seed_from(1);
        let batch = 5;
        let mut x = vec![0.0; batch * 6];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 1, 2, 3, 1];
        grad_check(&m, &x, &y, 2);
    }

    #[test]
    fn training_reduces_loss_on_separable_data() {
        let m = SoftmaxRegression::new(2, 2);
        let mut rng = Pcg64::seed_from(3);
        let mut params = m.init(&mut rng);
        // Two separated blobs.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..100 {
            let c = i % 2;
            let cx = if c == 0 { -2.0 } else { 2.0 };
            x.push(cx + rng.normal_f32(0.0, 0.3));
            x.push(rng.normal_f32(0.0, 0.3));
            y.push(c);
        }
        let mut grad = vec![0.0; m.dim()];
        let l0 = m.loss_grad(&params, &x, &y, &mut grad);
        for _ in 0..200 {
            m.loss_grad(&params, &x, &y, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.5 * g;
            }
        }
        let (l1, acc) = m.evaluate(&params, &x, &y);
        assert!(l1 < l0 as f64 * 0.2, "loss {l0} -> {l1}");
        assert!(acc > 0.95, "acc {acc}");
    }

    #[test]
    fn eval_on_random_params_is_chance() {
        let m = SoftmaxRegression::new(8, 10);
        let mut rng = Pcg64::seed_from(4);
        let params = m.init(&mut rng);
        let n = 500;
        let mut x = vec![0.0; n * 8];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y: Vec<usize> = (0..n).map(|_| rng.index(10)).collect();
        let (_, acc) = m.evaluate(&params, &x, &y);
        assert!(acc < 0.25, "untrained acc {acc}");
    }
}
