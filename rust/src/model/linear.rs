//! Linear softmax classifier — the convex substrate used for fast
//! integration tests and the theory-validation experiments.

use super::{ensure_len, softmax_xent_backward, softmax_xent_eval, Model, ModelWorkspace};
use crate::util::linalg::{gemm_with, Epilogue, MatLayout};
use crate::util::rng::Pcg64;

/// `logits = x·Wᵀ + b`, cross-entropy loss.
///
/// Parameter layout (flat): `W` stored `classes×inputs` row-major, then
/// `b` (`classes`).
#[derive(Clone, Debug)]
pub struct SoftmaxRegression {
    pub inputs: usize,
    pub classes: usize,
}

impl SoftmaxRegression {
    pub fn new(inputs: usize, classes: usize) -> Self {
        assert!(inputs > 0 && classes > 1);
        Self { inputs, classes }
    }

    fn split<'a>(&self, params: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        let wlen = self.classes * self.inputs;
        (&params[..wlen], &params[wlen..wlen + self.classes])
    }

    /// Compute `logits = x·Wᵀ + b` into the workspace delta buffer
    /// (bias-add fused into the GEMM store loop; zero allocations in
    /// steady state).
    fn logits_into(&self, params: &[f32], x: &[f32], batch: usize, ws: &mut ModelWorkspace) {
        let (w, b) = self.split(params);
        ensure_len(&mut ws.delta, batch * self.classes);
        gemm_with(
            &mut ws.gemm,
            &mut ws.delta,
            x,
            MatLayout::Normal,
            w,
            MatLayout::Transpose,
            batch,
            self.inputs,
            self.classes,
            false,
            Epilogue::Bias(b),
        );
    }
}

impl Model for SoftmaxRegression {
    fn dim(&self) -> usize {
        self.classes * self.inputs + self.classes
    }

    fn loss_grad_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        grad: &mut [f32],
        ws: &mut ModelWorkspace,
    ) -> f32 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let batch = y.len();
        assert_eq!(x.len(), batch * self.inputs, "batch feature shape");
        self.logits_into(params, x, batch, ws);
        let dlogits = &mut ws.delta;
        let loss = softmax_xent_backward(dlogits, y, self.classes);
        // dW = dlogitsᵀ · x  (classes×inputs); dlogits: batch×classes.
        // The GEMM overwrites the weight block; only db needs clearing.
        let wlen = self.classes * self.inputs;
        gemm_with(
            &mut ws.gemm,
            &mut grad[..wlen],
            &ws.delta,
            MatLayout::Transpose,
            x,
            MatLayout::Normal,
            self.classes,
            batch,
            self.inputs,
            false,
            Epilogue::None,
        );
        // db = column sums of dlogits.
        let db = &mut grad[wlen..];
        db.fill(0.0);
        for drow in ws.delta.chunks_exact(self.classes) {
            for (dbj, &dl) in db.iter_mut().zip(drow) {
                *dbj += dl;
            }
        }
        loss
    }

    fn evaluate_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        ws: &mut ModelWorkspace,
    ) -> (f64, f64) {
        let batch = y.len();
        assert_eq!(x.len(), batch * self.inputs, "batch feature shape");
        self.logits_into(params, x, batch, ws);
        softmax_xent_eval(&mut ws.delta, y, self.classes)
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut p = vec![0.0f32; self.dim()];
        let std = (1.0 / self.inputs as f32).sqrt();
        let wlen = self.classes * self.inputs;
        rng.fill_normal(&mut p[..wlen], 0.0, std);
        // biases at zero
        p
    }

    fn describe(&self) -> String {
        format!("softmax-regression {}→{}", self.inputs, self.classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::grad_check;

    #[test]
    fn gradient_matches_finite_differences() {
        let m = SoftmaxRegression::new(6, 4);
        let mut rng = Pcg64::seed_from(1);
        let batch = 5;
        let mut x = vec![0.0; batch * 6];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 1, 2, 3, 1];
        grad_check(&m, &x, &y, 2);
    }

    #[test]
    fn training_reduces_loss_on_separable_data() {
        let m = SoftmaxRegression::new(2, 2);
        let mut rng = Pcg64::seed_from(3);
        let mut params = m.init(&mut rng);
        // Two separated blobs.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..100 {
            let c = i % 2;
            let cx = if c == 0 { -2.0 } else { 2.0 };
            x.push(cx + rng.normal_f32(0.0, 0.3));
            x.push(rng.normal_f32(0.0, 0.3));
            y.push(c);
        }
        let mut grad = vec![0.0; m.dim()];
        let l0 = m.loss_grad(&params, &x, &y, &mut grad);
        for _ in 0..200 {
            m.loss_grad(&params, &x, &y, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.5 * g;
            }
        }
        let (l1, acc) = m.evaluate(&params, &x, &y);
        assert!(l1 < l0 as f64 * 0.2, "loss {l0} -> {l1}");
        assert!(acc > 0.95, "acc {acc}");
    }

    #[test]
    fn eval_on_random_params_is_chance() {
        let m = SoftmaxRegression::new(8, 10);
        let mut rng = Pcg64::seed_from(4);
        let params = m.init(&mut rng);
        let n = 500;
        let mut x = vec![0.0; n * 8];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y: Vec<usize> = (0..n).map(|_| rng.index(10)).collect();
        let (_, acc) = m.evaluate(&params, &x, &y);
        assert!(acc < 0.25, "untrained acc {acc}");
    }
}
