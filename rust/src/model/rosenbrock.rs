//! The §6.1 Rosenbrock experiment substrate.
//!
//! The paper minimizes the d=10 Rosenbrock function
//! `F(x) = Σ_i 100(x_{i+1} − x_i²)² + (1 − x_i)²` across M=100 workers,
//! where worker `m` sees a *scaled objective* `v_m·F(·)` with
//!
//! `Σ_m v_m = 1` and `Σ_m 1[v_m < 0] = 80`            (eq. 11)
//!
//! — i.e. 80 of 100 workers see sign-flipped gradients, so deterministic
//! sign majority-vote aggregates the *wrong* sign on every coordinate
//! while the magnitude-weighted average still points the right way. This
//! is the cleanest demonstration of why magnitudes matter.
//!
//! (The paper's eq. (10) prints `100(x_{i+1} − x_i²) + (1 − x_i)²`,
//! dropping the square on the first term — that expression is unbounded
//! below and cannot be "minimized" as §6.1 describes; we implement the
//! standard Rosenbrock the cited source (Safaryan & Richtárik 2021) uses.)

use crate::util::rng::Pcg64;

/// Rosenbrock objective over `n ≥ 2` variables.
#[derive(Clone, Copy, Debug)]
pub struct Rosenbrock {
    pub n: usize,
}

impl Rosenbrock {
    pub fn new(n: usize) -> Self {
        assert!(n >= 2, "Rosenbrock needs at least 2 variables");
        Self { n }
    }

    /// Function value.
    pub fn value(&self, x: &[f32]) -> f64 {
        assert_eq!(x.len(), self.n);
        let mut f = 0.0f64;
        for i in 0..self.n - 1 {
            let a = (x[i + 1] - x[i] * x[i]) as f64;
            let b = (1.0 - x[i]) as f64;
            f += 100.0 * a * a + b * b;
        }
        f
    }

    /// Analytic gradient into `g`.
    pub fn grad(&self, x: &[f32], g: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(g.len(), self.n);
        g.fill(0.0);
        for i in 0..self.n - 1 {
            let t = x[i + 1] - x[i] * x[i];
            g[i] += -400.0 * x[i] * t - 2.0 * (1.0 - x[i]);
            g[i + 1] += 200.0 * t;
        }
    }

    /// Standard starting point used in the literature.
    pub fn start(&self) -> Vec<f32> {
        let mut x = vec![-1.2f32; self.n];
        for (i, v) in x.iter_mut().enumerate() {
            if i % 2 == 1 {
                *v = 1.0;
            }
        }
        x
    }
}

/// The eq. (11) heterogeneous worker population: worker `m` observes
/// `v_m · ∇F(x)`.
#[derive(Clone, Debug)]
pub struct ScaledObjectiveWorkers {
    /// Per-worker scale `v_m`, Σ v_m = 1, with `negatives` of them < 0.
    pub scales: Vec<f64>,
}

impl ScaledObjectiveWorkers {
    /// Draw scales satisfying eq. (11): `negatives` workers get `v_m < 0`,
    /// the rest `v_m > 0`, then the vector is shifted/normalized so
    /// `Σ v_m = 1` while preserving the sign pattern.
    pub fn generate(workers: usize, negatives: usize, rng: &mut Pcg64) -> Self {
        Self::generate_scaled(workers, negatives, 1.0, rng)
    }

    /// [`Self::generate`] with an explicit magnitude scale for the
    /// sign-flipped workers. Eq. (11) fixes only the sign pattern and
    /// `Σ v_m = 1`; `neg_scale` controls how much *magnitude mass* the
    /// wrong-sign majority carries. Small values (the Fig. 1/2 setting,
    /// 0.01) are the regime the paper illustrates: 80% of workers report
    /// the wrong sign but carry little magnitude — exactly the information
    /// deterministic sign discards and sparsign preserves.
    pub fn generate_scaled(
        workers: usize,
        negatives: usize,
        neg_scale: f64,
        rng: &mut Pcg64,
    ) -> Self {
        assert!(negatives < workers, "need at least one positive worker");
        assert!(neg_scale > 0.0);
        // |v| magnitudes: uniform in neg_scale·(0.5, 1.5) for negatives,
        // and the positive mass is set to balance the sum to exactly 1.
        let mut scales = vec![0.0f64; workers];
        let mut neg_sum = 0.0;
        for s in scales.iter_mut().take(negatives) {
            let mag = (0.5 + rng.f64()) * neg_scale;
            *s = -mag;
            neg_sum += mag;
        }
        let positives = workers - negatives;
        // Positive magnitudes: proportional to random weights, scaled so
        // total sum = 1 ⇒ pos_sum = 1 + neg_sum.
        let weights: Vec<f64> = (0..positives).map(|_| 0.5 + rng.f64()).collect();
        let wsum: f64 = weights.iter().sum();
        let target = 1.0 + neg_sum;
        for (s, w) in scales.iter_mut().skip(negatives).zip(weights) {
            *s = w / wsum * target;
        }
        rng.shuffle(&mut scales);
        Self { scales }
    }

    pub fn workers(&self) -> usize {
        self.scales.len()
    }

    /// Worker `m`'s gradient: `v_m · ∇F(x)` (+ optional Gaussian noise,
    /// the paper's SGD-vs-GD distinction in Remark 5).
    pub fn worker_grad(
        &self,
        f: &Rosenbrock,
        m: usize,
        x: &[f32],
        noise_std: f32,
        rng: &mut Pcg64,
        out: &mut [f32],
    ) {
        f.grad(x, out);
        let v = self.scales[m] as f32;
        for o in out.iter_mut() {
            *o *= v;
            if noise_std > 0.0 {
                *o += rng.normal_f32(0.0, noise_std);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_matches_finite_differences() {
        let f = Rosenbrock::new(10);
        let mut rng = Pcg64::seed_from(1);
        let mut x = vec![0.0f32; 10];
        rng.fill_normal(&mut x, 0.0, 0.5);
        let mut g = vec![0.0f32; 10];
        f.grad(&x, &mut g);
        let eps = 1e-3f32;
        for i in 0..10 {
            let mut xp = x.clone();
            xp[i] += eps;
            let fp = f.value(&xp);
            xp[i] -= 2.0 * eps;
            let fm = f.value(&xp);
            let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[i]).abs() / fd.abs().max(g[i].abs()).max(1.0) < 0.02,
                "coord {i}: fd {fd} analytic {}",
                g[i]
            );
        }
    }

    #[test]
    fn minimum_at_ones() {
        let f = Rosenbrock::new(10);
        let ones = vec![1.0f32; 10];
        assert!(f.value(&ones) < 1e-10);
        let mut g = vec![0.0f32; 10];
        f.grad(&ones, &mut g);
        assert!(g.iter().all(|&v| v.abs() < 1e-5));
    }

    #[test]
    fn gd_descends() {
        let f = Rosenbrock::new(10);
        let mut x = f.start();
        let mut g = vec![0.0f32; 10];
        let f0 = f.value(&x);
        for _ in 0..5_000 {
            f.grad(&x, &mut g);
            for (xi, gi) in x.iter_mut().zip(&g) {
                *xi -= 1e-4 * gi;
            }
        }
        let f1 = f.value(&x);
        assert!(f1 < f0 * 0.1, "{f0} -> {f1}");
    }

    #[test]
    fn eq11_constraints_hold() {
        let mut rng = Pcg64::seed_from(2);
        let w = ScaledObjectiveWorkers::generate(100, 80, &mut rng);
        assert_eq!(w.workers(), 100);
        let sum: f64 = w.scales.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "Σv = {sum}");
        let negs = w.scales.iter().filter(|&&v| v < 0.0).count();
        assert_eq!(negs, 80);
        assert!(w.scales.iter().all(|&v| v != 0.0));
    }

    #[test]
    fn majority_of_worker_grads_point_wrong_way() {
        // The defining pathology: per-coordinate, 80% of worker gradient
        // signs disagree with the true gradient sign.
        let f = Rosenbrock::new(10);
        let mut rng = Pcg64::seed_from(3);
        let w = ScaledObjectiveWorkers::generate(100, 80, &mut rng);
        let x = f.start();
        let mut true_g = vec![0.0f32; 10];
        f.grad(&x, &mut true_g);
        let mut buf = vec![0.0f32; 10];
        let mut wrong = 0;
        let mut total = 0;
        for m in 0..100 {
            w.worker_grad(&f, m, &x, 0.0, &mut rng, &mut buf);
            for i in 0..10 {
                if true_g[i] != 0.0 {
                    total += 1;
                    if (buf[i] > 0.0) != (true_g[i] > 0.0) {
                        wrong += 1;
                    }
                }
            }
        }
        let frac = wrong as f64 / total as f64;
        assert!((frac - 0.8).abs() < 1e-9, "wrong-sign fraction {frac}");
    }
}
